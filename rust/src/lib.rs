//! # QERA — Quantization Error Reconstruction Analysis
//!
//! Rust + JAX + Pallas reproduction of *QERA: an Analytical Framework for
//! Quantization Error Reconstruction* (ICLR 2025).
//!
//! Given a linear layer `y = x W`, quantize `W -> W~` and add a low-rank
//! high-precision correction `C_k = A_k B_k` minimizing the **expected layer
//! output error** `E ||x(W~ + C_k) - x W||^2`:
//!
//! * [`solver`] `qera_exact` — Theorem 1: `C_k = (R½)⁻¹ SVD_k(R½ (W − W~))`
//!   with `R = E[xᵀx]`.
//! * [`solver`] `qera_approx` — Theorem 2: diagonal `S = diag(√E[x_i²])`.
//! * Baselines: `zeroquant_v2` (weight-error SVD), `lqer` (abs-mean
//!   heuristic), `loftq` (iterative), QLoRA-zero.
//! * [`budget`] — analytical mixed-precision planning: score every layer ×
//!   `(format, rank)` cell with the closed-form error, then allocate a
//!   global bits/weight budget (uniform / greedy / Lagrangian).
//!
//! ## Architecture (three layers, python never at request time)
//!
//! * **L3 (this crate)** — coordinator: calibration orchestration,
//!   closed-form solvers, quantization pipeline, training driver, evaluation
//!   harness, serving batcher, CLI.
//! * **L2/L1 (python/compile)** — JAX transformer + Pallas kernels,
//!   AOT-lowered once to `artifacts/*.hlo.txt`.
//! * **runtime** — [`runtime`] loads the HLO text through the PJRT C API
//!   (`xla` crate) and executes it from the hot path.

pub mod util;
pub mod obs;
pub mod tensor;
pub mod linalg;
pub mod quant;
pub mod stats;
pub mod solver;
pub mod config;
pub mod data;
pub mod model;
pub mod runtime;
pub mod coordinator;
pub mod budget;
pub mod train;
pub mod eval;
pub mod serve;
pub mod experiments;
pub mod bench_util;
pub mod cli;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
