//! Execution runtimes: the PJRT artifact route and the native CPU backend.
//!
//! * [`artifact`] — `manifest.json` schema + artifact registry with a
//!   compile-once executable cache;
//! * [`exec`] — typed execution: `Value` marshalling, shape validation
//!   against the manifest, tuple-output decomposition;
//! * [`client`] — lazily-initialized process-wide `PjRtClient` (CPU);
//! * [`native`] — pure-Rust forward ([`NativeModel`]) running quantized
//!   linears fused straight from packed blocks (`--exec native` /
//!   `QERA_EXEC=native` via [`ExecBackend`]) — no artifacts needed.

pub mod artifact;
pub mod client;
pub mod exec;
pub mod native;

pub use artifact::{ArtifactInfo, IoSpec, Registry};
pub use exec::{Exec, Value};
pub use native::{ExecBackend, NativeModel};
