//! PJRT runtime: loads the AOT HLO-text artifacts and executes them from
//! the coordinator's hot path (the only place device compute happens;
//! python is never invoked).
//!
//! * [`artifact`] — `manifest.json` schema + artifact registry with a
//!   compile-once executable cache;
//! * [`exec`] — typed execution: `Value` marshalling, shape validation
//!   against the manifest, tuple-output decomposition;
//! * [`client`] — lazily-initialized process-wide `PjRtClient` (CPU).

pub mod artifact;
pub mod client;
pub mod exec;

pub use artifact::{ArtifactInfo, IoSpec, Registry};
pub use exec::{Exec, Value};
