//! Native CPU execution backend — the transformer forward evaluated in
//! Rust, with quantized linears running **straight from packed blocks** via
//! the fused kernels ([`crate::quant::exec`]): `y = x·W_q + (x·A)·B` with
//! in-register dequantize per k-tile, never materializing a dense f32
//! weight.
//!
//! This is the `--exec native` / `QERA_EXEC=native` path selected through
//! [`ExecBackend`]; the [`ExecBackend::Stub`] default keeps the PJRT
//! artifact route (a stub in this image, real on boxes with a PJRT plugin).
//! The math mirrors `python/compile/model.py` (`use_pallas=False` oracle):
//! LayerNorm (ε = 1e-5), causal attention at `1/√hd`, tanh-approximate
//! GELU, logits through the tied embedding.

use crate::model::{ModelSpec, QWeight, QuantCheckpoint};
use crate::quant::{exec, PackedWeight};
use crate::solver::LowRank;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Which engine executes forward/eval/serve math.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// PJRT artifacts via the `xla` vendor crate (stub fallback).
    #[default]
    Stub,
    /// Pure-Rust fused quantized execution ([`NativeModel`]).
    Native,
}

impl ExecBackend {
    /// `stub` (aliases `xla`, `pjrt`) or `native` (aliases `cpu`, `fused`).
    pub fn parse(s: &str) -> Result<ExecBackend> {
        match s.trim().to_lowercase().as_str() {
            "stub" | "xla" | "pjrt" => Ok(ExecBackend::Stub),
            "native" | "cpu" | "fused" => Ok(ExecBackend::Native),
            other => bail!("unknown exec backend '{other}' (stub | native)"),
        }
    }

    /// `QERA_EXEC` env override; defaults to [`ExecBackend::Stub`].  An
    /// unparseable value warns and falls back instead of being silently
    /// swallowed — a typo'd `QERA_EXEC=navite` should not quietly serve on
    /// the stub path.
    pub fn from_env() -> ExecBackend {
        match std::env::var("QERA_EXEC") {
            Ok(s) => ExecBackend::parse(&s).unwrap_or_else(|e| {
                crate::warn_!("ignoring QERA_EXEC: {e}");
                ExecBackend::default()
            }),
            Err(_) => ExecBackend::Stub,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecBackend::Stub => "stub",
            ExecBackend::Native => "native",
        }
    }
}

/// One model parameter as the native engine holds it.
enum NativeParam {
    /// Dense f32 (embeddings, LayerNorms, unquantized linears).
    Plain(Tensor),
    /// Packed quantized linear `[k, n]` + optional low-rank correction,
    /// evaluated fused — the packed payload is the *only* weight copy.
    Linear { k: usize, n: usize, pw: PackedWeight, lr: Option<LowRank> },
}

/// The transformer with parameters in canonical layout order.
pub struct NativeModel {
    pub spec: ModelSpec,
    params: Vec<NativeParam>,
}

fn layernorm(x: &Tensor, g: &Tensor, b: &Tensor) -> Tensor {
    let (rows, d) = (x.rows(), x.cols());
    let (gd, bd) = (g.data(), b.data());
    let mut out = vec![0.0f32; rows * d];
    for i in 0..rows {
        let row = x.row(i);
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (j, o) in out[i * d..(i + 1) * d].iter_mut().enumerate() {
            *o = (row[j] - mu) * inv * gd[j] + bd[j];
        }
    }
    Tensor::new(vec![rows, d], out)
}

/// Tanh-approximate GELU (`jax.nn.gelu(..., approximate=True)`).
fn gelu_tanh(v: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * v * (1.0 + (C * (v + 0.044_715 * v * v * v)).tanh())
}

/// Multi-head causal attention over `[bsz·s, heads·hd]` activations (head
/// h occupies feature columns `[h·hd, (h+1)·hd)`), softmax at `scale`.
fn causal_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    bsz: usize,
    s: usize,
    heads: usize,
    hd: usize,
) -> Tensor {
    let d = heads * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    let mut out = vec![0.0f32; bsz * s * d];
    let mut scores = vec![0.0f32; s];
    for b in 0..bsz {
        for h in 0..heads {
            let off = h * hd;
            for i in 0..s {
                let qat = (b * s + i) * d + off;
                let qrow = &qd[qat..qat + hd];
                let mut maxv = f32::NEG_INFINITY;
                for (j, sc) in scores[..=i].iter_mut().enumerate() {
                    let kat = (b * s + j) * d + off;
                    let mut dot = 0.0f32;
                    for (a, bb) in qrow.iter().zip(&kd[kat..kat + hd]) {
                        dot += a * bb;
                    }
                    *sc = dot * scale;
                    maxv = maxv.max(*sc);
                }
                let mut denom = 0.0f32;
                for sc in scores[..=i].iter_mut() {
                    *sc = (*sc - maxv).exp();
                    denom += *sc;
                }
                let (o0, o1) = ((b * s + i) * d + off, (b * s + i) * d + off + hd);
                for (j, &p) in scores[..=i].iter().enumerate() {
                    let w = p / denom;
                    let vat = (b * s + j) * d + off;
                    for (o, &vv) in out[o0..o1].iter_mut().zip(&vd[vat..vat + hd]) {
                        *o += w * vv;
                    }
                }
            }
        }
    }
    Tensor::new(vec![bsz * s, d], out)
}

impl NativeModel {
    /// Wrap a dense parameter list (canonical layout order).
    pub fn from_dense(spec: ModelSpec, params: Vec<Tensor>) -> NativeModel {
        assert_eq!(params.len(), spec.param_layout().len(), "param count mismatch");
        NativeModel { spec, params: params.into_iter().map(NativeParam::Plain).collect() }
    }

    /// Build from a quantized checkpoint **without materializing** dense
    /// weights for the packed sites — they execute fused from the payload.
    /// (Unquantized / identity-format sites fall back to dense, with the
    /// low-rank term merged in.)
    pub fn from_quant(q: &QuantCheckpoint) -> NativeModel {
        let layout = q.spec.param_layout();
        let params = layout
            .iter()
            .zip(&q.dense)
            .map(|((name, _), d)| match d {
                Some(t) => NativeParam::Plain(t.clone()),
                None => match &q.qweights[name] {
                    QWeight::Packed { shape, pw } => NativeParam::Linear {
                        k: shape[0],
                        n: shape[1],
                        pw: pw.clone(),
                        lr: q.lowrank.get(name).cloned(),
                    },
                    QWeight::Dense(t) => NativeParam::Plain(match q.lowrank.get(name) {
                        Some(lr) => lr.merged_with(t),
                        None => t.clone(),
                    }),
                },
            })
            .collect();
        NativeModel { spec: q.spec.clone(), params }
    }

    /// Load a quantized checkpoint from disk — monolithic `.qkpt` or a
    /// sharded manifest, sniffed by [`crate::model::open`] — and build the
    /// fused-execution model.  Sharded sources load their shards in
    /// parallel on the worker pool with per-shard sha256 verification.
    pub fn open_quant(path: impl AsRef<std::path::Path>) -> Result<NativeModel> {
        Ok(NativeModel::from_quant(&crate::model::open(path)?.into_quant()?))
    }

    /// Total bytes held for quantized sites (packed payloads, not f32).
    pub fn packed_bytes(&self) -> usize {
        self.params
            .iter()
            .map(|p| match p {
                NativeParam::Linear { pw, .. } => pw.payload_bytes(),
                NativeParam::Plain(_) => 0,
            })
            .sum()
    }

    fn plain(&self, idx: usize) -> &Tensor {
        match &self.params[idx] {
            NativeParam::Plain(t) => t,
            NativeParam::Linear { .. } => unreachable!("param {idx} is a packed linear"),
        }
    }

    fn apply_linear(&self, idx: usize, x: &Tensor) -> Tensor {
        match &self.params[idx] {
            NativeParam::Plain(w) => x.matmul(w),
            NativeParam::Linear { k, n, pw, lr } => {
                // sampled span: with tracing off this is one relaxed load;
                // with tracing on, only every 64th fused matmul allocates a
                // span, so steady-state decode stays allocation-free
                let sp =
                    crate::obs::trace::sample_span("native.fused_matmul", 64).attr("param", idx);
                let out = exec::fused_matmul(x, pw, *k, *n, lr.as_ref().map(|l| (&l.a, &l.b)));
                drop(sp);
                out
            }
        }
    }

    /// Trunk forward shared by [`Self::hidden`] and [`Self::forward_taps`]:
    /// tokens `[bsz, s]` (row-major) → final hidden `[bsz·s, d]` after the
    /// last LayerNorm.  With `taps`, every linear-input activation is moved
    /// out per block in `(block, tap)` order — the native equivalent of the
    /// `lm_fwd_taps` artifact's `outputs[1..]`.
    fn trunk(
        &self,
        tokens: &[i32],
        bsz: usize,
        s: usize,
        mut taps: Option<&mut Vec<Tensor>>,
    ) -> Tensor {
        let spec = &self.spec;
        assert_eq!(tokens.len(), bsz * s, "token count mismatch");
        assert!(s <= spec.seq, "sequence {s} exceeds positional table {}", spec.seq);
        let d = spec.d_model;
        let (embed, pos) = (self.plain(0), self.plain(1));
        let mut x = vec![0.0f32; bsz * s * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            assert!(t < spec.vocab, "token {t} out of vocab");
            let (erow, prow) = (embed.row(t), pos.row(i % s));
            for (o, (e, p)) in x[i * d..(i + 1) * d].iter_mut().zip(erow.iter().zip(prow)) {
                *o = e + p;
            }
        }
        let mut x = Tensor::new(vec![bsz * s, d], x);
        for blk in 0..spec.n_layers {
            let base = 2 + blk * 10;
            let h_in = layernorm(&x, self.plain(base), self.plain(base + 1));
            let q = self.apply_linear(base + 2, &h_in);
            let k = self.apply_linear(base + 3, &h_in);
            let v = self.apply_linear(base + 4, &h_in);
            let ctx = causal_attention(&q, &k, &v, bsz, s, spec.n_heads, spec.head_dim());
            x.add_assign(&self.apply_linear(base + 5, &ctx));
            let m_in = layernorm(&x, self.plain(base + 6), self.plain(base + 7));
            let u = self.apply_linear(base + 8, &m_in).map(gelu_tanh);
            x.add_assign(&self.apply_linear(base + 9, &u));
            if let Some(out) = taps.as_deref_mut() {
                // attn_in / o_in / mlp_in / mlp_mid — matches TAP_SITES and
                // therefore `spec.tap_index(blk, tap)` addressing
                out.extend([h_in, ctx, m_in, u]);
            }
        }
        let lnf = 2 + spec.n_layers * 10;
        layernorm(&x, self.plain(lnf), self.plain(lnf + 1))
    }

    /// Trunk forward: tokens `[bsz, s]` (row-major) → final hidden
    /// `[bsz·s, d]` after the last LayerNorm.
    fn hidden(&self, tokens: &[i32], bsz: usize, s: usize) -> Tensor {
        self.trunk(tokens, bsz, s, None)
    }

    /// Quantizable-linear input activations for one batch, indexed by
    /// `spec.tap_index(block, tap)`: per block `attn_in` (ln1 output feeding
    /// q/k/v), `o_in` (attention context feeding `wo`), `mlp_in` (ln2 output
    /// feeding `w_up`), `mlp_mid` (post-GELU feeding `w_down`).  Each is
    /// `[bsz·s, tap_dim]` — what [`crate::coordinator::calibrate_native`]
    /// folds into per-site statistics without any PJRT artifact.
    pub fn forward_taps(&self, tokens: &[i32], bsz: usize, s: usize) -> Vec<Tensor> {
        let mut taps = Vec::with_capacity(self.spec.n_taps());
        self.trunk(tokens, bsz, s, Some(&mut taps));
        taps
    }

    /// Logits `[bsz·s, vocab]` through the tied embedding.
    pub fn logits(&self, tokens: &[i32], bsz: usize, s: usize) -> Tensor {
        self.hidden(tokens, bsz, s).matmul_t(self.plain(0))
    }

    /// Per-token negative log-likelihood (`lm_nll` artifact equivalent).
    pub fn nll(&self, tokens: &[i32], targets: &[i32], bsz: usize, s: usize) -> Vec<f32> {
        assert_eq!(targets.len(), bsz * s, "target count mismatch");
        let logits = self.logits(tokens, bsz, s);
        targets
            .iter()
            .enumerate()
            .map(|(i, &t)| {
                let row = logits.row(i);
                let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
                let logz = maxv + row.iter().map(|&x| (x - maxv).exp()).sum::<f32>().ln();
                logz - row[t as usize]
            })
            .collect()
    }

    /// Final-position logits `[bsz, vocab]` (`lm_logits_last` equivalent) —
    /// only the last hidden row per sequence hits the vocab projection.
    pub fn logits_last(&self, tokens: &[i32], bsz: usize, s: usize) -> Tensor {
        let hid = self.hidden(tokens, bsz, s);
        let d = self.spec.d_model;
        let mut last = vec![0.0f32; bsz * d];
        for b in 0..bsz {
            last[b * d..(b + 1) * d].copy_from_slice(hid.row(b * s + s - 1));
        }
        Tensor::new(vec![bsz, d], last).matmul_t(self.plain(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Checkpoint, LinearSite};
    use crate::quant::QFormat;
    use crate::util::json::Json;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    fn dense_model(name: &str, seed: u64) -> NativeModel {
        let spec = ModelSpec::builtin(name).unwrap();
        let params = crate::model::init::init_params(&spec, &mut Rng::new(seed));
        NativeModel::from_dense(spec, params)
    }

    fn tokens_for(spec: &ModelSpec, rng: &mut Rng) -> Vec<i32> {
        (0..spec.batch * spec.seq).map(|_| rng.below(spec.vocab) as i32).collect()
    }

    #[test]
    fn backend_parse_and_env_default() {
        assert_eq!(ExecBackend::parse("native").unwrap(), ExecBackend::Native);
        assert_eq!(ExecBackend::parse("cpu").unwrap(), ExecBackend::Native);
        assert_eq!(ExecBackend::parse("stub").unwrap(), ExecBackend::Stub);
        assert_eq!(ExecBackend::parse("xla").unwrap(), ExecBackend::Stub);
        assert!(ExecBackend::parse("tpu").is_err());
        assert_eq!(ExecBackend::default().name(), "stub");
        assert_eq!(ExecBackend::Native.name(), "native");
    }

    #[test]
    fn forward_finite_deterministic_and_causal() {
        let m = dense_model("micro", 3);
        let spec = m.spec.clone();
        let mut rng = Rng::new(4);
        let tokens = tokens_for(&spec, &mut rng);
        let (b, s, v) = (spec.batch, spec.seq, spec.vocab);
        let out = m.logits(&tokens, b, s);
        assert_eq!(out.shape(), &[b * s, v]);
        assert!(out.data().iter().all(|x| x.is_finite()));
        assert_eq!(out, m.logits(&tokens, b, s), "forward must be deterministic");

        // causality: perturbing the last token of row 0 leaves earlier
        // positions bit-identical and changes the last one
        let mut tok2 = tokens.clone();
        tok2[s - 1] = (tok2[s - 1] + 1) % v as i32;
        let out2 = m.logits(&tok2, b, s);
        assert_eq!(out.row(s - 2), out2.row(s - 2));
        assert_ne!(out.row(s - 1), out2.row(s - 1));
    }

    #[test]
    fn logits_last_matches_full_forward() {
        let m = dense_model("micro", 5);
        let spec = m.spec.clone();
        let mut rng = Rng::new(6);
        let tokens = tokens_for(&spec, &mut rng);
        let (b, s) = (spec.batch, spec.seq);
        let full = m.logits(&tokens, b, s);
        let last = m.logits_last(&tokens, b, s);
        assert_eq!(last.shape(), &[b, spec.vocab]);
        for bi in 0..b {
            assert_eq!(last.row(bi), full.row(bi * s + s - 1), "batch row {bi}");
        }
    }

    #[test]
    fn nll_is_logsumexp_minus_gold() {
        let m = dense_model("micro", 7);
        let spec = m.spec.clone();
        let mut rng = Rng::new(8);
        let tokens = tokens_for(&spec, &mut rng);
        let targets = tokens_for(&spec, &mut rng);
        let (b, s) = (spec.batch, spec.seq);
        let nll = m.nll(&tokens, &targets, b, s);
        assert_eq!(nll.len(), b * s);
        // all positive-ish and finite; a uniform model sits near ln(vocab)
        assert!(nll.iter().all(|x| x.is_finite() && *x > 0.0));
        let mean = nll.iter().sum::<f32>() / nll.len() as f32;
        assert!((mean - (spec.vocab as f32).ln()).abs() < 1.0, "{mean}");
    }

    #[test]
    fn forward_taps_cover_every_site_with_correct_dims() {
        let m = dense_model("micro", 13);
        let spec = m.spec.clone();
        let mut rng = Rng::new(14);
        let tokens = tokens_for(&spec, &mut rng);
        let (b, s) = (spec.batch, spec.seq);
        let taps = m.forward_taps(&tokens, b, s);
        assert_eq!(taps.len(), spec.n_taps());
        for blk in 0..spec.n_layers {
            for &tap in crate::model::TAP_SITES.iter() {
                let t = &taps[spec.tap_index(blk, tap)];
                assert_eq!(t.shape(), &[b * s, spec.tap_dim(tap)], "blk{blk}.{tap}");
                assert!(t.data().iter().all(|x| x.is_finite()), "blk{blk}.{tap}");
            }
        }
        // same trunk as logits(): deterministic, and collecting taps must
        // not perturb the forward itself
        assert_eq!(taps, m.forward_taps(&tokens, b, s));
        assert_eq!(m.logits(&tokens, b, s), m.logits(&tokens, b, s));
    }

    fn quant_ckpt(fmt: QFormat, rank: usize, seed: u64) -> (Checkpoint, QuantCheckpoint) {
        let spec = ModelSpec::builtin("micro").unwrap();
        let mut rng = Rng::new(seed);
        let params = crate::model::init::init_params(&spec, &mut rng);
        let ckpt = Checkpoint::new(spec, params);
        let mut solved = BTreeMap::new();
        for site in ckpt.spec.linear_sites() {
            let LinearSite { param_idx, shape, name, .. } = site;
            let w = &ckpt.params[param_idx];
            let lr = (rank > 0).then(|| LowRank {
                a: Tensor::randn(vec![shape[0], rank], 0.02, &mut rng),
                b: Tensor::randn(vec![rank, shape[1]], 0.02, &mut rng),
            });
            solved.insert(name, (fmt.qdq(w), lr));
        }
        let q = QuantCheckpoint::from_solved(&ckpt, fmt, &solved, Json::obj(vec![]));
        (ckpt, q)
    }

    #[test]
    fn quantized_forward_tracks_merged_dense() {
        // packed-fused execution vs. dense execution of the materialized
        // merged weights: same model up to f32 association in W~ + A·B
        let mut rng = Rng::new(9);
        for fmt in [
            QFormat::Mxint { bits: 4, block: 32 },
            QFormat::IntAffine { bits: 4, group: 32, refine_iters: 10 },
            QFormat::Fp4 { group: 32 },
        ] {
            let (_, q) = quant_ckpt(fmt, 4, 10);
            let native_q = NativeModel::from_quant(&q);
            let native_d = NativeModel::from_dense(q.spec.clone(), q.materialize_merged());
            assert!(native_q.packed_bytes() > 0, "{}", fmt.name());
            let spec = native_q.spec.clone();
            let tokens = tokens_for(&spec, &mut rng);
            let (b, s) = (spec.batch, spec.seq);
            let lq = native_q.logits(&tokens, b, s);
            let ld = native_d.logits(&tokens, b, s);
            let rel = lq.sub(&ld).frob_norm() / ld.frob_norm().max(1e-12);
            assert!(rel < 1e-4, "{}: rel {rel}", fmt.name());
            assert!(lq.data().iter().all(|x| x.is_finite()), "{}", fmt.name());
        }
    }

    #[test]
    fn quantized_forward_reproducible() {
        let (_, q) = quant_ckpt(QFormat::Mxint { bits: 4, block: 32 }, 4, 11);
        let m = NativeModel::from_quant(&q);
        let spec = m.spec.clone();
        let mut rng = Rng::new(12);
        let tokens = tokens_for(&spec, &mut rng);
        let a = m.logits_last(&tokens, spec.batch, spec.seq);
        let b = m.logits_last(&tokens, spec.batch, spec.seq);
        assert_eq!(a, b);
    }
}
