//! Artifact manifest + registry.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) is the
//! single source of truth binding the two languages: artifact names, files,
//! input/output signatures, and per-config parameter layouts.  The registry
//! lazily loads + compiles executables and caches them process-wide.

use super::exec::Exec;
use crate::model::ModelSpec;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::cell::RefCell;
use std::rc::Rc;

/// Input/output tensor signature entry.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j.req_str("name")?.to_string(),
            dtype: j.req_str("dtype")?.to_string(),
            shape: j.req_arr("shape")?.iter().filter_map(Json::as_usize).collect(),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub config: String,
    pub rank: Option<usize>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Loaded manifest + executable cache.
pub struct Registry {
    pub dir: PathBuf,
    artifacts: HashMap<String, ArtifactInfo>,
    pub specs: HashMap<String, ModelSpec>,
    cache: RefCell<HashMap<String, Rc<Exec>>>,
}

impl Registry {
    /// Open `dir/manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = HashMap::new();
        for a in j.req_arr("artifacts")? {
            let info = ArtifactInfo {
                name: a.req_str("name")?.to_string(),
                file: a.req_str("file")?.to_string(),
                config: a.req_str("config")?.to_string(),
                rank: a.get("rank").and_then(Json::as_usize),
                inputs: a
                    .req_arr("inputs")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: a
                    .req_arr("outputs")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<_>>()?,
            };
            artifacts.insert(info.name.clone(), info);
        }

        let mut specs = HashMap::new();
        if let Some(cfgs) = j.get("configs").and_then(Json::as_obj) {
            for (name, cfg) in cfgs {
                let spec = ModelSpec::from_manifest_cfg(cfg)
                    .with_context(|| format!("config '{name}'"))?;
                specs.insert(name.clone(), spec);
            }
        }

        Ok(Registry { dir, artifacts, specs, cache: RefCell::new(HashMap::new()) })
    }

    /// Default location (`$QERA_ARTIFACTS` or `./artifacts`).
    pub fn open_default() -> Result<Registry> {
        let dir = std::env::var("QERA_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Registry::open(dir)
    }

    pub fn info(&self, name: &str) -> Result<&ArtifactInfo> {
        match self.artifacts.get(name) {
            Some(i) => Ok(i),
            None => bail!(
                "artifact '{name}' not in manifest (have: {})",
                self.names().join(", ")
            ),
        }
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.artifacts.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn spec(&self, config: &str) -> Result<&ModelSpec> {
        self.specs
            .get(config)
            .with_context(|| format!("config '{config}' not in manifest"))
    }

    /// Load + compile (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Exec>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let info = self.info(name)?.clone();
        let path = self.dir.join(&info.file);
        let t0 = std::time::Instant::now();
        let exec = Rc::new(Exec::load(&path, info)?);
        crate::info!("compiled artifact '{name}' in {:.2}s", t0.elapsed().as_secs_f64());
        self.cache.borrow_mut().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn open_built_manifest() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let reg = Registry::open(dir).unwrap();
        assert!(reg.names().iter().any(|n| n == "lm_fwd.nano"));
        let spec = reg.spec("nano").unwrap();
        assert_eq!(spec.d_model, 64);
        let info = reg.info("lm_fwd.nano").unwrap();
        assert_eq!(info.inputs[0].name, "tokens");
        assert_eq!(info.inputs[0].shape, vec![spec.batch, spec.seq]);
        assert_eq!(info.inputs.len(), 1 + spec.param_layout().len());
        assert_eq!(info.outputs[0].shape, vec![spec.batch, spec.seq, spec.vocab]);
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let Some(dir) = manifest_dir() else {
            return;
        };
        let reg = Registry::open(dir).unwrap();
        let err = reg.info("nope").unwrap_err().to_string();
        assert!(err.contains("lm_fwd.nano"));
    }

    #[test]
    fn io_spec_from_json() {
        let j = Json::parse(r#"{"name":"x","dtype":"float32","shape":[2,3]}"#).unwrap();
        let io = IoSpec::from_json(&j).unwrap();
        assert_eq!(io.numel(), 6);
        assert_eq!(io.dtype, "float32");
    }
}
