//! Typed execution of a loaded artifact.
//!
//! Marshals [`Value`]s (f32 tensors / i32 token arrays) into PJRT literals,
//! validates shapes against the manifest signature, executes, and
//! decomposes the tuple output back into [`Tensor`]s.

use super::artifact::ArtifactInfo;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use std::rc::Rc;

/// An input value for an artifact call.  f32 tensors are `Rc`-backed so
/// callers that reuse the same parameters every step (the serve engine's
/// full-context decode loop) pay a refcount bump per input, not a tensor
/// copy.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Rc<Tensor>),
    /// i32 data + shape (tokens, targets, labels).
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Value::F32(_) => "float32",
            Value::I32(..) => "int32",
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Value::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
            Value::I32(v, s) => {
                ensure!(v.len() == s.iter().product::<usize>(), "i32 shape mismatch");
                xla::Literal::vec1(v).reshape(&dims)?
            }
        })
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(Rc::new(t))
    }
}

impl From<Rc<Tensor>> for Value {
    fn from(t: Rc<Tensor>) -> Value {
        Value::F32(t)
    }
}

/// A compiled artifact ready to run.
pub struct Exec {
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl Exec {
    /// Load HLO text, compile on this thread's client.
    pub fn load(path: &Path, info: ArtifactInfo) -> Result<Exec> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = super::client::with_client(|client| {
            client.compile(&comp).with_context(|| format!("compiling {}", info.name))
        })?;
        Ok(Exec { info, exe })
    }

    /// Validate inputs against the manifest signature.
    fn check_inputs(&self, inputs: &[Value]) -> Result<()> {
        ensure!(
            inputs.len() == self.info.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.info.name,
            self.info.inputs.len(),
            inputs.len()
        );
        for (v, spec) in inputs.iter().zip(&self.info.inputs) {
            ensure!(
                v.shape() == &spec.shape[..],
                "{}: input '{}' shape {:?} != manifest {:?}",
                self.info.name,
                spec.name,
                v.shape(),
                spec.shape
            );
            let want = if spec.dtype.contains("int") { "int32" } else { "float32" };
            ensure!(
                v.dtype() == want,
                "{}: input '{}' dtype {} != {}",
                self.info.name,
                spec.name,
                v.dtype(),
                want
            );
        }
        Ok(())
    }

    /// Execute; returns one f32 tensor per manifest output.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(Value::to_literal).collect::<Result<_>>()?;
        let bufs = self.exe.execute::<xla::Literal>(&literals)?;
        let result = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose
        let parts = result.to_tuple()?;
        ensure!(
            parts.len() == self.info.outputs.len(),
            "{}: {} outputs returned, manifest says {}",
            self.info.name,
            parts.len(),
            self.info.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&self.info.outputs) {
            let v: Vec<f32> = match lit.ty()? {
                xla::ElementType::F32 => lit.to_vec::<f32>()?,
                xla::ElementType::S32 => {
                    lit.to_vec::<i32>()?.into_iter().map(|x| x as f32).collect()
                }
                other => bail!("unsupported output type {other:?} for '{}'", spec.name),
            };
            ensure!(
                v.len() == spec.numel(),
                "{}: output '{}' has {} elements, manifest says {}",
                self.info.name,
                spec.name,
                v.len(),
                spec.numel()
            );
            out.push(Tensor::new(spec.shape.clone(), v));
        }
        Ok(out)
    }
}

/// Convenience: build the `Value` list `[tokens(, targets/labels), params...]`.
///
/// Generic over the parameter element: `&[Tensor]` copies each tensor into
/// its `Value` (one-shot callers), while `&[Rc<Tensor>]` only bumps
/// refcounts — steady-state loops should wrap once via [`rc_params`] and
/// pass the `Rc` slice so repeated calls do **zero** parameter copies.
pub fn lm_inputs<P: Clone + Into<Value>>(
    tokens: &[i32],
    second: Option<(&[i32], &[usize])>,
    tok_shape: &[usize],
    params: &[P],
) -> Vec<Value> {
    let mut v: Vec<Value> = Vec::with_capacity(params.len() + 2);
    v.push(Value::I32(tokens.to_vec(), tok_shape.to_vec()));
    if let Some((data, shape)) = second {
        v.push(Value::I32(data.to_vec(), shape.to_vec()));
    }
    v.extend(params.iter().cloned().map(Into::into));
    v
}

/// Wrap a dense parameter list for reuse across [`lm_inputs`] calls: one
/// tensor copy here, then every call is refcount-only.
pub fn rc_params(params: &[Tensor]) -> Vec<Rc<Tensor>> {
    params.iter().cloned().map(Rc::new).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Registry;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    #[test]
    fn qlinear_artifact_matches_cpu_math() {
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let exec = reg.load("qlinear.m64k128n96r8").unwrap();
        let mut rng = crate::util::rng::Rng::new(0);
        let x = Tensor::randn(vec![64, 128], 1.0, &mut rng);
        let w = Tensor::randn(vec![128, 96], 1.0, &mut rng);
        let a = Tensor::randn(vec![128, 8], 1.0, &mut rng);
        let b = Tensor::randn(vec![8, 96], 1.0, &mut rng);
        let out = exec
            .run(&[x.clone().into(), w.clone().into(), a.clone().into(), b.clone().into()])
            .unwrap();
        assert_eq!(out.len(), 1);
        // rust-side reference: x @ w + (x @ a) @ b
        let want = x.matmul(&w).add(&x.matmul(&a).matmul(&b));
        let got = &out[0];
        let denom = want.frob_norm().max(1.0);
        assert!(got.sub(&want).frob_norm() / denom < 1e-5);
    }

    #[test]
    fn mxint_artifact_bitexact_with_rust_quantizer() {
        let Some(reg) = registry() else {
            return;
        };
        let exec = reg.load("mxint_qdq.b4s32").unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        let x = Tensor::randn(vec![64, 128], 0.7, &mut rng);
        let out = exec.run(&[x.clone().into()]).unwrap();
        let want = crate::quant::mxint::qdq(&x, 4, 32);
        assert_eq!(out[0], want, "L1 kernel vs rust quantizer must be bit-exact");
    }

    #[test]
    fn calib_stats_artifact_matches_rust_stats() {
        let Some(reg) = registry() else {
            return;
        };
        let exec = reg.load("calib_stats.m128").unwrap();
        let mut rng = crate::util::rng::Rng::new(2);
        let x = Tensor::randn(vec![256, 128], 1.0, &mut rng);
        let out = exec.run(&[x.clone().into()]).unwrap();
        let mut st = crate::stats::CalibStats::new(128, true);
        st.update(&x);
        for i in 0..128 {
            assert!((out[0].data()[i] as f64 - st.sum_sq[i]).abs() < 2e-2);
            assert!((out[1].data()[i] as f64 - st.sum_abs[i]).abs() < 2e-2);
        }
        let rxx = st.rxx_mean().unwrap().scale(256.0);
        let mut maxdiff = 0.0f64;
        for i in 0..128 {
            for j in 0..128 {
                maxdiff = maxdiff.max((out[2].at2(i, j) as f64 - rxx.at(i, j)).abs());
            }
        }
        assert!(maxdiff < 5e-2, "{maxdiff}");
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let Some(reg) = registry() else {
            return;
        };
        let exec = reg.load("mxint_qdq.b4s32").unwrap();
        let bad = Tensor::zeros(vec![4, 4]);
        assert!(exec.run(&[bad.into()]).is_err());
        assert!(exec.run(&[]).is_err());
    }

    #[test]
    fn lm_fwd_runs_and_is_causal() {
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let exec = reg.load("lm_fwd.nano").unwrap();
        let mut rng = crate::util::rng::Rng::new(3);
        let params = crate::model::init::init_params(&spec, &mut rng);
        let tokens: Vec<i32> =
            (0..spec.batch * spec.seq).map(|_| rng.below(spec.vocab) as i32).collect();
        let inputs = lm_inputs(&tokens, None, &[spec.batch, spec.seq], &params);
        let out = exec.run(&inputs).unwrap();
        assert_eq!(out[0].shape(), &[spec.batch, spec.seq, spec.vocab]);
        assert!(out[0].data().iter().all(|v| v.is_finite()));

        // causality through the full stack: perturb the last token
        let mut tokens2 = tokens.clone();
        let last = spec.seq - 1;
        tokens2[last] = (tokens2[last] + 1) % spec.vocab as i32;
        let out2 = exec.run(&lm_inputs(&tokens2, None, &[spec.batch, spec.seq], &params)).unwrap();
        let v = spec.vocab;
        let row = |t: &Tensor, pos: usize| t.data()[pos * v..(pos + 1) * v].to_vec();
        // position last-1 of batch row 0 unchanged; position last changed
        assert_eq!(row(&out[0], last - 1), row(&out2[0], last - 1));
        assert_ne!(row(&out[0], last), row(&out2[0], last));
    }
}
