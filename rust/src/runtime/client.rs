//! Per-thread PJRT client (CPU).
//!
//! The `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`/`Sync`), so all
//! device execution stays on the calling thread — the coordinator keeps PJRT
//! work on the main thread and uses the worker pool only for pure-Rust
//! solver math (which is where the parallelism is anyway, App. A.7).

use anyhow::Result;
use std::cell::OnceCell;
use xla::PjRtClient;

thread_local! {
    static CLIENT: OnceCell<PjRtClient> = const { OnceCell::new() };
}

/// Run `f` with this thread's client (created on first use).
pub fn with_client<T>(f: impl FnOnce(&PjRtClient) -> Result<T>) -> Result<T> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let c = PjRtClient::cpu()?;
            crate::info!(
                "pjrt client up: platform={} devices={}",
                c.platform_name(),
                c.device_count()
            );
            let _ = cell.set(c);
        }
        f(cell.get().unwrap())
    })
}

#[cfg(test)]
mod tests {
    #[test]
    fn client_initializes() {
        let n = super::with_client(|c| Ok(c.device_count())).unwrap();
        assert!(n >= 1);
    }
}
