//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! All experiment randomness (synthetic corpora, init, shuffles, the paper's
//! seeds 42/1/2) flows through this so runs are exactly reproducible.

/// xoshiro256** generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal variate from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent child stream (for per-layer / per-task seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-cryptographic) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of iid N(0, std^2) f32.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "{ratio}");
    }

    #[test]
    fn fork_independent() {
        let mut base = Rng::new(42);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
