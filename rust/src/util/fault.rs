//! Deterministic storage fault injection behind the [`CkptIo`] seam.
//!
//! [`FaultyIo`] wraps a real [`CkptIo`] and fires scripted faults: each
//! arm of the script names a fault kind, the operation it intercepts
//! (read or write), a path substring to match, and how many matching
//! operations it fires on.  Randomness (which bit flips, where a torn
//! write tears) comes from a seeded [`Rng`], so a failing chaos run
//! replays exactly from its script string.
//!
//! Script syntax (the `QERA_FAULTS` env var uses the same form):
//!
//! ```text
//! seed=7,flip@w:shard-002,transient@r:shard-001:2,enospc@w:manifest
//! ```
//!
//! comma-separated entries, each `kind@op:substr[:count]` (count defaults
//! to 1; `op` is `r` or `w`; `substr` must not contain `:` or `,`), plus
//! an optional `seed=N`.  Kinds:
//!
//! * `torn`  — write: a strict prefix lands on disk, then the write
//!   errors (a crash mid-write); read: a strict prefix is returned.
//! * `flip`  — one seeded bit is flipped; writes still report success
//!   (silent corruption — only content verification catches it).
//! * `enospc` — write fails with no bytes written, permanently
//!   (disk full; retrying is pointless, callers must fail fast).
//! * `transient` — the operation fails with an `Interrupted` error the
//!   retry layer is allowed to ride out.
//! * `perm`  — the operation fails permanently (`NotFound` on read).

use crate::obs::lazy::Lazy;
use crate::obs::metrics::{self, Counter};
use crate::util::fsio::{CkptIo, StdIo};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::io;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Process-wide injected-fault tally (`qera_faults_injected_total`); the
/// per-run view stays on each [`FaultyIo`] (`faults_injected`), which
/// `StreamSummary` reports.
static FAULTS_INJECTED: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_faults_injected_total", &[]));

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Torn,
    Flip,
    Enospc,
    Transient,
    Perm,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Torn => "torn",
            FaultKind::Flip => "flip",
            FaultKind::Enospc => "enospc",
            FaultKind::Transient => "transient",
            FaultKind::Perm => "perm",
        }
    }

    fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "torn" => Some(FaultKind::Torn),
            "flip" => Some(FaultKind::Flip),
            "enospc" => Some(FaultKind::Enospc),
            "transient" => Some(FaultKind::Transient),
            "perm" => Some(FaultKind::Perm),
            _ => None,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOp {
    Read,
    Write,
}

/// One arm of a fault script.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    pub kind: FaultKind,
    pub op: FaultOp,
    /// Fires on operations whose path contains this substring.
    pub substr: String,
    /// How many matching operations fire this arm (then it is spent).
    pub count: usize,
}

impl FaultSpec {
    pub fn new(kind: FaultKind, op: FaultOp, substr: impl Into<String>) -> FaultSpec {
        FaultSpec { kind, op, substr: substr.into(), count: 1 }
    }
}

/// Parse a fault script (see the module docs for the grammar).  Returns
/// the seed (default 0) and the arms in script order — the FIRST matching
/// arm with budget left fires on each operation.
pub fn parse_script(s: &str) -> Result<(u64, Vec<FaultSpec>)> {
    let mut seed = 0u64;
    let mut specs = Vec::new();
    for raw in s.split(',') {
        let entry = raw.trim();
        if entry.is_empty() {
            continue;
        }
        if let Some(v) = entry.strip_prefix("seed=") {
            seed = v.parse().with_context(|| format!("bad fault seed '{v}'"))?;
            continue;
        }
        let (kind_s, rest) = entry
            .split_once('@')
            .with_context(|| format!("fault '{entry}': expected kind@op:substr[:count]"))?;
        let kind = FaultKind::parse(kind_s)
            .with_context(|| format!("unknown fault kind '{kind_s}' in '{entry}'"))?;
        let mut parts = rest.splitn(3, ':');
        let op = match parts.next().unwrap_or("") {
            "r" => FaultOp::Read,
            "w" => FaultOp::Write,
            other => bail!("fault '{entry}': op must be r or w, got '{other}'"),
        };
        let substr =
            parts.next().with_context(|| format!("fault '{entry}': missing path substring"))?;
        ensure!(!substr.is_empty(), "fault '{entry}': empty path substring");
        let count = match parts.next() {
            Some(c) => c.parse().with_context(|| format!("bad fault count '{c}' in '{entry}'"))?,
            None => 1,
        };
        ensure!(count > 0, "fault '{entry}': count must be positive");
        ensure!(
            !(kind == FaultKind::Enospc && op == FaultOp::Read),
            "fault '{entry}': enospc applies to writes"
        );
        specs.push(FaultSpec { kind, op, substr: substr.to_string(), count });
    }
    Ok((seed, specs))
}

struct FaultState {
    arms: Vec<(FaultSpec, usize)>,
    rng: Rng,
    injected: usize,
}

/// A [`CkptIo`] that fires scripted deterministic faults, delegating
/// everything else to the wrapped implementation.
pub struct FaultyIo {
    inner: Box<dyn CkptIo>,
    state: Mutex<FaultState>,
}

impl FaultyIo {
    pub fn new(specs: Vec<FaultSpec>, seed: u64, inner: Box<dyn CkptIo>) -> FaultyIo {
        let arms = specs.into_iter().map(|s| (s.clone(), s.count)).collect();
        FaultyIo { inner, state: Mutex::new(FaultState { arms, rng: Rng::new(seed), injected: 0 }) }
    }

    /// Faults over real `std::fs` I/O.
    pub fn std(specs: Vec<FaultSpec>, seed: u64) -> FaultyIo {
        FaultyIo::new(specs, seed, Box::new(StdIo))
    }

    pub fn from_script(script: &str, inner: Box<dyn CkptIo>) -> Result<FaultyIo> {
        let (seed, specs) = parse_script(script)?;
        Ok(FaultyIo::new(specs, seed, inner))
    }

    /// Arm lookup: first scripted fault with budget left that matches this
    /// operation + path.  Returns the kind and a deterministic RNG draw
    /// for the fault's randomness (bit index, tear point).
    fn fire(&self, op: FaultOp, path: &Path) -> Option<(FaultKind, u64)> {
        let mut st = self.state.lock().unwrap();
        let p = path.to_string_lossy().into_owned();
        let idx = st
            .arms
            .iter()
            .position(|(spec, left)| *left > 0 && spec.op == op && p.contains(&spec.substr))?;
        st.arms[idx].1 -= 1;
        let kind = st.arms[idx].0.kind;
        st.injected += 1;
        FAULTS_INJECTED.inc();
        let draw = st.rng.next_u64();
        Some((kind, draw))
    }
}

/// Flip one bit chosen by `draw` (no-op on empty buffers).
fn flip_bit(bytes: &mut [u8], draw: u64) {
    if bytes.is_empty() {
        return;
    }
    let bit = (draw as usize) % (bytes.len() * 8);
    bytes[bit / 8] ^= 1 << (bit % 8);
}

/// Length of the strict prefix a torn operation keeps (possibly 0).
fn torn_len(len: usize, draw: u64) -> usize {
    if len == 0 {
        0
    } else {
        (draw as usize) % len
    }
}

impl CkptIo for FaultyIo {
    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        match self.fire(FaultOp::Read, path) {
            Some((FaultKind::Transient, _)) => Err(io::Error::new(
                io::ErrorKind::Interrupted,
                format!("injected transient read fault: {}", path.display()),
            )),
            Some((FaultKind::Perm | FaultKind::Enospc, _)) => Err(io::Error::new(
                io::ErrorKind::NotFound,
                format!("injected permanent read fault: {}", path.display()),
            )),
            Some((FaultKind::Flip, draw)) => {
                let mut bytes = self.inner.read(path)?;
                flip_bit(&mut bytes, draw);
                Ok(bytes)
            }
            Some((FaultKind::Torn, draw)) => {
                let bytes = self.inner.read(path)?;
                Ok(bytes[..torn_len(bytes.len(), draw)].to_vec())
            }
            None => self.inner.read(path),
        }
    }

    fn write(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        match self.fire(FaultOp::Write, path) {
            Some((FaultKind::Enospc, _)) => {
                Err(io::Error::other("injected fault: no space left on device"))
            }
            Some((FaultKind::Transient, _)) => Err(io::Error::new(
                io::ErrorKind::Interrupted,
                format!("injected transient write fault: {}", path.display()),
            )),
            Some((FaultKind::Perm, _)) => Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                format!("injected permanent write fault: {}", path.display()),
            )),
            Some((FaultKind::Torn, draw)) => {
                let keep = torn_len(bytes.len(), draw);
                self.inner.write(path, &bytes[..keep])?;
                Err(io::Error::other(format!(
                    "injected torn write after {keep} of {} bytes",
                    bytes.len()
                )))
            }
            // a flipped write REPORTS success: only content verification
            // (sha256 read-back) can catch it
            Some((FaultKind::Flip, draw)) => {
                let mut corrupt = bytes.to_vec();
                flip_bit(&mut corrupt, draw);
                self.inner.write(path, &corrupt)
            }
            None => self.inner.write(path, bytes),
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        self.inner.rename(from, to)
    }

    fn sync_dir(&self, dir: &Path) -> io::Result<()> {
        self.inner.sync_dir(dir)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        self.inner.remove_file(path)
    }

    fn faults_injected(&self) -> usize {
        self.state.lock().unwrap().injected
    }
}

/// The ambient [`CkptIo`]: a [`FaultyIo`] scripted by the `QERA_FAULTS`
/// env var when set (chaos runs against the real CLI), plain [`StdIo`]
/// otherwise.
pub fn io_from_env() -> Result<Arc<dyn CkptIo>> {
    match std::env::var("QERA_FAULTS") {
        Ok(s) if !s.trim().is_empty() => {
            let (seed, specs) = parse_script(&s).context("parsing QERA_FAULTS")?;
            crate::info!("QERA_FAULTS active: {} fault arm(s), seed {}", specs.len(), seed);
            Ok(Arc::new(FaultyIo::new(specs, seed, Box::new(StdIo))))
        }
        _ => Ok(Arc::new(StdIo)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("qera_fault_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn script_parses_and_rejects_garbage() {
        let (seed, specs) =
            parse_script("seed=7, flip@w:shard-002, transient@r:shard-001:2").unwrap();
        assert_eq!(seed, 7);
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].kind, FaultKind::Flip);
        assert_eq!(specs[0].op, FaultOp::Write);
        assert_eq!(specs[0].substr, "shard-002");
        assert_eq!(specs[0].count, 1);
        assert_eq!(specs[1].kind, FaultKind::Transient);
        assert_eq!(specs[1].count, 2);

        assert!(parse_script("bitrot@r:x").is_err(), "unknown kind");
        assert!(parse_script("flip@x:y").is_err(), "bad op");
        assert!(parse_script("flip@r:").is_err(), "empty substring");
        assert!(parse_script("flip@r:x:zero").is_err(), "bad count");
        assert!(parse_script("enospc@r:x").is_err(), "enospc is write-only");
        assert!(parse_script("seed=nope").is_err(), "bad seed");
        assert_eq!(parse_script("").unwrap().1.len(), 0);
    }

    #[test]
    fn faults_are_deterministic_and_budgeted() {
        let path = tmpfile("det.bin");
        std::fs::write(&path, vec![0u8; 256]).unwrap();
        let read_corrupt = |seed: u64| {
            let io =
                FaultyIo::std(vec![FaultSpec::new(FaultKind::Flip, FaultOp::Read, "det")], seed);
            io.read(&path).unwrap()
        };
        // same seed, same flipped bit; the arm spends after one shot
        assert_eq!(read_corrupt(3), read_corrupt(3));
        let io = FaultyIo::std(vec![FaultSpec::new(FaultKind::Flip, FaultOp::Read, "det")], 3);
        let first = io.read(&path).unwrap();
        assert_ne!(first, vec![0u8; 256], "one bit must differ");
        assert_eq!(io.read(&path).unwrap(), vec![0u8; 256], "arm budget spent");
        assert_eq!(io.faults_injected(), 1);
    }

    #[test]
    fn torn_write_leaves_a_strict_prefix() {
        let path = tmpfile("torn.bin");
        let io = FaultyIo::std(vec![FaultSpec::new(FaultKind::Torn, FaultOp::Write, "torn")], 11);
        let payload = vec![7u8; 100];
        let err = io.write(&path, &payload).unwrap_err();
        assert!(err.to_string().contains("torn write"), "{err}");
        let on_disk = std::fs::read(&path).unwrap();
        assert!(on_disk.len() < payload.len(), "strict prefix, got {}", on_disk.len());
        assert_eq!(on_disk, payload[..on_disk.len()]);
        // a clean retry through the same io succeeds (budget spent)
        io.write(&path, &payload).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), payload);
    }

    #[test]
    fn substring_scoping_leaves_other_paths_alone() {
        let hit = tmpfile("scoped-hit.bin");
        let miss = tmpfile("scoped-miss.bin");
        let io =
            FaultyIo::std(vec![FaultSpec::new(FaultKind::Perm, FaultOp::Write, "scoped-hit")], 0);
        assert!(io.write(&hit, b"x").is_err());
        io.write(&miss, b"x").unwrap();
        assert_eq!(io.faults_injected(), 1);
    }
}
