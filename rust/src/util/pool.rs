//! Scoped worker pool: `parallel_for` over independent jobs.
//!
//! The paper notes (App. A.7) that per-layer quantization is independent and
//! parallelizable; the coordinator uses this pool for the per-layer solver
//! jobs.  Built on `std::thread::scope` (no rayon offline).  Worker count
//! defaults to the available parallelism and can be forced via
//! `QERA_THREADS`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers: `QERA_THREADS` env or available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("QERA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f(i)` for all `i in 0..n` on a scoped pool and collect results in
/// index order.  `f` may be called from worker threads concurrently.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not run"))
        .collect()
}

/// Convenience: parallel map with default worker count.
pub fn parallel_map_auto<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map(n, default_workers(), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn all_jobs_run_once() {
        use std::sync::atomic::AtomicU32;
        let counter = AtomicU32::new(0);
        let out = parallel_map(57, 3, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 57);
        assert_eq!(out.len(), 57);
    }

    #[test]
    fn heavier_work() {
        let out = parallel_map(16, 8, |i| {
            let mut s = 0u64;
            for j in 0..10_000u64 {
                s = s.wrapping_add(j.wrapping_mul(i as u64 + 1));
            }
            s
        });
        for (i, v) in out.iter().enumerate() {
            let mut s = 0u64;
            for j in 0..10_000u64 {
                s = s.wrapping_add(j.wrapping_mul(i as u64 + 1));
            }
            assert_eq!(*v, s);
        }
    }
}
