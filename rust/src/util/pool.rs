//! Scoped worker pool: `parallel_for` over independent jobs.
//!
//! The paper notes (App. A.7) that per-layer quantization is independent and
//! parallelizable; the coordinator uses this pool for the per-layer solver
//! jobs.  Built on `std::thread::scope` (no rayon offline).  Worker count
//! defaults to the available parallelism and can be forced via
//! `QERA_THREADS`.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// True on threads spawned by this pool.  Kernels that can fan out on
    /// their own (the blocked matmuls in [`crate::linalg::mat`]) check this
    /// to stay single-threaded inside per-layer solver jobs instead of
    /// oversubscribing the machine with nested parallelism.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is a pool worker (see `IN_POOL`).
pub fn in_pool_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Number of workers: `QERA_THREADS` env or available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("QERA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Minimum m·k·n multiply volume before a matmul kernel fans out to
/// threads (shared by the `Mat64` f64 and `Tensor` f32 kernels so the two
/// families can't silently diverge).
pub const MATMUL_PAR_MIN_WORK: usize = 1 << 21;

/// Worker count for a multiply of volume `work` with `m` output rows:
/// serial when the volume is small or when already inside a pool worker
/// (no nested parallelism), otherwise the default worker count capped at
/// one row per worker.
pub fn matmul_workers(m: usize, work: usize) -> usize {
    if work < MATMUL_PAR_MIN_WORK || in_pool_worker() {
        1
    } else {
        default_workers().max(1).min(m.max(1))
    }
}

/// Worker count for the calibration-statistics fold: `QERA_CALIB_WORKERS`
/// env if set, else the pool default ([`default_workers`], itself
/// `QERA_THREADS`-pinnable).  A dedicated knob because calibration runs
/// concurrently with device execution and may want fewer cores than the
/// solver jobs.
pub fn default_calib_workers() -> usize {
    if let Ok(v) = std::env::var("QERA_CALIB_WORKERS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    default_workers()
}

/// Worker count for an upper-triangular SYRK fold with `m` output rows and
/// `work` total multiply volume: serial when the volume is small or inside
/// a pool worker (no nested parallelism), otherwise
/// [`default_calib_workers`] capped at one output row per worker.
pub fn calib_workers(m: usize, work: usize) -> usize {
    if work < MATMUL_PAR_MIN_WORK || in_pool_worker() {
        1
    } else {
        default_calib_workers().max(1).min(m.max(1))
    }
}

/// Minimum `rows × m` element volume before the diagonal (`sum_abs` /
/// `sum_sq`) calibration accumulation fans out to channel-chunk threads.
pub const DIAG_PAR_MIN_ELEMS: usize = 1 << 20;

/// Worker count for the diagonal calibration fold over `n = rows·m`
/// elements with `m` channels: serial when the volume is small or inside a
/// pool worker, otherwise [`default_calib_workers`] capped at one channel
/// per worker.  Lives here with the other fan-out policies so the kernel
/// families can't silently diverge.
pub fn diag_workers(m: usize, n: usize) -> usize {
    if n < DIAG_PAR_MIN_ELEMS || in_pool_worker() {
        1
    } else {
        default_calib_workers().max(1).min(m.max(1))
    }
}

/// Minimum element count before a quantize-dequantize kernel fans out
/// (per-element work is tiny, so only large weights benefit).
pub const QDQ_PAR_MIN_ELEMS: usize = 1 << 16;

/// Worker count for a quantize-dequantize over `n` elements: serial for
/// small tensors or inside pool workers (the per-layer solver jobs already
/// quantize on the pool), else the default worker count.
pub fn quant_workers(n: usize) -> usize {
    if n < QDQ_PAR_MIN_ELEMS || in_pool_worker() {
        1
    } else {
        default_workers()
    }
}

/// Apply `f(i)` for all `i in 0..n` on a scoped pool and collect results in
/// index order.  `f` may be called from worker threads concurrently.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    // one span per fan-out, not per job: observe-only and cold relative to
    // the work the pool runs (a relaxed load when tracing is off)
    let _sp = crate::obs::trace::span("pool.parallel_map").attr("n", n).attr("workers", workers);
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                IN_POOL.with(|c| c.set(true));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = f(i);
                    *results[i].lock().unwrap() = Some(out);
                }
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job did not run"))
        .collect()
}

/// Convenience: parallel map with default worker count.
pub fn parallel_map_auto<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map(n, default_workers(), f)
}

/// Split `data` into contiguous `chunk_len`-sized pieces and run
/// `f(chunk_index, chunk)` on scoped threads, one per chunk (callers size
/// `chunk_len` so there are about `workers` chunks).  The partition is
/// deterministic, so a kernel that writes only its own chunk produces
/// identical output for every worker count — the blocked matmuls rely on
/// this for the pipeline's bit-exactness guarantee.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk_len = chunk_len.max(1);
    if workers <= 1 || data.len() <= chunk_len {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci, chunk);
        }
        return;
    }
    std::thread::scope(|scope| {
        let f = &f;
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            scope.spawn(move || {
                IN_POOL.with(|c| c.set(true));
                f(ci, chunk);
            });
        }
    });
}

/// Split `data` into consecutive pieces of the given element lengths and
/// run `f(piece_index, piece)` on scoped threads, one per non-empty piece.
/// Unlike [`parallel_chunks_mut`] the pieces may be *uneven* — the caller
/// chooses boundaries that balance work (e.g. the upper-triangular SYRK
/// fold, where early output rows carry more entries than late ones).  The
/// partition is deterministic, so a kernel that writes only its own piece
/// produces identical output for every piece layout.
pub fn parallel_pieces_mut<T, F>(data: &mut [T], lens: &[usize], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(lens.iter().sum::<usize>(), data.len(), "piece lengths must cover data");
    // carve the disjoint pieces up front (move-out split so each piece
    // keeps the full input lifetime)
    let mut pieces: Vec<(usize, &mut [T])> = Vec::with_capacity(lens.len());
    let mut rest = data;
    for (pi, &len) in lens.iter().enumerate() {
        let tmp = rest;
        let (piece, tail) = tmp.split_at_mut(len);
        rest = tail;
        if len > 0 {
            pieces.push((pi, piece));
        }
    }
    debug_assert!(rest.is_empty());
    if pieces.len() <= 1 {
        // run inline on the caller thread
        for (pi, piece) in pieces {
            f(pi, piece);
        }
        return;
    }
    std::thread::scope(|scope| {
        let f = &f;
        for (pi, piece) in pieces {
            scope.spawn(move || {
                IN_POOL.with(|c| c.set(true));
                f(pi, piece);
            });
        }
    });
}

/// Run `f(index, &mut item)` over every item on a scoped worker pool with a
/// shared work queue (at most `workers` threads).  Each item is handed to
/// exactly one worker, so per-item state mutates without locks and —
/// because each item's update is internally serial — the result per item is
/// identical for every worker count.  Used for the embarrassingly parallel
/// per-tap calibration fold.
pub fn parallel_for_each_mut<T, F>(items: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let workers = workers.max(1).min(items.len().max(1));
    if workers <= 1 || items.len() <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let queue = Mutex::new(items.iter_mut().enumerate());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                IN_POOL.with(|c| c.set(true));
                loop {
                    let next = queue.lock().unwrap().next();
                    match next {
                        Some((i, item)) => f(i, item),
                        None => break,
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn all_jobs_run_once() {
        use std::sync::atomic::AtomicU32;
        let counter = AtomicU32::new(0);
        let out = parallel_map(57, 3, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 57);
        assert_eq!(out.len(), 57);
    }

    #[test]
    fn chunks_cover_everything_any_worker_count() {
        let n = 103usize;
        let mut serial: Vec<usize> = vec![0; n];
        parallel_chunks_mut(&mut serial, 10, 1, |ci, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = ci * 10 + k + 1;
            }
        });
        let mut threaded: Vec<usize> = vec![0; n];
        parallel_chunks_mut(&mut threaded, 10, 4, |ci, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = ci * 10 + k + 1;
            }
        });
        assert_eq!(serial, threaded);
        assert_eq!(serial, (1..=n).collect::<Vec<_>>());
    }

    #[test]
    fn chunks_empty_and_degenerate() {
        let mut empty: Vec<u8> = vec![];
        parallel_chunks_mut(&mut empty, 0, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![7u8];
        parallel_chunks_mut(&mut one, 16, 4, |ci, chunk| {
            assert_eq!(ci, 0);
            chunk[0] += 1;
        });
        assert_eq!(one, vec![8]);
    }

    #[test]
    fn matmul_worker_heuristic() {
        // small volume stays serial; large volume is capped by row count
        assert_eq!(matmul_workers(64, 1 << 10), 1);
        assert_eq!(matmul_workers(1, 1 << 30), 1);
        let w = matmul_workers(1 << 20, 1 << 30);
        assert!(w >= 1 && w <= default_workers().max(1));
        // inside a pool worker the kernels must stay single-threaded
        let inner = parallel_map(4, 2, |_| matmul_workers(1 << 20, 1 << 30));
        assert!(inner.iter().all(|&w| w == 1));
    }

    #[test]
    fn workers_are_marked_in_pool() {
        assert!(!in_pool_worker());
        let flags = parallel_map(8, 4, |_| in_pool_worker());
        assert!(flags.iter().all(|&b| b));
        // serial path runs inline on the caller thread
        let inline = parallel_map(1, 1, |_| in_pool_worker());
        assert!(!inline[0]);
    }

    #[test]
    fn pieces_cover_everything_uneven() {
        // uneven boundaries, including an empty piece in the middle
        let mut v = vec![0usize; 10];
        parallel_pieces_mut(&mut v, &[4, 0, 1, 5], |pi, piece| {
            for x in piece.iter_mut() {
                *x = pi + 1;
            }
        });
        assert_eq!(v, vec![1, 1, 1, 1, 3, 4, 4, 4, 4, 4]);
        // single non-empty piece runs inline (no pool marker)
        let mut one = vec![0u8; 3];
        parallel_pieces_mut(&mut one, &[3], |_, piece| {
            assert!(!in_pool_worker());
            piece[0] = 9;
        });
        assert_eq!(one[0], 9);
    }

    #[test]
    #[should_panic]
    fn pieces_must_cover_data() {
        let mut v = vec![0u8; 4];
        parallel_pieces_mut(&mut v, &[1, 2], |_, _| {});
    }

    #[test]
    fn for_each_mut_visits_every_item_once() {
        for workers in [1usize, 3, 8] {
            let mut items: Vec<u64> = (0..57).collect();
            parallel_for_each_mut(&mut items, workers, |i, v| {
                assert_eq!(*v, i as u64);
                *v += 100;
            });
            assert_eq!(items, (100..157).collect::<Vec<u64>>());
        }
        let mut empty: Vec<u8> = vec![];
        parallel_for_each_mut(&mut empty, 4, |_, _| panic!("no items expected"));
    }

    #[test]
    fn for_each_mut_workers_are_marked_in_pool() {
        let mut flags = vec![false; 8];
        parallel_for_each_mut(&mut flags, 4, |_, b| *b = in_pool_worker());
        assert!(flags.iter().all(|&b| b));
    }

    #[test]
    fn quant_and_calib_worker_heuristics() {
        assert_eq!(quant_workers(16), 1);
        assert!(quant_workers(1 << 20) >= 1);
        assert_eq!(calib_workers(64, 1 << 10), 1);
        assert_eq!(diag_workers(64, 1 << 10), 1);
        let w = calib_workers(1 << 20, 1 << 30);
        assert!(w >= 1 && w <= default_calib_workers().max(1));
        let d = diag_workers(1 << 20, 1 << 30);
        assert!(d >= 1 && d <= default_calib_workers().max(1));
        // nested: all stay serial inside pool workers
        let inner = parallel_map(4, 2, |_| {
            (
                quant_workers(1 << 20),
                calib_workers(1 << 20, 1 << 30),
                diag_workers(1 << 20, 1 << 30),
            )
        });
        assert!(inner.iter().all(|&(q, c, d)| q == 1 && c == 1 && d == 1));
    }

    #[test]
    fn heavier_work() {
        let out = parallel_map(16, 8, |i| {
            let mut s = 0u64;
            for j in 0..10_000u64 {
                s = s.wrapping_add(j.wrapping_mul(i as u64 + 1));
            }
            s
        });
        for (i, v) in out.iter().enumerate() {
            let mut s = 0u64;
            for j in 0..10_000u64 {
                s = s.wrapping_add(j.wrapping_mul(i as u64 + 1));
            }
            assert_eq!(*v, s);
        }
    }
}
