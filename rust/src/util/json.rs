//! Minimal JSON: parser + serializer (serde is unavailable offline).
//!
//! Powers the artifact manifest, typed configs, and experiment records.
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP (sufficient for everything this repo writes/reads).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------ accessors
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup (None if not an object / missing).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Required-field helpers used by config deserialization.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field '{key}'"))
    }
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing array field '{key}'"))
    }

    // --------------------------------------------------------- constructors
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---------------------------------------------------------- serializer
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn dump_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            other => other.write(out),
        }
    }

    // -------------------------------------------------------------- parser
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        // JSON has no inf/nan; encode as null (consumers treat as missing)
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"qera","ranks":[4,8,16],"pi":3.25,"flag":false,"nil":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.dump_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café \t \"q\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café \t \"q\"");
        let back = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn integers_stay_integral() {
        let v = Json::Num(42.0);
        assert_eq!(v.dump(), "42");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).dump(), "[]");
    }

    #[test]
    fn real_manifest_like() {
        let src = r#"{"version":1,"artifacts":[{"name":"lm_fwd.nano","inputs":[{"name":"tokens","dtype":"int32","shape":[4,64]}]}]}"#;
        let v = Json::parse(src).unwrap();
        let a = &v.req_arr("artifacts").unwrap()[0];
        assert_eq!(a.req_str("name").unwrap(), "lm_fwd.nano");
        let shape = a.req_arr("inputs").unwrap()[0].req_arr("shape").unwrap();
        let dims: Vec<usize> = shape.iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![4, 64]);
    }
}
