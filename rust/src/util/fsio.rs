//! Binary file I/O helpers (little-endian) for checkpoints and caches,
//! plus the [`CkptIo`] seam the sharded-checkpoint stack does all its file
//! I/O through.
//!
//! `CkptIo` exists so storage faults are injectable: production code runs
//! on [`StdIo`] (real `std::fs`, with fsync discipline), tests and
//! `QERA_FAULTS` chaos runs swap in `util::fault::FaultyIo` to script
//! torn writes, bit flips, ENOSPC, and transient read errors
//! deterministically — the `FaultyEngine` pattern from `serve/daemon.rs`
//! applied to storage.

use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

pub fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

pub fn read_str(r: &mut impl Read) -> Result<String> {
    let n = read_u32(r)? as usize;
    anyhow::ensure!(n < 1 << 24, "string too long: {n}");
    let mut b = vec![0u8; n];
    r.read_exact(&mut b)?;
    String::from_utf8(b).context("invalid utf-8 string")
}

pub fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    // bulk byte copy (safe: f32 -> le bytes)
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

pub fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u64(r)? as usize;
    anyhow::ensure!(n < 1 << 31, "tensor too large: {n}");
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn write_bytes(w: &mut impl Write, xs: &[u8]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    w.write_all(xs)?;
    Ok(())
}

pub fn read_bytes(r: &mut impl Read) -> Result<Vec<u8>> {
    let n = read_u64(r)? as usize;
    anyhow::ensure!(n < 1 << 32, "blob too large: {n}");
    let mut b = vec![0u8; n];
    r.read_exact(&mut b)?;
    Ok(b)
}

pub fn read_to_string(path: impl AsRef<Path>) -> Result<String> {
    std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))
}

/// The file-I/O surface of the sharded checkpoint stack.  Every byte the
/// shard writer/reader and the resume journal move goes through one of
/// these methods, so a single injected implementation can fault any of
/// them deterministically.
pub trait CkptIo: Send + Sync {
    /// Read a whole file.
    fn read(&self, path: &Path) -> std::io::Result<Vec<u8>>;
    /// Create/overwrite a file with `bytes` and fsync it before returning.
    fn write(&self, path: &Path, bytes: &[u8]) -> std::io::Result<()>;
    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()>;
    /// Fsync a directory, making completed renames inside it durable.
    fn sync_dir(&self, dir: &Path) -> std::io::Result<()>;
    fn remove_file(&self, path: &Path) -> std::io::Result<()>;
    /// Faults this implementation has injected so far (0 for real I/O).
    fn faults_injected(&self) -> usize {
        0
    }
}

/// The production [`CkptIo`]: `std::fs` with write-then-fsync.
pub struct StdIo;

impl CkptIo for StdIo {
    fn read(&self, path: &Path) -> std::io::Result<Vec<u8>> {
        std::fs::read(path)
    }

    fn write(&self, path: &Path, bytes: &[u8]) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(bytes)?;
        f.sync_all()
    }

    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()> {
        std::fs::rename(from, to)
    }

    fn sync_dir(&self, dir: &Path) -> std::io::Result<()> {
        // On unix a directory opens read-only and fsyncs like a file; this
        // is what makes a freshly renamed entry survive power loss.
        std::fs::File::open(dir)?.sync_all()
    }

    fn remove_file(&self, path: &Path) -> std::io::Result<()> {
        std::fs::remove_file(path)
    }
}

/// Durable atomic write through a [`CkptIo`]: write `<path>.tmp` (fsynced),
/// rename over `path`, then fsync the parent directory so the rename
/// itself survives a crash.  The `.tmp` suffix is appended to the full
/// file name (not swapped for the extension), so siblings like
/// `x.manifest.json` and `x.manifest.json.journal` never collide on the
/// same temp file.
pub fn write_atomic_with(io: &dyn CkptIo, path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    io.write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    io.rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            io.sync_dir(dir).with_context(|| format!("syncing dir {}", dir.display()))?;
        }
    }
    Ok(())
}

/// Atomic durable write on the real filesystem: see [`write_atomic_with`].
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    write_atomic_with(&StdIo, path.as_ref(), bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars_and_strings() {
        let mut buf = Vec::new();
        write_u32(&mut buf, 7).unwrap();
        write_u64(&mut buf, 1 << 40).unwrap();
        write_str(&mut buf, "blk0.wq").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_u32(&mut r).unwrap(), 7);
        assert_eq!(read_u64(&mut r).unwrap(), 1 << 40);
        assert_eq!(read_str(&mut r).unwrap(), "blk0.wq");
    }

    #[test]
    fn roundtrip_f32s() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        let mut buf = Vec::new();
        write_f32s(&mut buf, &xs).unwrap();
        let back = read_f32s(&mut &buf[..]).unwrap();
        assert_eq!(xs, back);
    }

    #[test]
    fn roundtrip_bytes() {
        let xs: Vec<u8> = (0..=255).collect();
        let mut buf = Vec::new();
        write_bytes(&mut buf, &xs).unwrap();
        assert_eq!(read_bytes(&mut &buf[..]).unwrap(), xs);
    }

    #[test]
    fn atomic_write() {
        let dir = std::env::temp_dir().join("qera_fsio_test");
        let path = dir.join("x.bin");
        write_atomic(&path, b"hello").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"hello");
        write_atomic(&path, b"world").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"world");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_input_errors() {
        let mut buf = Vec::new();
        write_f32s(&mut buf, &[1.0, 2.0]).unwrap();
        buf.truncate(buf.len() - 1);
        assert!(read_f32s(&mut &buf[..]).is_err());
    }
}
