//! Shared retry/backoff policy: exponential backoff with deterministic
//! seeded jitter.
//!
//! Grown out of `serve/daemon.rs` (PR 7), where it paced engine-step
//! retries under the supervisor; the storage layer (`model/shard.rs`,
//! `coordinator/stream.rs`) now uses the same policy to ride out transient
//! I/O faults, so retry timing everywhere is reproducible for a fixed
//! seed.  The daemon re-exports [`RetryPolicy`] unchanged — the extraction
//! is behavior-neutral and its backoff sequence is pinned by unit tests on
//! both sides.

use crate::obs::lazy::Lazy;
use crate::obs::metrics::{self, Counter};
use crate::util::rng::Rng;
use std::io;
use std::time::Duration;

/// Process-wide retry tally (`qera_io_retries_total`), the low-level view
/// behind the per-run `StreamSummary::io_retries`.  The handle is cached so
/// the steady state never touches the registry lock.
static IO_RETRIES: Lazy<Counter> = Lazy::new(|| metrics::counter("qera_io_retries_total", &[]));

/// Exponential backoff with jitter drawn from the caller's seeded RNG
/// discipline, so retry timing is reproducible for a fixed seed.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries per operation after the initial attempt; 0 fails straight
    /// away.
    pub max_retries: u32,
    /// First backoff; attempt `n` sleeps `base * factor^n` (capped).
    pub base: Duration,
    pub factor: f64,
    pub max: Duration,
    /// Multiplicative jitter fraction in `[0, 1)`: the sleep is scaled by
    /// a factor in `[1-jitter, 1+jitter)`.  0 disables jitter entirely.
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 2,
            base: Duration::from_millis(5),
            factor: 2.0,
            max: Duration::from_millis(200),
            jitter: 0.5,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (0-based).
    pub fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self.base.as_secs_f64() * self.factor.powi(attempt.min(30) as i32);
        let capped = exp.min(self.max.as_secs_f64());
        let scale = if self.jitter > 0.0 {
            1.0 + self.jitter * (2.0 * rng.f64() - 1.0)
        } else {
            1.0
        };
        Duration::from_secs_f64((capped * scale).max(0.0))
    }

    /// Defaults for checkpoint I/O: shard reads/writes are local-disk
    /// operations, so backoffs are short and the budget is one attempt
    /// deeper than the serving default (a transient read glitch at 70B
    /// scale is far cheaper to retry than to redo hours of solves).
    pub fn io_default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 3,
            base: Duration::from_millis(2),
            factor: 2.0,
            max: Duration::from_millis(50),
            jitter: 0.5,
        }
    }
}

/// I/O error kinds worth retrying.  Everything else — missing files,
/// permission errors, full disks, corrupt data — is permanent and must
/// fail fast with its typed error instead of burning the backoff budget.
pub fn is_transient(kind: io::ErrorKind) -> bool {
    matches!(
        kind,
        io::ErrorKind::Interrupted | io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock
    )
}

/// Run `op` under `policy`: transient failures back off and retry,
/// permanent ones (and budget exhaustion) return the last error.  The
/// second element is the number of retries taken (0 = first try worked).
pub fn retry_io<T>(
    policy: &RetryPolicy,
    rng: &mut Rng,
    mut op: impl FnMut() -> io::Result<T>,
) -> (io::Result<T>, u32) {
    let mut attempt = 0u32;
    let res = loop {
        match op() {
            Ok(v) => break Ok(v),
            Err(e) if is_transient(e.kind()) && attempt < policy.max_retries => {
                std::thread::sleep(policy.backoff(attempt, rng));
                attempt += 1;
            }
            Err(e) => break Err(e),
        }
    };
    if attempt > 0 {
        IO_RETRIES.add(attempt as u64);
    }
    (res, attempt)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The extraction from `serve/daemon.rs` must not change the backoff
    /// sequence: recompute the pre-extraction formula inline against the
    /// same RNG stream and demand exact equality, jittered and not.
    #[test]
    fn backoff_sequence_matches_daemon_formula_exactly() {
        let p = RetryPolicy {
            max_retries: 5,
            base: Duration::from_millis(10),
            factor: 2.0,
            max: Duration::from_millis(50),
            jitter: 0.5,
        };
        let mut actual = Rng::new(9);
        let mut expected = Rng::new(9);
        for attempt in 0..8u32 {
            let got = p.backoff(attempt, &mut actual);
            let exp = p.base.as_secs_f64() * p.factor.powi(attempt.min(30) as i32);
            let capped = exp.min(p.max.as_secs_f64());
            let scale = 1.0 + p.jitter * (2.0 * expected.f64() - 1.0);
            let want = Duration::from_secs_f64((capped * scale).max(0.0));
            assert_eq!(got, want, "attempt {attempt}");
        }
        // jitter 0 must not consume RNG state and gives the exact exponential
        let p0 = RetryPolicy { jitter: 0.0, ..p };
        let mut r = Rng::new(0);
        assert_eq!(p0.backoff(0, &mut r), Duration::from_millis(10));
        assert_eq!(p0.backoff(1, &mut r), Duration::from_millis(20));
        assert_eq!(p0.backoff(4, &mut r), Duration::from_millis(50));
        assert_eq!(r.next_u64(), Rng::new(0).next_u64(), "jitter 0 drew from the rng");
    }

    #[test]
    fn transient_kinds_are_narrow() {
        assert!(is_transient(io::ErrorKind::Interrupted));
        assert!(is_transient(io::ErrorKind::TimedOut));
        assert!(is_transient(io::ErrorKind::WouldBlock));
        assert!(!is_transient(io::ErrorKind::NotFound));
        assert!(!is_transient(io::ErrorKind::PermissionDenied));
        assert!(!is_transient(io::ErrorKind::InvalidData));
        assert!(!is_transient(io::ErrorKind::Other));
    }

    #[test]
    fn retry_io_retries_transient_and_fails_fast_on_permanent() {
        let policy = RetryPolicy { base: Duration::from_micros(10), ..RetryPolicy::io_default() };
        let mut rng = Rng::new(1);
        // other tests share the process-global counter, so assert a delta
        let retries_before = IO_RETRIES.get();

        // two transient failures, then success
        let mut calls = 0;
        let (res, retries) = retry_io(&policy, &mut rng, || {
            calls += 1;
            if calls <= 2 {
                Err(io::Error::new(io::ErrorKind::Interrupted, "transient"))
            } else {
                Ok(calls)
            }
        });
        assert_eq!(res.unwrap(), 3);
        assert_eq!(retries, 2);

        // permanent: exactly one call, no retries
        let mut calls = 0;
        let (res, retries) = retry_io(&policy, &mut rng, || -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::NotFound, "gone"))
        });
        assert!(res.is_err());
        assert_eq!((calls, retries), (1, 0));

        // budget exhaustion: initial try + max_retries, then the error
        let mut calls = 0;
        let (res, retries) = retry_io(&policy, &mut rng, || -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::Interrupted, "still down"))
        });
        assert!(res.is_err());
        assert_eq!(calls, 1 + policy.max_retries);
        assert_eq!(retries, policy.max_retries);
        // 2 (ride-out) + 0 (fail-fast) + max_retries (exhaustion) landed in
        // the registry counter on top of whatever parallel tests added
        assert!(IO_RETRIES.get() - retries_before >= 2 + policy.max_retries as u64);
    }
}
