//! Foundation utilities built in-repo (the image is offline: no serde, no
//! rand, no rayon — each hand-rolled here and unit-tested).

pub mod rng;
pub mod json;
pub mod pool;
pub mod logging;
pub mod fsio;
pub mod fault;
pub mod retry;
pub mod sha256;
