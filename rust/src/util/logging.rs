//! Tiny leveled logger (log crate not vendored):
//! `QERA_LOG=debug|info|warn|error|quiet` (`error` aliases `quiet`).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: once_cell_lite::Lazy<Instant> = once_cell_lite::Lazy::new(Instant::now);

/// Minimal Lazy (once_cell the crate is cached, but keep zero deps here).
mod once_cell_lite {
    use std::sync::Once;

    pub struct Lazy<T> {
        once: Once,
        init: fn() -> T,
        value: std::cell::UnsafeCell<Option<T>>,
    }
    unsafe impl<T: Sync> Sync for Lazy<T> {}
    impl<T> Lazy<T> {
        pub const fn new(init: fn() -> T) -> Self {
            Lazy { once: Once::new(), init, value: std::cell::UnsafeCell::new(None) }
        }
        pub fn get(&self) -> &T {
            self.once.call_once(|| unsafe {
                *self.value.get() = Some((self.init)());
            });
            unsafe { (*self.value.get()).as_ref().unwrap() }
        }
    }
    impl<T> std::ops::Deref for Lazy<T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.get()
        }
    }
}

#[derive(Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
}

/// Parse a `QERA_LOG` value into `(level, unrecognized)`; unrecognized
/// values fall back to info and surface the offending string for a
/// one-time warning.  `error` aliases `quiet`: the logger has no separate
/// error level, so both suppress everything the daemon would not treat as
/// fatal anyway.
fn parse_level(raw: Option<&str>) -> (u8, Option<String>) {
    match raw {
        Some("debug") => (0, None),
        Some("warn") => (2, None),
        Some("quiet") | Some("error") => (3, None),
        None | Some("info") | Some("") => (1, None),
        Some(other) => (1, Some(other.to_string())),
    }
}

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let var = std::env::var("QERA_LOG");
    let (lv, unknown) = parse_level(var.as_deref().ok());
    // Store before warning: the warn below re-enters level() and must see
    // the resolved value instead of recursing into the env parse.  The CAS
    // also makes the warning fire at most once under racing first calls.
    let won = LEVEL.compare_exchange(255, lv, Ordering::Relaxed, Ordering::Relaxed).is_ok();
    if let Some(bad) = unknown.filter(|_| won) {
        crate::warn_!("ignoring QERA_LOG={bad:?}: expected debug|info|warn|error|quiet");
    }
    lv
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.elapsed().as_secs_f64();
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qera_log_parse_accepts_error_alias_and_flags_unknown() {
        assert_eq!(parse_level(Some("debug")), (0, None));
        assert_eq!(parse_level(Some("info")), (1, None));
        assert_eq!(parse_level(Some("warn")), (2, None));
        assert_eq!(parse_level(Some("quiet")), (3, None));
        assert_eq!(parse_level(Some("error")), (3, None));
        assert_eq!(parse_level(None), (1, None));
        assert_eq!(parse_level(Some("")), (1, None));
        assert_eq!(parse_level(Some("verbose")), (1, Some("verbose".to_string())));
    }

    #[test]
    fn levels_ordered() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, format_args!("hello {}", 42));
        crate::info!("macro path {}", 1);
        crate::debug!("debug path");
        crate::warn_!("warn path");
    }
}
