//! Tiny leveled logger (log crate not vendored): `QERA_LOG=debug|info|warn`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: once_cell_lite::Lazy<Instant> = once_cell_lite::Lazy::new(Instant::now);

/// Minimal Lazy (once_cell the crate is cached, but keep zero deps here).
mod once_cell_lite {
    use std::sync::Once;

    pub struct Lazy<T> {
        once: Once,
        init: fn() -> T,
        value: std::cell::UnsafeCell<Option<T>>,
    }
    unsafe impl<T: Sync> Sync for Lazy<T> {}
    impl<T> Lazy<T> {
        pub const fn new(init: fn() -> T) -> Self {
            Lazy { once: Once::new(), init, value: std::cell::UnsafeCell::new(None) }
        }
        pub fn get(&self) -> &T {
            self.once.call_once(|| unsafe {
                *self.value.get() = Some((self.init)());
            });
            unsafe { (*self.value.get()).as_ref().unwrap() }
        }
    }
    impl<T> std::ops::Deref for Lazy<T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.get()
        }
    }
}

#[derive(Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
}

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let lv = match std::env::var("QERA_LOG").as_deref() {
        Ok("debug") => 0,
        Ok("warn") => 2,
        Ok("quiet") => 3,
        _ => 1,
    };
    LEVEL.store(lv, Ordering::Relaxed);
    lv
}

pub fn enabled(l: Level) -> bool {
    l as u8 >= level()
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.elapsed().as_secs_f64();
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, format_args!("hello {}", 42));
        crate::info!("macro path {}", 1);
        crate::debug!("debug path");
        crate::warn_!("warn path");
    }
}
