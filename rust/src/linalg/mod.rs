//! f64 linear algebra for the QERA solvers.
//!
//! The paper (App. A.7) computes `R_XX` in f64 and takes its matrix square
//! root with a blocked Schur algorithm on CPU.  `R_XX` is symmetric PSD, so
//! the Schur form *is* the spectral decomposition; this module provides:
//!
//! * [`mat::Mat64`] — dense f64 matrices with cache-blocked, optionally
//!   multi-threaded multiply kernels (bit-exact for any worker count);
//! * [`eigh`] — symmetric eigendecomposition (Householder tridiagonalization
//!   + implicit-shift QL; a cyclic-Jacobi implementation cross-checks it in
//!   tests and serves as the robustness fallback), plus [`eigh_topk`] — a
//!   truncated top-k path via blocked subspace iteration;
//! * [`svd`] — thin SVD via the Gram-matrix trick (work on the smaller
//!   side), plus [`svd_randomized`] — the Halko rank-k sketch behind the
//!   solvers' `SvdBackend::Randomized` fast path;
//! * [`psd`] — PSD matrix square root / inverse square root with eigenvalue
//!   clamping (Remark 1's diagonal perturbation), plus the low-rank +
//!   diagonal split ([`psd::PsdBackend::LowRank`]) behind QERA-exact's
//!   rank-aware whitening fast path.

pub mod mat;
pub mod eigh;
pub mod svd;
pub mod psd;

pub use eigh::{eigh, eigh_jacobi, eigh_topk, eigh_topk_iters, EighResult};
pub use mat::Mat64;
pub use psd::{
    psd_inv_sqrt, psd_sqrt, psd_sqrt_pair, psd_sqrt_pair_lowrank, psd_sqrt_pair_with, PsdBackend,
};
pub use svd::{svd_randomized, svd_thin, SvdResult};
