//! Thin + randomized SVD.
//!
//! Every solver in this repo needs the *truncated* SVD of an error matrix
//! `E` (m×n).  Two paths:
//!
//! * [`svd_thin`] — exact, via the Gram-matrix trick: eigendecompose the
//!   smaller Gram matrix (`E Eᵀ` if m ≤ n, else `Eᵀ E`) and recover the
//!   other factor by projection — O(min(m,n)³) instead of a full
//!   bidiagonal SVD, in f64 (the Gram squaring costs ~half the
//!   significand, plenty for rank-k reconstruction of quantization errors;
//!   cross-checked against reconstruction in tests).
//! * [`svd_randomized`] — Halko-style rank-k sketch (Gaussian range finder
//!   → MGS orthonormalization → small-Gram eigensolve), O(mnk) for the
//!   O(mnk)-sized answer the solvers actually consume.  Deterministic and
//!   cross-checked against [`svd_thin`] in tests.

use super::eigh::{eigh, eigh_topk};
use super::mat::Mat64;
use crate::util::rng::Rng;

/// `a = u * diag(s) * vt`, singular values descending.
/// u: [m, r], s: [r], vt: [r, n] with r = min(m, n).
#[derive(Clone, Debug)]
pub struct SvdResult {
    pub u: Mat64,
    pub s: Vec<f64>,
    pub vt: Mat64,
}

impl SvdResult {
    /// Rank-k reconstruction `U_k Σ_k Vt_k`.
    pub fn reconstruct_k(&self, k: usize) -> Mat64 {
        let k = k.min(self.s.len());
        let uk = self.u.cols_head(k); // m x k
        let mut usk = uk.clone();
        for i in 0..usk.r {
            for j in 0..k {
                usk.a[i * k + j] *= self.s[j];
            }
        }
        usk.matmul(&self.vt.rows_head(k))
    }

    /// (A_k, B_k) factors: A = U_k Σ_k scaled? — here A = U_k, B = Σ_k Vt_k.
    pub fn factors_k(&self, k: usize) -> (Mat64, Mat64) {
        let k = k.min(self.s.len());
        let a = self.u.cols_head(k);
        let mut b = self.vt.rows_head(k);
        for i in 0..k {
            for j in 0..b.c {
                b.a[i * b.c + j] *= self.s[i];
            }
        }
        (a, b)
    }

    /// First-k truncation (no-op when `k >= self.s.len()`).
    pub fn truncated(&self, k: usize) -> SvdResult {
        let k = k.min(self.s.len());
        SvdResult {
            u: self.u.cols_head(k),
            s: self.s[..k].to_vec(),
            vt: self.vt.rows_head(k),
        }
    }
}

/// Thin SVD of an arbitrary dense matrix.
pub fn svd_thin(a: &Mat64) -> SvdResult {
    let (m, n) = (a.r, a.c);
    let r = m.min(n);
    if m <= n {
        // G = A Aᵀ = U Λ Uᵀ ; V = Aᵀ U Σ⁻¹
        let g = a.matmul_nt(a);
        let e = eigh(&g);
        // eigh returns ascending; we want descending
        let (s, u) = desc_sqrt(&e.w, &e.v, r);
        // vt rows: vtᵢ = (uᵢᵀ A)/σᵢ
        let ut_a = u.matmul_tn(a); // [r, n]
        let mut vt = ut_a;
        normalize_rows(&mut vt, &s);
        SvdResult { u, s, vt }
    } else {
        // G = Aᵀ A = V Λ Vᵀ ; U = A V Σ⁻¹
        let g = a.matmul_tn(a);
        let e = eigh(&g);
        let (s, v) = desc_sqrt(&e.w, &e.v, r);
        let av = a.matmul(&v); // [m, r]
        let mut u = av;
        normalize_cols(&mut u, &s);
        SvdResult { u, s, vt: v.transpose() }
    }
}

/// Halko-style randomized truncated SVD: top-`k` singular triples of `a`.
///
/// Range finder: `Y = A Ω` with a Gaussian sketch `Ω [n, k+oversample]`,
/// orthonormalized by modified Gram–Schmidt; `power_iters` rounds of
/// `Y ← A (Aᵀ Y)` (re-orthonormalized each application) sharpen the
/// captured spectrum for slowly-decaying inputs.  The small problem
/// `B = Qᵀ A` is then solved through its `l×l` Gram matrix with the
/// truncated eigensolver ([`eigh_topk`]).
///
/// Deterministic: the sketch is seeded from the shape, so repeated calls
/// agree bit-for-bit (the pipeline's reproducibility tests rely on this).
/// Falls back to the exact [`svd_thin`] (truncated) when
/// `k + oversample >= min(m, n)`, where a sketch cannot win.
pub fn svd_randomized(a: &Mat64, k: usize, oversample: usize, power_iters: usize) -> SvdResult {
    let (m, n) = (a.r, a.c);
    let minmn = m.min(n);
    let k = k.min(minmn);
    if k == 0 {
        return SvdResult { u: Mat64::zeros(m, 0), s: vec![], vt: Mat64::zeros(0, n) };
    }
    let l = k + oversample.max(1);
    if l >= minmn {
        return svd_thin(a).truncated(k);
    }
    let mut rng = Rng::new(0x51D0_5EED ^ ((m as u64) << 32) ^ ((n as u64) << 8) ^ l as u64);
    let omega = Mat64::from_vec(n, l, (0..n * l).map(|_| rng.normal()).collect());
    let mut q = a.matmul(&omega); // [m, l]
    q.orthonormalize_cols();
    for _ in 0..power_iters {
        let mut z = a.matmul_tn(&q); // Aᵀ Q  [n, l]
        z.orthonormalize_cols();
        q = a.matmul(&z); // [m, l]
        q.orthonormalize_cols();
    }
    let b = q.matmul_tn(a); // Qᵀ A  [l, n]
    let mut g = b.matmul_nt(&b); // B Bᵀ  [l, l]
    g.symmetrize();
    let e = eigh_topk(&g, k); // descending
    let s: Vec<f64> = e.w.iter().map(|&w| w.max(0.0).sqrt()).collect();
    let mut vt = e.v.matmul_tn(&b); // Ubᵀ B  [k, n]
    normalize_rows(&mut vt, &s);
    let u = q.matmul(&e.v); // [m, k]
    SvdResult { u, s, vt }
}

/// Take the top-r eigenpairs (ascending input), σ = sqrt(clamped λ).
fn desc_sqrt(w: &[f64], v: &Mat64, r: usize) -> (Vec<f64>, Mat64) {
    let n = w.len();
    let mut s = Vec::with_capacity(r);
    let mut vv = Mat64::zeros(v.r, r);
    for j in 0..r {
        let src = n - 1 - j; // descending
        s.push(w[src].max(0.0).sqrt());
        for i in 0..v.r {
            vv.set(i, j, v.at(i, src));
        }
    }
    (s, vv)
}

fn normalize_rows(m: &mut Mat64, s: &[f64]) {
    let smax = s.first().copied().unwrap_or(0.0);
    let tol = smax * 1e-13;
    for i in 0..m.r {
        if s[i] > tol {
            let inv = 1.0 / s[i];
            for j in 0..m.c {
                m.a[i * m.c + j] *= inv;
            }
        } else {
            for j in 0..m.c {
                m.a[i * m.c + j] = 0.0;
            }
        }
    }
}

fn normalize_cols(m: &mut Mat64, s: &[f64]) {
    let smax = s.first().copied().unwrap_or(0.0);
    let tol = smax * 1e-13;
    for j in 0..m.c {
        if s[j] > tol {
            let inv = 1.0 / s[j];
            for i in 0..m.r {
                m.a[i * m.c + j] *= inv;
            }
        } else {
            for i in 0..m.r {
                m.a[i * m.c + j] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat64 {
        let mut rng = Rng::new(seed);
        Mat64::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    fn check_svd(a: &Mat64, tol: f64) {
        let r = svd_thin(a);
        let k = r.s.len();
        assert_eq!(k, a.r.min(a.c));
        // descending, non-negative
        for i in 0..k {
            assert!(r.s[i] >= -1e-12);
            if i > 0 {
                assert!(r.s[i] <= r.s[i - 1] + 1e-10);
            }
        }
        // reconstruction at full rank
        let rec = r.reconstruct_k(k);
        let diff = rec.sub(a).frob_norm();
        assert!(diff < tol * (1.0 + a.frob_norm()), "recon err {diff}");
        // orthonormality of the computed factor (up to null-space zeros)
        let utu = r.u.matmul_tn(&r.u);
        for i in 0..k {
            for j in 0..k {
                let got = utu.at(i, j);
                if r.s[i] > 1e-10 && r.s[j] > 1e-10 {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((got - want).abs() < 1e-7, "UᵀU ({i},{j}) = {got}");
                }
            }
        }
    }

    #[test]
    fn wide_and_tall() {
        check_svd(&randm(6, 10, 0), 1e-8);
        check_svd(&randm(10, 6, 1), 1e-8);
        check_svd(&randm(8, 8, 2), 1e-8);
        check_svd(&randm(1, 5, 3), 1e-8);
        check_svd(&randm(5, 1, 4), 1e-8);
    }

    #[test]
    fn known_diagonal() {
        let mut a = Mat64::zeros(3, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, -5.0); // singular value 5
        a.set(2, 2, 1.0);
        let r = svd_thin(&a);
        assert!((r.s[0] - 5.0).abs() < 1e-10);
        assert!((r.s[1] - 3.0).abs() < 1e-10);
        assert!((r.s[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn eckart_young_truncation_optimal() {
        // SVD_k must beat any other candidate low-rank approx we try
        let a = randm(8, 12, 5);
        let r = svd_thin(&a);
        let k = 3;
        let best = r.reconstruct_k(k);
        let best_err = best.sub(&a).frob_norm();
        // candidate: random rank-k
        let mut rng = Rng::new(99);
        for _ in 0..5 {
            let u = Mat64::from_vec(8, k, (0..8 * k).map(|_| rng.normal()).collect());
            let v = Mat64::from_vec(k, 12, (0..k * 12).map(|_| rng.normal()).collect());
            // least-squares won't help these random ones beat SVD
            let cand_err = u.matmul(&v).sub(&a).frob_norm();
            assert!(best_err <= cand_err + 1e-9);
        }
        // and the tail-energy identity: err² = Σ_{i>k} σ_i²
        let tail: f64 = r.s[k..].iter().map(|s| s * s).sum();
        assert!((best_err * best_err - tail).abs() < 1e-7 * (1.0 + tail));
    }

    #[test]
    fn rank_deficient_input() {
        // rank-2 matrix
        let u = randm(7, 2, 6);
        let v = randm(2, 9, 7);
        let a = u.matmul(&v);
        let r = svd_thin(&a);
        for i in 2..r.s.len() {
            assert!(r.s[i] < 1e-8 * r.s[0], "σ[{i}]={} not ~0", r.s[i]);
        }
        let rec = r.reconstruct_k(2);
        assert!(rec.sub(&a).frob_norm() < 1e-8 * (1.0 + a.frob_norm()));
    }

    #[test]
    fn factors_match_reconstruction() {
        let a = randm(6, 9, 8);
        let r = svd_thin(&a);
        let (fa, fb) = r.factors_k(4);
        let rec1 = fa.matmul(&fb);
        let rec2 = r.reconstruct_k(4);
        assert!(rec1.sub(&rec2).frob_norm() < 1e-10);
    }

    #[test]
    fn zero_matrix() {
        let a = Mat64::zeros(4, 6);
        let r = svd_thin(&a);
        for &s in &r.s {
            assert!(s.abs() < 1e-12);
        }
        assert!(r.reconstruct_k(2).frob_norm() < 1e-12);
    }

    #[test]
    fn frobenius_identity() {
        // ||A||_F² = Σ σ_i²
        let a = randm(9, 5, 10);
        let r = svd_thin(&a);
        let sum: f64 = r.s.iter().map(|s| s * s).sum();
        let frob2 = a.frob_norm().powi(2);
        assert!((sum - frob2).abs() < 1e-8 * frob2);
    }

    /// m×n matrix with singular values `decay^i` (full rank, random bases).
    fn decaying(m: usize, n: usize, decay: f64, seed: u64) -> Mat64 {
        let base = randm(m, n, seed);
        let r = svd_thin(&base);
        let shaped: Vec<f64> = (0..r.s.len()).map(|i| decay.powi(i as i32)).collect();
        let rr = SvdResult { u: r.u.clone(), s: shaped, vt: r.vt.clone() };
        rr.reconstruct_k(rr.s.len())
    }

    #[test]
    fn randomized_matches_thin_on_fast_decay() {
        // steep spectrum: the sketch captures the top-k essentially exactly
        let a = decaying(60, 80, 0.5, 20);
        let k = 6;
        let exact = svd_thin(&a);
        let rand = svd_randomized(&a, k, 8, 2);
        assert_eq!(rand.s.len(), k);
        for i in 0..k {
            assert!(
                (rand.s[i] - exact.s[i]).abs() < 1e-8 * (1.0 + exact.s[i]),
                "σ[{i}]: {} vs {}",
                rand.s[i],
                exact.s[i]
            );
        }
        let err_rand = rand.reconstruct_k(k).sub(&a).frob_norm();
        let err_exact = exact.reconstruct_k(k).sub(&a).frob_norm();
        assert!(err_rand <= err_exact * (1.0 + 1e-8) + 1e-9, "{err_rand} vs {err_exact}");
    }

    #[test]
    fn randomized_near_optimal_on_slow_decay() {
        // shallow spectrum: reconstruction must stay within 2% of optimal
        let a = decaying(64, 96, 0.93, 21);
        let k = 8;
        let err_rand = svd_randomized(&a, k, 8, 2).reconstruct_k(k).sub(&a).frob_norm();
        let err_exact = svd_thin(&a).reconstruct_k(k).sub(&a).frob_norm();
        assert!(err_rand <= err_exact * 1.02, "{err_rand} vs {err_exact}");
    }

    #[test]
    fn randomized_falls_back_to_exact_when_sketch_cannot_win() {
        let a = randm(10, 8, 22);
        // k + oversample >= min(m, n) -> identical to the truncated thin SVD
        let rand = svd_randomized(&a, 6, 8, 2);
        let want = svd_thin(&a).truncated(6);
        assert_eq!(rand.s, want.s);
        assert_eq!(rand.u, want.u);
        assert_eq!(rand.vt, want.vt);
    }

    #[test]
    fn randomized_deterministic() {
        let a = randm(48, 64, 23);
        let r1 = svd_randomized(&a, 5, 8, 2);
        let r2 = svd_randomized(&a, 5, 8, 2);
        assert_eq!(r1.s, r2.s);
        assert_eq!(r1.u, r2.u);
        assert_eq!(r1.vt, r2.vt);
    }

    #[test]
    fn randomized_orthonormal_u() {
        let a = decaying(50, 70, 0.7, 24);
        let r = svd_randomized(&a, 6, 8, 2);
        let utu = r.u.matmul_tn(&r.u);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - want).abs() < 1e-8, "UᵀU ({i},{j})");
            }
        }
        // descending non-negative singular values
        for i in 0..6 {
            assert!(r.s[i] >= 0.0);
            if i > 0 {
                assert!(r.s[i] <= r.s[i - 1] + 1e-12);
            }
        }
    }

    #[test]
    fn randomized_recovers_low_rank_exactly() {
        // rank-3 input, k=5: trailing σ ≈ 0 and the reconstruction is exact
        let u = randm(40, 3, 25);
        let v = randm(3, 50, 26);
        let a = u.matmul(&v);
        let r = svd_randomized(&a, 5, 8, 2);
        assert!(r.s[3] < 1e-8 * r.s[0], "σ3 = {}", r.s[3]);
        assert!(r.s[4] < 1e-8 * r.s[0], "σ4 = {}", r.s[4]);
        let rec = r.reconstruct_k(5);
        assert!(rec.sub(&a).frob_norm() < 1e-8 * (1.0 + a.frob_norm()));
    }

    #[test]
    fn randomized_zero_matrix_and_k0() {
        let z = Mat64::zeros(40, 50);
        let r = svd_randomized(&z, 4, 8, 2);
        for &s in &r.s {
            assert!(s.abs() < 1e-12);
        }
        assert!(r.reconstruct_k(4).frob_norm() < 1e-12);
        let r0 = svd_randomized(&randm(20, 30, 27), 0, 8, 2);
        assert!(r0.s.is_empty());
        assert_eq!((r0.u.r, r0.u.c), (20, 0));
        assert_eq!((r0.vt.r, r0.vt.c), (0, 30));
    }

    #[test]
    fn truncated_slices_factors() {
        let a = randm(12, 9, 28);
        let r = svd_thin(&a);
        let t = r.truncated(4);
        assert_eq!(t.s.len(), 4);
        assert_eq!((t.u.r, t.u.c), (12, 4));
        assert_eq!((t.vt.r, t.vt.c), (4, 9));
        let d = t.reconstruct_k(4).sub(&r.reconstruct_k(4)).frob_norm();
        assert!(d < 1e-12);
    }
}
