//! Thin SVD via the Gram-matrix trick.
//!
//! Every solver in this repo needs the *truncated* SVD of an error matrix
//! `E` (m×n).  We eigendecompose the smaller Gram matrix (`E Eᵀ` if m ≤ n,
//! else `Eᵀ E`) and recover the other factor by projection — O(min(m,n)³)
//! instead of a full bidiagonal SVD, in f64 (the Gram squaring costs
//! ~half the significand, plenty for rank-k reconstruction of quantization
//! errors; cross-checked against reconstruction in tests).

use super::eigh::eigh;
use super::mat::Mat64;

/// `a = u * diag(s) * vt`, singular values descending.
/// u: [m, r], s: [r], vt: [r, n] with r = min(m, n).
#[derive(Clone, Debug)]
pub struct SvdResult {
    pub u: Mat64,
    pub s: Vec<f64>,
    pub vt: Mat64,
}

impl SvdResult {
    /// Rank-k reconstruction `U_k Σ_k Vt_k`.
    pub fn reconstruct_k(&self, k: usize) -> Mat64 {
        let k = k.min(self.s.len());
        let uk = self.u.cols_head(k); // m x k
        let mut usk = uk.clone();
        for i in 0..usk.r {
            for j in 0..k {
                usk.a[i * k + j] *= self.s[j];
            }
        }
        usk.matmul(&self.vt.rows_head(k))
    }

    /// (A_k, B_k) factors: A = U_k Σ_k scaled? — here A = U_k, B = Σ_k Vt_k.
    pub fn factors_k(&self, k: usize) -> (Mat64, Mat64) {
        let k = k.min(self.s.len());
        let a = self.u.cols_head(k);
        let mut b = self.vt.rows_head(k);
        for i in 0..k {
            for j in 0..b.c {
                b.a[i * b.c + j] *= self.s[i];
            }
        }
        (a, b)
    }
}

/// Thin SVD of an arbitrary dense matrix.
pub fn svd_thin(a: &Mat64) -> SvdResult {
    let (m, n) = (a.r, a.c);
    let r = m.min(n);
    if m <= n {
        // G = A Aᵀ = U Λ Uᵀ ; V = Aᵀ U Σ⁻¹
        let g = a.matmul_nt(a);
        let e = eigh(&g);
        // eigh returns ascending; we want descending
        let (s, u) = desc_sqrt(&e.w, &e.v, r);
        // vt rows: vtᵢ = (uᵢᵀ A)/σᵢ
        let ut_a = u.matmul_tn(a); // [r, n]
        let mut vt = ut_a;
        normalize_rows(&mut vt, &s);
        SvdResult { u, s, vt }
    } else {
        // G = Aᵀ A = V Λ Vᵀ ; U = A V Σ⁻¹
        let g = a.matmul_tn(a);
        let e = eigh(&g);
        let (s, v) = desc_sqrt(&e.w, &e.v, r);
        let av = a.matmul(&v); // [m, r]
        let mut u = av;
        normalize_cols(&mut u, &s);
        SvdResult { u, s, vt: v.transpose() }
    }
}

/// Take the top-r eigenpairs (ascending input), σ = sqrt(clamped λ).
fn desc_sqrt(w: &[f64], v: &Mat64, r: usize) -> (Vec<f64>, Mat64) {
    let n = w.len();
    let mut s = Vec::with_capacity(r);
    let mut vv = Mat64::zeros(v.r, r);
    for j in 0..r {
        let src = n - 1 - j; // descending
        s.push(w[src].max(0.0).sqrt());
        for i in 0..v.r {
            vv.set(i, j, v.at(i, src));
        }
    }
    (s, vv)
}

fn normalize_rows(m: &mut Mat64, s: &[f64]) {
    let smax = s.first().copied().unwrap_or(0.0);
    let tol = smax * 1e-13;
    for i in 0..m.r {
        if s[i] > tol {
            let inv = 1.0 / s[i];
            for j in 0..m.c {
                m.a[i * m.c + j] *= inv;
            }
        } else {
            for j in 0..m.c {
                m.a[i * m.c + j] = 0.0;
            }
        }
    }
}

fn normalize_cols(m: &mut Mat64, s: &[f64]) {
    let smax = s.first().copied().unwrap_or(0.0);
    let tol = smax * 1e-13;
    for j in 0..m.c {
        if s[j] > tol {
            let inv = 1.0 / s[j];
            for i in 0..m.r {
                m.a[i * m.c + j] *= inv;
            }
        } else {
            for i in 0..m.r {
                m.a[i * m.c + j] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat64 {
        let mut rng = Rng::new(seed);
        Mat64::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    fn check_svd(a: &Mat64, tol: f64) {
        let r = svd_thin(a);
        let k = r.s.len();
        assert_eq!(k, a.r.min(a.c));
        // descending, non-negative
        for i in 0..k {
            assert!(r.s[i] >= -1e-12);
            if i > 0 {
                assert!(r.s[i] <= r.s[i - 1] + 1e-10);
            }
        }
        // reconstruction at full rank
        let rec = r.reconstruct_k(k);
        let diff = rec.sub(a).frob_norm();
        assert!(diff < tol * (1.0 + a.frob_norm()), "recon err {diff}");
        // orthonormality of the computed factor (up to null-space zeros)
        let utu = r.u.matmul_tn(&r.u);
        for i in 0..k {
            for j in 0..k {
                let got = utu.at(i, j);
                if r.s[i] > 1e-10 && r.s[j] > 1e-10 {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((got - want).abs() < 1e-7, "UᵀU ({i},{j}) = {got}");
                }
            }
        }
    }

    #[test]
    fn wide_and_tall() {
        check_svd(&randm(6, 10, 0), 1e-8);
        check_svd(&randm(10, 6, 1), 1e-8);
        check_svd(&randm(8, 8, 2), 1e-8);
        check_svd(&randm(1, 5, 3), 1e-8);
        check_svd(&randm(5, 1, 4), 1e-8);
    }

    #[test]
    fn known_diagonal() {
        let mut a = Mat64::zeros(3, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, -5.0); // singular value 5
        a.set(2, 2, 1.0);
        let r = svd_thin(&a);
        assert!((r.s[0] - 5.0).abs() < 1e-10);
        assert!((r.s[1] - 3.0).abs() < 1e-10);
        assert!((r.s[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn eckart_young_truncation_optimal() {
        // SVD_k must beat any other candidate low-rank approx we try
        let a = randm(8, 12, 5);
        let r = svd_thin(&a);
        let k = 3;
        let best = r.reconstruct_k(k);
        let best_err = best.sub(&a).frob_norm();
        // candidate: random rank-k
        let mut rng = Rng::new(99);
        for _ in 0..5 {
            let u = Mat64::from_vec(8, k, (0..8 * k).map(|_| rng.normal()).collect());
            let v = Mat64::from_vec(k, 12, (0..k * 12).map(|_| rng.normal()).collect());
            // least-squares won't help these random ones beat SVD
            let cand_err = u.matmul(&v).sub(&a).frob_norm();
            assert!(best_err <= cand_err + 1e-9);
        }
        // and the tail-energy identity: err² = Σ_{i>k} σ_i²
        let tail: f64 = r.s[k..].iter().map(|s| s * s).sum();
        assert!((best_err * best_err - tail).abs() < 1e-7 * (1.0 + tail));
    }

    #[test]
    fn rank_deficient_input() {
        // rank-2 matrix
        let u = randm(7, 2, 6);
        let v = randm(2, 9, 7);
        let a = u.matmul(&v);
        let r = svd_thin(&a);
        for i in 2..r.s.len() {
            assert!(r.s[i] < 1e-8 * r.s[0], "σ[{i}]={} not ~0", r.s[i]);
        }
        let rec = r.reconstruct_k(2);
        assert!(rec.sub(&a).frob_norm() < 1e-8 * (1.0 + a.frob_norm()));
    }

    #[test]
    fn factors_match_reconstruction() {
        let a = randm(6, 9, 8);
        let r = svd_thin(&a);
        let (fa, fb) = r.factors_k(4);
        let rec1 = fa.matmul(&fb);
        let rec2 = r.reconstruct_k(4);
        assert!(rec1.sub(&rec2).frob_norm() < 1e-10);
    }

    #[test]
    fn zero_matrix() {
        let a = Mat64::zeros(4, 6);
        let r = svd_thin(&a);
        for &s in &r.s {
            assert!(s.abs() < 1e-12);
        }
        assert!(r.reconstruct_k(2).frob_norm() < 1e-12);
    }

    #[test]
    fn frobenius_identity() {
        // ||A||_F² = Σ σ_i²
        let a = randm(9, 5, 10);
        let r = svd_thin(&a);
        let sum: f64 = r.s.iter().map(|s| s * s).sum();
        let frob2 = a.frob_norm().powi(2);
        assert!((sum - frob2).abs() < 1e-8 * frob2);
    }
}
