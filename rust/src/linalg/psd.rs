//! PSD matrix square root and inverse square root (Theorem 1's `R_XX^{1/2}`
//! and `(R_XX^{1/2})^{-1}`), with eigenvalue clamping implementing Remark 1's
//! diagonal perturbation for near-singular autocorrelation matrices.
//!
//! The paper uses SciPy's blocked Schur on CPU; for a symmetric PSD matrix
//! the Schur decomposition coincides with the spectral one, so an `eigh`
//! based sqrt is the numerically-equivalent (and TPU-friendlier) route.
//! Following App. A.7, all accumulation upstream of this is f64.

use super::eigh::eigh;
use super::mat::Mat64;

/// Relative eigenvalue floor for the inverse (Remark 1's perturbation).
pub const EIG_CLAMP_REL: f64 = 1e-10;

/// `R^{1/2}`: eigenvalues clamped at 0 from below.
pub fn psd_sqrt(r: &Mat64) -> Mat64 {
    psd_pow(r, 0.5, 0.0)
}

/// `R^{-1/2}` with relative clamping `λ >= eps_rel * λ_max`.
pub fn psd_inv_sqrt(r: &Mat64, eps_rel: f64) -> Mat64 {
    psd_pow(r, -0.5, eps_rel)
}

/// Both `R^{1/2}` and its inverse from a single eigendecomposition — the
/// form QERA-exact consumes.
pub fn psd_sqrt_pair(r: &Mat64, eps_rel: f64) -> (Mat64, Mat64) {
    let e = eigh(r);
    let wmax = e.w.iter().cloned().fold(0.0f64, f64::max).max(f64::MIN_POSITIVE);
    let floor = wmax * eps_rel.max(0.0);
    let sq: Vec<f64> = e.w.iter().map(|&w| w.max(0.0).sqrt()).collect();
    let isq: Vec<f64> = e.w.iter().map(|&w| 1.0 / w.max(floor).max(f64::MIN_POSITIVE).sqrt()).collect();
    (recompose(&e.v, &sq), recompose(&e.v, &isq))
}

fn psd_pow(r: &Mat64, p: f64, eps_rel: f64) -> Mat64 {
    let e = eigh(r);
    let wmax = e.w.iter().cloned().fold(0.0f64, f64::max).max(f64::MIN_POSITIVE);
    let floor = wmax * eps_rel.max(0.0);
    let d: Vec<f64> = e
        .w
        .iter()
        .map(|&w| {
            let wc = if p < 0.0 { w.max(floor).max(f64::MIN_POSITIVE) } else { w.max(0.0) };
            wc.powf(p)
        })
        .collect();
    recompose(&e.v, &d)
}

/// V diag(d) Vᵀ.
fn recompose(v: &Mat64, d: &[f64]) -> Mat64 {
    let n = v.r;
    let mut vd = v.clone();
    for j in 0..n {
        for i in 0..n {
            vd.a[i * n + j] *= d[j];
        }
    }
    vd.matmul_nt(v)
}

/// Relative error of the square root: ||(R½)² − R||_F / ||R||_F — the metric
/// of the paper's Figure 8a.
pub fn sqrt_error_ratio(r: &Mat64) -> f64 {
    let rh = psd_sqrt(r);
    rh.matmul(&rh).sub(r).frob_norm() / r.frob_norm().max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_psd(n: usize, seed: u64, cond: f64) -> Mat64 {
        let mut rng = Rng::new(seed);
        let q = Mat64::from_vec(n, 2 * n, (0..2 * n * n).map(|_| rng.normal()).collect());
        let mut g = q.matmul_nt(&q).scale(1.0 / (2 * n) as f64);
        // stretch the spectrum to a target-ish condition number
        if cond > 1.0 {
            let e = eigh(&g);
            let d: Vec<f64> = (0..n)
                .map(|i| 1.0 + (cond - 1.0) * (i as f64 / (n - 1).max(1) as f64))
                .collect();
            g = super::recompose(&e.v, &d);
        }
        g
    }

    #[test]
    fn sqrt_squares_back() {
        for n in [2, 5, 12, 24] {
            let r = rand_psd(n, n as u64, 100.0);
            let rh = psd_sqrt(&r);
            let err = rh.matmul(&rh).sub(&r).frob_norm() / r.frob_norm();
            assert!(err < 1e-9, "n={n}: {err}");
            assert!(rh.is_symmetric(1e-9));
        }
    }

    #[test]
    fn inv_sqrt_inverts() {
        let r = rand_psd(10, 3, 50.0);
        let (rh, rhi) = psd_sqrt_pair(&r, EIG_CLAMP_REL);
        let prod = rh.matmul(&rhi);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-7, "({i},{j}) {}", prod.at(i, j));
            }
        }
    }

    #[test]
    fn pair_consistent_with_singles() {
        let r = rand_psd(8, 5, 10.0);
        let (rh, rhi) = psd_sqrt_pair(&r, EIG_CLAMP_REL);
        let rh2 = psd_sqrt(&r);
        let rhi2 = psd_inv_sqrt(&r, EIG_CLAMP_REL);
        assert!(rh.sub(&rh2).frob_norm() < 1e-10);
        assert!(rhi.sub(&rhi2).frob_norm() < 1e-10);
    }

    #[test]
    fn diagonal_case_exact() {
        let r = Mat64::diag(&[4.0, 9.0, 16.0]);
        let rh = psd_sqrt(&r);
        assert!((rh.at(0, 0) - 2.0).abs() < 1e-12);
        assert!((rh.at(1, 1) - 3.0).abs() < 1e-12);
        assert!((rh.at(2, 2) - 4.0).abs() < 1e-12);
        assert!(rh.at(0, 1).abs() < 1e-12);
    }

    #[test]
    fn singular_matrix_clamped_inverse_finite() {
        // rank-deficient PSD
        let x = Mat64::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let r = x.matmul_nt(&x); // rank 1
        let (_, rhi) = psd_sqrt_pair(&r, 1e-8);
        for v in &rhi.a {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn sqrt_error_ratio_small_for_wellconditioned() {
        let r = rand_psd(16, 9, 10.0);
        assert!(sqrt_error_ratio(&r) < 1e-10);
    }

    #[test]
    fn psd_sqrt_positive_semidefinite() {
        let r = rand_psd(9, 11, 30.0);
        let rh = psd_sqrt(&r);
        let e = eigh(&rh);
        for &w in &e.w {
            assert!(w > -1e-9, "{w}");
        }
    }
}
