//! PSD matrix square root and inverse square root (Theorem 1's `R_XX^{1/2}`
//! and `(R_XX^{1/2})^{-1}`), with eigenvalue clamping implementing Remark 1's
//! diagonal perturbation for near-singular autocorrelation matrices.
//!
//! The paper uses SciPy's blocked Schur on CPU; for a symmetric PSD matrix
//! the Schur decomposition coincides with the spectral one, so an `eigh`
//! based sqrt is the numerically-equivalent (and TPU-friendlier) route.
//! Following App. A.7, all accumulation upstream of this is f64.
//!
//! Two paths produce the `(R^{1/2}, R^{-1/2})` pair QERA-exact consumes:
//!
//! * [`psd_sqrt_pair`] — exact, via a full dense eigendecomposition, O(m³);
//! * [`psd_sqrt_pair_with`] + [`PsdBackend::LowRank`] — a low-rank +
//!   diagonal split: the top-k eigenpairs from [`eigh_topk_iters`]'s
//!   subspace iteration (O(m²·k·iters)) model the head of the spectrum
//!   exactly, and the residual spectrum is modeled as a clamped flat
//!   diagonal `τ·(I − V Vᵀ)` in the eigenbasis, so both roots assemble in
//!   O(m²k).  At the ranks the solvers reconstruct, only this head of the
//!   calibration statistics matters (the LQER observation), which is why
//!   `Auto` takes the split whenever the rank is small relative to `m`.

use super::eigh::{eigh, eigh_topk_iters};
use super::mat::Mat64;
use anyhow::{bail, Result};

/// Relative eigenvalue floor for the inverse (Remark 1's perturbation).
pub const EIG_CLAMP_REL: f64 = 1e-10;

/// Backend for the `(R^{1/2}, R^{-1/2})` pair inside QERA-exact.
///
/// `Exact` pays the full O(m³) eigendecomposition.  `LowRank` extracts the
/// top `rank_mult · rank` eigenpairs by subspace iteration (capped at
/// `power_iters` rounds) and models the residual spectrum as a clamped flat
/// diagonal — O(m²k) total.  `Auto` (the pipeline default) picks the
/// low-rank split whenever the subspace path can actually win
/// (`rank_mult · rank · 4 <= m`, mirroring `svd_randomized`'s guard) and
/// falls back to exact when the reconstruction rank is too close to `m`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PsdBackend {
    /// Low-rank split when `DEFAULT_RANK_MULT · rank · 4 <= m`, else exact.
    Auto,
    /// Full dense eigendecomposition ([`psd_sqrt_pair`]).
    Exact,
    /// Top-`rank_mult · rank` eigenpairs + clamped flat residual diagonal.
    LowRank { rank_mult: usize, power_iters: usize },
}

impl Default for PsdBackend {
    fn default() -> PsdBackend {
        PsdBackend::Auto
    }
}

impl PsdBackend {
    /// Subspace size as a multiple of the reconstruction rank: the whitening
    /// only has to be faithful on the directions the rank-k SVD can keep,
    /// plus headroom for the spectrum it competes against.
    pub const DEFAULT_RANK_MULT: usize = 4;
    /// Cap on the subspace iterations (the convergence check usually stops
    /// far earlier on decaying calibration spectra).
    pub const DEFAULT_POWER_ITERS: usize = 32;

    /// `auto`, `exact`, or `lowrank[:rank_mult[:power_iters]]`.
    pub fn parse(s: &str) -> Result<PsdBackend> {
        let s = s.trim().to_lowercase();
        match s.as_str() {
            "auto" => return Ok(PsdBackend::Auto),
            "exact" | "eigh" | "full" => return Ok(PsdBackend::Exact),
            _ => {}
        }
        let rest = s
            .strip_prefix("lowrank")
            .or_else(|| s.strip_prefix("low-rank"))
            .or_else(|| s.strip_prefix("lr"));
        let Some(rest) = rest else {
            bail!("unknown psd backend '{s}' (auto | exact | lowrank[:rank_mult[:power_iters]])")
        };
        let mut rank_mult = Self::DEFAULT_RANK_MULT;
        let mut power_iters = Self::DEFAULT_POWER_ITERS;
        if !rest.is_empty() {
            let Some(spec) = rest.strip_prefix(':') else {
                bail!("bad psd backend spec '{s}'")
            };
            let parts: Vec<&str> = spec.split(':').collect();
            if parts.len() > 2 {
                bail!("bad psd backend spec '{s}' (at most lowrank:rank_mult:power_iters)");
            }
            rank_mult = parts[0].parse()?;
            if parts.len() == 2 {
                power_iters = parts[1].parse()?;
            }
        }
        // reject 0 rather than silently bumping at use: the backend name
        // is recorded in checkpoint meta and must describe the actual run
        if rank_mult == 0 || power_iters == 0 {
            bail!("psd backend '{s}': rank_mult and power_iters must be >= 1");
        }
        Ok(PsdBackend::LowRank { rank_mult, power_iters })
    }

    pub fn name(&self) -> String {
        match self {
            PsdBackend::Auto => "auto".into(),
            PsdBackend::Exact => "exact".into(),
            PsdBackend::LowRank { rank_mult, power_iters } => {
                format!("lowrank:{rank_mult}:{power_iters}")
            }
        }
    }

    /// Resolve `Auto` for an `m×m` correlation matrix whitening a rank-`rank`
    /// reconstruction; `Exact` and `LowRank` pass through unchanged.
    pub fn resolve(self, m: usize, rank: usize) -> PsdBackend {
        match self {
            PsdBackend::Auto => {
                let k = Self::DEFAULT_RANK_MULT * rank;
                if rank > 0 && k * 4 <= m {
                    PsdBackend::LowRank {
                        rank_mult: Self::DEFAULT_RANK_MULT,
                        power_iters: Self::DEFAULT_POWER_ITERS,
                    }
                } else {
                    PsdBackend::Exact
                }
            }
            b => b,
        }
    }
}

/// `R^{1/2}`: eigenvalues clamped at 0 from below.
pub fn psd_sqrt(r: &Mat64) -> Mat64 {
    psd_pow(r, 0.5, 0.0)
}

/// `R^{-1/2}` with relative clamping `λ >= eps_rel * λ_max`.
pub fn psd_inv_sqrt(r: &Mat64, eps_rel: f64) -> Mat64 {
    psd_pow(r, -0.5, eps_rel)
}

/// Both `R^{1/2}` and its inverse from a single eigendecomposition — the
/// form QERA-exact consumes.
pub fn psd_sqrt_pair(r: &Mat64, eps_rel: f64) -> (Mat64, Mat64) {
    let e = eigh(r);
    let wmax = e.w.iter().cloned().fold(0.0f64, f64::max).max(f64::MIN_POSITIVE);
    let floor = wmax * eps_rel.max(0.0);
    let sq: Vec<f64> = e.w.iter().map(|&w| w.max(0.0).sqrt()).collect();
    let isq: Vec<f64> =
        e.w.iter().map(|&w| 1.0 / w.max(floor).max(f64::MIN_POSITIVE).sqrt()).collect();
    (recompose(&e.v, &sq), recompose(&e.v, &isq))
}

/// [`psd_sqrt_pair`] with backend dispatch (`Auto` resolved against the
/// downstream reconstruction rank `rank`; see [`PsdBackend::resolve`]).
pub fn psd_sqrt_pair_with(
    r: &Mat64,
    eps_rel: f64,
    backend: PsdBackend,
    rank: usize,
) -> (Mat64, Mat64) {
    match backend.resolve(r.r, rank) {
        PsdBackend::LowRank { rank_mult, power_iters } => {
            let k = rank_mult.max(1).saturating_mul(rank.max(1));
            psd_sqrt_pair_lowrank(r, eps_rel, k, power_iters)
        }
        _ => psd_sqrt_pair(r, eps_rel),
    }
}

/// Low-rank + diagonal split of a PSD `R`:
///
/// ```text
///   R ≈ V diag(w) Vᵀ + τ (I − V Vᵀ)
/// ```
///
/// with `(w, V)` the top-k eigenpairs (subspace iteration) and `τ` the
/// residual spectrum modeled as a single clamped level — the mean of the
/// unexplained trace over the `m − k` complement dimensions, clamped to
/// `[λ_max · eps_rel, w_k]` so the inverse stays bounded (Remark 1) and the
/// tail never exceeds the smallest captured eigenvalue.  Both roots follow
/// analytically:
///
/// ```text
///   R^{1/2}  = √τ · I + V diag(√w − √τ) Vᵀ
///   R^{-1/2} = τ^{-1/2} · I + V diag(w_cl^{-1/2} − τ^{-1/2}) Vᵀ
/// ```
///
/// so `R^{1/2} · R^{-1/2} = I` holds exactly on the complement and up to the
/// eigenvalue clamp on the head.  Falls back to the exact pair when the
/// requested `k` is too close to `m` for the split to pay (mirroring
/// `svd_randomized`'s guard).
pub fn psd_sqrt_pair_lowrank(
    r: &Mat64,
    eps_rel: f64,
    k: usize,
    power_iters: usize,
) -> (Mat64, Mat64) {
    let m = r.r;
    assert_eq!(r.r, r.c, "psd_sqrt_pair_lowrank needs a square matrix");
    if k == 0 || 2 * k >= m {
        return psd_sqrt_pair(r, eps_rel);
    }
    let e = eigh_topk_iters(r, k, power_iters.max(1)); // descending w, v: [m, k]
    let wmax = e.w.first().copied().unwrap_or(0.0).max(f64::MIN_POSITIVE);
    let floor = (wmax * eps_rel.max(0.0)).max(f64::MIN_POSITIVE);
    // flat-tail level: unexplained trace spread over the complement dims
    let trace: f64 = (0..m).map(|i| r.at(i, i)).sum();
    let captured: f64 = e.w.iter().map(|&w| w.max(0.0)).sum();
    let wk = e.w.last().copied().unwrap_or(0.0).max(0.0);
    let tau = ((trace - captured) / (m - k) as f64).clamp(floor, wk.max(floor));
    let (st, ist) = (tau.sqrt(), 1.0 / tau.sqrt());
    let d_sq: Vec<f64> = e.w.iter().map(|&w| w.max(0.0).sqrt() - st).collect();
    let d_isq: Vec<f64> = e.w.iter().map(|&w| 1.0 / w.max(floor).sqrt() - ist).collect();
    let mut rh = recompose(&e.v, &d_sq);
    let mut rhi = recompose(&e.v, &d_isq);
    for i in 0..m {
        rh.a[i * m + i] += st;
        rhi.a[i * m + i] += ist;
    }
    (rh, rhi)
}

fn psd_pow(r: &Mat64, p: f64, eps_rel: f64) -> Mat64 {
    let e = eigh(r);
    let wmax = e.w.iter().cloned().fold(0.0f64, f64::max).max(f64::MIN_POSITIVE);
    let floor = wmax * eps_rel.max(0.0);
    let d: Vec<f64> = e
        .w
        .iter()
        .map(|&w| {
            let wc = if p < 0.0 { w.max(floor).max(f64::MIN_POSITIVE) } else { w.max(0.0) };
            wc.powf(p)
        })
        .collect();
    recompose(&e.v, &d)
}

/// V diag(d) Vᵀ for V `[m, k]` (square V is the k = m case) — the O(m²k)
/// assembly step of the low-rank split (the matmul is the blocked/threaded
/// kernel).
fn recompose(v: &Mat64, d: &[f64]) -> Mat64 {
    let (m, k) = (v.r, v.c);
    debug_assert_eq!(d.len(), k);
    let mut vd = v.clone();
    for i in 0..m {
        for j in 0..k {
            vd.a[i * k + j] *= d[j];
        }
    }
    vd.matmul_nt(v)
}

/// Relative error of the square root: ||(R½)² − R||_F / ||R||_F — the metric
/// of the paper's Figure 8a.
pub fn sqrt_error_ratio(r: &Mat64) -> f64 {
    let rh = psd_sqrt(r);
    rh.matmul(&rh).sub(r).frob_norm() / r.frob_norm().max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_psd(n: usize, seed: u64, cond: f64) -> Mat64 {
        let mut rng = Rng::new(seed);
        let q = Mat64::from_vec(n, 2 * n, (0..2 * n * n).map(|_| rng.normal()).collect());
        let mut g = q.matmul_nt(&q).scale(1.0 / (2 * n) as f64);
        // stretch the spectrum to a target-ish condition number
        if cond > 1.0 {
            let e = eigh(&g);
            let d: Vec<f64> = (0..n)
                .map(|i| 1.0 + (cond - 1.0) * (i as f64 / (n - 1).max(1) as f64))
                .collect();
            g = super::recompose(&e.v, &d);
        }
        g
    }

    /// Spiked-spectrum PSD: `n_spikes` large eigenvalues decaying from
    /// `top`, then an exactly flat tail at `tail` — the shape of a
    /// calibration `R_XX` where a few activation directions dominate.
    fn spiked_psd(n: usize, n_spikes: usize, top: f64, tail: f64, seed: u64) -> Mat64 {
        let mut rng = Rng::new(seed);
        let mut q = Mat64::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect());
        q.orthonormalize_cols();
        let d: Vec<f64> = (0..n)
            .map(|i| {
                if i < n_spikes {
                    top * 0.6f64.powi(i as i32)
                } else {
                    tail
                }
            })
            .collect();
        super::recompose(&q, &d)
    }

    #[test]
    fn sqrt_squares_back() {
        for n in [2, 5, 12, 24] {
            let r = rand_psd(n, n as u64, 100.0);
            let rh = psd_sqrt(&r);
            let err = rh.matmul(&rh).sub(&r).frob_norm() / r.frob_norm();
            assert!(err < 1e-9, "n={n}: {err}");
            assert!(rh.is_symmetric(1e-9));
        }
    }

    #[test]
    fn inv_sqrt_inverts() {
        let r = rand_psd(10, 3, 50.0);
        let (rh, rhi) = psd_sqrt_pair(&r, EIG_CLAMP_REL);
        let prod = rh.matmul(&rhi);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-7, "({i},{j}) {}", prod.at(i, j));
            }
        }
    }

    #[test]
    fn pair_consistent_with_singles() {
        let r = rand_psd(8, 5, 10.0);
        let (rh, rhi) = psd_sqrt_pair(&r, EIG_CLAMP_REL);
        let rh2 = psd_sqrt(&r);
        let rhi2 = psd_inv_sqrt(&r, EIG_CLAMP_REL);
        assert!(rh.sub(&rh2).frob_norm() < 1e-10);
        assert!(rhi.sub(&rhi2).frob_norm() < 1e-10);
    }

    #[test]
    fn diagonal_case_exact() {
        let r = Mat64::diag(&[4.0, 9.0, 16.0]);
        let rh = psd_sqrt(&r);
        assert!((rh.at(0, 0) - 2.0).abs() < 1e-12);
        assert!((rh.at(1, 1) - 3.0).abs() < 1e-12);
        assert!((rh.at(2, 2) - 4.0).abs() < 1e-12);
        assert!(rh.at(0, 1).abs() < 1e-12);
    }

    #[test]
    fn singular_matrix_clamped_inverse_finite() {
        // rank-deficient PSD
        let x = Mat64::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let r = x.matmul_nt(&x); // rank 1
        let (_, rhi) = psd_sqrt_pair(&r, 1e-8);
        for v in &rhi.a {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn sqrt_error_ratio_small_for_wellconditioned() {
        let r = rand_psd(16, 9, 10.0);
        assert!(sqrt_error_ratio(&r) < 1e-10);
    }

    #[test]
    fn psd_sqrt_positive_semidefinite() {
        let r = rand_psd(9, 11, 30.0);
        let rh = psd_sqrt(&r);
        let e = eigh(&rh);
        for &w in &e.w {
            assert!(w > -1e-9, "{w}");
        }
    }

    #[test]
    fn lowrank_pair_roundtrips_identity_on_spiked_spectrum() {
        // the low-rank split must still satisfy R½ · R^{-½} ≈ I: exact on
        // the complement by construction, up to eigenpair accuracy on the
        // head
        let n = 48;
        let r = spiked_psd(n, 6, 50.0, 0.5, 17);
        let (rh, rhi) = psd_sqrt_pair_lowrank(&r, EIG_CLAMP_REL, 8, 32);
        let prod = rh.matmul(&rhi);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-5, "({i},{j}) {}", prod.at(i, j));
            }
        }
        assert!(rh.is_symmetric(1e-8));
        assert!(rhi.is_symmetric(1e-8));
    }

    #[test]
    fn lowrank_sqrt_squares_back_on_spiked_spectrum() {
        // with an exactly flat tail the trace estimate recovers τ, so the
        // split reproduces R itself (up to subspace-iteration accuracy)
        let n = 64;
        let r = spiked_psd(n, 5, 20.0, 0.25, 18);
        let (rh, _) = psd_sqrt_pair_lowrank(&r, EIG_CLAMP_REL, 8, 32);
        let err = rh.matmul(&rh).sub(&r).frob_norm() / r.frob_norm();
        assert!(err < 1e-3, "{err}");
    }

    #[test]
    fn lowrank_close_to_exact_pair_on_decaying_spectrum() {
        let n = 64;
        let r = spiked_psd(n, 8, 30.0, 0.4, 19);
        let (rh_e, rhi_e) = psd_sqrt_pair(&r, EIG_CLAMP_REL);
        let (rh_l, rhi_l) = psd_sqrt_pair_lowrank(&r, EIG_CLAMP_REL, 12, 32);
        let rel_h = rh_l.sub(&rh_e).frob_norm() / rh_e.frob_norm();
        let rel_i = rhi_l.sub(&rhi_e).frob_norm() / rhi_e.frob_norm();
        assert!(rel_h < 5e-2, "sqrt rel err {rel_h}");
        assert!(rel_i < 5e-2, "inv sqrt rel err {rel_i}");
    }

    #[test]
    fn lowrank_guard_falls_back_to_exact() {
        // k too close to m: bit-identical to the exact pair
        let r = rand_psd(12, 21, 20.0);
        let (rh_e, rhi_e) = psd_sqrt_pair(&r, EIG_CLAMP_REL);
        let (rh_l, rhi_l) = psd_sqrt_pair_lowrank(&r, EIG_CLAMP_REL, 6, 32);
        assert_eq!(rh_e, rh_l);
        assert_eq!(rhi_e, rhi_l);
        // k == 0 likewise
        let (rh_0, _) = psd_sqrt_pair_lowrank(&r, EIG_CLAMP_REL, 0, 32);
        assert_eq!(rh_e, rh_0);
    }

    #[test]
    fn lowrank_deterministic() {
        let r = spiked_psd(40, 4, 10.0, 0.2, 22);
        let (a1, b1) = psd_sqrt_pair_lowrank(&r, EIG_CLAMP_REL, 6, 32);
        let (a2, b2) = psd_sqrt_pair_lowrank(&r, EIG_CLAMP_REL, 6, 32);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
    }

    #[test]
    fn backend_parse_and_name() {
        assert_eq!(PsdBackend::parse("auto").unwrap(), PsdBackend::Auto);
        assert_eq!(PsdBackend::parse("exact").unwrap(), PsdBackend::Exact);
        assert_eq!(
            PsdBackend::parse("lowrank").unwrap(),
            PsdBackend::LowRank {
                rank_mult: PsdBackend::DEFAULT_RANK_MULT,
                power_iters: PsdBackend::DEFAULT_POWER_ITERS
            }
        );
        assert_eq!(
            PsdBackend::parse("lowrank:2:16").unwrap(),
            PsdBackend::LowRank { rank_mult: 2, power_iters: 16 }
        );
        assert_eq!(
            PsdBackend::parse("lr:3").unwrap(),
            PsdBackend::LowRank {
                rank_mult: 3,
                power_iters: PsdBackend::DEFAULT_POWER_ITERS
            }
        );
        assert!(PsdBackend::parse("nope").is_err());
        assert!(PsdBackend::parse("lowrank:a").is_err());
        assert!(PsdBackend::parse("lowrank:1:2:3").is_err());
        assert!(PsdBackend::parse("lowrank:0").is_err());
        assert!(PsdBackend::parse("lowrank:2:0").is_err());
        for b in [
            PsdBackend::Auto,
            PsdBackend::Exact,
            PsdBackend::LowRank { rank_mult: 2, power_iters: 12 },
        ] {
            assert_eq!(PsdBackend::parse(&b.name()).unwrap(), b);
        }
        assert_eq!(PsdBackend::default(), PsdBackend::Auto);
    }

    #[test]
    fn backend_auto_resolution() {
        // small rank relative to m -> low-rank split
        assert!(matches!(
            PsdBackend::Auto.resolve(512, 8),
            PsdBackend::LowRank { .. }
        ));
        // rank too close to m (nano-sized layer) or rank 0 -> exact
        assert_eq!(PsdBackend::Auto.resolve(64, 8), PsdBackend::Exact);
        assert_eq!(PsdBackend::Auto.resolve(256, 0), PsdBackend::Exact);
        // explicit choices pass through
        assert_eq!(PsdBackend::Exact.resolve(4096, 1), PsdBackend::Exact);
        let fixed = PsdBackend::LowRank { rank_mult: 2, power_iters: 8 };
        assert_eq!(fixed.resolve(16, 16), fixed);
    }

    #[test]
    fn pair_with_dispatches() {
        let r = spiked_psd(64, 6, 25.0, 0.3, 23);
        // Exact backend == the plain pair
        let (rh_e, rhi_e) = psd_sqrt_pair(&r, EIG_CLAMP_REL);
        let (rh_b, rhi_b) = psd_sqrt_pair_with(&r, EIG_CLAMP_REL, PsdBackend::Exact, 8);
        assert_eq!(rh_e, rh_b);
        assert_eq!(rhi_e, rhi_b);
        // explicit LowRank == the lowrank pair at k = rank_mult * rank
        let lr = PsdBackend::LowRank { rank_mult: 2, power_iters: 32 };
        let (rh_l, rhi_l) = psd_sqrt_pair_with(&r, EIG_CLAMP_REL, lr, 8);
        let (rh_l2, rhi_l2) = psd_sqrt_pair_lowrank(&r, EIG_CLAMP_REL, 16, 32);
        assert_eq!(rh_l, rh_l2);
        assert_eq!(rhi_l, rhi_l2);
        // Auto on a small matrix resolves to exact
        let small = rand_psd(16, 24, 10.0);
        let (rh_a, _) = psd_sqrt_pair_with(&small, EIG_CLAMP_REL, PsdBackend::Auto, 4);
        let (rh_se, _) = psd_sqrt_pair(&small, EIG_CLAMP_REL);
        assert_eq!(rh_a, rh_se);
    }
}
