//! Symmetric eigendecomposition.
//!
//! Default algorithm: cyclic Jacobi — unconditionally robust, quadratically
//! convergent, and embarrassingly verifiable (`A V = V diag(w)` is asserted
//! in tests).  The perf pass adds a Householder-tridiagonalization +
//! implicit-QL fast path behind the same API (see `tridiag` below); both
//! agree to 1e-10 on random PSD instances (cross-check test).
//!
//! Used for: `R_XX^{1/2}` / `(R_XX^{1/2})^{-1}` (Theorem 1), and the Gram
//! eigendecompositions inside [`super::svd`].

use super::mat::Mat64;

/// Eigenvalues ascending, eigenvectors as columns of `v` (`a = v w vᵀ`).
#[derive(Clone, Debug)]
pub struct EighResult {
    pub w: Vec<f64>,
    pub v: Mat64,
}

const MAX_SWEEPS: usize = 64;

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
pub fn eigh_jacobi(a_in: &Mat64) -> EighResult {
    assert_eq!(a_in.r, a_in.c, "eigh needs a square matrix");
    let n = a_in.r;
    let mut a = a_in.clone();
    a.symmetrize();
    let mut v = Mat64::eye(n);
    if n == 0 {
        return EighResult { w: vec![], v };
    }
    let norm = a.frob_norm().max(f64::MIN_POSITIVE);
    let tol = 1e-14 * norm;

    for _sweep in 0..MAX_SWEEPS {
        // off-diagonal magnitude
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a.at(i, j) * a.at(i, j);
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                let apq = a.at(p, q);
                if apq.abs() <= tol / (n as f64) {
                    continue;
                }
                let app = a.at(p, p);
                let aqq = a.at(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // A <- Rᵀ A R  (columns then rows)
                for k in 0..n {
                    let akp = a.at(k, p);
                    let akq = a.at(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                for k in 0..n {
                    let apk = a.at(p, k);
                    let aqk = a.at(q, k);
                    a.set(p, k, c * apk - s * aqk);
                    a.set(q, k, s * apk + c * aqk);
                }
                // V <- V R
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }

    let mut w: Vec<f64> = (0..n).map(|i| a.at(i, i)).collect();
    sort_pairs(&mut w, &mut v);
    EighResult { w, v }
}

/// Sort eigenpairs ascending by eigenvalue (columns of v permuted alongside).
fn sort_pairs(w: &mut [f64], v: &mut Mat64) {
    let n = w.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| w[i].partial_cmp(&w[j]).unwrap());
    let wold = w.to_vec();
    let vold = v.clone();
    for (newj, &oldj) in idx.iter().enumerate() {
        w[newj] = wold[oldj];
        for k in 0..n {
            v.set(k, newj, vold.at(k, oldj));
        }
    }
}

// ---------------------------------------------------------------------------
// Fast path: Householder tridiagonalization + implicit-shift QL (EISPACK
// tred2/tql2).  O(4/3 n^3) vs Jacobi's ~O(10 n^3); selected by `eigh` for
// n >= EIGH_TRIDIAG_MIN unless QERA_EIGH=jacobi.
// ---------------------------------------------------------------------------

const EIGH_TRIDIAG_MIN: usize = 3;

/// Householder reduction: A -> tridiagonal (d, e); `a` becomes the
/// accumulated orthogonal transform Q with A = Q T Qᵀ.  (EISPACK tred2.)
fn tred2(a: &mut Mat64, d: &mut [f64], e: &mut [f64]) {
    let n = a.r;
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0f64;
        if l > 0 {
            let mut scale = 0.0f64;
            for k in 0..=l {
                scale += a.at(i, k).abs();
            }
            if scale == 0.0 {
                e[i] = a.at(i, l);
            } else {
                for k in 0..=l {
                    let v = a.at(i, k) / scale;
                    a.set(i, k, v);
                    h += v * v;
                }
                let mut f = a.at(i, l);
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a.set(i, l, f - g);
                f = 0.0;
                for j in 0..=l {
                    a.set(j, i, a.at(i, j) / h);
                    let mut g2 = 0.0f64;
                    for k in 0..=j {
                        g2 += a.at(j, k) * a.at(i, k);
                    }
                    for k in (j + 1)..=l {
                        g2 += a.at(k, j) * a.at(i, k);
                    }
                    e[j] = g2 / h;
                    f += e[j] * a.at(i, j);
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let fj = a.at(i, j);
                    let gj = e[j] - hh * fj;
                    e[j] = gj;
                    for k in 0..=j {
                        let v = a.at(j, k) - (fj * e[k] + gj * a.at(i, k));
                        a.set(j, k, v);
                    }
                }
            }
        } else {
            e[i] = a.at(i, l);
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0f64;
                for k in 0..i {
                    g += a.at(i, k) * a.at(k, j);
                }
                for k in 0..i {
                    let v = a.at(k, j) - g * a.at(k, i);
                    a.set(k, j, v);
                }
            }
        }
        d[i] = a.at(i, i);
        a.set(i, i, 1.0);
        for j in 0..i {
            a.set(j, i, 0.0);
            a.set(i, j, 0.0);
        }
    }
}

/// Implicit-shift QL on a tridiagonal (d, e), rotating the columns of `z`
/// (EISPACK tql2).  Returns false if an eigenvalue fails to converge.
fn tql2(d: &mut [f64], e: &mut [f64], z: &mut Mat64) -> bool {
    let n = d.len();
    if n == 0 {
        return true;
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find small off-diagonal to split
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 64 {
                return false;
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            let sgn = if g >= 0.0 { r } else { -r };
            g = d[m] - d[l] + e[l] / (g + sgn);
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            let mut underflow = false;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                for k in 0..n {
                    f = z.at(k, i + 1);
                    z.set(k, i + 1, s * z.at(k, i) + c * f);
                    z.set(k, i, c * z.at(k, i) - s * f);
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    true
}

/// Tridiagonal fast path; falls back to Jacobi on (rare) non-convergence.
pub fn eigh_tridiag(a_in: &Mat64) -> EighResult {
    assert_eq!(a_in.r, a_in.c);
    let n = a_in.r;
    let mut a = a_in.clone();
    a.symmetrize();
    let mut d = vec![0.0f64; n];
    let mut e = vec![0.0f64; n];
    tred2(&mut a, &mut d, &mut e);
    if !tql2(&mut d, &mut e, &mut a) {
        return eigh_jacobi(a_in);
    }
    let mut w = d;
    sort_pairs(&mut w, &mut a);
    EighResult { w, v: a }
}

/// Symmetric eigendecomposition — dispatches to the fast tridiagonal path
/// (override with `QERA_EIGH=jacobi`).
pub fn eigh(a: &Mat64) -> EighResult {
    let force_jacobi = std::env::var("QERA_EIGH").as_deref() == Ok("jacobi");
    if force_jacobi || a.r < EIGH_TRIDIAG_MIN {
        eigh_jacobi(a)
    } else {
        eigh_tridiag(a)
    }
}

// ---------------------------------------------------------------------------
// Truncated top-k path: blocked subspace iteration + Rayleigh–Ritz.  The
// rank-aware solver fast path ([`super::svd::svd_randomized`]) only ever
// needs the top-k eigenpairs of a (PSD) Gram matrix, which costs O(n²·k·it)
// instead of the full O(n³) decomposition.
// ---------------------------------------------------------------------------

/// When `k` is this fraction of `n` (or `n` is small), a truncated solve
/// stops paying — take the dense decomposition and slice it.
const TOPK_DENSE_MIN_N: usize = 32;
const SUBSPACE_MAX_ITERS: usize = 48;
const SUBSPACE_OVERSAMPLE: usize = 8;

/// Top-`k` eigenpairs of a symmetric matrix, eigenvalues **descending**
/// (unlike [`eigh`], which returns the full ascending spectrum): `w[0]` is
/// the largest eigenvalue and `v` is `n×k` with matching columns.
///
/// Intended for PSD matrices (Gram/autocorrelation): the subspace iteration
/// converges to the largest eigenvalues by magnitude.  Deterministic (the
/// start block is seeded from the shape).  Falls back to the dense
/// decomposition when `k` is a large fraction of `n` or when the iteration
/// fails its residual check, so results are always trustworthy.
pub fn eigh_topk(a: &Mat64, k: usize) -> EighResult {
    eigh_topk_iters(a, k, SUBSPACE_MAX_ITERS)
}

/// [`eigh_topk`] with an explicit cap on the subspace (power) iterations.
/// The cap bounds how long the iteration keeps trying before giving up —
/// accuracy is never traded away: a basis that has not converged fails the
/// residual check and falls back to the dense decomposition, so setting
/// the cap very low on a slowly-decaying spectrum buys the dense cost *on
/// top of* the wasted subspace work.  The convergence check usually stops
/// far before any reasonable cap.
pub fn eigh_topk_iters(a: &Mat64, k: usize, max_iters: usize) -> EighResult {
    assert_eq!(a.r, a.c, "eigh_topk needs a square matrix");
    let n = a.r;
    let k = k.min(n);
    if k == 0 {
        return EighResult { w: vec![], v: Mat64::zeros(n, 0) };
    }
    if n <= TOPK_DENSE_MIN_N || k * 4 >= n {
        return dense_topk(a, k);
    }
    subspace_topk(a, k, max_iters.max(1)).unwrap_or_else(|| dense_topk(a, k))
}

/// Dense decomposition sliced to the top-k pairs (descending).
fn dense_topk(a: &Mat64, k: usize) -> EighResult {
    let e = eigh(a);
    let n = a.r;
    let mut w = Vec::with_capacity(k);
    let mut v = Mat64::zeros(n, k);
    for j in 0..k {
        let src = n - 1 - j;
        w.push(e.w[src]);
        for i in 0..n {
            v.set(i, j, e.v.at(i, src));
        }
    }
    EighResult { w, v }
}

/// Blocked subspace iteration; `None` when the residual check fails.
fn subspace_topk(a: &Mat64, k: usize, max_iters: usize) -> Option<EighResult> {
    let n = a.r;
    let l = (k + SUBSPACE_OVERSAMPLE).min(n);
    let mut rng = crate::util::rng::Rng::new(
        0xE16E_702C ^ ((n as u64) << 20) ^ ((k as u64) << 4),
    );
    let mut q = Mat64::from_vec(n, l, (0..n * l).map(|_| rng.normal()).collect());
    q.orthonormalize_cols();
    let mut prev = vec![f64::INFINITY; k];
    for iter in 0..max_iters {
        let z = a.matmul(&q);
        // Rayleigh quotients diag(Qᵀ A Q) before re-orthonormalizing
        let mut ritz = vec![0.0f64; l];
        for j in 0..l {
            let mut d = 0.0;
            for i in 0..n {
                d += q.a[i * l + j] * z.a[i * l + j];
            }
            ritz[j] = d;
        }
        q = z;
        q.orthonormalize_cols();
        ritz.sort_by(|x, y| y.partial_cmp(x).unwrap());
        let scale = ritz[0].abs().max(f64::MIN_POSITIVE);
        let done = ritz[..k]
            .iter()
            .zip(&prev)
            .all(|(r, p)| (r - p).abs() <= 1e-12 * scale);
        prev.copy_from_slice(&ritz[..k]);
        if done && iter > 0 {
            break;
        }
    }
    // Rayleigh–Ritz on the converged basis
    let az = a.matmul(&q); // [n, l]
    let mut t = q.matmul_tn(&az); // [l, l]
    t.symmetrize();
    let et = eigh(&t); // ascending
    let mut w = Vec::with_capacity(k);
    let mut y = Mat64::zeros(l, k);
    for j in 0..k {
        let src = l - 1 - j;
        w.push(et.w[src]);
        for i in 0..l {
            y.set(i, j, et.v.at(i, src));
        }
    }
    let v = q.matmul(&y); // [n, k]
    // accept only if every eigenpair satisfies A v ≈ w v
    let av = a.matmul(&v);
    let wmax = w[0].abs().max(f64::MIN_POSITIVE);
    for j in 0..k {
        let mut r2 = 0.0f64;
        for i in 0..n {
            let d = av.a[i * k + j] - w[j] * v.a[i * k + j];
            r2 += d * d;
        }
        if r2.sqrt() > 1e-7 * wmax {
            return None;
        }
    }
    Some(EighResult { w, v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_sym(n: usize, seed: u64) -> Mat64 {
        let mut rng = Rng::new(seed);
        let mut a = Mat64::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect());
        a.symmetrize();
        a
    }

    fn rand_psd(n: usize, seed: u64) -> Mat64 {
        let mut rng = Rng::new(seed);
        let b = Mat64::from_vec(n, 2 * n, (0..2 * n * n).map(|_| rng.normal()).collect());
        b.matmul_nt(&b).scale(1.0 / (2 * n) as f64)
    }

    fn check_decomposition(a: &Mat64, r: &EighResult, tol: f64) {
        let n = a.r;
        // A v_i = w_i v_i
        let av = a.matmul(&r.v);
        for j in 0..n {
            for i in 0..n {
                let want = r.w[j] * r.v.at(i, j);
                assert!(
                    (av.at(i, j) - want).abs() < tol,
                    "Av != wv at ({i},{j}): {} vs {want}",
                    av.at(i, j)
                );
            }
        }
        // orthonormal columns
        let vtv = r.v.matmul_tn(&r.v);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv.at(i, j) - want).abs() < tol, "VᵀV not I at ({i},{j})");
            }
        }
        // ascending
        for i in 1..n {
            assert!(r.w[i] >= r.w[i - 1] - 1e-12);
        }
    }

    #[test]
    fn jacobi_diagonal_matrix() {
        let a = Mat64::diag(&[3.0, 1.0, 2.0]);
        let r = eigh_jacobi(&a);
        assert!((r.w[0] - 1.0).abs() < 1e-12);
        assert!((r.w[1] - 2.0).abs() < 1e-12);
        assert!((r.w[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3
        let a = Mat64::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let r = eigh_jacobi(&a);
        assert!((r.w[0] - 1.0).abs() < 1e-12);
        assert!((r.w[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_random_sym() {
        for n in [1, 2, 3, 5, 8, 16, 33] {
            let a = rand_sym(n, n as u64);
            let r = eigh_jacobi(&a);
            check_decomposition(&a, &r, 1e-9);
        }
    }

    #[test]
    fn tridiag_random_sym() {
        for n in [2, 3, 5, 8, 16, 33, 64] {
            let a = rand_sym(n, 100 + n as u64);
            let r = eigh_tridiag(&a);
            check_decomposition(&a, &r, 1e-8);
        }
    }

    #[test]
    fn tridiag_matches_jacobi() {
        for n in [4, 9, 25] {
            let a = rand_psd(n, 7 + n as u64);
            let rj = eigh_jacobi(&a);
            let rt = eigh_tridiag(&a);
            for i in 0..n {
                assert!(
                    (rj.w[i] - rt.w[i]).abs() < 1e-9 * (1.0 + rj.w[i].abs()),
                    "n={n} i={i}: {} vs {}",
                    rj.w[i],
                    rt.w[i]
                );
            }
        }
    }

    #[test]
    fn psd_eigenvalues_nonnegative() {
        let a = rand_psd(12, 3);
        let r = eigh(&a);
        for &w in &r.w {
            assert!(w > -1e-10, "{w}");
        }
    }

    #[test]
    fn trace_preserved() {
        let a = rand_sym(10, 4);
        let tr: f64 = (0..10).map(|i| a.at(i, i)).sum();
        let r = eigh(&a);
        let sum: f64 = r.w.iter().sum();
        assert!((tr - sum).abs() < 1e-9, "{tr} vs {sum}");
    }

    #[test]
    fn rank_deficient() {
        // rank-1 PSD: outer product
        let x = vec![1.0, -2.0, 3.0, 0.5];
        let n = x.len();
        let mut a = Mat64::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a.set(i, j, x[i] * x[j]);
            }
        }
        let r = eigh(&a);
        let norm2: f64 = x.iter().map(|v| v * v).sum();
        assert!((r.w[n - 1] - norm2).abs() < 1e-9);
        for i in 0..n - 1 {
            assert!(r.w[i].abs() < 1e-9);
        }
    }

    #[test]
    fn tridiag_handles_tridiagonal_input() {
        // already-tridiagonal (scale==0 branches in tred2)
        let mut a = Mat64::zeros(5, 5);
        for i in 0..5 {
            a.set(i, i, i as f64 + 1.0);
        }
        for i in 0..4 {
            a.set(i, i + 1, 0.5);
            a.set(i + 1, i, 0.5);
        }
        let r = eigh_tridiag(&a);
        check_decomposition(&a, &r, 1e-9);
    }

    #[test]
    fn identity_eigh() {
        let a = Mat64::eye(6);
        let r = eigh(&a);
        for &w in &r.w {
            assert!((w - 1.0).abs() < 1e-12);
        }
    }

    /// PSD matrix with a controlled decaying spectrum: Q diag(d) Qᵀ.
    fn decaying_psd(n: usize, decay: f64, seed: u64) -> Mat64 {
        let mut rng = Rng::new(seed);
        let mut q = Mat64::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect());
        q.orthonormalize_cols();
        let mut qd = q.clone();
        for j in 0..n {
            let d = decay.powi(j as i32);
            for i in 0..n {
                qd.a[i * n + j] *= d;
            }
        }
        qd.matmul_nt(&q)
    }

    #[test]
    fn topk_dense_path_matches_full() {
        // n small -> dense slice path
        let a = rand_psd(16, 21);
        let full = eigh(&a);
        let top = eigh_topk(&a, 5);
        assert_eq!(top.w.len(), 5);
        assert_eq!((top.v.r, top.v.c), (16, 5));
        for j in 0..5 {
            let want = full.w[15 - j];
            assert!((top.w[j] - want).abs() < 1e-10, "j={j}: {} vs {want}", top.w[j]);
        }
        // descending
        for j in 1..5 {
            assert!(top.w[j] <= top.w[j - 1] + 1e-12);
        }
    }

    #[test]
    fn topk_subspace_matches_full_on_decaying_spectrum() {
        let a = decaying_psd(64, 0.8, 22);
        let k = 6; // 6*4 < 64 and n > 32 -> subspace branch eligible
        let top = eigh_topk(&a, k);
        let full = eigh(&a);
        for j in 0..k {
            let want = full.w[63 - j];
            assert!(
                (top.w[j] - want).abs() < 1e-8 * (1.0 + want.abs()),
                "j={j}: {} vs {want}",
                top.w[j]
            );
        }
        // eigenpair residual + orthonormal columns
        let av = a.matmul(&top.v);
        for j in 0..k {
            let mut r2 = 0.0;
            for i in 0..64 {
                let d = av.at(i, j) - top.w[j] * top.v.at(i, j);
                r2 += d * d;
            }
            assert!(r2.sqrt() < 1e-7 * top.w[0].abs(), "residual j={j}: {}", r2.sqrt());
        }
        let vtv = top.v.matmul_tn(&top.v);
        for i in 0..k {
            for j in 0..k {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv.at(i, j) - want).abs() < 1e-8, "VᵀV ({i},{j})");
            }
        }
    }

    #[test]
    fn topk_deterministic() {
        let a = decaying_psd(48, 0.7, 23);
        let t1 = eigh_topk(&a, 4);
        let t2 = eigh_topk(&a, 4);
        assert_eq!(t1.w, t2.w);
        assert_eq!(t1.v, t2.v);
    }

    #[test]
    fn topk_edge_cases() {
        let a = rand_psd(10, 24);
        let empty = eigh_topk(&a, 0);
        assert!(empty.w.is_empty());
        assert_eq!((empty.v.r, empty.v.c), (10, 0));
        // k >= n clamps to the full (reversed) spectrum
        let all = eigh_topk(&a, 32);
        let full = eigh(&a);
        assert_eq!(all.w.len(), 10);
        for j in 0..10 {
            assert!((all.w[j] - full.w[9 - j]).abs() < 1e-10);
        }
        // zero matrix
        let z = eigh_topk(&Mat64::zeros(40, 40), 3);
        for &w in &z.w {
            assert!(w.abs() < 1e-12);
        }
    }
}
