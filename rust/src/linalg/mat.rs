//! Dense f64 matrix with cache-blocked multiply — the solver workhorse.

use crate::tensor::Tensor;

#[derive(Clone, Debug, PartialEq)]
pub struct Mat64 {
    pub r: usize,
    pub c: usize,
    pub a: Vec<f64>,
}

impl Mat64 {
    pub fn zeros(r: usize, c: usize) -> Self {
        Mat64 { r, c, a: vec![0.0; r * c] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(r: usize, c: usize, a: Vec<f64>) -> Self {
        assert_eq!(r * c, a.len());
        Mat64 { r, c, a }
    }

    pub fn from_tensor(t: &Tensor) -> Self {
        let t2 = t.as_2d();
        Mat64 {
            r: t2.rows(),
            c: t2.cols(),
            a: t2.data().iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(vec![self.r, self.c], self.a.iter().map(|&x| x as f32).collect())
    }

    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.a[i * n + i] = d[i];
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.c + j]
    }
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.c + j] = v;
    }
    pub fn row(&self, i: usize) -> &[f64] {
        &self.a[i * self.c..(i + 1) * self.c]
    }

    pub fn transpose(&self) -> Mat64 {
        let mut out = Mat64::zeros(self.c, self.r);
        for i in 0..self.r {
            for j in 0..self.c {
                out.a[j * self.r + i] = self.a[i * self.c + j];
            }
        }
        out
    }

    /// self [m,k] x other [k,n].  i-k-j order with row streaming.
    pub fn matmul(&self, other: &Mat64) -> Mat64 {
        assert_eq!(self.c, other.r, "matmul dims");
        let (m, k, n) = (self.r, self.c, other.c);
        let mut out = vec![0.0f64; m * n];
        for i in 0..m {
            let arow = &self.a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &other.a[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        Mat64 { r: m, c: n, a: out }
    }

    /// selfᵀ x other:  [k,m]ᵀ... i.e. self is [k,m], other [k,n] -> [m,n].
    pub fn matmul_tn(&self, other: &Mat64) -> Mat64 {
        assert_eq!(self.r, other.r, "matmul_tn dims");
        let (k, m, n) = (self.r, self.c, other.c);
        let mut out = vec![0.0f64; m * n];
        for kk in 0..k {
            let arow = &self.a[kk * m..(kk + 1) * m];
            let brow = &other.a[kk * n..(kk + 1) * n];
            for i in 0..m {
                let av = arow[i];
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        Mat64 { r: m, c: n, a: out }
    }

    /// self x otherᵀ: self [m,k], other [n,k] -> [m,n] (dot products of rows).
    pub fn matmul_nt(&self, other: &Mat64) -> Mat64 {
        assert_eq!(self.c, other.c, "matmul_nt dims");
        let (m, k, n) = (self.r, self.c, other.r);
        let mut out = vec![0.0f64; m * n];
        for i in 0..m {
            let arow = &self.a[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &other.a[j * k..(j + 1) * k];
                let mut s = 0.0;
                for kk in 0..k {
                    s += arow[kk] * brow[kk];
                }
                out[i * n + j] = s;
            }
        }
        Mat64 { r: m, c: n, a: out }
    }

    pub fn add(&self, other: &Mat64) -> Mat64 {
        assert_eq!((self.r, self.c), (other.r, other.c));
        let a = self.a.iter().zip(&other.a).map(|(x, y)| x + y).collect();
        Mat64 { r: self.r, c: self.c, a }
    }

    pub fn sub(&self, other: &Mat64) -> Mat64 {
        assert_eq!((self.r, self.c), (other.r, other.c));
        let a = self.a.iter().zip(&other.a).map(|(x, y)| x - y).collect();
        Mat64 { r: self.r, c: self.c, a }
    }

    pub fn scale(&self, s: f64) -> Mat64 {
        Mat64 { r: self.r, c: self.c, a: self.a.iter().map(|x| x * s).collect() }
    }

    /// Row-scale: diag(d) * self.
    pub fn scale_rows(&self, d: &[f64]) -> Mat64 {
        assert_eq!(d.len(), self.r);
        let mut out = self.clone();
        for i in 0..self.r {
            for j in 0..self.c {
                out.a[i * self.c + j] *= d[i];
            }
        }
        out
    }

    pub fn frob_norm(&self) -> f64 {
        self.a.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.a.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.r != self.c {
            return false;
        }
        for i in 0..self.r {
            for j in (i + 1)..self.c {
                if (self.at(i, j) - self.at(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Symmetrize in place: (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.r, self.c);
        for i in 0..self.r {
            for j in (i + 1)..self.c {
                let v = 0.5 * (self.at(i, j) + self.at(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
    }

    /// First k columns.
    pub fn cols_head(&self, k: usize) -> Mat64 {
        assert!(k <= self.c);
        let mut out = Mat64::zeros(self.r, k);
        for i in 0..self.r {
            out.a[i * k..(i + 1) * k].copy_from_slice(&self.a[i * self.c..i * self.c + k]);
        }
        out
    }

    /// First k rows.
    pub fn rows_head(&self, k: usize) -> Mat64 {
        assert!(k <= self.r);
        Mat64 { r: k, c: self.c, a: self.a[..k * self.c].to_vec() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat64 {
        let mut rng = Rng::new(seed);
        Mat64::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn matmul_identity() {
        let a = randm(4, 4, 0);
        let i = Mat64::eye(4);
        let b = a.matmul(&i);
        for (x, y) in a.a.iter().zip(&b.a) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_variants_agree() {
        let a = randm(5, 7, 1);
        let b = randm(7, 3, 2);
        let c0 = a.matmul(&b);
        let c1 = a.transpose().matmul_tn(&b);
        let c2 = a.matmul_nt(&b.transpose());
        for i in 0..c0.a.len() {
            assert!((c0.a[i] - c1.a[i]).abs() < 1e-12);
            assert!((c0.a[i] - c2.a[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn associativity() {
        let a = randm(3, 4, 3);
        let b = randm(4, 5, 4);
        let c = randm(5, 2, 5);
        let l = a.matmul(&b).matmul(&c);
        let r = a.matmul(&b.matmul(&c));
        for i in 0..l.a.len() {
            assert!((l.a[i] - r.a[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn scale_rows_is_diag_mul() {
        let a = randm(3, 4, 6);
        let d = vec![2.0, -1.0, 0.5];
        let want = Mat64::diag(&d).matmul(&a);
        let got = a.scale_rows(&d);
        for i in 0..want.a.len() {
            assert!((want.a[i] - got.a[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn symmetrize_and_check() {
        let mut a = randm(4, 4, 7);
        assert!(!a.is_symmetric(1e-9));
        a.symmetrize();
        assert!(a.is_symmetric(1e-12));
    }

    #[test]
    fn heads() {
        let a = randm(4, 6, 8);
        let ch = a.cols_head(2);
        assert_eq!((ch.r, ch.c), (4, 2));
        assert_eq!(ch.at(3, 1), a.at(3, 1));
        let rh = a.rows_head(3);
        assert_eq!((rh.r, rh.c), (3, 6));
        assert_eq!(rh.at(2, 5), a.at(2, 5));
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let m = Mat64::from_tensor(&t);
        assert_eq!(m.to_tensor(), t);
    }

    #[test]
    fn frob_and_maxabs() {
        let m = Mat64::from_vec(1, 2, vec![3.0, -4.0]);
        assert!((m.frob_norm() - 5.0).abs() < 1e-12);
        assert_eq!(m.max_abs(), 4.0);
    }
}
