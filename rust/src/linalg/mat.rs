//! Dense f64 matrix — the solver workhorse.
//!
//! The multiply kernels are cache-blocked (k×j tiles of `B` sized to stay
//! L2-resident, with 2 KB row slices streamed through L1) and optionally
//! multi-threaded over contiguous output-row panels via
//! [`crate::util::pool::parallel_chunks_mut`].  Threading only partitions
//! *output rows*; the per-element accumulation order (ascending k) is
//! identical for every worker count and identical to the naive triple loop,
//! so results are bit-exact regardless of `QERA_THREADS` — the pipeline's
//! `parallel_matches_serial` test and the quantized-checkpoint round-trips
//! rely on this.  Nested parallelism is suppressed: a multiply running
//! inside a pool worker (the per-layer solver jobs) stays single-threaded
//! ([`pool::in_pool_worker`]).

use crate::tensor::Tensor;
use crate::util::pool;

/// k×j tile of `B`: 64 × 256 f64 ≈ 128 KB per tile.
const BLOCK_K: usize = 64;
const BLOCK_J: usize = 256;

#[derive(Clone, Debug, PartialEq)]
pub struct Mat64 {
    pub r: usize,
    pub c: usize,
    pub a: Vec<f64>,
}

/// Blocked kernel for one output-row panel: `out[i0..i1, :] += A[i0..i1, :] B`
/// with `A` row-major of row stride `lda` and `out` holding only the panel
/// rows.  Per output element the k-accumulation runs strictly ascending, so
/// the result is independent of the panel split and of the tile sizes.
fn mm_nn_panel(
    a: &[f64],
    lda: usize,
    b: &[f64],
    k: usize,
    n: usize,
    i0: usize,
    i1: usize,
    out: &mut [f64],
) {
    for k0 in (0..k).step_by(BLOCK_K) {
        let k1 = (k0 + BLOCK_K).min(k);
        for j0 in (0..n).step_by(BLOCK_J) {
            let j1 = (j0 + BLOCK_J).min(n);
            for i in i0..i1 {
                let arow = &a[i * lda..i * lda + k];
                let orow = &mut out[(i - i0) * n + j0..(i - i0) * n + j1];
                for kk in k0..k1 {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n + j0..kk * n + j1];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
}

impl Mat64 {
    pub fn zeros(r: usize, c: usize) -> Self {
        Mat64 { r, c, a: vec![0.0; r * c] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(r: usize, c: usize, a: Vec<f64>) -> Self {
        assert_eq!(r * c, a.len());
        Mat64 { r, c, a }
    }

    pub fn from_tensor(t: &Tensor) -> Self {
        let t2 = t.as_2d();
        Mat64 {
            r: t2.rows(),
            c: t2.cols(),
            a: t2.data().iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(vec![self.r, self.c], self.a.iter().map(|&x| x as f32).collect())
    }

    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.a[i * n + i] = d[i];
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.c + j]
    }
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.c + j] = v;
    }
    pub fn row(&self, i: usize) -> &[f64] {
        &self.a[i * self.c..(i + 1) * self.c]
    }

    /// Tiled transpose (32×32 tiles keep both access patterns cache-local).
    pub fn transpose(&self) -> Mat64 {
        const TILE: usize = 32;
        let mut out = Mat64::zeros(self.c, self.r);
        for i0 in (0..self.r).step_by(TILE) {
            let i1 = (i0 + TILE).min(self.r);
            for j0 in (0..self.c).step_by(TILE) {
                let j1 = (j0 + TILE).min(self.c);
                for i in i0..i1 {
                    for j in j0..j1 {
                        out.a[j * self.r + i] = self.a[i * self.c + j];
                    }
                }
            }
        }
        out
    }

    /// self [m,k] x other [k,n], cache-blocked, auto-threaded when large.
    pub fn matmul(&self, other: &Mat64) -> Mat64 {
        self.matmul_workers(other, 0)
    }

    /// [`Mat64::matmul`] with an explicit worker count (`0` = auto).
    /// Bit-identical for every worker count.
    pub fn matmul_workers(&self, other: &Mat64, workers: usize) -> Mat64 {
        assert_eq!(self.c, other.r, "matmul dims");
        let (m, k, n) = (self.r, self.c, other.c);
        let mut out = vec![0.0f64; m * n];
        let w = if workers == 0 {
            pool::matmul_workers(m, m.saturating_mul(k).saturating_mul(n))
        } else {
            workers.max(1).min(m.max(1))
        };
        let rows_per = (m + w - 1) / w.max(1);
        pool::parallel_chunks_mut(&mut out, rows_per * n, w, |ci, chunk| {
            let i0 = ci * rows_per;
            let i1 = i0 + chunk.len() / n.max(1);
            mm_nn_panel(&self.a, k, &other.a, k, n, i0, i1, chunk);
        });
        Mat64 { r: m, c: n, a: out }
    }

    /// selfᵀ x other:  [k,m]ᵀ... i.e. self is [k,m], other [k,n] -> [m,n].
    pub fn matmul_tn(&self, other: &Mat64) -> Mat64 {
        self.matmul_tn_workers(other, 0)
    }

    /// [`Mat64::matmul_tn`] with an explicit worker count (`0` = auto).
    /// Each panel packs its slice of `selfᵀ` contiguously once, then reuses
    /// the blocked NN kernel.
    pub fn matmul_tn_workers(&self, other: &Mat64, workers: usize) -> Mat64 {
        assert_eq!(self.r, other.r, "matmul_tn dims");
        let (k, m, n) = (self.r, self.c, other.c);
        let mut out = vec![0.0f64; m * n];
        let w = if workers == 0 {
            pool::matmul_workers(m, m.saturating_mul(k).saturating_mul(n))
        } else {
            workers.max(1).min(m.max(1))
        };
        let rows_per = (m + w - 1) / w.max(1);
        pool::parallel_chunks_mut(&mut out, rows_per * n, w, |ci, chunk| {
            let i0 = ci * rows_per;
            let rows = chunk.len() / n.max(1);
            let mut apack = vec![0.0f64; rows * k];
            for kk in 0..k {
                let arow = &self.a[kk * m + i0..kk * m + i0 + rows];
                for (r, &v) in arow.iter().enumerate() {
                    apack[r * k + kk] = v;
                }
            }
            mm_nn_panel(&apack, k, &other.a, k, n, 0, rows, chunk);
        });
        Mat64 { r: m, c: n, a: out }
    }

    /// self x otherᵀ: self [m,k], other [n,k] -> [m,n] (dot products of rows).
    pub fn matmul_nt(&self, other: &Mat64) -> Mat64 {
        self.matmul_nt_workers(other, 0)
    }

    /// [`Mat64::matmul_nt`] with an explicit worker count (`0` = auto).
    pub fn matmul_nt_workers(&self, other: &Mat64, workers: usize) -> Mat64 {
        assert_eq!(self.c, other.c, "matmul_nt dims");
        let (m, k, n) = (self.r, self.c, other.r);
        let mut out = vec![0.0f64; m * n];
        let w = if workers == 0 {
            pool::matmul_workers(m, m.saturating_mul(k).saturating_mul(n))
        } else {
            workers.max(1).min(m.max(1))
        };
        let rows_per = (m + w - 1) / w.max(1);
        pool::parallel_chunks_mut(&mut out, rows_per * n, w, |ci, chunk| {
            let i0 = ci * rows_per;
            let rows = chunk.len() / n.max(1);
            for r in 0..rows {
                let arow = &self.a[(i0 + r) * k..(i0 + r + 1) * k];
                let orow = &mut chunk[r * n..(r + 1) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    let brow = &other.a[j * k..(j + 1) * k];
                    let mut s = 0.0;
                    for (x, y) in arow.iter().zip(brow) {
                        s += x * y;
                    }
                    *o = s;
                }
            }
        });
        Mat64 { r: m, c: n, a: out }
    }

    pub fn add(&self, other: &Mat64) -> Mat64 {
        assert_eq!((self.r, self.c), (other.r, other.c));
        let a = self.a.iter().zip(&other.a).map(|(x, y)| x + y).collect();
        Mat64 { r: self.r, c: self.c, a }
    }

    pub fn sub(&self, other: &Mat64) -> Mat64 {
        assert_eq!((self.r, self.c), (other.r, other.c));
        let a = self.a.iter().zip(&other.a).map(|(x, y)| x - y).collect();
        Mat64 { r: self.r, c: self.c, a }
    }

    pub fn scale(&self, s: f64) -> Mat64 {
        Mat64 { r: self.r, c: self.c, a: self.a.iter().map(|x| x * s).collect() }
    }

    /// Row-scale: diag(d) * self.
    pub fn scale_rows(&self, d: &[f64]) -> Mat64 {
        assert_eq!(d.len(), self.r);
        let mut out = self.clone();
        for i in 0..self.r {
            for j in 0..self.c {
                out.a[i * self.c + j] *= d[i];
            }
        }
        out
    }

    /// Overflow/underflow-safe Frobenius norm (LAPACK `dlassq`-style scaled
    /// sum of squares): finite for entries near `f64::MAX` and non-zero for
    /// entries far below `sqrt(f64::MIN_POSITIVE)`.
    pub fn frob_norm(&self) -> f64 {
        let mut scale = 0.0f64;
        let mut ssq = 1.0f64;
        for &x in &self.a {
            if x == 0.0 {
                continue;
            }
            let ax = x.abs();
            if scale < ax {
                let r = scale / ax;
                ssq = 1.0 + ssq * r * r;
                scale = ax;
            } else {
                let r = ax / scale;
                ssq += r * r;
            }
        }
        scale * ssq.sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.a.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.r != self.c {
            return false;
        }
        for i in 0..self.r {
            for j in (i + 1)..self.c {
                if (self.at(i, j) - self.at(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Symmetrize in place: (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.r, self.c);
        for i in 0..self.r {
            for j in (i + 1)..self.c {
                let v = 0.5 * (self.at(i, j) + self.at(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
    }

    /// Orthonormalize the columns in place (modified Gram–Schmidt with one
    /// re-orthogonalization pass — the randomized-SVD range finder's QR
    /// step).  Numerically-dead columns are zeroed, so `selfᵀ self` equals
    /// the identity up to dropped null directions.
    pub fn orthonormalize_cols(&mut self) {
        let (m, l) = (self.r, self.c);
        for j in 0..l {
            // pre-projection norm: the dead-column test must be *relative*
            // (a dependent column leaves ~1e-16·‖col‖ of rounding noise
            // after projection, never an absolute-tiny residual)
            let mut orig2 = 0.0f64;
            for i in 0..m {
                orig2 += self.a[i * l + j] * self.a[i * l + j];
            }
            for _pass in 0..2 {
                for p in 0..j {
                    let mut dot = 0.0f64;
                    for i in 0..m {
                        dot += self.a[i * l + p] * self.a[i * l + j];
                    }
                    if dot != 0.0 {
                        for i in 0..m {
                            let sub = dot * self.a[i * l + p];
                            self.a[i * l + j] -= sub;
                        }
                    }
                }
            }
            let mut nrm2 = 0.0f64;
            for i in 0..m {
                nrm2 += self.a[i * l + j] * self.a[i * l + j];
            }
            let nrm = nrm2.sqrt();
            let floor = 1e-12 * orig2.sqrt().max(f64::MIN_POSITIVE);
            if nrm > floor {
                let inv = 1.0 / nrm;
                for i in 0..m {
                    self.a[i * l + j] *= inv;
                }
            } else {
                for i in 0..m {
                    self.a[i * l + j] = 0.0;
                }
            }
        }
    }

    /// First k columns.
    pub fn cols_head(&self, k: usize) -> Mat64 {
        assert!(k <= self.c);
        let mut out = Mat64::zeros(self.r, k);
        for i in 0..self.r {
            out.a[i * k..(i + 1) * k].copy_from_slice(&self.a[i * self.c..i * self.c + k]);
        }
        out
    }

    /// First k rows.
    pub fn rows_head(&self, k: usize) -> Mat64 {
        assert!(k <= self.r);
        Mat64 { r: k, c: self.c, a: self.a[..k * self.c].to_vec() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(r: usize, c: usize, seed: u64) -> Mat64 {
        let mut rng = Rng::new(seed);
        Mat64::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    /// Naive i-k-j reference with the same ascending-k accumulation order
    /// as the blocked kernel — results must match bit-for-bit.
    fn naive_matmul(a: &Mat64, b: &Mat64) -> Mat64 {
        let (m, k, n) = (a.r, a.c, b.c);
        let mut out = vec![0.0f64; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = a.a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[i * n + j] += av * b.a[kk * n + j];
                }
            }
        }
        Mat64 { r: m, c: n, a: out }
    }

    #[test]
    fn matmul_identity() {
        let a = randm(4, 4, 0);
        let i = Mat64::eye(4);
        let b = a.matmul(&i);
        for (x, y) in a.a.iter().zip(&b.a) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_variants_agree() {
        let a = randm(5, 7, 1);
        let b = randm(7, 3, 2);
        let c0 = a.matmul(&b);
        let c1 = a.transpose().matmul_tn(&b);
        let c2 = a.matmul_nt(&b.transpose());
        for i in 0..c0.a.len() {
            assert!((c0.a[i] - c1.a[i]).abs() < 1e-12);
            assert!((c0.a[i] - c2.a[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn blocked_matches_naive_bitexact_across_block_boundaries() {
        // sizes straddle BLOCK_K/BLOCK_J and panel splits
        for (m, k, n, seed) in [(70, 131, 93, 3), (1, 300, 5, 4), (65, 64, 257, 5)] {
            let a = randm(m, k, seed);
            let b = randm(k, n, seed + 100);
            let want = naive_matmul(&a, &b);
            assert_eq!(a.matmul(&b), want, "{m}x{k}x{n}");
            assert_eq!(a.matmul_workers(&b, 3), want, "{m}x{k}x{n} w=3");
        }
    }

    #[test]
    fn workers_are_bit_identical() {
        let a = randm(70, 90, 6);
        let b = randm(90, 83, 7);
        let serial = a.matmul_workers(&b, 1);
        for w in [2, 3, 4, 8] {
            assert_eq!(serial, a.matmul_workers(&b, w), "matmul w={w}");
        }
        let at = a.transpose();
        let tn1 = at.matmul_tn_workers(&b, 1);
        for w in [2, 4] {
            assert_eq!(tn1, at.matmul_tn_workers(&b, w), "tn w={w}");
        }
        let bt = b.transpose();
        let nt1 = a.matmul_nt_workers(&bt, 1);
        for w in [2, 4] {
            assert_eq!(nt1, a.matmul_nt_workers(&bt, w), "nt w={w}");
        }
    }

    #[test]
    fn large_variants_agree_with_nn() {
        // cross the k-tile boundary in tn/nt too
        let a = randm(40, 150, 8);
        let b = randm(150, 37, 9);
        let c0 = a.matmul(&b);
        let c1 = a.transpose().matmul_tn(&b);
        let c2 = a.matmul_nt(&b.transpose());
        for i in 0..c0.a.len() {
            assert!((c0.a[i] - c1.a[i]).abs() < 1e-10);
            assert!((c0.a[i] - c2.a[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn associativity() {
        let a = randm(3, 4, 3);
        let b = randm(4, 5, 4);
        let c = randm(5, 2, 5);
        let l = a.matmul(&b).matmul(&c);
        let r = a.matmul(&b.matmul(&c));
        for i in 0..l.a.len() {
            assert!((l.a[i] - r.a[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn scale_rows_is_diag_mul() {
        let a = randm(3, 4, 6);
        let d = vec![2.0, -1.0, 0.5];
        let want = Mat64::diag(&d).matmul(&a);
        let got = a.scale_rows(&d);
        for i in 0..want.a.len() {
            assert!((want.a[i] - got.a[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn symmetrize_and_check() {
        let mut a = randm(4, 4, 7);
        assert!(!a.is_symmetric(1e-9));
        a.symmetrize();
        assert!(a.is_symmetric(1e-12));
    }

    #[test]
    fn transpose_involution_odd_sizes() {
        let a = randm(33, 65, 10);
        let t = a.transpose();
        assert_eq!((t.r, t.c), (65, 33));
        assert_eq!(t.at(64, 32), a.at(32, 64));
        assert_eq!(t.transpose(), a);
    }

    #[test]
    fn heads() {
        let a = randm(4, 6, 8);
        let ch = a.cols_head(2);
        assert_eq!((ch.r, ch.c), (4, 2));
        assert_eq!(ch.at(3, 1), a.at(3, 1));
        let rh = a.rows_head(3);
        assert_eq!((rh.r, rh.c), (3, 6));
        assert_eq!(rh.at(2, 5), a.at(2, 5));
    }

    #[test]
    fn tensor_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let m = Mat64::from_tensor(&t);
        assert_eq!(m.to_tensor(), t);
    }

    #[test]
    fn frob_and_maxabs() {
        let m = Mat64::from_vec(1, 2, vec![3.0, -4.0]);
        assert!((m.frob_norm() - 5.0).abs() < 1e-12);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn frob_norm_survives_extreme_magnitudes() {
        // entries whose squares overflow f64 (naive sum-of-squares -> inf)
        let big = f64::MAX.sqrt() * 8.0;
        let m = Mat64::from_vec(1, 2, vec![big, -big]);
        let got = m.frob_norm();
        assert!(got.is_finite());
        assert!((got / big - std::f64::consts::SQRT_2).abs() < 1e-12, "{got}");
        // entries whose squares underflow to zero (naive -> 0)
        let tiny = 1e-200f64;
        let m2 = Mat64::from_vec(2, 1, vec![tiny, tiny]);
        let got2 = m2.frob_norm();
        assert!(got2 > 0.0);
        assert!((got2 / tiny - std::f64::consts::SQRT_2).abs() < 1e-12, "{got2}");
        // zero matrix still reports exactly zero
        assert_eq!(Mat64::zeros(3, 3).frob_norm(), 0.0);
    }

    #[test]
    fn orthonormalize_cols_gives_orthonormal_basis() {
        let mut q = randm(20, 6, 11);
        q.orthonormalize_cols();
        let qtq = q.matmul_tn(&q);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.at(i, j) - want).abs() < 1e-10, "({i},{j}) {}", qtq.at(i, j));
            }
        }
    }

    #[test]
    fn orthonormalize_cols_zeroes_dependent_columns() {
        // column 2 duplicates column 0 -> must be dropped to zero
        let mut q = Mat64::zeros(5, 3);
        for i in 0..5 {
            let v = (i + 1) as f64;
            q.set(i, 0, v);
            q.set(i, 1, (i as f64).sin() + 2.0);
            q.set(i, 2, v);
        }
        q.orthonormalize_cols();
        for i in 0..5 {
            assert_eq!(q.at(i, 2), 0.0, "row {i}");
        }
        let qtq = q.matmul_tn(&q);
        assert!((qtq.at(0, 0) - 1.0).abs() < 1e-12);
        assert!((qtq.at(1, 1) - 1.0).abs() < 1e-12);
        assert!(qtq.at(0, 1).abs() < 1e-12);
    }
}
