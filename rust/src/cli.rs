//! The `qera` launcher: hand-rolled CLI (clap is not available offline).
//!
//! ```text
//! qera info                               list artifacts + configs
//! qera init      [--model nano --seed 42 --out ckpt.qkpt]  fresh dense ckpt
//! qera pretrain  [--model nano --steps 300 --out ckpt.qkpt ...]
//! qera quantize  [--ckpt x.qkpt --method qera-exact --format mxint4:32 ...]
//! qera eval-ppl  [--ckpt x.qkpt | --qckpt q.qkpt --exec native ...]
//! qera serve     [--qckpt q.qkpt --exec native --prompts 8 ...]
//! qera assumption [--ckpt x.qkpt]         Figure-5 off-diagonal report
//! qera e2e       [--model nano ...]       full pipeline, end to end
//! ```

use crate::budget::{self, BudgetPlan};
use crate::config::ExperimentConfig;
use crate::coordinator::{
    calibrate, calibrate_native, quantize, quantize_streaming_with, CalibResult, PipelineConfig,
    StreamOptions,
};
use crate::data::corpus::Corpus;
use crate::model::{Checkpoint, ModelSpec};
use crate::runtime::{ExecBackend, NativeModel, Registry};
use crate::solver::Method;
use crate::train::{pretrain, PretrainConfig};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed `--key value` arguments.
pub struct Args {
    pub cmd: String,
    pub kv: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            bail!("usage: qera <command> [--key value ...]; try `qera help`");
        }
        let cmd = argv[0].clone();
        let mut kv = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --key, got '{}'", argv[i]))?;
            let v = argv.get(i + 1).with_context(|| format!("missing value for --{k}"))?;
            kv.insert(k.to_string(), v.clone());
            i += 2;
        }
        Ok(Args { cmd, kv })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => bail!("--{key} must be true/false, got '{v}'"),
            None => Ok(default),
        }
    }

    /// Fold recognized keys into an [`ExperimentConfig`].
    pub fn to_config(&self) -> Result<ExperimentConfig> {
        let mut cfg = match self.get("config") {
            Some(path) => ExperimentConfig::load(path)?,
            None => ExperimentConfig::default(),
        };
        for (k, v) in &self.kv {
            if k == "config"
                || k == "ckpt"
                || k == "qckpt"
                || k == "out"
                || k == "artifacts"
                || k == "plan-in"
                || k == "plan-out"
                || k == "exec"
                || k == "prompts"
                || k == "new-tokens"
                || k == "temperature"
                || k == "queue-cap"
                || k == "deadline-ms"
                || k == "drain-ms"
                || k == "shard-layers"
                || k == "resume"
                || k == "metrics-out"
                || k == "trace-out"
            {
                continue;
            }
            cfg.set(k, v)?;
        }
        Ok(cfg)
    }
}

fn registry(args: &Args) -> Result<Registry> {
    match args.get("artifacts") {
        Some(dir) => Registry::open(dir),
        None => Registry::open_default(),
    }
}

/// `--exec` flag, falling back to `QERA_EXEC`, then the stub default.
fn exec_backend(args: &Args) -> Result<ExecBackend> {
    match args.get("exec") {
        Some(s) => ExecBackend::parse(s),
        None => Ok(ExecBackend::from_env()),
    }
}

/// Model spec lookup honoring the backend: the stub route reads the PJRT
/// manifest; native falls back to the builtin table so commands work with
/// no artifacts at all.
fn spec_for(args: &Args, model: &str) -> Result<ModelSpec> {
    match exec_backend(args)? {
        ExecBackend::Native => ModelSpec::builtin(model)
            .with_context(|| format!("unknown builtin model '{model}'")),
        ExecBackend::Stub => Ok(registry(args)?.spec(model)?.clone()),
    }
}

/// Calibrate on the selected backend: native computes the taps in Rust
/// ([`calibrate_native`], artifact-free), stub streams them through the
/// `lm_fwd_taps` PJRT artifact.
fn calibrate_on(
    args: &Args,
    spec: &ModelSpec,
    params: &[crate::tensor::Tensor],
    corpus: &Corpus,
    batches: usize,
    track_rxx: bool,
) -> Result<CalibResult> {
    match exec_backend(args)? {
        ExecBackend::Native => {
            let model = NativeModel::from_dense(spec.clone(), params.to_vec());
            calibrate_native(&model, corpus, batches, track_rxx)
        }
        ExecBackend::Stub => {
            calibrate(&registry(args)?, spec, params, corpus, batches, track_rxx)
        }
    }
}

fn artifact_dir(args: &Args) -> std::path::PathBuf {
    match args.get("artifacts") {
        Some(d) => d.into(),
        None => std::env::var("QERA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()).into(),
    }
}

/// CLI entry point; returns the process exit code.
pub fn main_with_args(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    // observability flags apply to every command: --trace-out enables the
    // span tracer exactly like QERA_TRACE=<path>, and --metrics-out dumps
    // the process-global registry after the command runs
    if let Some(path) = args.get("trace-out") {
        crate::obs::trace::enable_to(path);
    }
    let res = match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        "info" => cmd_info(&args),
        "init" => cmd_init(&args),
        "pretrain" => cmd_pretrain(&args),
        "quantize" => cmd_quantize(&args),
        "eval-ppl" => cmd_eval_ppl(&args),
        "serve" => cmd_serve(&args),
        "assumption" => cmd_assumption(&args),
        "e2e" => cmd_e2e(&args),
        other => bail!("unknown command '{other}'; try `qera help`"),
    };
    // flush/dump even on failure: a failed run's partial telemetry is
    // exactly what an operator wants to look at
    let _ = crate::obs::trace::flush();
    let dumped = match args.get("metrics-out") {
        Some(path) => crate::obs::metrics::global()
            .dump(path)
            .with_context(|| format!("writing --metrics-out {path}")),
        None => Ok(()),
    };
    res.and(dumped)
}

const HELP: &str = "qera — Quantization Error Reconstruction Analysis (ICLR 2025 reproduction)

commands:
  info         list artifacts and model configs in the manifest
  init         write a deterministically-initialized dense checkpoint
  pretrain     pretrain a subject model on the synthetic corpus
  quantize     calibrate + quantize a checkpoint with a chosen method
  eval-ppl     perplexity of a dense or quantized checkpoint
  serve        batched generation server over a checkpoint
  assumption   Figure-5 off-diagonal (Assumption 1) report
  e2e          pretrain -> calibrate -> quantize (all methods) -> eval

common flags: --artifacts DIR --model NAME --method M --format F --rank K
              --svd auto|exact|randomized[:oversample[:power_iters]]
              --psd auto|exact|lowrank[:rank_mult[:power_iters]]
              --corpus-tokens N --calib-batches N --eval-batches N --seed S
              --ckpt PATH --out PATH --config FILE.json
              --exec stub|native   execution backend (or QERA_EXEC env);
                                   native runs the pure-Rust fused path:
                                   quantized linears evaluate straight from
                                   packed blocks, no artifacts needed —
                                   honored uniformly by quantize (calibration
                                   taps), eval-ppl, serve, assumption, e2e

checkpoints: every --ckpt/--qckpt flag accepts a monolithic .qkpt/.qqkpt
              file or a sharded .manifest.json; the format is sniffed, and
              sharded sources load their shards in parallel with per-shard
              sha256 verification
              --shard-layers N  (quantize) write a sharded checkpoint —
                                manifest + one shard per N transformer
                                layers — through the streaming pipeline:
                                load shard -> solve -> pack -> write ->
                                drop, so peak memory is bounded by a few
                                layer groups regardless of model depth
              --resume true     (quantize, with --shard-layers) continue a
                                crashed streaming run: shards recorded in
                                the <out>.journal sidecar are re-verified
                                by sha256 and skipped, and the finished
                                manifest is bit-identical to an uncrashed
                                run; refuses to resume over a journal
                                written under a different config
              QERA_FAULTS env   deterministic I/O fault injection for
                                crash-recovery testing, e.g.
                                'seed=7,enospc@w:shard-002' — entries are
                                kind@op:substr[:count] with kinds
                                torn|flip|enospc|transient|perm

serving (serve): --prompts N --new-tokens N --temperature T  synthetic
              request burst against the serving daemon; with --qckpt and
              --exec native the packed weights serve without dense
              materialization
              --queue-cap N     admission queue bound (default 256); excess
                                submissions are rejected, not buffered
              --deadline-ms N   per-request deadline (0 = none, default);
                                expired work is dropped between decode steps
              --drain-ms N      graceful-drain budget on shutdown
                                (default 5000); unfinished work is shed with
                                a typed outcome

observability: --metrics-out PATH  dump the process-global metrics registry
              (counters, gauges, latency histograms from the quantize,
              serve, calibrate, and retry layers) after the command —
              Prometheus text, or the JSON encoding for .json paths
              --trace-out PATH  record hierarchical timed spans (streaming
              quantize stages, serve batches/restarts/swaps, calibration
              phases, sampled fused matmuls) as a Chrome trace-event file;
              open it in chrome://tracing or https://ui.perfetto.dev
              QERA_TRACE env    same as --trace-out; instrumentation is
              observe-only (bit-identical outputs) and costs one relaxed
              atomic load per site when disabled

budget planning (quantize): --budget-bits B  target avg bits/weight; profiles
              every layer x (format, rank) cell with the closed-form error
              and allocates per-layer precision under the budget
              --alloc uniform|greedy|lagrangian   (default greedy)
              --plan-out PATH   write the BudgetPlan JSON artifact
              --plan-in PATH    execute a saved plan (skips profiling; the
                                plan's method/svd/psd/format/rank override
                                the session flags)";

fn cmd_info(args: &Args) -> Result<()> {
    let reg = registry(args)?;
    println!("artifact dir: {}", reg.dir.display());
    for (name, spec) in &reg.specs {
        println!(
            "config {name}: d={} L={} H={} V={} seq={} batch={} ({:.2}M params)",
            spec.d_model,
            spec.n_layers,
            spec.n_heads,
            spec.vocab,
            spec.seq,
            spec.batch,
            spec.n_params() as f64 / 1e6
        );
    }
    for n in reg.names() {
        println!("  {n}");
    }
    Ok(())
}

/// Deterministically-initialized dense checkpoint — the artifact-free way
/// to get a `--ckpt` for quantize/serve smoke runs (the CI obs-smoke job
/// uses it; pretraining needs PJRT artifacts, init does not).
fn cmd_init(args: &Args) -> Result<()> {
    let cfg = args.to_config()?;
    let spec = ModelSpec::builtin(&cfg.model)
        .with_context(|| format!("unknown builtin model '{}'", cfg.model))?;
    let mut rng = crate::util::rng::Rng::new(cfg.seed);
    let params = crate::model::init::init_params(&spec, &mut rng);
    let out = args.get_or("out", &format!("{}/{}.qkpt", cfg.out_dir, cfg.model));
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    Checkpoint::new(spec, params).save(&out)?;
    println!("initialized {} (seed {}) -> {out}", cfg.model, cfg.seed);
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let cfg = args.to_config()?;
    let reg = registry(args)?;
    let spec = reg.spec(&cfg.model)?.clone();
    let corpus = Corpus::generate(spec.vocab, cfg.corpus_tokens, cfg.seed);
    let pcfg = PretrainConfig {
        steps: cfg.pretrain_steps,
        lr: cfg.pretrain_lr,
        warmup: (cfg.pretrain_steps / 20).max(5),
        seed: cfg.seed,
        log_every: (cfg.pretrain_steps / 10).max(1),
    };
    let (ckpt, report) = pretrain(&reg, &spec, &corpus, &pcfg)?;
    let out = args.get_or("out", &format!("{}/{}.qkpt", cfg.out_dir, cfg.model));
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    ckpt.save(&out)?;
    println!(
        "pretrained {}: final loss {:.4} over {} tokens in {:.1}s -> {out}",
        cfg.model, report.final_loss, report.tokens_seen, report.wall_s
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let cfg = args.to_config()?;
    let ckpt_path = args.get("ckpt").context("--ckpt required")?;
    let shard_layers = args.usize_or("shard-layers", 0)?;
    let resume = args.bool_or("resume", false)?;
    anyhow::ensure!(
        shard_layers > 0 || !resume,
        "--resume only applies to sharded streaming runs; pass --shard-layers N"
    );
    let reader = crate::model::open(ckpt_path)?;
    let spec = reader.spec().clone();
    let corpus = Corpus::generate(spec.vocab, cfg.corpus_tokens, cfg.seed);

    // --plan-in executes a saved plan; --budget-bits profiles + allocates
    // a fresh one (optionally saved via --plan-out)
    let plan_in = match args.get("plan-in") {
        Some(p) => Some(BudgetPlan::load(p)?),
        None => None,
    };
    let method = plan_in.as_ref().map(|p| p.method).unwrap_or(cfg.method);
    let budgeting = plan_in.is_none() && cfg.budget_bits.is_some();
    // calibration, budget profiling, and the in-memory pipeline all need
    // the full dense weights; the pure streaming path never loads them
    let ckpt = if method.needs_stats() || budgeting || shard_layers == 0 {
        Some(reader.into_dense()?)
    } else {
        None
    };
    let calib = if method.needs_stats() || budgeting {
        let c = ckpt.as_ref().expect("calibration loads the dense weights");
        Some(calibrate_on(
            args,
            &c.spec,
            &c.params,
            &corpus,
            cfg.calib_batches,
            method.needs_rxx() || budgeting,
        )?)
    } else {
        None
    };
    let base = PipelineConfig::new(cfg.method, cfg.format, cfg.rank)
        .with_svd(cfg.svd)
        .with_psd(cfg.psd);
    let plan = match (plan_in, cfg.budget_bits) {
        (Some(p), _) => Some(p),
        (None, Some(bits)) => {
            let prof = budget::profile(
                ckpt.as_ref().expect("budget profiling loads the dense weights"),
                calib.as_ref().expect("budget profiling calibrates"),
                &base,
                &budget::CandidateGrid::default_ptq(),
            )?;
            let plan = budget::allocate(&prof, bits, cfg.alloc)?;
            println!(
                "allocated {} plan: {:.3}/{:.3} bits/weight, predicted error {:.4}",
                plan.strategy.name(),
                plan.achieved_bits,
                plan.budget_bits,
                plan.total_error,
            );
            Some(plan)
        }
        (None, None) => None,
    };
    if let Some(out) = args.get("plan-out") {
        match &plan {
            Some(p) => {
                p.save(out)?;
                println!("plan -> {out}");
            }
            None => bail!("--plan-out requires --budget-bits or --plan-in"),
        }
    }
    let pcfg = match plan {
        Some(p) => base.with_plan(p),
        None => base,
    };
    if shard_layers > 0 {
        let out = args.get_or(
            "out",
            &format!("{}/{}-{}.manifest.json", cfg.out_dir, spec.name, method.name()),
        );
        if let Some(dir) = std::path::Path::new(&out).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let opts = StreamOptions { resume, ..Default::default() };
        let sum =
            quantize_streaming_with(ckpt_path, &pcfg, calib.as_ref(), &out, shard_layers, &opts)?;
        println!(
            "quantized {} sites into {} shard(s): payload {:.2} MB, solver {:.1} ms, peak live {:.2} MB -> {}",
            sum.diags.len(),
            sum.n_shards,
            sum.payload_bytes as f64 / 1e6,
            sum.solve_ms_total,
            sum.peak_live_bytes as f64 / 1e6,
            sum.manifest.display(),
        );
        if sum.shards_skipped_resume + sum.io_retries + sum.faults_injected > 0 {
            println!(
                "  recovery: {} shard(s) reused from the resume journal, {} I/O retries, {} faults injected",
                sum.shards_skipped_resume, sum.io_retries, sum.faults_injected,
            );
        }
        return Ok(());
    }
    let ckpt = ckpt.expect("in-memory pipeline keeps the dense checkpoint");
    let qm = quantize(&ckpt, &pcfg, calib.as_ref())?;
    let out = args.get_or(
        "out",
        &format!("{}/{}-{}.qqkpt", cfg.out_dir, spec.name, method.name()),
    );
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    qm.ckpt.save(&out)?;
    match &pcfg.plan {
        Some(p) => println!(
            "quantized with {} ({} plan @ {:.3} bits budget): effective {:.3} bits, payload {:.2} MB, solver {:.1} ms -> {out}",
            p.method.name(),
            p.strategy.name(),
            p.budget_bits,
            qm.effective_bits(),
            qm.ckpt.payload_bytes() as f64 / 1e6,
            qm.solve_ms_total,
        ),
        None => println!(
            "quantized with {} ({}, rank {}, svd {}, psd {}): effective {:.3} bits, payload {:.2} MB, solver {:.1} ms -> {out}",
            cfg.method.name(),
            cfg.format.name(),
            cfg.rank,
            cfg.svd.name(),
            cfg.psd.name(),
            qm.effective_bits(),
            qm.ckpt.payload_bytes() as f64 / 1e6,
            qm.solve_ms_total,
        ),
    }
    Ok(())
}

fn cmd_eval_ppl(args: &Args) -> Result<()> {
    let cfg = args.to_config()?;
    let backend = exec_backend(args)?;
    // native path first: no registry / artifacts needed, and a quantized
    // checkpoint evaluates fused straight from its packed payload
    if backend == ExecBackend::Native {
        let model = if let Some(p) = args.get("qckpt") {
            NativeModel::open_quant(p)?
        } else {
            let p = args.get("ckpt").context("--ckpt or --qckpt required")?;
            let c = crate::model::open(p)?.into_dense()?;
            NativeModel::from_dense(c.spec.clone(), c.params)
        };
        let corpus = Corpus::generate(model.spec.vocab, cfg.corpus_tokens, cfg.seed);
        let (_, val) = corpus.split(0.1);
        let ppl = crate::eval::perplexity_native(&model, &val, cfg.eval_batches)?;
        println!("perplexity: {ppl:.4} (exec native)");
        return Ok(());
    }
    let reg = registry(args)?;
    let (spec, params) = if let Some(p) = args.get("qckpt") {
        let q = crate::model::open(p)?.into_quant()?;
        (q.spec.clone(), q.materialize_merged())
    } else {
        let p = args.get("ckpt").context("--ckpt or --qckpt required")?;
        let c = crate::model::open(p)?.into_dense()?;
        (c.spec.clone(), c.params)
    };
    let corpus = Corpus::generate(spec.vocab, cfg.corpus_tokens, cfg.seed);
    let (_, val) = corpus.split(0.1);
    let ppl = crate::eval::perplexity(&reg, &spec, &params, &val, cfg.eval_batches)?;
    println!("perplexity: {ppl:.4}");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use crate::serve::{Outcome, ServeModel, Server, ServerConfig};
    let cfg = args.to_config()?;
    let backend = exec_backend(args)?;
    // ServeModel::open sniffs dense vs quantized and monolithic vs sharded,
    // so --ckpt and --qckpt both take any checkpoint source
    let path = args
        .get("qckpt")
        .or_else(|| args.get("ckpt"))
        .context("--ckpt or --qckpt required")?;
    let (spec, model) = ServeModel::open(path)?;
    let n_prompts = args.usize_or("prompts", 8)?;
    let new_tokens = args.usize_or("new-tokens", 16)?;
    let temperature: f32 = match args.get("temperature") {
        Some(v) => v.parse().context("--temperature must be a float")?,
        None => 0.0,
    };
    let queue_cap = args.usize_or("queue-cap", 256)?;
    let deadline_ms = args.usize_or("deadline-ms", 0)?;
    let drain_ms = args.usize_or("drain-ms", 5000)?;
    println!(
        "serving {} ({} backend): {n_prompts} prompts x {new_tokens} tokens",
        spec.name,
        backend.name()
    );
    let server = Server::start_model(
        artifact_dir(args),
        spec.clone(),
        model,
        ServerConfig {
            seed: cfg.seed,
            backend,
            queue_cap,
            deadline: (deadline_ms > 0)
                .then(|| std::time::Duration::from_millis(deadline_ms as u64)),
            drain: std::time::Duration::from_millis(drain_ms as u64),
            ..Default::default()
        },
    );
    let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0x5e17e);
    let handles: Vec<_> = (0..n_prompts)
        .map(|i| {
            let len = 1 + rng.below(spec.seq / 2);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(spec.vocab) as i32).collect();
            (i, server.submit(prompt, new_tokens, temperature))
        })
        .collect();
    for (i, h) in handles {
        let h = match h {
            Ok(h) => h,
            Err(e) => {
                println!("  prompt {i}: rejected at admission ({e})");
                continue;
            }
        };
        match h.wait() {
            Outcome::Done(resp) => {
                anyhow::ensure!(
                    resp.tokens.len() == new_tokens,
                    "prompt {i}: got {} tokens, wanted {new_tokens}",
                    resp.tokens.len()
                );
                println!(
                    "  prompt {i}: {} tokens (batch {}, model v{}, queue {:.1} ms, total {:.1} ms)",
                    resp.tokens.len(),
                    resp.batch_size,
                    resp.model_version,
                    resp.queue_ms,
                    resp.total_ms
                );
            }
            Outcome::TimedOut { waited_ms } => {
                println!("  prompt {i}: deadline expired after {waited_ms:.1} ms");
            }
            Outcome::Cancelled => println!("  prompt {i}: cancelled"),
            Outcome::Shed(r) => println!("  prompt {i}: shed ({})", r.name()),
            Outcome::Failed { error, attempts } => {
                println!("  prompt {i}: failed after {attempts} attempt(s): {error}");
            }
        }
    }
    let stats = server.stop()?;
    println!(
        "served {}/{} admitted in {} batches: {:.1} tok/s, queue p50/p95 {:.1}/{:.1} ms, total p50/p95 {:.1}/{:.1} ms",
        stats.requests,
        stats.admitted,
        stats.batches,
        stats.throughput_tok_s(),
        stats.queue_p50_ms(),
        stats.queue_p95_ms(),
        stats.total_p50_ms(),
        stats.total_p95_ms()
    );
    if stats.rejected_at_gate + stats.shed + stats.timed_out + stats.cancelled + stats.errored > 0
    {
        println!(
            "  degraded: {} gate-rejected, {} shed, {} timed out, {} cancelled, {} errored ({} retries, {} engine restarts)",
            stats.rejected_at_gate,
            stats.shed,
            stats.timed_out,
            stats.cancelled,
            stats.errored,
            stats.retries,
            stats.engine_restarts
        );
    }
    if let Some(strategy) = &stats.plan_strategy {
        println!(
            "  plan: {} @ {:.3} bits/weight ({} swaps)",
            strategy,
            stats.plan_bits.unwrap_or(f64::NAN),
            stats.swaps
        );
    }
    Ok(())
}

fn cmd_assumption(args: &Args) -> Result<()> {
    let cfg = args.to_config()?;
    let ckpt = match args.get("ckpt") {
        Some(p) => crate::model::open(p)?.into_dense()?,
        None => {
            // untrained fallback so the command works standalone
            let spec = spec_for(args, &cfg.model)?;
            let params =
                crate::model::init::init_params(&spec, &mut crate::util::rng::Rng::new(cfg.seed));
            Checkpoint::new(spec, params)
        }
    };
    let corpus = Corpus::generate(ckpt.spec.vocab, cfg.corpus_tokens, cfg.seed);
    let calib = calibrate_on(args, &ckpt.spec, &ckpt.params, &corpus, cfg.calib_batches, true)?;
    println!("Assumption 1 diagnostic per site (frobenius mass / per-element):");
    for (name, frob, elem) in calib.offdiag_report() {
        let bar = "#".repeat((elem * 60.0).min(60.0) as usize);
        println!("  {name:<18} frob {frob:.3}  elem {elem:.3} {bar}");
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let cfg = args.to_config()?;
    if exec_backend(args)? == ExecBackend::Native {
        return cmd_e2e_native(args, &cfg);
    }
    let reg = registry(args)?;
    let spec = reg.spec(&cfg.model)?.clone();
    println!("== e2e: {} ({:.2}M params) ==", spec.name, spec.n_params() as f64 / 1e6);

    let corpus = Corpus::generate(spec.vocab, cfg.corpus_tokens, cfg.seed);
    let (train, val) = corpus.split(0.1);

    let pcfg = PretrainConfig {
        steps: cfg.pretrain_steps,
        lr: cfg.pretrain_lr,
        warmup: (cfg.pretrain_steps / 20).max(5),
        seed: cfg.seed,
        log_every: (cfg.pretrain_steps / 10).max(1),
    };
    let (ckpt, report) = pretrain(&reg, &spec, &train, &pcfg)?;
    let base_ppl = crate::eval::perplexity(&reg, &spec, &ckpt.params, &val, cfg.eval_batches)?;
    println!(
        "pretrained: loss {:.4}, val ppl {:.3} ({} steps, {:.1}s)",
        report.final_loss, base_ppl, cfg.pretrain_steps, report.wall_s
    );

    let calib = calibrate(&reg, &spec, &ckpt.params, &train, cfg.calib_batches, true)?;
    let mut table = crate::bench_util::Table::new(
        &format!("e2e {} {} rank {}", spec.name, cfg.format.name(), cfg.rank),
        &["method", "ppl", "delta-vs-bf16", "weight-err", "solver-ms"],
    );
    table.row(vec!["bf16".into(), format!("{base_ppl:.3}"), "0".into(), "0".into(), "0".into()]);
    for method in Method::ptq_grid() {
        let qm = quantize(
            &ckpt,
            &PipelineConfig::new(method, cfg.format, cfg.rank)
                .with_svd(cfg.svd)
                .with_psd(cfg.psd),
            Some(&calib),
        )?;
        let ppl = crate::eval::perplexity(&reg, &spec, &qm.merged, &val, cfg.eval_batches)?;
        let werr: f64 = qm.diags.iter().map(|d| d.weight_error).sum();
        table.row(vec![
            method.name(),
            format!("{ppl:.3}"),
            format!("{:+.3}", ppl - base_ppl),
            format!("{werr:.3}"),
            format!("{:.0}", qm.solve_ms_total),
        ]);
    }
    table.emit(&format!("e2e_{}", spec.name));
    Ok(())
}

/// `e2e` on the native backend — no PJRT artifacts anywhere.  Pretraining
/// needs the gradient artifacts, so the native run starts from `--ckpt`
/// when given (a previously pretrained model, monolithic or sharded) or a
/// deterministic init, then covers calibrate -> quantize (all methods) ->
/// eval entirely in Rust.
fn cmd_e2e_native(args: &Args, cfg: &ExperimentConfig) -> Result<()> {
    let ckpt = match args.get("ckpt") {
        Some(p) => crate::model::open(p)?.into_dense()?,
        None => {
            let spec = spec_for(args, &cfg.model)?;
            let params =
                crate::model::init::init_params(&spec, &mut crate::util::rng::Rng::new(cfg.seed));
            Checkpoint::new(spec, params)
        }
    };
    let spec = ckpt.spec.clone();
    println!(
        "== e2e: {} ({:.2}M params, native exec) ==",
        spec.name,
        spec.n_params() as f64 / 1e6
    );
    let corpus = Corpus::generate(spec.vocab, cfg.corpus_tokens, cfg.seed);
    let (train, val) = corpus.split(0.1);
    let base_model = NativeModel::from_dense(spec.clone(), ckpt.params.clone());
    let base_ppl = crate::eval::perplexity_native(&base_model, &val, cfg.eval_batches)?;
    println!("base: val ppl {base_ppl:.3} (no pretraining on the native path)");

    let calib = calibrate_native(&base_model, &train, cfg.calib_batches, true)?;
    let mut table = crate::bench_util::Table::new(
        &format!("e2e {} {} rank {} (native)", spec.name, cfg.format.name(), cfg.rank),
        &["method", "ppl", "delta-vs-bf16", "weight-err", "solver-ms"],
    );
    table.row(vec!["bf16".into(), format!("{base_ppl:.3}"), "0".into(), "0".into(), "0".into()]);
    for method in Method::ptq_grid() {
        let qm = quantize(
            &ckpt,
            &PipelineConfig::new(method, cfg.format, cfg.rank)
                .with_svd(cfg.svd)
                .with_psd(cfg.psd),
            Some(&calib),
        )?;
        let qmodel = NativeModel::from_quant(&qm.ckpt);
        let ppl = crate::eval::perplexity_native(&qmodel, &val, cfg.eval_batches)?;
        let werr: f64 = qm.diags.iter().map(|d| d.weight_error).sum();
        table.row(vec![
            method.name(),
            format!("{ppl:.3}"),
            format!("{:+.3}", ppl - base_ppl),
            format!("{werr:.3}"),
            format!("{:.0}", qm.solve_ms_total),
        ]);
    }
    table.emit(&format!("e2e_{}_native", spec.name));
    Ok(())
}
