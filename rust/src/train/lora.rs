//! QPEFT (LoRA) fine-tuning drivers — the paper's Tables 1-2 machinery.
//!
//! Initialization is where the methods differ (the paper's point):
//! QLoRA = Gaussian A / zero B on top of `W~`; LoftQ / QERA initialize
//! `(A, B)` from the corresponding solver so fine-tuning starts close to
//! the full-precision model.  Training then runs identically for everyone:
//! grads from `lora_*_step.<cfg>.r<k>`, Adam here.

use super::optimizer::Adam;
use crate::coordinator::CalibResult;
use crate::data::batch::{cls_epoch, lm_batch_random};
use crate::data::corpus::Corpus;
use crate::data::tasks::ClsExample;
use crate::model::{Checkpoint, ModelSpec};
use crate::quant::QFormat;
use crate::runtime::{exec::lm_inputs, Registry, Value};
use crate::solver::{self, Method};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

/// Frozen base + trainable adapters, both in canonical order.
#[derive(Clone)]
pub struct LoraInit {
    pub base: Vec<Tensor>,
    /// `[A, B] * linear_sites` (the HLO trailing-argument order).
    pub lora: Vec<Tensor>,
    pub rank: usize,
}

impl LoraInit {
    /// Merged dense weights `W~ + A B` (for ppl / output-error evaluation).
    pub fn merged(&self, spec: &ModelSpec) -> Vec<Tensor> {
        let mut out = self.base.clone();
        for (i, site) in spec.linear_sites().iter().enumerate() {
            let a = &self.lora[2 * i];
            let b = &self.lora[2 * i + 1];
            out[site.param_idx] = out[site.param_idx].add(&a.matmul(b));
        }
        out
    }
}

/// Build the initialization for a QPEFT method.
///
/// `Method::QloraZero` gives the QLoRA baseline; `Method::Loftq{..}` /
/// `Method::QeraApprox` / `Method::QeraExact` give solver inits;
/// 16-bit LoRA uses `fmt = QFormat::None` with `QloraZero`.
pub fn lora_init(
    ckpt: &Checkpoint,
    method: Method,
    fmt: QFormat,
    rank: usize,
    calib: Option<&CalibResult>,
    seed: u64,
) -> Result<LoraInit> {
    let spec = &ckpt.spec;
    let mut base = ckpt.params.clone();
    let mut lora = Vec::with_capacity(spec.linear_sites().len() * 2);
    for (i, site) in spec.linear_sites().iter().enumerate() {
        let w = &ckpt.params[site.param_idx];
        let stats = calib.map(|c| c.for_site(site));
        let out = solver::solve(method, w, fmt, rank, stats, seed ^ (i as u64) << 8)?;
        base[site.param_idx] = out.w_dq;
        match out.lowrank {
            Some(lr) => {
                ensure!(lr.rank() == rank, "solver returned rank {} != {rank}", lr.rank());
                lora.push(lr.a);
                lora.push(lr.b);
            }
            None => {
                // w-only: zero adapters (still trainable)
                lora.push(Tensor::zeros(vec![site.shape[0], rank]));
                lora.push(Tensor::zeros(vec![rank, site.shape[1]]));
            }
        }
    }
    Ok(LoraInit { base, lora, rank })
}

/// Language-model QPEFT trainer (SlimPajama-analog, Table 2).
pub struct LoraLmTrainer {
    pub spec: ModelSpec,
    pub init: LoraInit,
    opt: Adam,
    pub losses: Vec<f64>,
}

impl LoraLmTrainer {
    pub fn new(spec: ModelSpec, init: LoraInit, lr: f32) -> Self {
        let opt = Adam::new(lr, &init.lora);
        LoraLmTrainer { spec, init, opt, losses: Vec::new() }
    }

    /// Run `steps` optimizer steps on random windows of `corpus`.
    pub fn train(
        &mut self,
        reg: &Registry,
        corpus: &Corpus,
        steps: usize,
        rng: &mut Rng,
    ) -> Result<()> {
        let exec = reg.load(&format!("lora_lm_step.{}.r{}", self.spec.name, self.init.rank))?;
        let shape = [self.spec.batch, self.spec.seq];
        for step in 0..steps {
            let (tokens, targets) = lm_batch_random(corpus, self.spec.batch, self.spec.seq, rng);
            let mut inputs = lm_inputs(&tokens, Some((&targets, &shape)), &shape, &self.init.base);
            inputs.extend(self.init.lora.iter().cloned().map(Value::from));
            let out = exec.run(&inputs)?;
            let loss = out[0].data()[0] as f64;
            ensure!(loss.is_finite(), "lora-lm loss diverged at step {step}");
            self.opt.step(&mut self.init.lora, &out[1..]);
            self.losses.push(loss);
        }
        Ok(())
    }

    pub fn merged(&self) -> Vec<Tensor> {
        self.init.merged(&self.spec)
    }
}

/// Classification QPEFT trainer (GLUE-analog, Table 1).
pub struct LoraClsTrainer {
    pub spec: ModelSpec,
    pub init: LoraInit,
    pub head_w: Tensor,
    pub head_b: Tensor,
    opt: Adam,
    pub losses: Vec<f64>,
}

impl LoraClsTrainer {
    pub fn new(spec: ModelSpec, init: LoraInit, lr: f32, rng: &mut Rng) -> Self {
        let (head_w, head_b) = crate::model::init::init_head(&spec, rng);
        let mut train_set = init.lora.clone();
        train_set.push(head_w.clone());
        train_set.push(head_b.clone());
        let opt = Adam::new(lr, &train_set);
        LoraClsTrainer { spec, init, head_w, head_b, opt, losses: Vec::new() }
    }

    /// One epoch over `data`; returns mean loss.
    pub fn train_epoch(
        &mut self,
        reg: &Registry,
        data: &[ClsExample],
        rng: &mut Rng,
    ) -> Result<f64> {
        let exec = reg.load(&format!("lora_cls_step.{}.r{}", self.spec.name, self.init.rank))?;
        let seq = data[0].tokens.len();
        ensure!(seq == self.spec.seq, "task seq {seq} != spec {}", self.spec.seq);
        let mut sum = 0.0;
        let mut n = 0usize;
        for b in cls_epoch(data, self.spec.batch, rng) {
            let mut inputs: Vec<Value> =
                vec![Value::I32(b.tokens.clone(), vec![self.spec.batch, seq])];
            inputs.push(Value::I32(b.labels.clone(), vec![self.spec.batch]));
            inputs.extend(self.init.base.iter().cloned().map(Value::from));
            inputs.extend(self.init.lora.iter().cloned().map(Value::from));
            inputs.push(Value::from(self.head_w.clone()));
            inputs.push(Value::from(self.head_b.clone()));
            let out = exec.run(&inputs)?;
            let loss = out[0].data()[0] as f64;
            ensure!(loss.is_finite(), "cls loss diverged");
            // grads: lora..., head_w, head_b
            let mut train_params: Vec<Tensor> = self.init.lora.clone();
            train_params.push(self.head_w.clone());
            train_params.push(self.head_b.clone());
            self.opt.step(&mut train_params, &out[1..]);
            self.head_b = train_params.pop().unwrap();
            self.head_w = train_params.pop().unwrap();
            self.init.lora = train_params;
            sum += loss;
            n += 1;
            self.losses.push(loss);
        }
        Ok(sum / n as f64)
    }

    /// Accuracy on `data` via the `cls_fwd` artifact.
    pub fn accuracy(&self, reg: &Registry, data: &[ClsExample]) -> Result<f64> {
        crate::eval::cls_accuracy(
            reg,
            &self.spec,
            &self.init.base,
            &self.init.lora,
            self.init.rank,
            (&self.head_w, &self.head_b),
            data,
        )
    }
}

/// Full fine-tuning baseline (Table 1's "Full FT"): all params + head.
pub struct FullClsTrainer {
    pub spec: ModelSpec,
    pub params: Vec<Tensor>,
    pub head_w: Tensor,
    pub head_b: Tensor,
    opt: Adam,
    pub losses: Vec<f64>,
}

impl FullClsTrainer {
    pub fn new(ckpt: &Checkpoint, lr: f32, rng: &mut Rng) -> Self {
        let (head_w, head_b) = crate::model::init::init_head(&ckpt.spec, rng);
        let mut all = ckpt.params.clone();
        all.push(head_w.clone());
        all.push(head_b.clone());
        let opt = Adam::new(lr, &all);
        FullClsTrainer {
            spec: ckpt.spec.clone(),
            params: ckpt.params.clone(),
            head_w,
            head_b,
            opt,
            losses: Vec::new(),
        }
    }

    pub fn train_epoch(
        &mut self,
        reg: &Registry,
        data: &[ClsExample],
        rng: &mut Rng,
    ) -> Result<f64> {
        let exec = reg.load(&format!("full_cls_step.{}", self.spec.name))?;
        let seq = data[0].tokens.len();
        let mut sum = 0.0;
        let mut n = 0usize;
        for b in cls_epoch(data, self.spec.batch, rng) {
            let mut inputs: Vec<Value> =
                vec![Value::I32(b.tokens.clone(), vec![self.spec.batch, seq])];
            inputs.push(Value::I32(b.labels.clone(), vec![self.spec.batch]));
            inputs.extend(self.params.iter().cloned().map(Value::from));
            inputs.push(Value::from(self.head_w.clone()));
            inputs.push(Value::from(self.head_b.clone()));
            let out = exec.run(&inputs)?;
            let loss = out[0].data()[0] as f64;
            ensure!(loss.is_finite());
            let mut all = self.params.clone();
            all.push(self.head_w.clone());
            all.push(self.head_b.clone());
            self.opt.step(&mut all, &out[1..]);
            self.head_b = all.pop().unwrap();
            self.head_w = all.pop().unwrap();
            self.params = all;
            sum += loss;
            n += 1;
            self.losses.push(loss);
        }
        Ok(sum / n as f64)
    }

    pub fn accuracy(&self, reg: &Registry, data: &[ClsExample]) -> Result<f64> {
        crate::eval::cls_accuracy(
            reg,
            &self.spec,
            &self.params,
            &[],
            0,
            (&self.head_w, &self.head_b),
            data,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::Task;
    use crate::model::init::init_params;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    fn nano_ckpt(seed: u64, spec: &ModelSpec) -> Checkpoint {
        Checkpoint::new(spec.clone(), init_params(spec, &mut Rng::new(seed)))
    }

    #[test]
    fn qera_init_merged_is_closer_than_qlora() {
        // the initialization quality claim, measured in weight space
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let ckpt = nano_ckpt(0, &spec);
        let fmt = QFormat::Mxint { bits: 2, block: 16 };
        let q = lora_init(&ckpt, Method::QloraZero, fmt, 4, None, 1).unwrap();
        let z = lora_init(&ckpt, Method::ZeroQuantV2, fmt, 4, None, 1).unwrap();
        let mq = q.merged(&spec);
        let mz = z.merged(&spec);
        let mut err_q = 0.0;
        let mut err_z = 0.0;
        for site in spec.linear_sites() {
            err_q += mq[site.param_idx].sub(&ckpt.params[site.param_idx]).frob_norm();
            err_z += mz[site.param_idx].sub(&ckpt.params[site.param_idx]).frob_norm();
        }
        assert!(err_z < err_q, "zq {err_z} !< qlora {err_q}");
    }

    #[test]
    fn lora_lm_training_descends() {
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let ckpt = nano_ckpt(1, &spec);
        let corpus = Corpus::generate(spec.vocab, 30_000, 2);
        let fmt = QFormat::Mxint { bits: 4, block: 32 };
        let init = lora_init(&ckpt, Method::QloraZero, fmt, 4, None, 3).unwrap();
        let mut tr = LoraLmTrainer::new(spec.clone(), init, 3e-3);
        tr.train(&reg, &corpus, 25, &mut Rng::new(4)).unwrap();
        let first: f64 = tr.losses[..5].iter().sum::<f64>() / 5.0;
        let last: f64 = tr.losses[tr.losses.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(last < first, "no descent: {first} -> {last}");
    }

    #[test]
    fn lora_cls_learns_majority_task() {
        // A briefly-pretrained backbone (like the paper's pretrained models)
        // + LoRA/head fine-tuning must learn the majority task: loss descends
        // well below chance (ln 2) and accuracy beats chance.
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let corpus = Corpus::generate(spec.vocab, 60_000, 0);
        let pcfg = crate::train::PretrainConfig {
            steps: 80, lr: 2e-3, warmup: 10, seed: 42, log_every: 40,
        };
        let (ckpt, _) = crate::train::pretrain(&reg, &spec, &corpus, &pcfg).unwrap();
        let task = Task::by_name("majority").unwrap();
        let train = task.generate(384, spec.vocab, spec.seq, 10);
        let test = task.generate(128, spec.vocab, spec.seq, 11);
        let fmt = QFormat::Mxint { bits: 4, block: 32 };
        let init = lora_init(&ckpt, Method::ZeroQuantV2, fmt, 4, None, 5).unwrap();
        let mut tr = LoraClsTrainer::new(spec.clone(), init, 3e-3, &mut Rng::new(6));
        let mut rng = Rng::new(7);
        let mut last = f64::NAN;
        for _ in 0..8 {
            last = tr.train_epoch(&reg, &train, &mut rng).unwrap();
        }
        assert!(last < 0.62, "loss did not descend: {last}");
        let acc = tr.accuracy(&reg, &test).unwrap();
        assert!(acc > 0.6, "accuracy {acc}");
    }
}
