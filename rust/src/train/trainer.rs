//! Full-parameter pretraining of the subject models.
//!
//! The repo's experiment subjects are *pretrained in-repo* (DESIGN.md §6):
//! grads come from `pretrain_step.<cfg>`, Adam runs here, and the loss
//! curve + checkpoints are the artifacts every experiment consumes.

use super::optimizer::Adam;
use crate::data::batch::{lm_batch_random, lm_batches};
use crate::data::corpus::Corpus;
use crate::model::{init::init_params, Checkpoint, ModelSpec};
use crate::runtime::{exec::lm_inputs, Registry};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

#[derive(Clone, Debug)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig { steps: 300, lr: 3e-3, warmup: 20, seed: 42, log_every: 50 }
    }
}

#[derive(Clone, Debug)]
pub struct PretrainReport {
    pub losses: Vec<(usize, f64)>,
    pub final_loss: f64,
    pub tokens_seen: usize,
    pub wall_s: f64,
}

/// Pretrain from scratch on `corpus`; returns the checkpoint + loss curve.
pub fn pretrain(
    reg: &Registry,
    spec: &ModelSpec,
    corpus: &Corpus,
    cfg: &PretrainConfig,
) -> Result<(Checkpoint, PretrainReport)> {
    let exec = reg.load(&format!("pretrain_step.{}", spec.name))?;
    let mut rng = Rng::new(cfg.seed);
    let mut params = init_params(spec, &mut rng);
    let mut opt = Adam::new(cfg.lr, &params);
    let shape = [spec.batch, spec.seq];
    let t0 = std::time::Instant::now();
    let mut losses = Vec::new();
    let mut final_loss = f64::NAN;

    for step in 0..cfg.steps {
        // linear warmup then constant (cosine would also be fine at this
        // scale; constant keeps curves easy to compare across methods)
        opt.lr = if step < cfg.warmup {
            cfg.lr * (step + 1) as f32 / cfg.warmup as f32
        } else {
            cfg.lr
        };
        let (tokens, targets) = lm_batch_random(corpus, spec.batch, spec.seq, &mut rng);
        let out = exec.run(&lm_inputs(&tokens, Some((&targets, &shape)), &shape, &params))?;
        let loss = out[0].data()[0] as f64;
        ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        let grads = &out[1..];
        ensure!(grads.len() == params.len(), "grad count mismatch");
        opt.step(&mut params, grads);
        final_loss = loss;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            crate::info!("pretrain[{}] step {step}: loss {loss:.4}", spec.name);
            losses.push((step, loss));
        }
    }

    let report = PretrainReport {
        losses,
        final_loss,
        tokens_seen: cfg.steps * spec.tokens_per_batch(),
        wall_s: t0.elapsed().as_secs_f64(),
    };
    Ok((Checkpoint::new(spec.clone(), params), report))
}

/// Validation loss (mean NLL) over up to `max_batches`.
pub fn validation_loss(
    reg: &Registry,
    spec: &ModelSpec,
    params: &[crate::tensor::Tensor],
    corpus: &Corpus,
    max_batches: usize,
) -> Result<f64> {
    let exec = reg.load(&format!("lm_nll.{}", spec.name))?;
    let shape = [spec.batch, spec.seq];
    let mut total = 0.0;
    let mut count = 0usize;
    for (bi, (tokens, targets)) in lm_batches(corpus, spec.batch, spec.seq).enumerate() {
        if bi >= max_batches {
            break;
        }
        let out = exec.run(&lm_inputs(&tokens, Some((&targets, &shape)), &shape, params))?;
        total += out[0].data().iter().map(|&v| v as f64).sum::<f64>();
        count += out[0].numel();
    }
    ensure!(count > 0);
    Ok(total / count as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    #[test]
    fn short_pretrain_reduces_loss() {
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let corpus = Corpus::generate(spec.vocab, 50_000, 0);
        let cfg = PretrainConfig { steps: 30, lr: 2e-3, warmup: 5, seed: 42, log_every: 10 };
        let (ckpt, report) = pretrain(&reg, &spec, &corpus, &cfg).unwrap();
        let first = report.losses.first().unwrap().1;
        assert!(
            report.final_loss < first - 0.2,
            "no learning: {first} -> {}",
            report.final_loss
        );
        assert_eq!(ckpt.params.len(), spec.param_layout().len());
        // loss should start near ln(vocab) for a uniform-ish init
        assert!((first - (spec.vocab as f64).ln()).abs() < 1.0, "{first}");
    }

    #[test]
    fn validation_loss_consistent_with_ppl() {
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let corpus = Corpus::generate(spec.vocab, 8192, 1);
        let params = crate::model::init::init_params(&spec, &mut Rng::new(0));
        let vl = validation_loss(&reg, &spec, &params, &corpus, 2).unwrap();
        let ppl = crate::eval::perplexity(&reg, &spec, &params, &corpus, 2).unwrap();
        assert!((vl.exp() - ppl).abs() < 1e-6 * ppl);
    }
}
