//! Optimizers over flat `Vec<Tensor>` parameter lists.

use crate::tensor::Tensor;

/// Adam (Kingma & Ba) with optional decoupled weight decay.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f32, params: &[Tensor]) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            m: params.iter().map(|p| Tensor::zeros(p.shape().to_vec())).collect(),
            v: params.iter().map(|p| Tensor::zeros(p.shape().to_vec())).collect(),
            t: 0,
        }
    }

    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for ((p, g), (m, v)) in
            params.iter_mut().zip(grads).zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            assert_eq!(p.shape(), g.shape());
            let pd = p.data_mut();
            let gd = g.data();
            let md = m.data_mut();
            let vd = v.data_mut();
            for i in 0..pd.len() {
                let gi = gd[i];
                md[i] = self.beta1 * md[i] + (1.0 - self.beta1) * gi;
                vd[i] = self.beta2 * vd[i] + (1.0 - self.beta2) * gi * gi;
                let mhat = md[i] / bc1;
                let vhat = vd[i] / bc2;
                let mut upd = mhat / (vhat.sqrt() + self.eps);
                if self.weight_decay > 0.0 {
                    upd += self.weight_decay * pd[i];
                }
                pd[i] -= self.lr * upd;
            }
        }
    }
}

/// SGD with momentum.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    vel: Vec<Tensor>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32, params: &[Tensor]) -> Sgd {
        Sgd {
            lr,
            momentum,
            vel: params.iter().map(|p| Tensor::zeros(p.shape().to_vec())).collect(),
        }
    }

    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        for ((p, g), v) in params.iter_mut().zip(grads).zip(self.vel.iter_mut()) {
            let pd = p.data_mut();
            let gd = g.data();
            let vd = v.data_mut();
            for i in 0..pd.len() {
                vd[i] = self.momentum * vd[i] + gd[i];
                pd[i] -= self.lr * vd[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic bowl: f(x) = 0.5 * ||x - target||².
    fn quad_grads(params: &[Tensor], target: &[f32]) -> Vec<Tensor> {
        params
            .iter()
            .map(|p| {
                let g: Vec<f32> =
                    p.data().iter().zip(target).map(|(&x, &t)| x - t).collect();
                Tensor::new(p.shape().to_vec(), g)
            })
            .collect()
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let target = vec![1.0f32, -2.0, 3.0, 0.5];
        let mut params = vec![Tensor::zeros(vec![4])];
        let mut opt = Adam::new(0.1, &params);
        for _ in 0..500 {
            let grads = quad_grads(&params, &target);
            opt.step(&mut params, &grads);
        }
        for (x, t) in params[0].data().iter().zip(&target) {
            assert!((x - t).abs() < 1e-2, "{x} vs {t}");
        }
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let target = vec![0.7f32, -0.3];
        let mut params = vec![Tensor::zeros(vec![2])];
        let mut opt = Sgd::new(0.05, 0.9, &params);
        for _ in 0..400 {
            let grads = quad_grads(&params, &target);
            opt.step(&mut params, &grads);
        }
        for (x, t) in params[0].data().iter().zip(&target) {
            assert!((x - t).abs() < 1e-3);
        }
    }

    #[test]
    fn adam_bias_correction_first_step() {
        // first step with unit gradient must move by ~lr regardless of betas
        let mut params = vec![Tensor::zeros(vec![1])];
        let mut opt = Adam::new(0.01, &params);
        let grads = vec![Tensor::full(vec![1], 1.0)];
        opt.step(&mut params, &grads);
        let moved = -params[0].data()[0];
        assert!((moved - 0.01).abs() < 1e-4, "{moved}");
    }

    #[test]
    fn weight_decay_shrinks() {
        let mut params = vec![Tensor::full(vec![1], 10.0)];
        let mut opt = Adam::new(0.1, &params);
        opt.weight_decay = 0.1;
        let grads = vec![Tensor::zeros(vec![1])];
        for _ in 0..10 {
            opt.step(&mut params, &grads);
        }
        assert!(params[0].data()[0] < 10.0);
    }
}
