//! Training drivers: full pretraining of the subject models and QPEFT
//! (LoRA) fine-tuning — grads come from the AOT `*_step` artifacts, the
//! optimizer state and update rule live here in Rust.

pub mod optimizer;
pub mod trainer;
pub mod lora;

pub use lora::{LoraClsTrainer, LoraLmTrainer};
pub use optimizer::{Adam, Sgd};
pub use trainer::{pretrain, PretrainConfig, PretrainReport};
