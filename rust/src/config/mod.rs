//! Typed experiment configuration (JSON file + `--key value` overrides).
//!
//! One config drives the whole pipeline: which model, corpus size, the
//! quantization method grid, rank, calibration budget, seeds.  The launcher
//! (`qera` CLI) reads these; benches construct them programmatically.

use crate::budget::AllocStrategy;
use crate::quant::QFormat;
use crate::solver::{Method, PsdBackend, SvdBackend};
use crate::util::json::Json;
use anyhow::{Context, Result};

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Model config name (must exist in the artifact manifest).
    pub model: String,
    /// Corpus size in tokens.
    pub corpus_tokens: usize,
    /// Corpus / experiment seed.
    pub seed: u64,
    /// Quantization method.
    pub method: Method,
    /// Quantization format.
    pub format: QFormat,
    /// Low-rank reconstruction rank.
    pub rank: usize,
    /// SVD backend for the solver (`auto` picks randomized for small ranks).
    pub svd: SvdBackend,
    /// PSD backend for QERA-exact's whitening pair (`auto` picks the
    /// low-rank + diagonal split for small ranks).
    pub psd: PsdBackend,
    /// Memory budget in average bits/weight (low-rank overhead included).
    /// When set, quantization runs from a per-layer budget plan instead of
    /// the single global `(format, rank)` pair.
    pub budget_bits: Option<f64>,
    /// Allocation strategy for the budget plan.
    pub alloc: AllocStrategy,
    /// Calibration batches.
    pub calib_batches: usize,
    /// Pretraining steps for the subject model.
    pub pretrain_steps: usize,
    /// Learning rate for pretraining.
    pub pretrain_lr: f32,
    /// Evaluation batches (ppl / output error).
    pub eval_batches: usize,
    /// Output directory for checkpoints/results.
    pub out_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "nano".into(),
            corpus_tokens: 200_000,
            seed: 42,
            method: Method::QeraExact,
            format: QFormat::Mxint { bits: 4, block: 32 },
            rank: 8,
            svd: SvdBackend::Auto,
            psd: PsdBackend::Auto,
            budget_bits: None,
            alloc: AllocStrategy::Greedy,
            calib_batches: 16,
            pretrain_steps: 300,
            pretrain_lr: 3e-3,
            eval_batches: 16,
            out_dir: "results".into(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_json(j: &Json) -> Result<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        if let Some(v) = j.get("model").and_then(Json::as_str) {
            c.model = v.to_string();
        }
        if let Some(v) = j.get("corpus_tokens").and_then(Json::as_usize) {
            c.corpus_tokens = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_usize) {
            c.seed = v as u64;
        }
        if let Some(v) = j.get("method").and_then(Json::as_str) {
            c.method = Method::parse(v)?;
        }
        if let Some(v) = j.get("format").and_then(Json::as_str) {
            c.format = QFormat::parse(v)?;
        }
        if let Some(v) = j.get("rank").and_then(Json::as_usize) {
            c.rank = v;
        }
        if let Some(v) = j.get("svd").and_then(Json::as_str) {
            c.svd = SvdBackend::parse(v)?;
        }
        if let Some(v) = j.get("psd").and_then(Json::as_str) {
            c.psd = PsdBackend::parse(v)?;
        }
        if let Some(v) = j.get("budget_bits").and_then(Json::as_f64) {
            c.budget_bits = Some(v);
        }
        if let Some(v) = j.get("alloc").and_then(Json::as_str) {
            c.alloc = AllocStrategy::parse(v)?;
        }
        if let Some(v) = j.get("calib_batches").and_then(Json::as_usize) {
            c.calib_batches = v;
        }
        if let Some(v) = j.get("pretrain_steps").and_then(Json::as_usize) {
            c.pretrain_steps = v;
        }
        if let Some(v) = j.get("pretrain_lr").and_then(Json::as_f64) {
            c.pretrain_lr = v as f32;
        }
        if let Some(v) = j.get("eval_batches").and_then(Json::as_usize) {
            c.eval_batches = v;
        }
        if let Some(v) = j.get("out_dir").and_then(Json::as_str) {
            c.out_dir = v.to_string();
        }
        Ok(c)
    }

    pub fn load(path: &str) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Apply one `--key value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "model" => self.model = value.to_string(),
            "corpus-tokens" | "corpus_tokens" => self.corpus_tokens = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "method" => self.method = Method::parse(value)?,
            "format" => self.format = QFormat::parse(value)?,
            "rank" => self.rank = value.parse()?,
            "svd" | "svd-backend" | "svd_backend" => self.svd = SvdBackend::parse(value)?,
            "psd" | "psd-backend" | "psd_backend" => self.psd = PsdBackend::parse(value)?,
            "budget-bits" | "budget_bits" => {
                self.budget_bits = match value {
                    "none" | "off" => None,
                    v => Some(v.parse()?),
                }
            }
            "alloc" | "alloc-strategy" | "alloc_strategy" => {
                self.alloc = AllocStrategy::parse(value)?
            }
            "calib-batches" | "calib_batches" => self.calib_batches = value.parse()?,
            "pretrain-steps" | "pretrain_steps" => self.pretrain_steps = value.parse()?,
            "pretrain-lr" | "pretrain_lr" => self.pretrain_lr = value.parse()?,
            "eval-batches" | "eval_batches" => self.eval_batches = value.parse()?,
            "out-dir" | "out_dir" => self.out_dir = value.to_string(),
            _ => anyhow::bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("corpus_tokens", Json::Num(self.corpus_tokens as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("method", Json::str(self.method.name())),
            ("format", Json::str(self.format.name())),
            ("rank", Json::Num(self.rank as f64)),
            ("svd", Json::str(self.svd.name())),
            ("psd", Json::str(self.psd.name())),
            (
                "budget_bits",
                match self.budget_bits {
                    Some(b) => Json::Num(b),
                    None => Json::Null,
                },
            ),
            ("alloc", Json::str(self.alloc.name())),
            ("calib_batches", Json::Num(self.calib_batches as f64)),
            ("pretrain_steps", Json::Num(self.pretrain_steps as f64)),
            ("pretrain_lr", Json::Num(self.pretrain_lr as f64)),
            ("eval_batches", Json::Num(self.eval_batches as f64)),
            ("out_dir", Json::str(self.out_dir.clone())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig::default();
        c.method = Method::Lqer;
        c.rank = 32;
        let j = c.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.method, Method::Lqer);
        assert_eq!(back.rank, 32);
        assert_eq!(back.model, c.model);
    }

    #[test]
    fn overrides() {
        let mut c = ExperimentConfig::default();
        c.set("method", "lqer").unwrap();
        c.set("rank", "16").unwrap();
        c.set("format", "mxint3:32").unwrap();
        c.set("svd", "randomized:4:1").unwrap();
        c.set("psd", "lowrank:2:16").unwrap();
        c.set("budget-bits", "3.75").unwrap();
        c.set("alloc", "lagrangian").unwrap();
        assert_eq!(c.method, Method::Lqer);
        assert_eq!(c.rank, 16);
        assert!((c.format.avg_bits() - 3.25).abs() < 1e-12);
        assert_eq!(c.svd, SvdBackend::Randomized { oversample: 4, power_iters: 1 });
        assert_eq!(c.psd, PsdBackend::LowRank { rank_mult: 2, power_iters: 16 });
        assert_eq!(c.budget_bits, Some(3.75));
        assert_eq!(c.alloc, AllocStrategy::Lagrangian);
        c.set("budget-bits", "none").unwrap();
        assert_eq!(c.budget_bits, None);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("rank", "not-a-number").is_err());
        assert!(c.set("svd", "bogus").is_err());
        assert!(c.set("psd", "bogus").is_err());
        assert!(c.set("alloc", "bogus").is_err());
        assert!(c.set("budget-bits", "not-a-number").is_err());
    }

    #[test]
    fn svd_backend_roundtrips_through_json() {
        let mut c = ExperimentConfig::default();
        c.svd = SvdBackend::Randomized { oversample: 6, power_iters: 3 };
        c.psd = PsdBackend::LowRank { rank_mult: 3, power_iters: 24 };
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.svd, c.svd);
        assert_eq!(back.psd, c.psd);
        // defaults when absent
        let j = Json::parse(r#"{"model":"small"}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().svd, SvdBackend::Auto);
        assert_eq!(ExperimentConfig::from_json(&j).unwrap().psd, PsdBackend::Auto);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"model":"small"}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "small");
        assert_eq!(c.rank, ExperimentConfig::default().rank);
        assert_eq!(c.budget_bits, None);
        assert_eq!(c.alloc, AllocStrategy::Greedy);
    }

    #[test]
    fn budget_roundtrips_through_json() {
        let mut c = ExperimentConfig::default();
        c.budget_bits = Some(3.25);
        c.alloc = AllocStrategy::Uniform;
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.budget_bits, Some(3.25));
        assert_eq!(back.alloc, AllocStrategy::Uniform);
        // unset budget serializes as null and deserializes as None
        let d = ExperimentConfig::default();
        let back = ExperimentConfig::from_json(&d.to_json()).unwrap();
        assert_eq!(back.budget_bits, None);
    }
}
