//! Supervised serving daemon: the failure-containment layer between the
//! [`super::batcher::Server`] handle and the decode engine.
//!
//! The batcher of earlier revisions was a library loop: unbounded queue, no
//! deadlines, and the first `Engine::step` error killed the serving thread
//! with a `warn_!`, silently dropping every queued reply channel.  This
//! module owns everything that makes `serve/` survive production traffic:
//!
//! * **admission control** — a bounded submission queue ([`Shared`]);
//!   overload is answered with a typed rejection instead of buffering;
//! * **typed outcomes** — every admitted request terminates in exactly one
//!   [`Outcome`] on its reply channel; no client ever hangs forever;
//! * **deadlines + cancellation** — expired or cancelled rows are pruned
//!   before and between decode steps;
//! * **retry with backoff** — a failed batch is retried under
//!   [`RetryPolicy`] (exponential backoff, deterministic jitter from the
//!   server seed) on an engine the [`Supervisor`] re-creates, with capped
//!   restarts; exhausted budgets produce [`Outcome::Failed`] /
//!   [`ShedReason::EngineDead`], never a dropped channel;
//! * **graceful drain** — shutdown stops admitting, finishes or sheds
//!   queued work within a drain deadline, and accounts for every request;
//! * **hot swap** — a control message atomically replaces the engine
//!   between batches; in-flight batches finish on the old model, and the
//!   new model's plan telemetry lands in `ServerStats`.

use super::batcher::{Request, Reservoir, Response, ServerConfig, ServerStats};
use super::engine::Engine;
use crate::model::ModelSpec;
use crate::obs::lazy::Lazy;
use crate::obs::metrics::{self, Counter, Gauge, Histogram};
use crate::obs::trace;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

// Process-global serve metrics (see `crate::obs`).  `ServerStats` stays the
// authoritative per-run record returned by `Server::stop`; these series are
// the registry-side roll-up a `--metrics-out` dump exposes.
static M_QUEUE_DEPTH: Lazy<Gauge> = Lazy::new(|| metrics::gauge("qera_serve_queue_depth", &[]));
static M_BATCHES: Lazy<Counter> = Lazy::new(|| metrics::counter("qera_serve_batches_total", &[]));
static M_RETRIES: Lazy<Counter> = Lazy::new(|| metrics::counter("qera_serve_retries_total", &[]));
static M_RESTARTS: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_engine_restarts_total", &[]));
static M_SWAPS: Lazy<Counter> = Lazy::new(|| metrics::counter("qera_serve_swaps_total", &[]));
static M_OUT_DONE: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_outcomes_total", &[("outcome", "done")]));
static M_OUT_SHED: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_outcomes_total", &[("outcome", "shed")]));
static M_OUT_TIMEOUT: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_outcomes_total", &[("outcome", "timed_out")]));
static M_OUT_CANCELLED: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_outcomes_total", &[("outcome", "cancelled")]));
static M_OUT_FAILED: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_outcomes_total", &[("outcome", "failed")]));
static M_QUEUE_MS: Lazy<Histogram> =
    Lazy::new(|| metrics::histogram("qera_serve_queue_ms", &[], metrics::LATENCY_MS_BUCKETS));
static M_TOTAL_MS: Lazy<Histogram> =
    Lazy::new(|| metrics::histogram("qera_serve_total_ms", &[], metrics::LATENCY_MS_BUCKETS));

/// Why the daemon refused (at the gate) or shed (after admission) a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The bounded submission queue is at `queue_cap`.
    QueueFull,
    /// The server is draining (stop was requested) and admits nothing new.
    Draining,
    /// The engine exhausted its restart budget and no swap has revived it.
    EngineDead,
}

impl ShedReason {
    pub fn name(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::Draining => "draining",
            ShedReason::EngineDead => "engine_dead",
        }
    }
}

/// Synchronous admission failure from `Server::submit` — load shedding is
/// explicit and observable, never an unbounded buffer or a hung channel.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Rejected at the admission gate for the given reason.
    Rejected(ShedReason),
    /// The serving thread is gone (stopped or panicked).
    Dead,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Rejected(r) => write!(f, "request rejected: {}", r.name()),
            SubmitError::Dead => write!(f, "serve daemon is dead"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Terminal state of an admitted request.  The daemon guarantees every
/// admitted request reaches exactly one `Outcome` on its reply channel.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// Generation completed.
    Done(Response),
    /// Admitted but shed before completion (drain deadline, dead engine).
    Shed(ShedReason),
    /// The request's deadline expired before or during decoding.
    TimedOut { waited_ms: f64 },
    /// The client cancelled via [`super::batcher::RequestHandle::cancel`].
    Cancelled,
    /// The batch kept failing after `attempts` tries; `error` is the last
    /// engine error rendered with its full context chain.
    Failed { error: String, attempts: u32 },
}

impl Outcome {
    pub fn is_done(&self) -> bool {
        matches!(self, Outcome::Done(_))
    }

    /// Unwrap the response, converting every non-success into an error.
    pub fn response(self) -> Result<Response> {
        match self {
            Outcome::Done(r) => Ok(r),
            other => anyhow::bail!("request did not complete: {other:?}"),
        }
    }
}

/// Retry/backoff policy for failed batches.  Extracted verbatim to
/// `util::retry` (the storage layer shares it now); re-exported here so
/// daemon callers and the serving API are unchanged, and its backoff
/// sequence stays pinned by `backoff_is_capped_and_deterministic` below.
pub use crate::util::retry::RetryPolicy;

/// Plan provenance surfaced in `ServerStats` — what the budget allocator
/// recorded in the serving checkpoint's meta (PR-5 artifacts).
#[derive(Clone, Debug, Default)]
pub struct PlanTelemetry {
    pub plan_bits: Option<f64>,
    pub plan_strategy: Option<String>,
}

/// The decode surface the daemon drives.  [`Engine`] is the production
/// implementation; tests inject faulty or gated engines through
/// `Server::start_custom` to exercise the supervisor.
pub trait BatchEngine {
    fn spec(&self) -> &ModelSpec;
    fn backend_name(&self) -> &'static str;
    /// One decode step with a per-row temperature (`temperatures.len() ==
    /// contexts.len()`); returns the next token per row.
    fn step(&self, contexts: &[Vec<i32>], temperatures: &[f32], rng: &mut Rng) -> Result<Vec<i32>>;
}

impl BatchEngine for Engine {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn backend_name(&self) -> &'static str {
        Engine::backend_name(self)
    }

    fn step(&self, contexts: &[Vec<i32>], temperatures: &[f32], rng: &mut Rng) -> Result<Vec<i32>> {
        self.step_multi(contexts, temperatures, rng)
    }
}

/// Fault-injection wrapper: fails `step` on the given global call indices
/// (counted across batches and retries).  This is the chaos hook the
/// regression tests use to prove the daemon survives engine failures.
pub struct FaultyEngine {
    inner: Box<dyn BatchEngine>,
    fail_calls: Vec<usize>,
    fail_all: bool,
    calls: std::cell::Cell<usize>,
}

impl FaultyEngine {
    pub fn new(inner: Box<dyn BatchEngine>, fail_calls: Vec<usize>) -> FaultyEngine {
        FaultyEngine { inner, fail_calls, fail_all: false, calls: std::cell::Cell::new(0) }
    }

    /// An engine whose every step fails — the permanent-outage case.
    pub fn always_failing(inner: Box<dyn BatchEngine>) -> FaultyEngine {
        FaultyEngine {
            inner,
            fail_calls: Vec::new(),
            fail_all: true,
            calls: std::cell::Cell::new(0),
        }
    }
}

impl BatchEngine for FaultyEngine {
    fn spec(&self) -> &ModelSpec {
        self.inner.spec()
    }

    fn backend_name(&self) -> &'static str {
        "faulty"
    }

    fn step(&self, contexts: &[Vec<i32>], temperatures: &[f32], rng: &mut Rng) -> Result<Vec<i32>> {
        let n = self.calls.get();
        self.calls.set(n + 1);
        if self.fail_all || self.fail_calls.contains(&n) {
            anyhow::bail!("injected engine fault at step call {n}");
        }
        self.inner.step(contexts, temperatures, rng)
    }
}

/// Builds engines on the serving thread.  The closure itself must be
/// `Send` (it crosses into the daemon thread, and again on hot swap); the
/// engines it produces stay on-thread and need not be.
pub type EngineFactory = Box<dyn FnMut() -> Result<Box<dyn BatchEngine>> + Send>;

/// Admission-control state shared between client handles and the daemon.
#[derive(Default)]
pub(crate) struct Shared {
    /// Admitted requests not yet pulled into a batch (bounded by queue_cap).
    pub(crate) waiting: AtomicUsize,
    /// Set at drain start: the gate rejects everything with `Draining`.
    pub(crate) draining: AtomicBool,
    /// Set when the engine restart budget is exhausted; a successful hot
    /// swap clears it.
    pub(crate) engine_dead: AtomicBool,
    /// Requests rejected at the gate (never admitted), for stats.
    pub(crate) gate_rejections: AtomicUsize,
}

impl Shared {
    /// `waiting` increment mirrored into the `qera_serve_queue_depth`
    /// gauge; returns the pre-increment count (the admission-cap check).
    pub(crate) fn inc_waiting(&self) -> usize {
        M_QUEUE_DEPTH.add(1);
        self.waiting.fetch_add(1, Ordering::AcqRel)
    }

    pub(crate) fn dec_waiting(&self) {
        M_QUEUE_DEPTH.sub(1);
        self.waiting.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Control-plane messages from the `Server` handle to the daemon thread.
pub(crate) enum Msg {
    Req(Request),
    Swap {
        factory: EngineFactory,
        telemetry: PlanTelemetry,
        ack: mpsc::Sender<std::result::Result<(), String>>,
    },
    Stop(mpsc::Sender<ServerStats>),
}

/// Owns the engine lifecycle: lazy (re)builds after step failures, a capped
/// restart budget, and atomic factory replacement on hot swap.
pub(crate) struct Supervisor {
    factory: EngineFactory,
    engine: Option<Box<dyn BatchEngine>>,
    /// Step/build failures since the last successful swap.
    fails: u32,
    max_restarts: u32,
}

impl Supervisor {
    pub(crate) fn new(factory: EngineFactory, max_restarts: u32) -> Supervisor {
        Supervisor { factory, engine: None, fails: 0, max_restarts }
    }

    /// Restart budget exhausted and nothing serving.
    fn dead(&self) -> bool {
        self.engine.is_none() && self.fails > self.max_restarts
    }

    /// True when the next `ensure_built` would be a post-failure rebuild.
    fn pending_restart(&self) -> bool {
        self.engine.is_none() && self.fails > 0
    }

    fn ensure_built(&mut self) -> Result<()> {
        if self.engine.is_some() {
            return Ok(());
        }
        ensure!(
            self.fails <= self.max_restarts,
            "engine restart budget exhausted after {} failure(s)",
            self.fails
        );
        match (self.factory)() {
            Ok(e) => {
                self.engine = Some(e);
                Ok(())
            }
            Err(e) => {
                self.fails += 1;
                Err(e)
            }
        }
    }

    fn note_step_failure(&mut self) {
        self.engine = None;
        self.fails += 1;
    }

    /// Install a new model: build eagerly so a broken swap leaves the old
    /// engine serving; success resets the restart budget.
    fn swap(&mut self, mut factory: EngineFactory) -> Result<()> {
        let engine = factory()?;
        self.factory = factory;
        self.engine = Some(engine);
        self.fails = 0;
        Ok(())
    }

    fn batch_cap(&mut self, cfg: &ServerConfig) -> usize {
        let b = match self.ensure_built() {
            Ok(()) => self.engine.as_ref().map(|e| e.spec().batch).unwrap_or(1),
            Err(_) => 1,
        };
        b.min(cfg.inflight_cap).max(1)
    }
}

fn finish(req: Request, outcome: Outcome, stats: &mut ServerStats) {
    match &outcome {
        Outcome::Done(_) => {}
        Outcome::Shed(_) => {
            stats.shed += 1;
            M_OUT_SHED.inc();
        }
        Outcome::TimedOut { .. } => {
            stats.timed_out += 1;
            M_OUT_TIMEOUT.inc();
        }
        Outcome::Cancelled => {
            stats.cancelled += 1;
            M_OUT_CANCELLED.inc();
        }
        Outcome::Failed { .. } => {
            stats.errored += 1;
            M_OUT_FAILED.inc();
        }
    }
    let _ = req.reply.send(outcome);
}

/// One generation slot of an executing batch.
struct Slot {
    req: Request,
    ctx: Vec<i32>,
    plen: usize,
}

fn complete_done(s: Slot, started: Instant, bsize: usize, version: usize, stats: &mut ServerStats) {
    let resp = Response {
        tokens: s.ctx[s.plen..].to_vec(),
        queue_ms: started.duration_since(s.req.enqueued).as_secs_f64() * 1e3,
        total_ms: s.req.enqueued.elapsed().as_secs_f64() * 1e3,
        batch_size: bsize,
        model_version: version,
    };
    stats.queue_ms.push(resp.queue_ms);
    stats.total_ms.push(resp.total_ms);
    stats.requests += 1;
    stats.tokens_generated += resp.tokens.len();
    M_OUT_DONE.inc();
    M_QUEUE_MS.observe(resp.queue_ms);
    M_TOTAL_MS.observe(resp.total_ms);
    let _ = s.req.reply.send(Outcome::Done(resp));
}

enum BatchRun {
    Done,
    /// The engine failed mid-batch; surviving requests come back for retry.
    Failed { requests: Vec<Request>, error: anyhow::Error },
}

/// Decode one batch to completion.  Rows carry their own temperature, and
/// expired/cancelled rows are pruned before and between decode steps (a
/// retried batch restarts generation from the prompt — tokens only count
/// at completion, so retries never double-count).
fn run_batch(
    engine: &dyn BatchEngine,
    requests: Vec<Request>,
    rng: &mut Rng,
    stats: &mut ServerStats,
    version: usize,
) -> BatchRun {
    let started = Instant::now();
    let mut slots: Vec<Slot> = Vec::with_capacity(requests.len());
    for req in requests {
        if req.cancel.load(Ordering::Acquire) {
            finish(req, Outcome::Cancelled, stats);
        } else if req.deadline.is_some_and(|d| started >= d) {
            let waited = started.duration_since(req.enqueued).as_secs_f64() * 1e3;
            finish(req, Outcome::TimedOut { waited_ms: waited }, stats);
        } else {
            let ctx = req.prompt.clone();
            slots.push(Slot { plen: ctx.len(), ctx, req });
        }
    }
    // zero-token requests complete immediately without a decode step
    let mut i = 0;
    while i < slots.len() {
        if slots[i].req.max_new_tokens == 0 {
            let s = slots.remove(i);
            complete_done(s, started, 1, version, stats);
        } else {
            i += 1;
        }
    }
    if slots.is_empty() {
        return BatchRun::Done;
    }
    let bsize = slots.len();
    stats.batches += 1;
    M_BATCHES.inc();
    let _batch_sp = trace::span("serve.batch").attr("size", bsize);
    let max_new = slots.iter().map(|s| s.req.max_new_tokens).max().unwrap_or(0);
    for _ in 0..max_new {
        // prune rows that expired or were cancelled since the last step
        let now = Instant::now();
        let mut i = 0;
        while i < slots.len() {
            let gone = if slots[i].req.cancel.load(Ordering::Acquire) {
                Some(Outcome::Cancelled)
            } else if slots[i].req.deadline.is_some_and(|d| now >= d) {
                let waited = now.duration_since(slots[i].req.enqueued).as_secs_f64() * 1e3;
                Some(Outcome::TimedOut { waited_ms: waited })
            } else {
                None
            };
            match gone {
                Some(out) => {
                    let s = slots.remove(i);
                    finish(s.req, out, stats);
                }
                None => i += 1,
            }
        }
        if slots.is_empty() {
            break;
        }
        let ctxs: Vec<Vec<i32>> = slots.iter().map(|s| s.ctx.clone()).collect();
        let temps: Vec<f32> = slots.iter().map(|s| s.req.temperature).collect();
        let next = match engine.step(&ctxs, &temps, rng) {
            Ok(n) => n,
            Err(error) => {
                let requests = slots.into_iter().map(|s| s.req).collect();
                return BatchRun::Failed { requests, error };
            }
        };
        // append tokens; rows that reached their own budget complete now
        let mut i = 0;
        for t in next {
            slots[i].ctx.push(t);
            if slots[i].ctx.len() - slots[i].plen >= slots[i].req.max_new_tokens {
                let s = slots.remove(i);
                complete_done(s, started, bsize, version, stats);
            } else {
                i += 1;
            }
        }
        if slots.is_empty() {
            break;
        }
    }
    // zero-token requests (max_new_tokens == 0) land here
    for s in slots {
        complete_done(s, started, bsize, version, stats);
    }
    BatchRun::Done
}

/// Run one batch under the supervisor: retry with backoff on engine
/// failures, rebuilding the engine between attempts; exhausted budgets
/// produce typed failures instead of killing the daemon.
#[allow(clippy::too_many_arguments)]
fn execute(
    sup: &mut Supervisor,
    batch: Vec<Request>,
    cfg: &ServerConfig,
    rng: &mut Rng,
    backoff_rng: &mut Rng,
    stats: &mut ServerStats,
    shared: &Shared,
    version: usize,
) {
    let mut requests = batch;
    let mut attempts: u32 = 0;
    loop {
        let restarting = sup.pending_restart();
        let built = {
            // a post-failure rebuild is its own traced span
            let _sp = restarting.then(|| trace::span("serve.restart"));
            sup.ensure_built()
        };
        if let Err(e) = built {
            if sup.dead() {
                shared.engine_dead.store(true, Ordering::Release);
                for r in requests {
                    finish(r, Outcome::Shed(ShedReason::EngineDead), stats);
                }
                return;
            }
            attempts += 1;
            if attempts > cfg.retry.max_retries {
                let error = format!("{e:#}");
                for r in requests {
                    finish(r, Outcome::Failed { error: error.clone(), attempts }, stats);
                }
                return;
            }
            stats.retries += 1;
            M_RETRIES.inc();
            std::thread::sleep(cfg.retry.backoff(attempts - 1, backoff_rng));
            continue;
        }
        if restarting {
            stats.engine_restarts += 1;
            M_RESTARTS.inc();
        }
        let engine = sup.engine.as_deref().expect("ensure_built succeeded");
        match run_batch(engine, requests, rng, stats, version) {
            BatchRun::Done => return,
            BatchRun::Failed { requests: back, error } => {
                sup.note_step_failure();
                attempts += 1;
                if attempts > cfg.retry.max_retries {
                    let error = format!("{error:#}");
                    for r in back {
                        finish(r, Outcome::Failed { error: error.clone(), attempts }, stats);
                    }
                    return;
                }
                stats.retries += 1;
                M_RETRIES.inc();
                std::thread::sleep(cfg.retry.backoff(attempts - 1, backoff_rng));
                requests = back;
            }
        }
    }
}

enum Flow {
    Cont,
    Stop(mpsc::Sender<ServerStats>),
}

#[allow(clippy::too_many_arguments)]
fn handle_msg(
    msg: Msg,
    sup: &mut Supervisor,
    stats: &mut ServerStats,
    queue: &mut VecDeque<Request>,
    shared: &Shared,
    version: &mut usize,
) -> Flow {
    match msg {
        Msg::Req(r) => {
            stats.admitted += 1;
            queue.push_back(r);
            Flow::Cont
        }
        Msg::Swap { factory, telemetry, ack } => {
            let swap_sp = trace::span("serve.swap");
            let res = sup.swap(factory);
            drop(swap_sp);
            match res {
                Ok(()) => {
                    *version += 1;
                    stats.swaps += 1;
                    M_SWAPS.inc();
                    stats.plan_bits = telemetry.plan_bits;
                    stats.plan_strategy = telemetry.plan_strategy;
                    // a working swap revives a daemon whose engine died
                    shared.engine_dead.store(false, Ordering::Release);
                    let _ = ack.send(Ok(()));
                }
                Err(e) => {
                    let _ = ack.send(Err(format!("{e:#}")));
                }
            }
            Flow::Cont
        }
        Msg::Stop(ack) => Flow::Stop(ack),
    }
}

fn pop_batch(
    queue: &mut VecDeque<Request>,
    shared: &Shared,
    cap: usize,
) -> Vec<Request> {
    let take = queue.len().min(cap);
    let mut batch = Vec::with_capacity(take);
    for _ in 0..take {
        let r = queue.pop_front().expect("len checked");
        shared.dec_waiting();
        batch.push(r);
    }
    batch
}

/// Graceful drain: stop admitting, finish queued work within the drain
/// deadline (per-request deadlines clamped to it), shed the rest, and
/// report fully-accounted stats to the stopper.
#[allow(clippy::too_many_arguments)]
fn drain(
    sup: &mut Supervisor,
    cfg: &ServerConfig,
    queue: &mut VecDeque<Request>,
    rx: &mpsc::Receiver<Msg>,
    rng: &mut Rng,
    backoff_rng: &mut Rng,
    stats: &mut ServerStats,
    shared: &Shared,
    version: usize,
    t0: Instant,
    ack: mpsc::Sender<ServerStats>,
) {
    shared.draining.store(true, Ordering::Release);
    let mut late_acks: Vec<mpsc::Sender<ServerStats>> = Vec::new();
    // absorb the channel backlog that beat the draining flag
    while let Ok(msg) = rx.try_recv() {
        match msg {
            Msg::Req(r) => {
                stats.admitted += 1;
                queue.push_back(r);
            }
            Msg::Swap { ack, .. } => {
                let _ = ack.send(Err("server is draining".into()));
            }
            Msg::Stop(a) => late_acks.push(a),
        }
    }
    let drain_deadline = Instant::now() + cfg.drain;
    // every remaining request must finish by the drain deadline
    for r in queue.iter_mut() {
        r.deadline = Some(match r.deadline {
            Some(d) => d.min(drain_deadline),
            None => drain_deadline,
        });
    }
    while !queue.is_empty() && Instant::now() < drain_deadline {
        let cap = sup.batch_cap(cfg);
        let batch = pop_batch(queue, shared, cap);
        execute(sup, batch, cfg, rng, backoff_rng, stats, shared, version);
    }
    while let Some(r) = queue.pop_front() {
        shared.dec_waiting();
        finish(r, Outcome::Shed(ShedReason::Draining), stats);
    }
    // a submit may have raced past the gate after the backlog sweep
    while let Ok(msg) = rx.try_recv() {
        match msg {
            Msg::Req(r) => {
                stats.admitted += 1;
                shared.dec_waiting();
                finish(r, Outcome::Shed(ShedReason::Draining), stats);
            }
            Msg::Swap { ack, .. } => {
                let _ = ack.send(Err("server is draining".into()));
            }
            Msg::Stop(a) => late_acks.push(a),
        }
    }
    stats.wall_s = t0.elapsed().as_secs_f64();
    stats.rejected_at_gate = shared.gate_rejections.load(Ordering::Acquire);
    for a in late_acks {
        let _ = a.send(stats.clone());
    }
    let _ = ack.send(stats.clone());
}

/// The daemon thread body.  Never exits on an engine error: it either
/// serves, degrades to typed failures, or drains and reports.
pub(crate) fn daemon_loop(
    mut sup: Supervisor,
    cfg: ServerConfig,
    telemetry: PlanTelemetry,
    rx: mpsc::Receiver<Msg>,
    shared: Arc<Shared>,
) {
    let mut rng = Rng::new(cfg.seed);
    let mut backoff_rng = Rng::new(cfg.seed ^ 0xb0ff_5eed);
    let mut stats = ServerStats {
        plan_bits: telemetry.plan_bits,
        plan_strategy: telemetry.plan_strategy,
        // deterministic reservoirs: same seed, same kept tail samples
        queue_ms: Reservoir::new(Reservoir::DEFAULT_CAP, cfg.seed ^ 0x51e5_e1fe),
        total_ms: Reservoir::new(Reservoir::DEFAULT_CAP, cfg.seed ^ 0x7074_a15e),
        ..ServerStats::default()
    };
    let t0 = Instant::now();
    let mut queue: VecDeque<Request> = VecDeque::new();
    let mut version = 0usize;

    loop {
        // block until there is work (or a control message)
        if queue.is_empty() {
            match rx.recv() {
                Ok(msg) => {
                    if let Flow::Stop(ack) = handle_msg(
                        msg, &mut sup, &mut stats, &mut queue, &shared, &mut version,
                    ) {
                        drain(
                            &mut sup, &cfg, &mut queue, &rx, &mut rng, &mut backoff_rng,
                            &mut stats, &shared, version, t0, ack,
                        );
                        return;
                    }
                }
                Err(_) => {
                    // every Server handle dropped without stop(): shed what
                    // is queued so no reply channel dangles, then exit
                    shared.draining.store(true, Ordering::Release);
                    while let Some(r) = queue.pop_front() {
                        shared.dec_waiting();
                        finish(r, Outcome::Shed(ShedReason::Draining), &mut stats);
                    }
                    return;
                }
            }
            continue;
        }
        // fill the batch within the wait window
        let wait_deadline = Instant::now() + cfg.max_wait;
        let cap = sup.batch_cap(&cfg);
        while queue.len() < cap {
            let now = Instant::now();
            if now >= wait_deadline {
                break;
            }
            match rx.recv_timeout(wait_deadline - now) {
                Ok(msg) => {
                    if let Flow::Stop(ack) = handle_msg(
                        msg, &mut sup, &mut stats, &mut queue, &shared, &mut version,
                    ) {
                        drain(
                            &mut sup, &cfg, &mut queue, &rx, &mut rng, &mut backoff_rng,
                            &mut stats, &shared, version, t0, ack,
                        );
                        return;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let batch = pop_batch(&mut queue, &shared, cap);
        execute(
            &mut sup, batch, &cfg, &mut rng, &mut backoff_rng, &mut stats, &shared, version,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_capped_and_deterministic() {
        let p = RetryPolicy {
            max_retries: 5,
            base: Duration::from_millis(10),
            factor: 2.0,
            max: Duration::from_millis(50),
            jitter: 0.5,
        };
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for attempt in 0..6 {
            let da = p.backoff(attempt, &mut a);
            let db = p.backoff(attempt, &mut b);
            assert_eq!(da, db, "same seed, same jitter");
            // cap (50ms) times max jitter factor (1.5)
            assert!(da <= Duration::from_millis(75), "attempt {attempt}: {da:?}");
        }
        // jitter 0: exact exponential, capped
        let p0 = RetryPolicy { jitter: 0.0, ..p };
        let mut r = Rng::new(0);
        assert_eq!(p0.backoff(0, &mut r), Duration::from_millis(10));
        assert_eq!(p0.backoff(1, &mut r), Duration::from_millis(20));
        assert_eq!(p0.backoff(4, &mut r), Duration::from_millis(50));
    }

    #[test]
    fn supervisor_caps_restarts_and_swap_resets() {
        // a factory that always fails to build
        let factory: EngineFactory = Box::new(|| anyhow::bail!("no engine"));
        let mut sup = Supervisor::new(factory, 1);
        assert!(sup.ensure_built().is_err()); // fails = 1
        assert!(!sup.dead());
        assert!(sup.ensure_built().is_err()); // fails = 2 > max_restarts
        assert!(sup.dead());
        // budget exhausted: ensure_built refuses without calling the factory
        assert!(sup.ensure_built().is_err());
        // a swap with a working factory revives it
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = crate::model::init::init_params(&spec, &mut Rng::new(3));
        let good: EngineFactory = Box::new(move || {
            Ok(Box::new(Engine::new_native(spec.clone(), params.clone())?) as Box<dyn BatchEngine>)
        });
        sup.swap(good).unwrap();
        assert!(!sup.dead());
        assert!(sup.ensure_built().is_ok());
    }

    #[test]
    fn outcome_response_unwraps_only_done() {
        let r = Response {
            tokens: vec![1, 2],
            queue_ms: 0.5,
            total_ms: 1.0,
            batch_size: 1,
            model_version: 0,
        };
        assert_eq!(Outcome::Done(r).response().unwrap().tokens, vec![1, 2]);
        assert!(Outcome::Cancelled.response().is_err());
        assert!(Outcome::Shed(ShedReason::QueueFull).response().is_err());
        assert!(Outcome::TimedOut { waited_ms: 3.0 }.response().is_err());
        let f = Outcome::Failed { error: "x".into(), attempts: 2 };
        assert!(!f.is_done());
        assert!(f.response().is_err());
    }

    #[test]
    fn shed_reason_names() {
        assert_eq!(ShedReason::QueueFull.name(), "queue_full");
        assert_eq!(
            SubmitError::Rejected(ShedReason::Draining).to_string(),
            "request rejected: draining"
        );
        assert_eq!(SubmitError::Dead.to_string(), "serve daemon is dead");
    }
}
