//! Generation engine: greedy / temperature sampling with full-context
//! recompute per step (the decode-cache variant is a roadmap item recorded
//! in DESIGN.md §9), over either execution backend:
//!
//! * **Artifact** — the `lm_logits_last.<cfg>` PJRT route.  Parameters are
//!   `Rc`-wrapped once at construction, so steady-state decode builds its
//!   input list with refcount bumps — zero parameter copies per step.
//! * **Native** — [`NativeModel`]: the pure-Rust forward, running quantized
//!   linears fused straight from packed blocks (no artifacts needed).

use crate::model::{ModelSpec, QuantCheckpoint};
use crate::runtime::{exec::lm_inputs, NativeModel, Registry};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::rc::Rc;

enum Backend {
    Artifact { exec: Rc<crate::runtime::Exec>, params: Vec<Rc<Tensor>> },
    Native(NativeModel),
}

pub struct Engine {
    pub spec: ModelSpec,
    backend: Backend,
}

impl Engine {
    /// Artifact-backed engine (`lm_logits_last.<cfg>` must exist in `reg`).
    pub fn new(reg: &Registry, spec: ModelSpec, params: Vec<Tensor>) -> Result<Engine> {
        ensure!(params.len() == spec.param_layout().len());
        let exec = reg.load(&format!("lm_logits_last.{}", spec.name))?;
        let params = params.into_iter().map(Rc::new).collect();
        Ok(Engine { spec, backend: Backend::Artifact { exec, params } })
    }

    /// Native engine over dense parameters — no artifact registry needed.
    pub fn new_native(spec: ModelSpec, params: Vec<Tensor>) -> Result<Engine> {
        ensure!(params.len() == spec.param_layout().len());
        let model = NativeModel::from_dense(spec.clone(), params);
        Ok(Engine { spec, backend: Backend::Native(model) })
    }

    /// Native engine straight from a quantized checkpoint: packed sites
    /// decode in-register inside the fused matmul, never materializing
    /// dense f32 weights.
    pub fn new_native_quant(q: &QuantCheckpoint) -> Engine {
        let model = NativeModel::from_quant(q);
        Engine { spec: q.spec.clone(), backend: Backend::Native(model) }
    }

    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            Backend::Artifact { .. } => "stub",
            Backend::Native(_) => "native",
        }
    }

    /// Right-align `ctx` into a fixed window of length `seq` (left-pad with
    /// token 0; the synthetic vocabulary treats 0 as an ordinary token).
    fn window(&self, ctx: &[i32]) -> Vec<i32> {
        let s = self.spec.seq;
        let mut w = vec![0i32; s];
        let take = ctx.len().min(s);
        w[s - take..].copy_from_slice(&ctx[ctx.len() - take..]);
        w
    }

    /// One decode step for up to `batch` contexts; returns the next token
    /// per slot.  `temperature <= 0` = greedy.
    pub fn step(&self, contexts: &[Vec<i32>], temperature: f32, rng: &mut Rng) -> Result<Vec<i32>> {
        let temps = vec![temperature; contexts.len()];
        self.step_multi(contexts, &temps, rng)
    }

    /// One decode step with a per-row temperature — a mixed batch can hold
    /// greedy and sampled requests side by side without one request's
    /// sampling settings leaking onto its batch-mates.  Greedy rows consume
    /// no RNG draws, so a greedy row's token stream is independent of who
    /// it shares a batch with.
    pub fn step_multi(
        &self,
        contexts: &[Vec<i32>],
        temperatures: &[f32],
        rng: &mut Rng,
    ) -> Result<Vec<i32>> {
        let b = self.spec.batch;
        ensure!(!contexts.is_empty() && contexts.len() <= b, "bad batch size");
        ensure!(temperatures.len() == contexts.len(), "one temperature per context required");
        let mut tokens = Vec::with_capacity(b * self.spec.seq);
        for i in 0..b {
            let ctx = &contexts[i.min(contexts.len() - 1)];
            tokens.extend(self.window(ctx));
        }
        let s = self.spec.seq;
        let logits = match &self.backend {
            Backend::Artifact { exec, params } => {
                let mut out = exec.run(&lm_inputs(&tokens, None, &[b, s], params))?;
                out.remove(0)
            }
            Backend::Native(model) => model.logits_last(&tokens, b, s),
        }; // [B, V]
        let v = self.spec.vocab;
        let mut next = Vec::with_capacity(contexts.len());
        for i in 0..contexts.len() {
            let row = &logits.data()[i * v..(i + 1) * v];
            let temperature = temperatures[i];
            let tok = if temperature <= 0.0 {
                let mut best = 0;
                for j in 1..v {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best
            } else {
                // softmax sampling with temperature
                let maxl = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
                let weights: Vec<f64> =
                    row.iter().map(|&x| (((x - maxl) / temperature) as f64).exp()).collect();
                rng.categorical(&weights)
            };
            next.push(tok as i32);
        }
        Ok(next)
    }

    /// Generate `n_new` tokens for each prompt (batched internally).
    pub fn generate(
        &self,
        prompts: &[Vec<i32>],
        n_new: usize,
        temperature: f32,
        rng: &mut Rng,
    ) -> Result<Vec<Vec<i32>>> {
        let mut outputs: Vec<Vec<i32>> = prompts.to_vec();
        for chunk_start in (0..prompts.len()).step_by(self.spec.batch) {
            let chunk_end = (chunk_start + self.spec.batch).min(prompts.len());
            for _ in 0..n_new {
                let ctxs: Vec<Vec<i32>> = outputs[chunk_start..chunk_end].to_vec();
                let next = self.step(&ctxs, temperature, rng)?;
                for (i, t) in next.into_iter().enumerate() {
                    outputs[chunk_start + i].push(t);
                }
            }
        }
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    fn native_engine(name: &str, seed: u64) -> Engine {
        let spec = ModelSpec::builtin(name).unwrap();
        let params = init_params(&spec, &mut Rng::new(seed));
        Engine::new_native(spec, params).unwrap()
    }

    #[test]
    fn native_greedy_generation_deterministic() {
        // artifact-free: the native backend serves without a registry
        let engine = native_engine("nano", 0);
        assert_eq!(engine.backend_name(), "native");
        let prompts = vec![vec![1i32, 2, 3], vec![7i32, 8]];
        let a = engine.generate(&prompts, 5, 0.0, &mut Rng::new(1)).unwrap();
        let b = engine.generate(&prompts, 5, 0.0, &mut Rng::new(2)).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 8);
        assert_eq!(a[1].len(), 7);
        let v = engine.spec.vocab as i32;
        assert!(a.iter().flatten().all(|&t| (0..v).contains(&t)));
    }

    #[test]
    fn native_sampled_generation_in_vocab() {
        let engine = native_engine("micro", 4);
        let out = engine.generate(&[vec![1, 2]], 10, 0.8, &mut Rng::new(5)).unwrap();
        assert_eq!(out[0].len(), 12);
        assert!(out[0].iter().all(|&t| (0..engine.spec.vocab as i32).contains(&t)));
    }

    #[test]
    fn step_multi_isolates_greedy_rows_from_sampled_neighbors() {
        // a greedy row must produce the same token whether its batch-mate
        // samples or not — per-row temperature, and greedy rows consume no
        // RNG state
        let engine = native_engine("micro", 9);
        let greedy_ctx = vec![vec![1i32, 2, 3]];
        let solo = engine.step_multi(&greedy_ctx, &[0.0], &mut Rng::new(1)).unwrap();
        let mixed_ctx = vec![vec![1i32, 2, 3], vec![5i32, 6]];
        let mixed = engine.step_multi(&mixed_ctx, &[0.0, 1.2], &mut Rng::new(1)).unwrap();
        assert_eq!(mixed[0], solo[0]);
        let v = engine.spec.vocab as i32;
        assert!((0..v).contains(&mixed[1]));
        // temperature-count mismatch is a typed error, not a panic
        assert!(engine.step_multi(&mixed_ctx, &[0.0], &mut Rng::new(1)).is_err());
    }

    #[test]
    fn native_quant_engine_generates() {
        use crate::model::Checkpoint;
        use crate::quant::QFormat;
        use crate::solver::Method;
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut Rng::new(6));
        let ckpt = Checkpoint::new(spec, params);
        let cfg = crate::coordinator::PipelineConfig::new(
            Method::WOnly,
            QFormat::Mxint { bits: 4, block: 32 },
            0,
        );
        let qm = crate::coordinator::quantize(&ckpt, &cfg, None).unwrap();
        let engine = Engine::new_native_quant(&qm.ckpt);
        let out = engine.generate(&[vec![3, 1]], 6, 0.0, &mut Rng::new(7)).unwrap();
        assert_eq!(out[0].len(), 8);
        assert!(out[0].iter().all(|&t| (0..engine.spec.vocab as i32).contains(&t)));
    }

    #[test]
    fn greedy_generation_deterministic() {
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(0));
        let engine = Engine::new(&reg, spec.clone(), params).unwrap();
        let prompts = vec![vec![1i32, 2, 3], vec![7i32, 8]];
        let a = engine.generate(&prompts, 5, 0.0, &mut Rng::new(1)).unwrap();
        let b = engine.generate(&prompts, 5, 0.0, &mut Rng::new(2)).unwrap();
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 8);
        assert_eq!(a[1].len(), 7);
        assert!(a.iter().flatten().all(|&t| (0..spec.vocab as i32).contains(&t)));
    }

    #[test]
    fn window_right_aligned() {
        // window logic is backend-independent; use the native engine so
        // this runs without artifacts
        let engine = native_engine("nano", 3);
        let spec = engine.spec.clone();
        let w = engine.window(&[5, 6, 7]);
        assert_eq!(w.len(), spec.seq);
        assert_eq!(&w[spec.seq - 3..], &[5, 6, 7]);
        assert!(w[..spec.seq - 3].iter().all(|&t| t == 0));
        // overlong context keeps the tail
        let long: Vec<i32> = (0..(spec.seq as i32 + 10)).collect();
        let w2 = engine.window(&long);
        assert_eq!(w2[0], 10);
        assert_eq!(w2[spec.seq - 1], spec.seq as i32 + 9);
    }

    #[test]
    fn sampled_generation_in_vocab() {
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(4));
        let engine = Engine::new(&reg, spec.clone(), params).unwrap();
        let out = engine
            .generate(&[vec![1, 2]], 10, 0.8, &mut Rng::new(5))
            .unwrap();
        assert_eq!(out[0].len(), 12);
        assert!(out[0].iter().all(|&t| (0..spec.vocab as i32).contains(&t)));
    }
}
