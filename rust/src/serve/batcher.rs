//! Request router + dynamic batcher (the vLLM-router-shaped piece), now a
//! supervised daemon front-end.
//!
//! Clients submit prompts from any thread through a **bounded admission
//! gate**; a dedicated serving thread owns the engine (PJRT handles are not
//! `Send`), drains the queue into batches of up to `spec.batch` requests
//! within a `max_wait` window, decodes step-locked batches with per-row
//! temperatures, and completes each request with a typed
//! [`Outcome`](super::daemon::Outcome) — success, timeout, cancellation,
//! shed, or failure — so no reply channel ever dangles.  The daemon layer
//! ([`super::daemon`]) adds retry-with-backoff, capped engine restarts,
//! graceful drain, and hot model swap; [`ServerStats`] accounts for every
//! admitted request and carries the serving plan's telemetry.

use super::daemon::{
    daemon_loop, EngineFactory, Msg, Outcome, PlanTelemetry, RetryPolicy, Shared, ShedReason,
    SubmitError, Supervisor,
};
use super::engine::Engine;
use crate::model::{CkptKind, ModelSpec, QuantCheckpoint};
use crate::obs::lazy::Lazy;
use crate::obs::metrics::{self, Counter};
use crate::runtime::ExecBackend;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::cell::OnceCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

// Admission-gate metrics: submissions the daemon never sees (gate
// rejections) are counted here, on the client side of the gate.
static M_ADMITTED: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_admitted_total", &[]));
static M_REJECTED_FULL: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_gate_rejected_total", &[("reason", "queue_full")]));
static M_REJECTED_DRAINING: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_gate_rejected_total", &[("reason", "draining")]));
static M_REJECTED_DEAD: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_serve_gate_rejected_total", &[("reason", "engine_dead")]));

/// Weights handed to the serving thread.
pub enum ServeModel {
    /// Dense parameter list in canonical order.
    Dense(Vec<crate::tensor::Tensor>),
    /// Quantized checkpoint; with [`ExecBackend::Native`] it serves fused
    /// straight from the packed payload (the stub route materializes the
    /// merged dense weights, since PJRT artifacts take f32 inputs).
    Quant(Box<QuantCheckpoint>),
}

impl ServeModel {
    /// Open a checkpoint for serving — dense or quantized, monolithic or a
    /// sharded manifest, sniffed by [`crate::model::open`] — returning the
    /// spec alongside the wrapped weights.  Sharded sources load their
    /// shards in parallel on the worker pool with per-shard sha256
    /// verification; a corrupt or truncated shard fails here, before the
    /// daemon thread ever starts (and, on the [`Server::swap_model`] path,
    /// before the old model stops serving).
    pub fn open(path: impl AsRef<std::path::Path>) -> Result<(ModelSpec, ServeModel)> {
        let reader = crate::model::open(path)?;
        match reader.kind() {
            CkptKind::Dense => {
                let c = reader.into_dense()?;
                Ok((c.spec.clone(), ServeModel::Dense(c.params)))
            }
            CkptKind::Quant => {
                let q = reader.into_quant()?;
                Ok((q.spec.clone(), ServeModel::Quant(Box::new(q))))
            }
        }
    }

    /// Plan provenance recorded by the budget allocator, if any — surfaced
    /// in [`ServerStats`] so operators can see which plan is serving.
    pub fn telemetry(&self) -> PlanTelemetry {
        match self {
            ServeModel::Dense(_) => PlanTelemetry::default(),
            ServeModel::Quant(q) => {
                let (plan_bits, plan_strategy) = q.plan_telemetry();
                PlanTelemetry { plan_bits, plan_strategy }
            }
        }
    }
}

/// One admitted generation request as the daemon sees it.
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub temperature: f32,
    /// Absolute completion deadline; rows past it are pruned between steps.
    pub(crate) deadline: Option<Instant>,
    pub(crate) enqueued: Instant,
    pub(crate) cancel: Arc<AtomicBool>,
    pub(crate) reply: mpsc::Sender<Outcome>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub tokens: Vec<i32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub batch_size: usize,
    /// Increments on every hot swap: which model generation served this.
    pub model_version: usize,
}

/// Per-request options for [`Server::submit_with`].
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestOpts {
    pub max_new_tokens: usize,
    pub temperature: f32,
    /// Relative deadline; `None` falls back to `ServerConfig::deadline`.
    pub deadline: Option<Duration>,
}

/// Client-side handle for one admitted request: await the typed outcome or
/// cancel it.  Waiting never hangs — if the daemon ever dropped the reply
/// channel (a bug, or a stop racing a submit), the wait maps to a
/// [`Outcome::Failed`] instead of blocking forever.
#[derive(Debug)]
pub struct RequestHandle {
    rx: mpsc::Receiver<Outcome>,
    cancel: Arc<AtomicBool>,
}

impl RequestHandle {
    /// Ask the daemon to drop this request at the next prune point.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Release);
    }

    fn dropped() -> Outcome {
        Outcome::Failed { error: "serve daemon dropped the reply channel".into(), attempts: 0 }
    }

    /// Block until the request reaches its terminal outcome.
    pub fn wait(&self) -> Outcome {
        self.rx.recv().unwrap_or_else(|_| Self::dropped())
    }

    /// Like [`RequestHandle::wait`] with a local patience bound; `None`
    /// means the request is still in flight.
    pub fn wait_timeout(&self, d: Duration) -> Option<Outcome> {
        match self.rx.recv_timeout(d) {
            Ok(o) => Some(o),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => Some(Self::dropped()),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    pub seed: u64,
    /// Execution backend; [`ExecBackend::Native`] serves without artifacts.
    pub backend: ExecBackend,
    /// Bound on admitted-but-not-yet-batched requests; submissions beyond
    /// it are rejected with [`ShedReason::QueueFull`].
    pub queue_cap: usize,
    /// Cap on requests decoded in one batch, on top of `spec.batch`.
    pub inflight_cap: usize,
    /// Default per-request deadline applied when a request carries none.
    pub deadline: Option<Duration>,
    /// Graceful-drain budget for [`Server::stop`]: queued work that cannot
    /// finish within it is shed with [`ShedReason::Draining`].
    pub drain: Duration,
    pub retry: RetryPolicy,
    /// Engine re-creations allowed after failures before the daemon
    /// declares the engine dead (a hot swap resets the budget).
    pub max_restarts: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(5),
            seed: 0,
            backend: ExecBackend::Stub,
            queue_cap: 256,
            inflight_cap: usize::MAX,
            deadline: None,
            drain: Duration::from_secs(5),
            retry: RetryPolicy::default(),
            max_restarts: 2,
        }
    }
}

/// Bounded deterministic latency-sample reservoir (Vitter's Algorithm R).
///
/// `ServerStats` used to keep every per-request latency sample in an
/// unbounded `Vec`, and every percentile accessor cloned and re-sorted it.
/// The reservoir caps memory at `cap` samples — an exact record below the
/// cap, a uniform subsample above it (seeded from the server seed, so runs
/// are reproducible) — and builds the sorted view at most once per
/// snapshot, invalidated on push.  The mean tracks every observation, not
/// just the kept ones.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    /// Total observations, including ones no longer kept.
    seen: u64,
    /// Running sum of every observation — the mean stays exact past the cap.
    sum: f64,
    samples: Vec<f64>,
    rng: Rng,
    /// Sorted view of `samples`, built lazily per snapshot.
    sorted: OnceCell<Vec<f64>>,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir::new(Reservoir::DEFAULT_CAP, 0)
    }
}

impl Reservoir {
    /// Default sample cap: enough for stable tails, bounded forever.
    pub const DEFAULT_CAP: usize = 4096;

    pub fn new(cap: usize, seed: u64) -> Reservoir {
        Reservoir {
            cap: cap.max(1),
            seen: 0,
            sum: 0.0,
            samples: Vec::new(),
            rng: Rng::new(seed),
            sorted: OnceCell::new(),
        }
    }

    /// Test/bench helper: a default reservoir preloaded with `samples`.
    pub fn from_samples(samples: impl IntoIterator<Item = f64>) -> Reservoir {
        let mut r = Reservoir::default();
        for s in samples {
            r.push(s);
        }
        r
    }

    pub fn push(&mut self, v: f64) {
        self.seen += 1;
        self.sum += v;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // Algorithm R: each of the `seen` observations survives with
            // probability cap/seen
            let j = self.rng.below(self.seen as usize);
            if j < self.cap {
                self.samples[j] = v;
            }
        }
        self.sorted = OnceCell::new();
    }

    /// Samples currently kept (equal to [`Reservoir::seen`] below the cap).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Total observations pushed, including ones no longer kept.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    fn sorted(&self) -> &[f64] {
        self.sorted.get_or_init(|| {
            let mut v = self.samples.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v
        })
    }

    /// Nearest-rank percentile over the kept samples (the `bench_util`
    /// convention); 0.0 when empty.
    pub fn pct(&self, p: f64) -> f64 {
        let v = self.sorted();
        if v.is_empty() {
            return 0.0;
        }
        v[((v.len() - 1) as f64 * p) as usize]
    }

    /// Exact mean over every observation ever pushed; 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            return 0.0;
        }
        self.sum / self.seen as f64
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Requests completed successfully.
    pub requests: usize,
    /// Executed batch attempts (retries of a failed batch count again).
    pub batches: usize,
    pub tokens_generated: usize,
    pub wall_s: f64,
    /// Per-request queue latency samples (ms) in a bounded [`Reservoir`] —
    /// the serving bench gates on the tails, not just the means.
    pub queue_ms: Reservoir,
    /// Per-request total latency samples (ms), reservoir-bounded.
    pub total_ms: Reservoir,
    /// Requests accepted past the admission gate.
    pub admitted: usize,
    /// Submissions rejected at the gate (queue full / draining / dead).
    pub rejected_at_gate: usize,
    /// Admitted requests shed before completion (drain deadline, dead
    /// engine).
    pub shed: usize,
    pub timed_out: usize,
    pub cancelled: usize,
    /// Admitted requests completed with a typed failure.
    pub errored: usize,
    /// Batch retry attempts taken after engine failures.
    pub retries: usize,
    /// Engines re-created by the supervisor after a failure.
    pub engine_restarts: usize,
    /// Successful hot model swaps.
    pub swaps: usize,
    /// Budget-plan telemetry of the currently-serving model (None when the
    /// model was not produced by a `BudgetPlan`).
    pub plan_bits: Option<f64>,
    pub plan_strategy: Option<String>,
}

impl ServerStats {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches > 0 {
            self.requests as f64 / self.batches as f64
        } else {
            0.0
        }
    }

    /// Admitted requests that reached a terminal outcome, by kind — the
    /// shutdown-ordering tests assert this sums to `admitted`.
    pub fn accounted(&self) -> usize {
        self.requests + self.shed + self.timed_out + self.cancelled + self.errored
    }

    pub fn queue_mean_ms(&self) -> f64 {
        self.queue_ms.mean()
    }
    pub fn queue_p50_ms(&self) -> f64 {
        self.queue_ms.pct(0.5)
    }
    pub fn queue_p95_ms(&self) -> f64 {
        self.queue_ms.pct(0.95)
    }
    pub fn total_mean_ms(&self) -> f64 {
        self.total_ms.mean()
    }
    pub fn total_p50_ms(&self) -> f64 {
        self.total_ms.pct(0.5)
    }
    pub fn total_p95_ms(&self) -> f64 {
        self.total_ms.pct(0.95)
    }
}

/// Handle for submitting requests; the daemon runs on its own thread.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    shared: Arc<Shared>,
    default_deadline: Option<Duration>,
    queue_cap: usize,
    /// Context for [`Server::swap_model`]; `None` for custom-factory
    /// servers (use [`Server::swap_factory`] there).
    swap_ctx: Option<(std::path::PathBuf, ExecBackend)>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Stats receiver parked by [`Server::begin_stop`], consumed by
    /// [`Server::stop`].
    pending_stats: Option<mpsc::Receiver<ServerStats>>,
}

/// Build the engine factory the supervisor (re)builds engines through.
/// Stub-backend quant models materialize their merged weights once, here,
/// on the caller's thread.
fn make_factory(
    artifact_dir: std::path::PathBuf,
    spec: ModelSpec,
    model: ServeModel,
    backend: ExecBackend,
) -> EngineFactory {
    match (backend, model) {
        (ExecBackend::Stub, model) => {
            let params = match model {
                ServeModel::Dense(p) => p,
                ServeModel::Quant(q) => q.materialize_merged(),
            };
            Box::new(move || {
                let reg = crate::runtime::Registry::open(&artifact_dir)?;
                Ok(Box::new(Engine::new(&reg, spec.clone(), params.clone())?) as _)
            })
        }
        (ExecBackend::Native, ServeModel::Dense(p)) => {
            Box::new(move || Ok(Box::new(Engine::new_native(spec.clone(), p.clone())?) as _))
        }
        (ExecBackend::Native, ServeModel::Quant(q)) => {
            Box::new(move || Ok(Box::new(Engine::new_native_quant(&q)) as _))
        }
    }
}

impl Server {
    /// Start the serving daemon.  `artifact_dir` and the model params are
    /// moved into the thread (PJRT handles are created there).
    pub fn start(
        artifact_dir: std::path::PathBuf,
        spec: ModelSpec,
        params: Vec<crate::tensor::Tensor>,
        cfg: ServerConfig,
    ) -> Server {
        Server::start_model(artifact_dir, spec, ServeModel::Dense(params), cfg)
    }

    /// [`Server::start`] generalized over [`ServeModel`] — quantized
    /// checkpoints serve without dense materialization on the native
    /// backend.
    pub fn start_model(
        artifact_dir: std::path::PathBuf,
        spec: ModelSpec,
        model: ServeModel,
        cfg: ServerConfig,
    ) -> Server {
        let telemetry = model.telemetry();
        let swap_ctx = Some((artifact_dir.clone(), cfg.backend));
        let factory = make_factory(artifact_dir, spec, model, cfg.backend);
        let mut s = Server::start_factory(factory, telemetry, cfg);
        s.swap_ctx = swap_ctx;
        s
    }

    /// Start the daemon over a custom engine factory — the fault-injection
    /// and chaos-test entry point ([`super::daemon::BatchEngine`]).
    pub fn start_custom<F>(cfg: ServerConfig, factory: F) -> Server
    where
        F: FnMut() -> Result<Box<dyn super::daemon::BatchEngine>> + Send + 'static,
    {
        Server::start_factory(Box::new(factory), PlanTelemetry::default(), cfg)
    }

    fn start_factory(
        factory: EngineFactory,
        telemetry: PlanTelemetry,
        cfg: ServerConfig,
    ) -> Server {
        let (tx, rx) = mpsc::channel::<Msg>();
        let shared = Arc::new(Shared::default());
        let shared2 = shared.clone();
        let default_deadline = cfg.deadline;
        let queue_cap = cfg.queue_cap;
        let max_restarts = cfg.max_restarts;
        let handle = std::thread::spawn(move || {
            let sup = Supervisor::new(factory, max_restarts);
            daemon_loop(sup, cfg, telemetry, rx, shared2);
        });
        Server {
            tx,
            shared,
            default_deadline,
            queue_cap,
            swap_ctx: None,
            handle: Some(handle),
            pending_stats: None,
        }
    }

    /// Submit a prompt through the admission gate.  Load shedding is
    /// explicit: a full queue, a draining server, or a dead engine rejects
    /// synchronously instead of buffering unboundedly.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        temperature: f32,
    ) -> Result<RequestHandle, SubmitError> {
        self.submit_with(
            prompt,
            RequestOpts { max_new_tokens, temperature, deadline: None },
        )
    }

    /// [`Server::submit`] with per-request options (deadline override).
    pub fn submit_with(
        &self,
        prompt: Vec<i32>,
        opts: RequestOpts,
    ) -> Result<RequestHandle, SubmitError> {
        if self.shared.engine_dead.load(Ordering::Acquire) {
            self.shared.gate_rejections.fetch_add(1, Ordering::AcqRel);
            M_REJECTED_DEAD.inc();
            return Err(SubmitError::Rejected(ShedReason::EngineDead));
        }
        if self.shared.draining.load(Ordering::Acquire) {
            self.shared.gate_rejections.fetch_add(1, Ordering::AcqRel);
            M_REJECTED_DRAINING.inc();
            return Err(SubmitError::Rejected(ShedReason::Draining));
        }
        let n = self.shared.inc_waiting();
        if n >= self.queue_cap {
            self.shared.dec_waiting();
            self.shared.gate_rejections.fetch_add(1, Ordering::AcqRel);
            M_REJECTED_FULL.inc();
            return Err(SubmitError::Rejected(ShedReason::QueueFull));
        }
        let now = Instant::now();
        let rel = opts.deadline.or(self.default_deadline);
        let cancel = Arc::new(AtomicBool::new(false));
        let (reply, rx) = mpsc::channel();
        let req = Request {
            prompt,
            max_new_tokens: opts.max_new_tokens,
            temperature: opts.temperature,
            deadline: rel.map(|d| now + d),
            enqueued: now,
            cancel: cancel.clone(),
            reply,
        };
        if self.tx.send(Msg::Req(req)).is_err() {
            self.shared.dec_waiting();
            return Err(SubmitError::Dead);
        }
        M_ADMITTED.inc();
        Ok(RequestHandle { rx, cancel })
    }

    /// The process-global metrics registry ([`crate::obs::metrics`]):
    /// carries the `qera_serve_*` series this server feeds alongside every
    /// other subsystem's — what `--metrics-out` dumps after a run.
    pub fn metrics(&self) -> &'static crate::obs::metrics::Registry {
        crate::obs::metrics::global()
    }

    /// Hot-swap the serving model: the daemon builds the new engine and
    /// replaces the old one atomically between batches — in-flight batches
    /// finish on the old model, later requests decode on the new one, and
    /// no admitted request is dropped.  Blocks until the swap is applied
    /// (or rejected, in which case the old model keeps serving).
    pub fn swap_model(&self, spec: ModelSpec, model: ServeModel) -> Result<()> {
        let (dir, backend) = self
            .swap_ctx
            .clone()
            .context("swap_model needs a Server::start/start_model server; use swap_factory")?;
        let telemetry = model.telemetry();
        let factory = make_factory(dir, spec, model, backend);
        self.swap_inner(factory, telemetry)
    }

    /// [`Server::swap_model`] over a custom engine factory.
    pub fn swap_factory<F>(&self, factory: F, telemetry: PlanTelemetry) -> Result<()>
    where
        F: FnMut() -> Result<Box<dyn super::daemon::BatchEngine>> + Send + 'static,
    {
        self.swap_inner(Box::new(factory), telemetry)
    }

    fn swap_inner(&self, factory: EngineFactory, telemetry: PlanTelemetry) -> Result<()> {
        let (ack, ackrx) = mpsc::channel();
        if self.tx.send(Msg::Swap { factory, telemetry, ack }).is_err() {
            bail!("serve daemon is dead");
        }
        match ackrx.recv() {
            Ok(Ok(())) => Ok(()),
            Ok(Err(e)) => bail!("hot swap rejected: {e}"),
            Err(_) => bail!("serve daemon died during swap"),
        }
    }

    /// Stop the server: drain gracefully (finish or shed queued work within
    /// `ServerConfig::drain`) and collect fully-accounted statistics.  A
    /// panicked or dead serving thread surfaces as an error instead of
    /// default stats masquerading as a clean run.
    pub fn stop(mut self) -> Result<ServerStats> {
        let srx = match self.pending_stats.take() {
            Some(rx) => Some(rx),
            None => {
                let (stx, srx) = mpsc::channel();
                self.tx.send(Msg::Stop(stx)).ok().map(|()| srx)
            }
        };
        let stats = srx.and_then(|rx| rx.recv().ok());
        let join = self.handle.take().expect("stop consumes the handle").join();
        match (join, stats) {
            (Err(_), _) => bail!("serve daemon thread panicked"),
            (Ok(()), Some(mut s)) => {
                // the gate can reject after the daemon snapshots its stats
                // (e.g. between begin_stop and stop) — refresh from the
                // live counter so rejections are never under-reported
                s.rejected_at_gate = self.shared.gate_rejections.load(Ordering::Acquire);
                Ok(s)
            }
            (Ok(()), None) => bail!("serve daemon exited without reporting stats"),
        }
    }

    /// Enqueue the graceful-stop request without blocking or consuming the
    /// server: the daemon finishes what the drain deadline allows, then
    /// parks the final stats for a later [`Server::stop`] call.  Once the
    /// daemon reaches the drain (observable via [`Server::is_draining`]),
    /// new submissions are rejected at the gate with
    /// [`ShedReason::Draining`].  Idempotent.
    pub fn begin_stop(&mut self) {
        if self.pending_stats.is_some() {
            return;
        }
        let (stx, srx) = mpsc::channel();
        if self.tx.send(Msg::Stop(stx)).is_ok() {
            self.pending_stats = Some(srx);
        }
    }

    /// True once the daemon has begun draining; from then on every
    /// submission is rejected at the admission gate.
    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::model::ModelSpec;
    use std::path::PathBuf;

    fn artifact_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn latency_percentiles_from_samples() {
        // artifact-free: the tail accessors must follow the bench_util
        // nearest-rank convention and degrade to 0.0 on empty stats
        let mut st = ServerStats::default();
        assert_eq!(st.queue_p50_ms(), 0.0);
        assert_eq!(st.total_p95_ms(), 0.0);
        st.queue_ms = Reservoir::from_samples([5.0, 1.0, 3.0, 2.0, 4.0]);
        st.total_ms = Reservoir::from_samples((1..=100).map(|i| i as f64));
        assert_eq!(st.queue_p50_ms(), 3.0);
        assert_eq!(st.queue_p95_ms(), 4.0); // idx (5-1)*0.95 = 3
        assert_eq!(st.queue_mean_ms(), 3.0);
        assert_eq!(st.total_p50_ms(), 50.0); // idx 49
        assert_eq!(st.total_p95_ms(), 95.0); // idx (99*0.95)=94
        assert!((st.total_mean_ms() - 50.5).abs() < 1e-12);
    }

    #[test]
    fn reservoir_bounds_memory_and_is_deterministic() {
        // below the cap: an exact record
        let r = Reservoir::from_samples((0..10).map(|i| i as f64));
        assert_eq!(r.len(), 10);
        assert_eq!(r.seen(), 10);
        assert!(!r.is_empty());
        assert_eq!(r.pct(0.0), 0.0);
        assert_eq!(r.pct(1.0), 9.0);
        assert!((r.mean() - 4.5).abs() < 1e-12);
        // above the cap: bounded memory, exact all-time mean, and the same
        // seed keeps the same subsample (identical tails)
        let mut a = Reservoir::new(64, 7);
        let mut b = Reservoir::new(64, 7);
        for i in 0..10_000 {
            a.push(i as f64);
            b.push(i as f64);
        }
        assert_eq!(a.len(), 64);
        assert_eq!(a.seen(), 10_000);
        assert!((a.mean() - 4999.5).abs() < 1e-9);
        assert_eq!(a.pct(0.5), b.pct(0.5));
        assert_eq!(a.pct(0.95), b.pct(0.95));
        assert!(a.pct(0.5) <= a.pct(0.95));
    }

    #[test]
    fn native_backend_serves_without_artifacts() {
        // ExecBackend::Native never opens the registry, so serving works
        // even when no artifacts were built — pass a bogus dir to prove it
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut crate::util::rng::Rng::new(7));
        let server = Server::start(
            PathBuf::from("/nonexistent-artifact-dir"),
            spec,
            params,
            ServerConfig {
                max_wait: Duration::from_millis(10),
                seed: 3,
                backend: crate::runtime::ExecBackend::Native,
                ..Default::default()
            },
        );
        let handles: Vec<_> =
            (0..3i32).map(|i| server.submit(vec![1 + i, 2], 4, 0.0).unwrap()).collect();
        for h in handles {
            let resp = h
                .wait_timeout(Duration::from_secs(120))
                .expect("completed in time")
                .response()
                .unwrap();
            assert_eq!(resp.tokens.len(), 4);
            assert_eq!(resp.model_version, 0);
        }
        // the process-global registry carries the serve series this fed
        assert!(server.metrics().render_prometheus().contains("qera_serve_admitted_total"));
        let stats = server.stop().unwrap();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.admitted, 3);
        assert_eq!(stats.accounted(), 3);
        assert_eq!(stats.tokens_generated, 12);
        assert_eq!(stats.swaps, 0);
        assert!(stats.plan_strategy.is_none());
    }

    #[test]
    fn serve_model_opens_sharded_checkpoints() {
        // ServeModel::open sniffs the source; a sharded dense manifest must
        // serve on the native backend exactly like in-memory params
        let dir = std::env::temp_dir().join("qera_serve_open_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut crate::util::rng::Rng::new(9));
        let ckpt = crate::model::Checkpoint::new(spec.clone(), params);
        let manifest = ckpt.save_sharded(dir.join("micro.manifest.json"), 1).unwrap();
        let (spec2, model) = ServeModel::open(&manifest).unwrap();
        assert_eq!(spec2.name, spec.name);
        assert!(matches!(model, ServeModel::Dense(_)));
        let server = Server::start_model(
            PathBuf::from("/nonexistent"),
            spec2,
            model,
            ServerConfig {
                max_wait: Duration::from_millis(10),
                backend: crate::runtime::ExecBackend::Native,
                ..Default::default()
            },
        );
        let h = server.submit(vec![1, 2, 3], 4, 0.0).unwrap();
        let resp = h.wait_timeout(Duration::from_secs(120)).unwrap().response().unwrap();
        assert_eq!(resp.tokens.len(), 4);
        server.stop().unwrap();
        // a missing manifest (or unrecognized file) fails up front
        assert!(ServeModel::open(dir.join("nope.manifest.json")).is_err());
    }

    #[test]
    fn per_request_temperature_is_not_batch_global() {
        // regression: run_batch used to apply batch[0].temperature to the
        // whole batch.  Submit a sampled-temperature request FIRST and a
        // greedy one second; the greedy row must still match the direct
        // greedy generation exactly.
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut crate::util::rng::Rng::new(11));
        let engine = Engine::new_native(spec.clone(), params.clone()).unwrap();
        let greedy_prompt = vec![2i32, 3, 5];
        let direct = engine
            .generate(&[greedy_prompt.clone()], 6, 0.0, &mut crate::util::rng::Rng::new(0))
            .unwrap();

        let server = Server::start(
            PathBuf::from("/nonexistent"),
            spec,
            params,
            ServerConfig {
                max_wait: Duration::from_millis(200),
                seed: 3,
                backend: crate::runtime::ExecBackend::Native,
                ..Default::default()
            },
        );
        // sampled first (would poison the old batch-global temperature),
        // greedy second; the wide max_wait coalesces them into one batch
        let sampled = server.submit(vec![7i32, 1], 6, 0.9).unwrap();
        let greedy = server.submit(greedy_prompt.clone(), 6, 0.0).unwrap();
        let s = sampled.wait_timeout(Duration::from_secs(120)).unwrap().response().unwrap();
        let g = greedy.wait_timeout(Duration::from_secs(120)).unwrap().response().unwrap();
        assert_eq!(s.batch_size, 2, "requests did not coalesce");
        assert_eq!(g.tokens, direct[0][greedy_prompt.len()..].to_vec());
        server.stop().unwrap();
    }

    #[test]
    fn cancellation_yields_typed_outcome() {
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut crate::util::rng::Rng::new(5));
        let server = Server::start(
            PathBuf::from("/nonexistent"),
            spec,
            params,
            ServerConfig {
                max_wait: Duration::from_millis(50),
                backend: crate::runtime::ExecBackend::Native,
                ..Default::default()
            },
        );
        // cancel before the batch window closes: the daemon prunes it at
        // batch start and replies Cancelled
        let h = server.submit(vec![1, 2], 4, 0.0).unwrap();
        h.cancel();
        match h.wait_timeout(Duration::from_secs(120)).expect("terminal outcome") {
            Outcome::Cancelled => {}
            other => panic!("expected Cancelled, got {other:?}"),
        }
        let stats = server.stop().unwrap();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.requests, 0);
        assert_eq!(stats.accounted(), stats.admitted);
    }

    #[test]
    fn expired_deadline_yields_timeout() {
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut crate::util::rng::Rng::new(6));
        let server = Server::start(
            PathBuf::from("/nonexistent"),
            spec,
            params,
            ServerConfig {
                max_wait: Duration::from_millis(30),
                backend: crate::runtime::ExecBackend::Native,
                ..Default::default()
            },
        );
        // a deadline that is already unmeetable when the batch starts
        let h = server
            .submit_with(
                vec![3, 4],
                RequestOpts {
                    max_new_tokens: 4,
                    temperature: 0.0,
                    deadline: Some(Duration::from_nanos(1)),
                },
            )
            .unwrap();
        match h.wait_timeout(Duration::from_secs(120)).expect("terminal outcome") {
            Outcome::TimedOut { waited_ms } => assert!(waited_ms >= 0.0),
            other => panic!("expected TimedOut, got {other:?}"),
        }
        let stats = server.stop().unwrap();
        assert_eq!(stats.timed_out, 1);
        assert_eq!(stats.accounted(), stats.admitted);
    }

    #[test]
    fn serves_batched_requests() {
        let Some(dir) = artifact_dir() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut crate::util::rng::Rng::new(0));
        let server = Server::start(
            dir,
            spec,
            params,
            ServerConfig { max_wait: Duration::from_millis(30), seed: 1, ..Default::default() },
        );
        // submit a burst: should coalesce into batches
        let handles: Vec<_> =
            (0..6i32).map(|i| server.submit(vec![1 + i, 2, 3], 4, 0.0).unwrap()).collect();
        let mut batched = 0;
        for h in handles {
            let resp = h
                .wait_timeout(Duration::from_secs(120))
                .expect("completed in time")
                .response()
                .unwrap();
            assert_eq!(resp.tokens.len(), 4);
            if resp.batch_size > 1 {
                batched += 1;
            }
        }
        let stats = server.stop().unwrap();
        assert_eq!(stats.requests, 6);
        assert!(stats.tokens_generated >= 24);
        // one latency sample per request, with coherent tails
        assert_eq!(stats.queue_ms.len(), 6);
        assert_eq!(stats.total_ms.len(), 6);
        assert!(stats.queue_p50_ms() <= stats.queue_p95_ms());
        assert!(stats.total_p50_ms() <= stats.total_p95_ms());
        assert!(stats.total_p50_ms() >= stats.queue_p50_ms());
        assert!(batched > 0, "burst never batched");
        assert!(stats.batches < 6, "no batching happened: {}", stats.batches);
    }

    #[test]
    fn stop_without_requests() {
        let Some(dir) = artifact_dir() else {
            return;
        };
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut crate::util::rng::Rng::new(2));
        let server = Server::start(dir, spec, params, ServerConfig::default());
        let stats = server.stop().unwrap();
        assert_eq!(stats.requests, 0);
        assert_eq!(stats.admitted, 0);
    }
}
