//! Request router + dynamic batcher (the vLLM-router-shaped piece).
//!
//! Clients submit prompts from any thread; a dedicated serving thread owns
//! the PJRT handles (they are not `Send`), drains the queue into batches of
//! up to `spec.batch` requests within a `max_wait` window, decodes
//! step-locked batches, and completes each request on its response channel.
//! Latency statistics (per-request queue / total samples with p50/p95
//! accessors, not just means) feed the serving bench's tail gates.

use crate::model::{ModelSpec, QuantCheckpoint};
use crate::runtime::ExecBackend;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Weights handed to the serving thread.
pub enum ServeModel {
    /// Dense parameter list in canonical order.
    Dense(Vec<crate::tensor::Tensor>),
    /// Quantized checkpoint; with [`ExecBackend::Native`] it serves fused
    /// straight from the packed payload (the stub route materializes the
    /// merged dense weights, since PJRT artifacts take f32 inputs).
    Quant(Box<QuantCheckpoint>),
}

pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub temperature: f32,
    enqueued: Instant,
    reply: mpsc::Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub tokens: Vec<i32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub batch_size: usize,
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    pub seed: u64,
    /// Execution backend; [`ExecBackend::Native`] serves without artifacts.
    pub backend: ExecBackend,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_wait: Duration::from_millis(5), seed: 0, backend: ExecBackend::Stub }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub tokens_generated: usize,
    pub wall_s: f64,
    /// Per-request queue latency samples (ms), in completion order — the
    /// serving bench gates on the tails, not just the means.
    pub queue_ms: Vec<f64>,
    /// Per-request total latency samples (ms), in completion order.
    pub total_ms: Vec<f64>,
}

impl ServerStats {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches > 0 {
            self.requests as f64 / self.batches as f64
        } else {
            0.0
        }
    }

    /// Percentile over a sample set (same convention as `bench_util`:
    /// nearest-rank on the sorted samples); 0.0 when empty.
    fn pct(samples: &[f64], p: f64) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        let mut v = samples.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[((v.len() - 1) as f64 * p) as usize]
    }

    fn mean(samples: &[f64]) -> f64 {
        if samples.is_empty() {
            return 0.0;
        }
        samples.iter().sum::<f64>() / samples.len() as f64
    }

    pub fn queue_mean_ms(&self) -> f64 {
        Self::mean(&self.queue_ms)
    }
    pub fn queue_p50_ms(&self) -> f64 {
        Self::pct(&self.queue_ms, 0.5)
    }
    pub fn queue_p95_ms(&self) -> f64 {
        Self::pct(&self.queue_ms, 0.95)
    }
    pub fn total_mean_ms(&self) -> f64 {
        Self::mean(&self.total_ms)
    }
    pub fn total_p50_ms(&self) -> f64 {
        Self::pct(&self.total_ms, 0.5)
    }
    pub fn total_p95_ms(&self) -> f64 {
        Self::pct(&self.total_ms, 0.95)
    }
}

enum Msg {
    Req(Request),
    Stop(mpsc::Sender<ServerStats>),
}

/// Handle for submitting requests; the engine runs on its own thread.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the serving thread.  `artifact_dir` and the model params are
    /// moved into the thread (PJRT handles are created there).
    pub fn start(
        artifact_dir: std::path::PathBuf,
        spec: ModelSpec,
        params: Vec<crate::tensor::Tensor>,
        cfg: ServerConfig,
    ) -> Server {
        Server::start_model(artifact_dir, spec, ServeModel::Dense(params), cfg)
    }

    /// [`Server::start`] generalized over [`ServeModel`] — quantized
    /// checkpoints serve without dense materialization on the native
    /// backend.
    pub fn start_model(
        artifact_dir: std::path::PathBuf,
        spec: ModelSpec,
        model: ServeModel,
        cfg: ServerConfig,
    ) -> Server {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = std::thread::spawn(move || {
            if let Err(e) = serve_loop(artifact_dir, spec, model, cfg, rx) {
                crate::warn_!("serve loop died: {e:#}");
            }
        });
        Server { tx, handle: Some(handle) }
    }

    /// Submit a prompt; returns the receiver for the response.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        temperature: f32,
    ) -> mpsc::Receiver<Response> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Req(Request {
            prompt,
            max_new_tokens,
            temperature,
            enqueued: Instant::now(),
            reply,
        }));
        rx
    }

    /// Stop the server and collect statistics.
    pub fn stop(mut self) -> ServerStats {
        let (stx, srx) = mpsc::channel();
        let _ = self.tx.send(Msg::Stop(stx));
        let stats = srx.recv().unwrap_or_default();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        stats
    }
}

fn serve_loop(
    artifact_dir: std::path::PathBuf,
    spec: ModelSpec,
    model: ServeModel,
    cfg: ServerConfig,
    rx: mpsc::Receiver<Msg>,
) -> Result<()> {
    use super::engine::Engine;
    let engine = match (cfg.backend, model) {
        (ExecBackend::Stub, model) => {
            let params = match model {
                ServeModel::Dense(p) => p,
                ServeModel::Quant(q) => q.materialize_merged(),
            };
            let reg = crate::runtime::Registry::open(artifact_dir)?;
            Engine::new(&reg, spec.clone(), params)?
        }
        (ExecBackend::Native, ServeModel::Dense(p)) => Engine::new_native(spec.clone(), p)?,
        (ExecBackend::Native, ServeModel::Quant(q)) => Engine::new_native_quant(&q),
    };
    let mut rng = Rng::new(cfg.seed);
    let mut stats = ServerStats::default();
    let t0 = Instant::now();

    'outer: loop {
        // block for the first request
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Stop(reply)) => {
                stats.wall_s = t0.elapsed().as_secs_f64();
                let _ = reply.send(stats.clone());
                break 'outer;
            }
            Err(_) => break 'outer,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        // fill the batch within the wait window
        while batch.len() < spec.batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => batch.push(r),
                Ok(Msg::Stop(reply)) => {
                    // finish this batch first, then stop
                    run_batch(&engine, &mut batch, &mut rng, &mut stats)?;
                    stats.wall_s = t0.elapsed().as_secs_f64();
                    let _ = reply.send(stats.clone());
                    break 'outer;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        run_batch(&engine, &mut batch, &mut rng, &mut stats)?;
    }
    Ok(())
}

fn run_batch(
    engine: &super::engine::Engine,
    batch: &mut Vec<Request>,
    rng: &mut Rng,
    stats: &mut ServerStats,
) -> Result<()> {
    if batch.is_empty() {
        return Ok(());
    }
    let bsize = batch.len();
    let started = Instant::now();
    let max_new = batch.iter().map(|r| r.max_new_tokens).max().unwrap();
    let temperature = batch[0].temperature;
    let mut contexts: Vec<Vec<i32>> = batch.iter().map(|r| r.prompt.clone()).collect();
    let lens: Vec<usize> = contexts.iter().map(Vec::len).collect();
    for step in 0..max_new {
        let next = engine.step(&contexts, temperature, rng)?;
        for (i, t) in next.into_iter().enumerate() {
            if step < batch[i].max_new_tokens {
                contexts[i].push(t);
                stats.tokens_generated += 1;
            }
        }
    }
    for (i, req) in batch.drain(..).enumerate() {
        let resp = Response {
            tokens: contexts[i][lens[i]..].to_vec(),
            queue_ms: (started - req.enqueued).as_secs_f64() * 1e3,
            total_ms: req.enqueued.elapsed().as_secs_f64() * 1e3,
            batch_size: bsize,
        };
        stats.queue_ms.push(resp.queue_ms);
        stats.total_ms.push(resp.total_ms);
        let _ = req.reply.send(resp);
        stats.requests += 1;
    }
    stats.batches += 1;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::model::ModelSpec;
    use std::path::PathBuf;

    fn artifact_dir() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn latency_percentiles_from_samples() {
        // artifact-free: the tail accessors must follow the bench_util
        // nearest-rank convention and degrade to 0.0 on empty stats
        let mut st = ServerStats::default();
        assert_eq!(st.queue_p50_ms(), 0.0);
        assert_eq!(st.total_p95_ms(), 0.0);
        st.queue_ms = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        st.total_ms = (1..=100).map(|i| i as f64).collect();
        assert_eq!(st.queue_p50_ms(), 3.0);
        assert_eq!(st.queue_p95_ms(), 4.0); // idx (5-1)*0.95 = 3
        assert_eq!(st.queue_mean_ms(), 3.0);
        assert_eq!(st.total_p50_ms(), 50.0); // idx 49
        assert_eq!(st.total_p95_ms(), 95.0); // idx (99*0.95)=94
        assert!((st.total_mean_ms() - 50.5).abs() < 1e-12);
    }

    #[test]
    fn native_backend_serves_without_artifacts() {
        // ExecBackend::Native never opens the registry, so serving works
        // even when no artifacts were built — pass a bogus dir to prove it
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut Rng::new(7));
        let server = Server::start(
            PathBuf::from("/nonexistent-artifact-dir"),
            spec,
            params,
            ServerConfig {
                max_wait: Duration::from_millis(10),
                seed: 3,
                backend: crate::runtime::ExecBackend::Native,
            },
        );
        let rxs: Vec<_> = (0..3).map(|i| server.submit(vec![1 + i as i32, 2], 4, 0.0)).collect();
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert_eq!(resp.tokens.len(), 4);
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.tokens_generated, 12);
    }

    #[test]
    fn serves_batched_requests() {
        let Some(dir) = artifact_dir() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut Rng::new(0));
        let server = Server::start(
            dir,
            spec,
            params,
            ServerConfig { max_wait: Duration::from_millis(30), seed: 1, ..Default::default() },
        );
        // submit a burst: should coalesce into batches
        let rxs: Vec<_> =
            (0..6).map(|i| server.submit(vec![1 + i as i32, 2, 3], 4, 0.0)).collect();
        let mut batched = 0;
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert_eq!(resp.tokens.len(), 4);
            if resp.batch_size > 1 {
                batched += 1;
            }
        }
        let stats = server.stop();
        assert_eq!(stats.requests, 6);
        assert!(stats.tokens_generated >= 24);
        // one latency sample per request, with coherent tails
        assert_eq!(stats.queue_ms.len(), 6);
        assert_eq!(stats.total_ms.len(), 6);
        assert!(stats.queue_p50_ms() <= stats.queue_p95_ms());
        assert!(stats.total_p50_ms() <= stats.total_p95_ms());
        assert!(stats.total_p50_ms() >= stats.queue_p50_ms());
        assert!(batched > 0, "burst never batched");
        assert!(stats.batches < 6, "no batching happened: {}", stats.batches);
    }

    #[test]
    fn stop_without_requests() {
        let Some(dir) = artifact_dir() else {
            return;
        };
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut Rng::new(2));
        let server = Server::start(dir, spec, params, ServerConfig::default());
        let stats = server.stop();
        assert_eq!(stats.requests, 0);
    }
}
