//! Serving runtime: batched generation over the quantized model.
//!
//! The paper's claim "QERA introduces no inference overhead — LQER,
//! QERA-approx and QERA-exact all serve as `y = x(W~ + A_k B_k)`" is made
//! concrete here: the engine serves any [`crate::coordinator::QuantizedModel`]
//! through either backend — the `lm_logits_last` PJRT artifact, or the
//! native fused path that evaluates `y = x·W_q + (x·A)·B` straight from
//! packed blocks ([`crate::runtime::ExecBackend`]) — and the latency bench
//! (`benches/hotpath.rs`) measures dense vs low-rank forward forms.

pub mod engine;
pub mod batcher;

pub use batcher::{ServeModel, Server, ServerConfig, ServerStats};
pub use engine::Engine;
