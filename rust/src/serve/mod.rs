//! Serving runtime: a supervised daemon over batched generation.
//!
//! The paper's claim "QERA introduces no inference overhead — LQER,
//! QERA-approx and QERA-exact all serve as `y = x(W~ + A_k B_k)`" is made
//! concrete here: the engine serves any [`crate::coordinator::QuantizedModel`]
//! through either backend — the `lm_logits_last` PJRT artifact, or the
//! native fused path that evaluates `y = x·W_q + (x·A)·B` straight from
//! packed blocks ([`crate::runtime::ExecBackend`]) — and the latency bench
//! (`benches/hotpath.rs`) measures dense vs low-rank forward forms.
//!
//! Layering:
//!
//! * [`engine`] — one decode step / batched generation, per-row
//!   temperatures ([`Engine::step_multi`]).
//! * [`daemon`] — the supervision layer: typed request [`Outcome`]s,
//!   retry-with-backoff ([`RetryPolicy`]), capped engine restarts, graceful
//!   drain, hot model swap, and the [`FaultyEngine`] chaos wrapper the
//!   fault-injection tests use.
//! * [`batcher`] — the client-facing [`Server`]: bounded admission gate
//!   ([`Server::submit`] returns `Result`), per-request deadlines and
//!   cancellation via [`RequestHandle`], [`Server::swap_model`], and
//!   fully-accounted [`ServerStats`].

pub mod batcher;
pub mod daemon;
pub mod engine;

pub use batcher::{
    RequestHandle, RequestOpts, Reservoir, ServeModel, Server, ServerConfig, ServerStats,
};
pub use daemon::{
    BatchEngine, FaultyEngine, Outcome, PlanTelemetry, RetryPolicy, ShedReason, SubmitError,
};
pub use engine::Engine;
