//! Streaming calibration statistics (f64 accumulation per App. A.7).
//!
//! For each linear-layer input site the coordinator accumulates, over
//! calibration batches of row vectors `x ∈ R^m`:
//!
//! * `sum |x_i|`      -> LQER's heuristic scale;
//! * `sum x_i²`       -> QERA-approx's `S = diag(√E[x_i²])` (Theorem 2);
//! * `sum xᵀx`        -> QERA-exact's `R_XX = E[xᵀx]` (Theorem 1).
//!
//! The outer products arrive as f32 partials from the L1 `calib_stats`
//! Pallas kernel or as raw activation taps; folding happens here in f64.

use crate::linalg::Mat64;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Per-site accumulator.
#[derive(Clone, Debug)]
pub struct CalibStats {
    pub dim: usize,
    pub count: u64,
    pub sum_abs: Vec<f64>,
    pub sum_sq: Vec<f64>,
    /// `Σ xᵀx`; optional because QERA-approx / LQER don't need the O(m²)
    /// memory (Table 8's init-time trade-off).
    pub rxx: Option<Mat64>,
}

impl CalibStats {
    pub fn new(dim: usize, track_rxx: bool) -> Self {
        CalibStats {
            dim,
            count: 0,
            sum_abs: vec![0.0; dim],
            sum_sq: vec![0.0; dim],
            rxx: if track_rxx { Some(Mat64::zeros(dim, dim)) } else { None },
        }
    }

    /// Fold a batch of rows `x` ([rows, dim], any leading shape collapsed).
    pub fn update(&mut self, x: &Tensor) {
        let x2 = x.as_2d();
        assert_eq!(x2.cols(), self.dim, "calib dim mismatch");
        let rows = x2.rows();
        let m = self.dim;
        let data = x2.data();
        for r in 0..rows {
            let row = &data[r * m..(r + 1) * m];
            for (i, &v) in row.iter().enumerate() {
                let v = v as f64;
                self.sum_abs[i] += v.abs();
                self.sum_sq[i] += v * v;
            }
        }
        if let Some(rxx) = &mut self.rxx {
            // blocked upper-triangular accumulation, mirrored afterwards
            for r in 0..rows {
                let row = &data[r * m..(r + 1) * m];
                for i in 0..m {
                    let vi = row[i] as f64;
                    if vi == 0.0 {
                        continue;
                    }
                    let dst = &mut rxx.a[i * m..(i + 1) * m];
                    for j in i..m {
                        dst[j] += vi * row[j] as f64;
                    }
                }
            }
        }
        self.count += rows as u64;
    }

    /// Fold pre-reduced f32 partials (from the L1 `calib_stats` kernel):
    /// `sumsq[m]`, `sumabs[m]`, `rxx[m,m]`, over `rows` source rows.
    pub fn update_partial(
        &mut self,
        sumsq: &[f32],
        sumabs: &[f32],
        rxx: Option<&[f32]>,
        rows: u64,
    ) -> Result<()> {
        ensure!(sumsq.len() == self.dim && sumabs.len() == self.dim, "partial dim mismatch");
        for i in 0..self.dim {
            self.sum_sq[i] += sumsq[i] as f64;
            self.sum_abs[i] += sumabs[i] as f64;
        }
        if let (Some(acc), Some(part)) = (&mut self.rxx, rxx) {
            ensure!(part.len() == self.dim * self.dim, "rxx partial size");
            for (a, &p) in acc.a.iter_mut().zip(part) {
                *a += p as f64;
            }
        }
        self.count += rows;
        Ok(())
    }

    /// Merge another accumulator (parallel calibration shards).
    pub fn merge(&mut self, other: &CalibStats) {
        assert_eq!(self.dim, other.dim);
        self.count += other.count;
        for i in 0..self.dim {
            self.sum_abs[i] += other.sum_abs[i];
            self.sum_sq[i] += other.sum_sq[i];
        }
        match (&mut self.rxx, &other.rxx) {
            (Some(a), Some(b)) => {
                for (x, y) in a.a.iter_mut().zip(&b.a) {
                    *x += y;
                }
            }
            (None, None) => {}
            _ => panic!("merging stats with mismatched rxx tracking"),
        }
    }

    /// `E[|x_i|]` (LQER's diagonal).
    pub fn mean_abs(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        self.sum_abs.iter().map(|&s| s / n).collect()
    }

    /// `E[x_i²]` (QERA-approx's diagonal, pre-sqrt).
    pub fn mean_sq(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        self.sum_sq.iter().map(|&s| s / n).collect()
    }

    /// `R_XX = E[xᵀx]`, symmetrized (only the upper triangle is accumulated
    /// on the row-tap path).
    pub fn rxx_mean(&self) -> Option<Mat64> {
        let rxx = self.rxx.as_ref()?;
        let n = self.count.max(1) as f64;
        let m = self.dim;
        let mut out = Mat64::zeros(m, m);
        for i in 0..m {
            for j in i..m {
                let v = rxx.at(i, j) / n;
                out.set(i, j, v);
                out.set(j, i, v);
            }
        }
        // partial-fold path may have filled the lower triangle instead;
        // prefer whichever half carries data.
        if out.frob_norm() == 0.0 {
            let mut alt = rxx.clone();
            alt.symmetrize();
            return Some(alt.scale(1.0 / n));
        }
        Some(out)
    }

    /// Mean |off-diagonal| element over mean diagonal element of `R_XX` —
    /// the per-element Assumption-1 diagnostic (Figure 5's "dark pixels"):
    /// iid dims give ≈0, perfectly correlated dims give ≈1.
    pub fn offdiag_element_ratio(&self) -> Option<f64> {
        let r = self.rxx_mean()?;
        let m = r.r;
        if m < 2 {
            return Some(0.0);
        }
        let mut diag = 0.0f64;
        let mut off = 0.0f64;
        for i in 0..m {
            diag += r.at(i, i).abs();
            for j in 0..m {
                if i != j {
                    off += r.at(i, j).abs();
                }
            }
        }
        let mean_diag = diag / m as f64;
        let mean_off = off / (m * (m - 1)) as f64;
        Some(mean_off / mean_diag.max(f64::MIN_POSITIVE))
    }

    /// Off-diagonal mass ratio `‖offdiag(R)‖_F / ‖R‖_F` — the Assumption 1
    /// diagnostic behind Figure 5.
    pub fn offdiag_ratio(&self) -> Option<f64> {
        let r = self.rxx_mean()?;
        let total = r.frob_norm();
        if total == 0.0 {
            return Some(0.0);
        }
        let mut diag = 0.0f64;
        for i in 0..r.r {
            diag += r.at(i, i) * r.at(i, i);
        }
        Some(((total * total - diag).max(0.0)).sqrt() / total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn batch(rows: usize, m: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(vec![rows, m], 1.0, &mut rng)
    }

    #[test]
    fn single_row_known() {
        let x = Tensor::new(vec![1, 3], vec![1.0, -2.0, 0.5]);
        let mut st = CalibStats::new(3, true);
        st.update(&x);
        assert_eq!(st.count, 1);
        assert_eq!(st.mean_abs(), vec![1.0, 2.0, 0.5]);
        assert_eq!(st.mean_sq(), vec![1.0, 4.0, 0.25]);
        let r = st.rxx_mean().unwrap();
        assert!((r.at(0, 1) + 2.0).abs() < 1e-12);
        assert!((r.at(1, 2) + 1.0).abs() < 1e-12);
        assert!((r.at(2, 0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rxx_matches_direct_outer_product() {
        let x = batch(50, 8, 0);
        let mut st = CalibStats::new(8, true);
        st.update(&x);
        let r = st.rxx_mean().unwrap();
        // direct: X^T X / n
        let xm = Mat64::from_tensor(&x);
        let want = xm.matmul_tn(&xm).scale(1.0 / 50.0);
        assert!(r.sub(&want).frob_norm() < 1e-6 * want.frob_norm());
    }

    #[test]
    fn streaming_equals_oneshot() {
        let a = batch(30, 6, 1);
        let b = batch(20, 6, 2);
        let mut st1 = CalibStats::new(6, true);
        st1.update(&a);
        st1.update(&b);
        let mut all = a.data().to_vec();
        all.extend_from_slice(b.data());
        let both = Tensor::new(vec![50, 6], all);
        let mut st2 = CalibStats::new(6, true);
        st2.update(&both);
        assert_eq!(st1.count, st2.count);
        for i in 0..6 {
            assert!((st1.sum_sq[i] - st2.sum_sq[i]).abs() < 1e-9);
        }
        let d = st1.rxx_mean().unwrap().sub(&st2.rxx_mean().unwrap()).frob_norm();
        assert!(d < 1e-9);
    }

    #[test]
    fn merge_equals_sequential() {
        let a = batch(16, 4, 3);
        let b = batch(24, 4, 4);
        let mut st1 = CalibStats::new(4, true);
        st1.update(&a);
        let mut st2 = CalibStats::new(4, true);
        st2.update(&b);
        st1.merge(&st2);
        let mut seq = CalibStats::new(4, true);
        seq.update(&a);
        seq.update(&b);
        assert_eq!(st1.count, seq.count);
        let d = st1.rxx_mean().unwrap().sub(&seq.rxx_mean().unwrap()).frob_norm();
        assert!(d < 1e-12);
    }

    #[test]
    fn partial_fold_matches_raw() {
        let x = batch(32, 5, 5);
        let mut raw = CalibStats::new(5, true);
        raw.update(&x);
        // compute the partials the L1 kernel would emit (f32)
        let x2 = x.as_2d();
        let mut sumsq = vec![0.0f32; 5];
        let mut sumabs = vec![0.0f32; 5];
        let mut rxx = vec![0.0f32; 25];
        for r in 0..32 {
            for i in 0..5 {
                let v = x2.at2(r, i);
                sumsq[i] += v * v;
                sumabs[i] += v.abs();
                for j in 0..5 {
                    rxx[i * 5 + j] += v * x2.at2(r, j);
                }
            }
        }
        let mut part = CalibStats::new(5, true);
        part.update_partial(&sumsq, &sumabs, Some(&rxx), 32).unwrap();
        for i in 0..5 {
            assert!((raw.mean_sq()[i] - part.mean_sq()[i]).abs() < 1e-4);
        }
        let d = raw.rxx_mean().unwrap().sub(&part.rxx_mean().unwrap()).frob_norm();
        assert!(d < 1e-3);
    }

    #[test]
    fn offdiag_ratio_iid_small_correlated_large() {
        // iid gaussian -> R ≈ I -> small ratio
        let mut st = CalibStats::new(16, true);
        st.update(&batch(4000, 16, 6));
        let iid = st.offdiag_ratio().unwrap();
        assert!(iid < 0.25, "{iid}");
        // perfectly correlated dims -> large ratio
        let mut rng = Rng::new(7);
        let mut data = Vec::new();
        for _ in 0..500 {
            let v = rng.normal_f32();
            for _ in 0..16 {
                data.push(v);
            }
        }
        let mut st2 = CalibStats::new(16, true);
        st2.update(&Tensor::new(vec![500, 16], data));
        let corr = st2.offdiag_ratio().unwrap();
        assert!(corr > 0.9, "{corr}");
    }

    #[test]
    fn no_rxx_mode() {
        let mut st = CalibStats::new(4, false);
        st.update(&batch(10, 4, 8));
        assert!(st.rxx_mean().is_none());
        assert!(st.offdiag_ratio().is_none());
        assert_eq!(st.mean_sq().len(), 4);
    }
}
