//! Streaming calibration statistics (f64 accumulation per App. A.7).
//!
//! For each linear-layer input site the coordinator accumulates, over
//! calibration batches of row vectors `x ∈ R^m`:
//!
//! * `sum |x_i|`      -> LQER's heuristic scale;
//! * `sum x_i²`       -> QERA-approx's `S = diag(√E[x_i²])` (Theorem 2);
//! * `sum xᵀx`        -> QERA-exact's `R_XX = E[xᵀx]` (Theorem 1).
//!
//! The outer products arrive as f32 partials from the L1 `calib_stats`
//! Pallas kernel or as raw activation taps; folding happens here in f64.
//!
//! The raw-tap `Σ xᵀx` fold is a cache-blocked SYRK kernel: each f32 row
//! panel is converted to f64 once, then upper-triangular output-row bands
//! (area-balanced, since early rows carry more entries) accumulate j-tiles
//! with the same blocking shape as the `Mat64` matmuls, threaded over bands
//! via [`crate::util::pool::parallel_pieces_mut`].  Only *output entries*
//! are partitioned and the per-entry accumulation runs strictly ascending
//! in the source-row index, so results are **bit-identical for every worker
//! count** (and identical to the seed scalar triple loop) — the repo-wide
//! invariant the pipeline's determinism tests rely on.  `QERA_CALIB_WORKERS`
//! pins the fold's worker count independently of `QERA_THREADS`.

use crate::linalg::Mat64;
use crate::tensor::Tensor;
use crate::util::pool;
use anyhow::{ensure, Result};

/// Row-panel height for the blocked SYRK fold: the converted f64 panel
/// (`SYRK_PANEL_ROWS × m`) stays cache-resident while the upper triangle
/// streams through it.
const SYRK_PANEL_ROWS: usize = 64;
/// j-tile width of the SYRK inner loop — the `Mat64` kernels' BLOCK_J shape.
const SYRK_BLOCK_J: usize = 256;

/// How the `Σ xᵀx` accumulator is laid out.  Explicit — this replaces the
/// old `frob_norm() == 0.0` triangle-detection heuristic in `rxx_mean`,
/// which could silently drop data for genuinely sparse statistics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RxxLayout {
    /// Raw-tap path: only entries `i <= j` carry data (the strict lower
    /// triangle is zero); `rxx_mean` mirrors the upper triangle.
    Upper,
    /// Partial-fold path: the full (symmetric) matrix carries data, e.g.
    /// after folding an L1 `calib_stats` kernel partial; `rxx_mean`
    /// symmetrizes to shed f32 round-trip asymmetry.
    Full,
}

/// Per-site accumulator.
#[derive(Clone, Debug)]
pub struct CalibStats {
    pub dim: usize,
    pub count: u64,
    pub sum_abs: Vec<f64>,
    pub sum_sq: Vec<f64>,
    /// `Σ xᵀx`; optional because QERA-approx / LQER don't need the O(m²)
    /// memory (Table 8's init-time trade-off).
    pub rxx: Option<Mat64>,
    /// Accumulation layout of `rxx` (see [`RxxLayout`]).
    pub rxx_layout: RxxLayout,
}

/// Row-band lengths (in output rows) for an upper-triangular `m×m` fold
/// split across `w` workers: boundaries chosen so every band owns roughly
/// the same number of triangle entries — early rows are wider, so equal-row
/// bands would leave the last workers idle.  The split never affects the
/// result (each entry is owned by exactly one band and accumulated in a
/// fixed order); it only balances wall time.
fn syrk_band_lens(m: usize, w: usize) -> Vec<usize> {
    let w = w.max(1).min(m.max(1));
    if w <= 1 {
        return vec![m];
    }
    let total = m * (m + 1) / 2;
    let target = (total + w - 1) / w;
    let mut lens = Vec::with_capacity(w);
    let (mut acc, mut len) = (0usize, 0usize);
    for i in 0..m {
        acc += m - i;
        len += 1;
        if acc >= target && lens.len() + 1 < w {
            lens.push(len);
            acc = 0;
            len = 0;
        }
    }
    if len > 0 {
        lens.push(len);
    }
    lens
}

/// One output-row band of the upper-triangular SYRK fold over a converted
/// f64 panel: `band[(i - i0)·m + j] += Σ_r px[r·m + i] · px[r·m + j]` for
/// `i0 <= i < i1`, `j >= i`.  j runs in `SYRK_BLOCK_J` tiles aligned to the
/// global grid, and for each entry the r-accumulation runs strictly
/// ascending — so the result is independent of the band split and the
/// tiling, and matches the seed scalar loop bit-for-bit (f32→f64 conversion
/// is exact, and zero rows are skipped exactly as before).
fn syrk_upper_band(px: &[f64], pr: usize, m: usize, i0: usize, i1: usize, band: &mut [f64]) {
    for jt0 in (0..m).step_by(SYRK_BLOCK_J) {
        let jt1 = (jt0 + SYRK_BLOCK_J).min(m);
        if jt1 <= i0 {
            continue;
        }
        for r in 0..pr {
            let xrow = &px[r * m..(r + 1) * m];
            for i in i0..i1.min(jt1) {
                let vi = xrow[i];
                if vi == 0.0 {
                    continue;
                }
                let lo = jt0.max(i);
                let dst = &mut band[(i - i0) * m + lo..(i - i0) * m + jt1];
                for (d, &vj) in dst.iter_mut().zip(&xrow[lo..jt1]) {
                    *d += vi * vj;
                }
            }
        }
    }
}

/// Copy the upper triangle into the strict lower triangle (an exact
/// mirror, no arithmetic) — the `Upper` → [`RxxLayout::Full`] promotion.
fn mirror_upper(a: &mut Mat64) {
    let m = a.r;
    for i in 0..m {
        for j in (i + 1)..m {
            a.a[j * m + i] = a.a[i * m + j];
        }
    }
}

/// Add `src`'s upper triangle into both triangles of `dst` (diagonal once):
/// folding an `Upper`-layout accumulation into a `Full`-layout one.
fn mirror_add_upper(dst: &mut Mat64, src: &Mat64) {
    let m = dst.r;
    for i in 0..m {
        for j in i..m {
            let v = src.a[i * m + j];
            dst.a[i * m + j] += v;
            if i != j {
                dst.a[j * m + i] += v;
            }
        }
    }
}

/// Blocked, threaded `dst += upper(Xᵀ X)` over f32 rows.  Bands own fixed
/// disjoint output-row ranges; each band converts the row panels to f64
/// itself (duplicated across bands but O(rows·m) against the fold's
/// O(rows·m²/2), so it vanishes for the widths that matter).
fn syrk_upper(dst: &mut Mat64, data: &[f32], rows: usize, m: usize, workers: usize) {
    let band_rows = syrk_band_lens(m, workers);
    let mut starts = Vec::with_capacity(band_rows.len());
    let mut s = 0usize;
    for &l in &band_rows {
        starts.push(s);
        s += l;
    }
    let lens: Vec<usize> = band_rows.iter().map(|&l| l * m).collect();
    pool::parallel_pieces_mut(&mut dst.a, &lens, |pi, band| {
        let i0 = starts[pi];
        let i1 = i0 + band_rows[pi];
        let mut panel = vec![0.0f64; SYRK_PANEL_ROWS.min(rows.max(1)) * m];
        for p0 in (0..rows).step_by(SYRK_PANEL_ROWS) {
            let pr = SYRK_PANEL_ROWS.min(rows - p0);
            for (pv, &sv) in panel[..pr * m].iter_mut().zip(&data[p0 * m..(p0 + pr) * m]) {
                *pv = sv as f64;
            }
            syrk_upper_band(&panel, pr, m, i0, i1, band);
        }
    });
}

/// Per-element Assumption-1 diagnostic on an already-materialized `R_XX`
/// (Figure 5's "dark pixels"): mean |off-diagonal| element over mean
/// diagonal element — iid dims give ≈0, perfectly correlated dims ≈1.
pub fn offdiag_element_ratio_of(r: &Mat64) -> f64 {
    let m = r.r;
    if m < 2 {
        return 0.0;
    }
    let mut diag = 0.0f64;
    let mut off = 0.0f64;
    for i in 0..m {
        diag += r.at(i, i).abs();
        for j in 0..m {
            if i != j {
                off += r.at(i, j).abs();
            }
        }
    }
    let mean_diag = diag / m as f64;
    let mean_off = off / (m * (m - 1)) as f64;
    mean_off / mean_diag.max(f64::MIN_POSITIVE)
}

/// Off-diagonal mass ratio `‖offdiag(R)‖_F / ‖R‖_F` on a materialized
/// `R_XX` — the Assumption 1 diagnostic behind Figure 5.
pub fn offdiag_ratio_of(r: &Mat64) -> f64 {
    let total = r.frob_norm();
    if total == 0.0 {
        return 0.0;
    }
    let mut diag = 0.0f64;
    for i in 0..r.r {
        diag += r.at(i, i) * r.at(i, i);
    }
    ((total * total - diag).max(0.0)).sqrt() / total
}

impl CalibStats {
    pub fn new(dim: usize, track_rxx: bool) -> Self {
        CalibStats {
            dim,
            count: 0,
            sum_abs: vec![0.0; dim],
            sum_sq: vec![0.0; dim],
            rxx: if track_rxx { Some(Mat64::zeros(dim, dim)) } else { None },
            rxx_layout: RxxLayout::Upper,
        }
    }

    /// Fold a batch of rows `x` ([rows, dim], any leading shape collapsed)
    /// with an auto-sized worker count (see [`CalibStats::update_workers`]).
    pub fn update(&mut self, x: &Tensor) {
        self.update_workers(x, 0)
    }

    /// [`CalibStats::update`] with an explicit worker count (`0` = auto:
    /// `QERA_CALIB_WORKERS` / pool default, serial for small batches or
    /// inside pool workers).  **Bit-identical for every worker count** and
    /// to the pre-blocking scalar loop: threading partitions output rows of
    /// the upper triangle only, never the per-entry accumulation order.
    pub fn update_workers(&mut self, x: &Tensor, workers: usize) {
        let (rows, cols, data) = x.view_2d();
        assert_eq!(cols, self.dim, "calib dim mismatch");
        self.update_rows(data, rows, workers);
    }

    /// Fold `rows` borrowed row-major rows — the zero-copy core of
    /// [`CalibStats::update_workers`], also handed each shard's row range by
    /// [`CalibStats::update_sharded`] without duplicating the batch.
    fn update_rows(&mut self, data: &[f32], rows: usize, workers: usize) {
        let m = self.dim;
        self.fold_diag(data, rows, workers);
        if let Some(rxx) = &mut self.rxx {
            let work = rows.saturating_mul(m).saturating_mul(m + 1) / 2;
            let w = if workers == 0 {
                pool::calib_workers(m, work)
            } else {
                workers.max(1).min(m.max(1))
            };
            match self.rxx_layout {
                RxxLayout::Upper => syrk_upper(rxx, data, rows, m, w),
                RxxLayout::Full => {
                    // partials were folded earlier, so the accumulator holds
                    // a full matrix: fold the batch into a scratch upper
                    // triangle, then mirror-add to keep both halves in sync
                    let mut scratch = Mat64::zeros(m, m);
                    syrk_upper(&mut scratch, data, rows, m, w);
                    mirror_add_upper(rxx, &scratch);
                }
            }
        }
        self.count += rows as u64;
    }

    /// `sum_abs` / `sum_sq` accumulation, threaded over channel chunks when
    /// the batch is large.  Each worker owns a disjoint channel range of the
    /// *running* accumulators and folds its channels in ascending row order
    /// — the same additions in the same order as the serial loop, so the
    /// result is bit-identical for any worker count (a per-batch sub-total
    /// reduced afterwards would round differently on streamed updates).
    fn fold_diag(&mut self, data: &[f32], rows: usize, workers: usize) {
        let m = self.dim;
        let w = if workers == 0 {
            pool::diag_workers(m, rows.saturating_mul(m))
        } else {
            workers.max(1).min(m.max(1))
        };
        if w <= 1 {
            for r in 0..rows {
                let row = &data[r * m..(r + 1) * m];
                for (i, &v) in row.iter().enumerate() {
                    let v = v as f64;
                    self.sum_abs[i] += v.abs();
                    self.sum_sq[i] += v * v;
                }
            }
            return;
        }
        let chunk = (m + w - 1) / w;
        let mut slices: Vec<(usize, &mut [f64], &mut [f64])> = self
            .sum_abs
            .chunks_mut(chunk)
            .zip(self.sum_sq.chunks_mut(chunk))
            .enumerate()
            .map(|(ci, (abs_chunk, sq_chunk))| (ci * chunk, abs_chunk, sq_chunk))
            .collect();
        pool::parallel_for_each_mut(&mut slices, w, |_, (c0, abs_chunk, sq_chunk)| {
            for r in 0..rows {
                let row = &data[r * m + *c0..r * m + *c0 + abs_chunk.len()];
                for (k, &v) in row.iter().enumerate() {
                    let v = v as f64;
                    abs_chunk[k] += v.abs();
                    sq_chunk[k] += v * v;
                }
            }
        });
    }

    /// Sharded fold: split the batch into `shards` contiguous row shards,
    /// accumulate each into its own per-worker [`CalibStats`] on the pool,
    /// then reduce with [`CalibStats::merge`] in fixed shard order.
    /// Deterministic for a fixed shard count, but the f64 reduction order
    /// differs from the streaming fold — use [`CalibStats::update`] when
    /// bit-identity with the streaming fold matters (it is also threaded).
    pub fn update_sharded(&mut self, x: &Tensor, shards: usize) {
        let (rows, cols, data) = x.view_2d();
        assert_eq!(cols, self.dim, "calib dim mismatch");
        let m = self.dim;
        let shards = shards.max(1).min(rows.max(1));
        if shards <= 1 {
            self.update(x);
            return;
        }
        let track = self.rxx.is_some();
        let rows_per = (rows + shards - 1) / shards;
        let parts: Vec<CalibStats> = pool::parallel_map(shards, shards, |si| {
            let r0 = (si * rows_per).min(rows);
            let r1 = ((si + 1) * rows_per).min(rows);
            let mut st = CalibStats::new(m, track);
            if r0 < r1 {
                st.update_rows(&data[r0 * m..r1 * m], r1 - r0, 0);
            }
            st
        });
        for p in &parts {
            self.merge(p);
        }
    }

    /// Fold pre-reduced f32 partials (from the L1 `calib_stats` kernel):
    /// `sumsq[m]`, `sumabs[m]`, `rxx[m,m]` (a **full** symmetric matrix),
    /// over `rows` source rows.  Switches the accumulator to the
    /// [`RxxLayout::Full`] layout, mirroring any raw-tap upper-triangular
    /// data already present (an exact copy, not arithmetic).
    pub fn update_partial(
        &mut self,
        sumsq: &[f32],
        sumabs: &[f32],
        rxx: Option<&[f32]>,
        rows: u64,
    ) -> Result<()> {
        ensure!(sumsq.len() == self.dim && sumabs.len() == self.dim, "partial dim mismatch");
        for i in 0..self.dim {
            self.sum_sq[i] += sumsq[i] as f64;
            self.sum_abs[i] += sumabs[i] as f64;
        }
        if let (Some(acc), Some(part)) = (&mut self.rxx, rxx) {
            ensure!(part.len() == self.dim * self.dim, "rxx partial size");
            if self.rxx_layout == RxxLayout::Upper {
                mirror_upper(acc);
                self.rxx_layout = RxxLayout::Full;
            }
            for (a, &p) in acc.a.iter_mut().zip(part) {
                *a += p as f64;
            }
        }
        self.count += rows;
        Ok(())
    }

    /// Merge another accumulator (parallel calibration shards).  Layouts are
    /// reconciled explicitly: merging a `Full` accumulator promotes the
    /// receiver to `Full` (mirroring its upper triangle first — exact).
    pub fn merge(&mut self, other: &CalibStats) {
        assert_eq!(self.dim, other.dim);
        self.count += other.count;
        for i in 0..self.dim {
            self.sum_abs[i] += other.sum_abs[i];
            self.sum_sq[i] += other.sum_sq[i];
        }
        match (&mut self.rxx, &other.rxx) {
            (Some(a), Some(b)) => match (self.rxx_layout, other.rxx_layout) {
                (RxxLayout::Upper, RxxLayout::Upper) | (RxxLayout::Full, RxxLayout::Full) => {
                    for (x, y) in a.a.iter_mut().zip(&b.a) {
                        *x += y;
                    }
                }
                (RxxLayout::Upper, RxxLayout::Full) => {
                    mirror_upper(a);
                    self.rxx_layout = RxxLayout::Full;
                    for (x, y) in a.a.iter_mut().zip(&b.a) {
                        *x += y;
                    }
                }
                (RxxLayout::Full, RxxLayout::Upper) => mirror_add_upper(a, b),
            },
            (None, None) => {}
            _ => panic!("merging stats with mismatched rxx tracking"),
        }
    }

    /// `E[|x_i|]` (LQER's diagonal).
    pub fn mean_abs(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        self.sum_abs.iter().map(|&s| s / n).collect()
    }

    /// `E[x_i²]` (QERA-approx's diagonal, pre-sqrt).
    pub fn mean_sq(&self) -> Vec<f64> {
        let n = self.count.max(1) as f64;
        self.sum_sq.iter().map(|&s| s / n).collect()
    }

    /// `R_XX = E[xᵀx]`, symmetric.  The accumulation layout is explicit
    /// ([`RxxLayout`]): the raw-tap path mirrors its upper triangle, the
    /// partial-fold path symmetrizes the full matrix — no data-dependent
    /// triangle guessing.  Materializes an m×m matrix; callers that need
    /// several diagnostics should materialize once and use the
    /// [`offdiag_ratio_of`] / [`offdiag_element_ratio_of`] helpers.
    pub fn rxx_mean(&self) -> Option<Mat64> {
        let rxx = self.rxx.as_ref()?;
        let n = self.count.max(1) as f64;
        let m = self.dim;
        match self.rxx_layout {
            RxxLayout::Upper => {
                let mut out = Mat64::zeros(m, m);
                for i in 0..m {
                    for j in i..m {
                        let v = rxx.at(i, j) / n;
                        out.set(i, j, v);
                        out.set(j, i, v);
                    }
                }
                Some(out)
            }
            RxxLayout::Full => {
                let mut out = rxx.clone();
                out.symmetrize();
                Some(out.scale(1.0 / n))
            }
        }
    }

    /// Mean |off-diagonal| element over mean diagonal element of `R_XX` —
    /// the per-element Assumption-1 diagnostic (Figure 5's "dark pixels").
    /// Materializes `rxx_mean` internally; see [`offdiag_element_ratio_of`]
    /// to share one materialization across diagnostics.
    pub fn offdiag_element_ratio(&self) -> Option<f64> {
        Some(offdiag_element_ratio_of(&self.rxx_mean()?))
    }

    /// Off-diagonal mass ratio `‖offdiag(R)‖_F / ‖R‖_F` — the Assumption 1
    /// diagnostic behind Figure 5.  Materializes `rxx_mean` internally; see
    /// [`offdiag_ratio_of`] to share one materialization.
    pub fn offdiag_ratio(&self) -> Option<f64> {
        Some(offdiag_ratio_of(&self.rxx_mean()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn batch(rows: usize, m: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::randn(vec![rows, m], 1.0, &mut rng)
    }

    /// The seed scalar triple loop (pre-blocking reference): the new kernel
    /// must reproduce it bit-for-bit at every worker count.
    fn scalar_reference(x: &Tensor, m: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let (rows, cols, data) = x.view_2d();
        assert_eq!(cols, m);
        let mut sum_abs = vec![0.0f64; m];
        let mut sum_sq = vec![0.0f64; m];
        let mut rxx = vec![0.0f64; m * m];
        for r in 0..rows {
            let row = &data[r * m..(r + 1) * m];
            for (i, &v) in row.iter().enumerate() {
                let v = v as f64;
                sum_abs[i] += v.abs();
                sum_sq[i] += v * v;
            }
            for i in 0..m {
                let vi = row[i] as f64;
                if vi == 0.0 {
                    continue;
                }
                let dst = &mut rxx[i * m..(i + 1) * m];
                for j in i..m {
                    dst[j] += vi * row[j] as f64;
                }
            }
        }
        (sum_abs, sum_sq, rxx)
    }

    #[test]
    fn single_row_known() {
        let x = Tensor::new(vec![1, 3], vec![1.0, -2.0, 0.5]);
        let mut st = CalibStats::new(3, true);
        st.update(&x);
        assert_eq!(st.count, 1);
        assert_eq!(st.mean_abs(), vec![1.0, 2.0, 0.5]);
        assert_eq!(st.mean_sq(), vec![1.0, 4.0, 0.25]);
        let r = st.rxx_mean().unwrap();
        assert!((r.at(0, 1) + 2.0).abs() < 1e-12);
        assert!((r.at(1, 2) + 1.0).abs() < 1e-12);
        assert!((r.at(2, 0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rxx_matches_direct_outer_product() {
        let x = batch(50, 8, 0);
        let mut st = CalibStats::new(8, true);
        st.update(&x);
        let r = st.rxx_mean().unwrap();
        // direct: X^T X / n
        let xm = Mat64::from_tensor(&x);
        let want = xm.matmul_tn(&xm).scale(1.0 / 50.0);
        assert!(r.sub(&want).frob_norm() < 1e-6 * want.frob_norm());
    }

    #[test]
    fn blocked_kernel_matches_scalar_reference_bitexact() {
        // sizes straddle the panel height and j-tile boundaries
        for (rows, m, seed) in [(7usize, 5usize, 1u64), (130, 67, 2), (65, 300, 3)] {
            let x = batch(rows, m, seed);
            let (want_abs, want_sq, want_rxx) = scalar_reference(&x, m);
            for w in [1usize, 4, 8] {
                let mut st = CalibStats::new(m, true);
                st.update_workers(&x, w);
                assert_eq!(st.sum_abs, want_abs, "{rows}x{m} w={w}");
                assert_eq!(st.sum_sq, want_sq, "{rows}x{m} w={w}");
                assert_eq!(st.rxx.as_ref().unwrap().a, want_rxx, "{rows}x{m} w={w}");
            }
        }
    }

    #[test]
    fn streaming_equals_oneshot() {
        let a = batch(30, 6, 1);
        let b = batch(20, 6, 2);
        let mut all = a.data().to_vec();
        all.extend_from_slice(b.data());
        let both = Tensor::new(vec![50, 6], all);
        for w in [1usize, 4, 8] {
            let mut st1 = CalibStats::new(6, true);
            st1.update_workers(&a, w);
            st1.update_workers(&b, w);
            let mut st2 = CalibStats::new(6, true);
            st2.update_workers(&both, w);
            assert_eq!(st1.count, st2.count, "w={w}");
            // streaming and one-shot folds share the per-entry accumulation
            // order (panels ascend through the rows), so they are bit-equal
            assert_eq!(st1.sum_sq, st2.sum_sq, "w={w}");
            assert_eq!(st1.sum_abs, st2.sum_abs, "w={w}");
            assert_eq!(st1.rxx.as_ref().unwrap().a, st2.rxx.as_ref().unwrap().a, "w={w}");
        }
    }

    #[test]
    fn merge_equals_sequential() {
        let a = batch(16, 4, 3);
        let b = batch(24, 4, 4);
        let fold = |w: usize| {
            let mut st1 = CalibStats::new(4, true);
            st1.update_workers(&a, w);
            let mut st2 = CalibStats::new(4, true);
            st2.update_workers(&b, w);
            st1.merge(&st2);
            st1
        };
        let merged = fold(1);
        // merged matches the sequential fold (the f64 reduction order
        // differs — one addition of B's total vs B's rows one by one — so
        // this comparison carries a tolerance, not bit-equality)
        let mut seq = CalibStats::new(4, true);
        seq.update(&a);
        seq.update(&b);
        assert_eq!(merged.count, seq.count);
        for i in 0..4 {
            assert!((merged.sum_sq[i] - seq.sum_sq[i]).abs() < 1e-12);
        }
        let d = merged.rxx_mean().unwrap().sub(&seq.rxx_mean().unwrap()).frob_norm();
        assert!(d < 1e-12);
        // the threaded kernel itself is bit-identical across worker counts
        for w in [4usize, 8] {
            let wn = fold(w);
            assert_eq!(merged.sum_sq, wn.sum_sq, "w={w}");
            assert_eq!(merged.sum_abs, wn.sum_abs, "w={w}");
            assert_eq!(merged.rxx.as_ref().unwrap().a, wn.rxx.as_ref().unwrap().a, "w={w}");
        }
    }

    #[test]
    fn sharded_fold_deterministic_and_close_to_streaming() {
        let x = batch(64, 12, 9);
        let mut streaming = CalibStats::new(12, true);
        streaming.update(&x);
        for shards in [1usize, 3, 8] {
            let mut a = CalibStats::new(12, true);
            a.update_sharded(&x, shards);
            let mut b = CalibStats::new(12, true);
            b.update_sharded(&x, shards);
            assert_eq!(a.count, streaming.count, "shards={shards}");
            // deterministic for a fixed shard count
            assert_eq!(a.sum_sq, b.sum_sq, "shards={shards}");
            assert_eq!(a.rxx.as_ref().unwrap().a, b.rxx.as_ref().unwrap().a, "shards={shards}");
            // and within f64 reduction noise of the streaming fold
            let d = a.rxx_mean().unwrap().sub(&streaming.rxx_mean().unwrap()).frob_norm();
            assert!(d < 1e-9, "shards={shards}: {d}");
            for i in 0..12 {
                assert!((a.sum_sq[i] - streaming.sum_sq[i]).abs() < 1e-9, "shards={shards}");
            }
        }
        // a single shard IS the streaming fold
        let mut one = CalibStats::new(12, true);
        one.update_sharded(&x, 1);
        assert_eq!(one.rxx.as_ref().unwrap().a, streaming.rxx.as_ref().unwrap().a);
    }

    #[test]
    fn partial_fold_matches_raw() {
        let x = batch(32, 5, 5);
        let mut raw = CalibStats::new(5, true);
        raw.update(&x);
        // compute the partials the L1 kernel would emit (f32)
        let x2 = x.as_2d();
        let mut sumsq = vec![0.0f32; 5];
        let mut sumabs = vec![0.0f32; 5];
        let mut rxx = vec![0.0f32; 25];
        for r in 0..32 {
            for i in 0..5 {
                let v = x2.at2(r, i);
                sumsq[i] += v * v;
                sumabs[i] += v.abs();
                for j in 0..5 {
                    rxx[i * 5 + j] += v * x2.at2(r, j);
                }
            }
        }
        let mut part = CalibStats::new(5, true);
        part.update_partial(&sumsq, &sumabs, Some(&rxx), 32).unwrap();
        assert_eq!(part.rxx_layout, RxxLayout::Full);
        for i in 0..5 {
            assert!((raw.mean_sq()[i] - part.mean_sq()[i]).abs() < 1e-4);
        }
        let d = raw.rxx_mean().unwrap().sub(&part.rxx_mean().unwrap()).frob_norm();
        assert!(d < 1e-3);
    }

    #[test]
    fn partial_fold_with_zero_upper_triangle_is_not_misread() {
        // A genuinely sparse partial: only channel correlations on the
        // diagonal (strictly-zero upper triangle).  The old frob_norm()==0
        // triangle-detection heuristic classified layouts by data content;
        // the explicit flag must keep the fold exact.
        let m = 4;
        let sumsq = [4.0f32, 9.0, 0.0, 1.0];
        let sumabs = [2.0f32, 3.0, 0.0, 1.0];
        let mut rxx = vec![0.0f32; m * m];
        rxx[0] = 4.0;
        rxx[5] = 9.0;
        rxx[15] = 1.0;
        let mut st = CalibStats::new(m, true);
        st.update_partial(&sumsq, &sumabs, Some(&rxx), 2).unwrap();
        assert_eq!(st.rxx_layout, RxxLayout::Full);
        let r = st.rxx_mean().unwrap();
        assert_eq!(r.at(0, 0), 2.0);
        assert_eq!(r.at(1, 1), 4.5);
        assert_eq!(r.at(3, 3), 0.5);
        for i in 0..m {
            for j in 0..m {
                if i != j {
                    assert_eq!(r.at(i, j), 0.0, "({i},{j})");
                }
            }
        }
        // an all-zero partial (the old heuristic's trigger case) stays exact
        let zeros = vec![0.0f32; 16];
        let mut z = CalibStats::new(m, true);
        z.update_partial(&[0.0; 4], &[0.0; 4], Some(&zeros), 8).unwrap();
        assert_eq!(z.rxx_mean().unwrap().frob_norm(), 0.0);
        assert_eq!(z.count, 8);
    }

    #[test]
    fn mixed_raw_and_partial_folds_promote_layout() {
        let m = 3;
        let x = batch(10, m, 6);
        // raw fold, then a partial fold on top
        let mut st = CalibStats::new(m, true);
        st.update(&x);
        assert_eq!(st.rxx_layout, RxxLayout::Upper);
        let part: Vec<f32> = (0..m * m)
            .map(|idx| {
                let (i, j) = (idx / m, idx % m);
                ((i * j) as f32 + 1.0) * 0.5 // symmetric: depends on i·j only
            })
            .collect();
        st.update_partial(&[1.0; 3], &[1.0; 3], Some(&part), 5).unwrap();
        assert_eq!(st.rxx_layout, RxxLayout::Full);
        // reference: mirror-free math on the dense sum
        let xm = Mat64::from_tensor(&x);
        let mut want = xm.matmul_tn(&xm);
        for idx in 0..m * m {
            want.a[idx] += part[idx] as f64;
        }
        let got = st.rxx_mean().unwrap();
        let want = want.scale(1.0 / 15.0);
        assert!(got.sub(&want).frob_norm() < 1e-6 * want.frob_norm().max(1.0));
        // raw folds keep working after the promotion (mirror-add path)
        let y = batch(4, m, 7);
        let mut after = st.clone();
        after.update(&y);
        let ym = Mat64::from_tensor(&y);
        let want2 = want.scale(15.0).add(&ym.matmul_tn(&ym)).scale(1.0 / 19.0);
        let got2 = after.rxx_mean().unwrap();
        assert!(got2.sub(&want2).frob_norm() < 1e-6 * want2.frob_norm().max(1.0));
        assert!(got2.is_symmetric(0.0));
    }

    #[test]
    fn merge_reconciles_layouts() {
        let m = 3;
        let x = batch(8, m, 10);
        let part: Vec<f32> = vec![
            1.0, 0.5, 0.25, //
            0.5, 2.0, 0.75, //
            0.25, 0.75, 3.0,
        ];
        let mut upper = CalibStats::new(m, true);
        upper.update(&x);
        let mut full = CalibStats::new(m, true);
        full.update_partial(&[1.0; 3], &[1.0; 3], Some(&part), 4).unwrap();
        // reference sum
        let xm = Mat64::from_tensor(&x);
        let mut want = xm.matmul_tn(&xm);
        for idx in 0..m * m {
            want.a[idx] += part[idx] as f64;
        }
        let want = want.scale(1.0 / 12.0);
        // upper <- full
        let mut a = upper.clone();
        a.merge(&full);
        assert_eq!(a.rxx_layout, RxxLayout::Full);
        assert!(a.rxx_mean().unwrap().sub(&want).frob_norm() < 1e-6);
        // full <- upper
        let mut b = full.clone();
        b.merge(&upper);
        assert_eq!(b.rxx_layout, RxxLayout::Full);
        assert!(b.rxx_mean().unwrap().sub(&want).frob_norm() < 1e-6);
        assert!(b.rxx.as_ref().unwrap().is_symmetric(0.0));
    }

    #[test]
    fn offdiag_ratio_iid_small_correlated_large() {
        // iid gaussian -> R ≈ I -> small ratio
        let mut st = CalibStats::new(16, true);
        st.update(&batch(4000, 16, 6));
        let iid = st.offdiag_ratio().unwrap();
        assert!(iid < 0.25, "{iid}");
        // perfectly correlated dims -> large ratio
        let mut rng = Rng::new(7);
        let mut data = Vec::new();
        for _ in 0..500 {
            let v = rng.normal_f32();
            for _ in 0..16 {
                data.push(v);
            }
        }
        let mut st2 = CalibStats::new(16, true);
        st2.update(&Tensor::new(vec![500, 16], data));
        let corr = st2.offdiag_ratio().unwrap();
        assert!(corr > 0.9, "{corr}");
    }

    #[test]
    fn offdiag_helpers_share_one_materialization() {
        let mut st = CalibStats::new(8, true);
        st.update(&batch(128, 8, 12));
        let r = st.rxx_mean().unwrap();
        assert_eq!(st.offdiag_ratio().unwrap(), offdiag_ratio_of(&r));
        assert_eq!(st.offdiag_element_ratio().unwrap(), offdiag_element_ratio_of(&r));
    }

    #[test]
    fn no_rxx_mode() {
        let mut st = CalibStats::new(4, false);
        st.update(&batch(10, 4, 8));
        assert!(st.rxx_mean().is_none());
        assert!(st.offdiag_ratio().is_none());
        assert_eq!(st.mean_sq().len(), 4);
    }
}
