//! Dense row-major f32 tensors — the coordinator's working representation
//! for weights and activations (device transfers are f32; the numerically
//! sensitive solver math happens in `linalg` on f64).
//!
//! The 2-D multiply kernels mirror [`crate::linalg::mat::Mat64`]: cache
//! blocked (k×j tiles of `B` kept L2-resident) and threaded over contiguous
//! output-row panels via [`crate::util::pool::parallel_chunks_mut`].  Only
//! *output rows* are partitioned and the per-element k-accumulation runs
//! strictly ascending, so results are bit-identical for every worker count
//! (and identical to the previous naive loops) — every consumer of these
//! kernels inherits the speedup with unchanged numerics.  Today those are
//! the low-rank merges (`LowRank::to_tensor` behind every quantized
//! checkpoint materialization and the LoRA merged-weight path) and the
//! native execution backend ([`crate::runtime::NativeModel`]), whose
//! forward/eval/serve matmuls — including the fused-from-packed path in
//! `quant::exec` — all reduce to these kernels.  Nested
//! parallelism is suppressed: a multiply running inside a pool worker
//! stays single-threaded ([`pool::in_pool_worker`]).

use crate::util::pool;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

/// k×j tile of `B`: 64 × 512 f32 ≈ 128 KB per tile.  `pub(crate)` so the
/// fused quantized-execution kernel (`quant::exec`) decodes packed weights
/// in exactly these k-row tiles and shares the accumulation order.
pub(crate) const BLOCK_K: usize = 64;
const BLOCK_J: usize = 512;

/// One k-tile of the blocked kernel: `out[i0..i1, :] += A[i0..i1, k0..k1] ·
/// btile` where `btile` holds only rows `k0..k1` of `B` ([`BLOCK_K`]-row
/// slabs) and `out` holds only the panel rows.  Shared with the fused
/// quantized kernel in `quant::exec`, which decodes each k-tile of a packed
/// weight into a scratch slab and must accumulate in the *identical* order
/// (including the `av == 0.0` skip: skipping vs adding a zero differs
/// bitwise when the accumulator holds `-0.0`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn mm_nn_ktile_f32(
    a: &[f32],
    btile: &[f32],
    k: usize,
    n: usize,
    k0: usize,
    k1: usize,
    i0: usize,
    i1: usize,
    out: &mut [f32],
) {
    for j0 in (0..n).step_by(BLOCK_J) {
        let j1 = (j0 + BLOCK_J).min(n);
        for i in i0..i1 {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[(i - i0) * n + j0..(i - i0) * n + j1];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &btile[(kk - k0) * n + j0..(kk - k0) * n + j1];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// Blocked kernel for one output-row panel: `out[i0..i1, :] += A[i0..i1, :] B`
/// with `A` row-major and `out` holding only the panel rows.  Per output
/// element the k-accumulation runs strictly ascending, so the result is
/// independent of the panel split and of the tile sizes.
pub(crate) fn mm_nn_panel_f32(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    i0: usize,
    i1: usize,
    out: &mut [f32],
) {
    for k0 in (0..k).step_by(BLOCK_K) {
        let k1 = (k0 + BLOCK_K).min(k);
        mm_nn_ktile_f32(a, &b[k0 * n..k1 * n], k, n, k0, k1, i0, i1, out);
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // ------------------------------------------------------------ creation
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn ones(shape: Vec<usize>) -> Self {
        Self::full(shape, 1.0)
    }

    /// iid N(0, std²).
    pub fn randn(shape: Vec<usize>, std: f32, rng: &mut Rng) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: rng.normal_vec(n, std) }
    }

    // ----------------------------------------------------------- accessors
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
    pub fn numel(&self) -> usize {
        self.data.len()
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// 2-D accessors.
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }
    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }
    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    // -------------------------------------------------------------- reshape
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        ensure!(
            shape.iter().product::<usize>() == self.data.len(),
            "reshape {:?} -> {:?}: element count mismatch",
            self.shape,
            shape
        );
        self.shape = shape;
        Ok(self)
    }

    /// View an n-d tensor as 2-D by merging all leading axes.
    pub fn as_2d(&self) -> Tensor {
        let last = *self.shape.last().expect("scalar tensor");
        let rows = self.data.len() / last;
        Tensor { shape: vec![rows, last], data: self.data.clone() }
    }

    /// Borrowed 2-D view `(rows, cols, data)` with all leading axes merged —
    /// the no-copy companion of [`Tensor::as_2d`] for kernels that only need
    /// the flattened row-major layout (e.g. the calibration SYRK fold, which
    /// previously cloned every batch just to read it).
    pub fn view_2d(&self) -> (usize, usize, &[f32]) {
        let last = *self.shape.last().expect("scalar tensor");
        (self.data.len() / last, last, &self.data)
    }

    // ---------------------------------------------------------- arithmetic
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    // ----------------------------------------------------------- reductions
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|&x| x as f64).sum::<f64>() / self.data.len() as f64
    }

    /// Mean squared difference (used for model-output-error experiments).
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = a as f64 - b as f64;
                d * d
            })
            .sum::<f64>()
            / self.data.len() as f64
    }

    /// Row-wise argmax of a 2-D tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        let (r, c) = (self.rows(), self.cols());
        (0..r)
            .map(|i| {
                let row = &self.data[i * c..(i + 1) * c];
                let mut best = 0;
                for j in 1..c {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best
            })
            .collect()
    }

    // -------------------------------------------------------------- linalg
    /// 2-D matmul: self [m,k] x other [k,n] -> [m,n].  Cache-blocked and
    /// auto-threaded over output-row panels; f32 accumulation in ascending-k
    /// order, bit-identical for any worker count (solver-grade math lives
    /// in linalg::Mat64).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        self.matmul_workers(other, 0)
    }

    /// [`Tensor::matmul`] with an explicit worker count (`0` = auto).
    pub fn matmul_workers(&self, other: &Tensor, workers: usize) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul inner dim mismatch");
        let mut out = vec![0.0f32; m * n];
        let w = if workers == 0 {
            pool::matmul_workers(m, m.saturating_mul(k).saturating_mul(n))
        } else {
            workers.max(1).min(m.max(1))
        };
        let rows_per = (m + w - 1) / w.max(1);
        pool::parallel_chunks_mut(&mut out, rows_per * n, w, |ci, chunk| {
            let i0 = ci * rows_per;
            let i1 = i0 + chunk.len() / n.max(1);
            mm_nn_panel_f32(&self.data, &other.data, k, n, i0, i1, chunk);
        });
        Tensor { shape: vec![m, n], data: out }
    }

    /// self [m,k] x otherᵀ where other is [n,k] -> [m,n] (row dot products).
    /// Auto-threaded over output-row panels, bit-identical per worker count.
    pub fn matmul_t(&self, other: &Tensor) -> Tensor {
        self.matmul_t_workers(other, 0)
    }

    /// [`Tensor::matmul_t`] with an explicit worker count (`0` = auto).
    pub fn matmul_t_workers(&self, other: &Tensor, workers: usize) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (n, k2) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul_t inner dim mismatch");
        let mut out = vec![0.0f32; m * n];
        let w = if workers == 0 {
            pool::matmul_workers(m, m.saturating_mul(k).saturating_mul(n))
        } else {
            workers.max(1).min(m.max(1))
        };
        let rows_per = (m + w - 1) / w.max(1);
        pool::parallel_chunks_mut(&mut out, rows_per * n, w, |ci, chunk| {
            let i0 = ci * rows_per;
            let rows = chunk.len() / n.max(1);
            for r in 0..rows {
                let arow = &self.data[(i0 + r) * k..(i0 + r + 1) * k];
                let orow = &mut chunk[r * n..(r + 1) * n];
                for (j, o) in orow.iter_mut().enumerate() {
                    let brow = &other.data[j * k..(j + 1) * k];
                    let mut s = 0.0f32;
                    for (x, y) in arow.iter().zip(brow) {
                        s += x * y;
                    }
                    *o = s;
                }
            }
        });
        Tensor { shape: vec![m, n], data: out }
    }

    pub fn transpose2d(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor { shape: vec![n, m], data: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: usize, cols: usize, v: Vec<f32>) -> Tensor {
        Tensor::new(vec![rows, cols], v)
    }

    #[test]
    fn create_and_access() {
        let t = t2(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn matmul_known() {
        let a = t2(2, 2, vec![1., 2., 3., 4.]);
        let b = t2(2, 2, vec![1., 1., 1., 1.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_t_matches_matmul() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(vec![5, 7], 1.0, &mut rng);
        let b = Tensor::randn(vec![7, 4], 1.0, &mut rng);
        let c1 = a.matmul(&b);
        let c2 = a.matmul_t(&b.transpose2d());
        for (x, y) in c1.data().iter().zip(c2.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    /// Naive i-k-j reference with the same ascending-k accumulation order
    /// as the blocked kernel — results must match bit-for-bit.
    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = a.data[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[i * n + j] += av * b.data[kk * n + j];
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    #[test]
    fn blocked_matches_naive_bitexact_across_block_boundaries() {
        // sizes straddle BLOCK_K/BLOCK_J and panel splits
        let mut rng = Rng::new(7);
        for (m, k, n) in [(70usize, 131usize, 93usize), (1, 300, 5), (65, 64, 513)] {
            let a = Tensor::randn(vec![m, k], 1.0, &mut rng);
            let b = Tensor::randn(vec![k, n], 1.0, &mut rng);
            let want = naive_matmul(&a, &b);
            assert_eq!(a.matmul(&b), want, "{m}x{k}x{n}");
            assert_eq!(a.matmul_workers(&b, 3), want, "{m}x{k}x{n} w=3");
        }
    }

    #[test]
    fn workers_are_bit_identical() {
        let mut rng = Rng::new(8);
        let a = Tensor::randn(vec![70, 90], 1.0, &mut rng);
        let b = Tensor::randn(vec![90, 83], 1.0, &mut rng);
        let serial = a.matmul_workers(&b, 1);
        for w in [2, 3, 4, 8] {
            assert_eq!(serial, a.matmul_workers(&b, w), "matmul w={w}");
        }
        let bt = b.transpose2d();
        let t1 = a.matmul_t_workers(&bt, 1);
        for w in [2, 4] {
            assert_eq!(t1, a.matmul_t_workers(&bt, w), "matmul_t w={w}");
        }
        // and the threaded transposed kernel agrees with the plain one
        for (x, y) in serial.data().iter().zip(t1.data()) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(vec![3, 8], 1.0, &mut rng);
        assert_eq!(a.transpose2d().transpose2d(), a);
    }

    #[test]
    fn reshape_and_as_2d() {
        let t = Tensor::new(vec![2, 3, 4], (0..24).map(|x| x as f32).collect());
        let flat = t.as_2d();
        assert_eq!(flat.shape(), &[6, 4]);
        let back = flat.reshape(vec![2, 3, 4]).unwrap();
        assert_eq!(back.shape(), &[2, 3, 4]);
        assert!(Tensor::zeros(vec![4]).reshape(vec![3]).is_err());
    }

    #[test]
    fn view_2d_matches_as_2d_without_copy() {
        let t = Tensor::new(vec![2, 3, 4], (0..24).map(|x| x as f32).collect());
        let (rows, cols, data) = t.view_2d();
        let flat = t.as_2d();
        assert_eq!((rows, cols), (flat.rows(), flat.cols()));
        assert_eq!(data, flat.data());
        assert!(std::ptr::eq(data.as_ptr(), t.data().as_ptr()));
    }

    #[test]
    fn arithmetic() {
        let a = t2(1, 3, vec![1., 2., 3.]);
        let b = t2(1, 3, vec![0.5, 0.5, 0.5]);
        assert_eq!(a.sub(&b).data(), &[0.5, 1.5, 2.5]);
        assert_eq!(a.add(&b).data(), &[1.5, 2.5, 3.5]);
        let mut c = a.clone();
        c.scale(2.0);
        assert_eq!(c.data(), &[2., 4., 6.]);
        let mut d = a.clone();
        d.add_assign(&b);
        assert_eq!(d.data(), &[1.5, 2.5, 3.5]);
    }

    #[test]
    fn norms_and_stats() {
        let a = t2(1, 4, vec![3., 4., 0., 0.]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-12);
        assert_eq!(a.max_abs(), 4.0);
        assert!((a.mean() - 1.75).abs() < 1e-12);
        let b = t2(1, 4, vec![3., 4., 0., 2.]);
        assert!((a.mse(&b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_rows_works() {
        let a = t2(2, 3, vec![0., 5., 1., 9., 2., 3.]);
        assert_eq!(a.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Rng::new(3);
        let t = Tensor::randn(vec![100, 100], 2.0, &mut rng);
        let mean = t.mean();
        let var = t.data().iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / t.numel() as f64;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 4.0).abs() < 0.2, "{var}");
    }
}
