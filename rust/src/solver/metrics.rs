//! Error metrics for solver evaluation and the paper's figures.

use super::types::SolveOutput;
use crate::linalg::Mat64;
use crate::tensor::Tensor;

/// Problem 1 objective: ‖W − W~ − C_k‖_F.
pub fn weight_error(w: &Tensor, out: &SolveOutput) -> f64 {
    Mat64::from_tensor(&out.merged()).sub(&Mat64::from_tensor(w)).frob_norm()
}

/// Problem 2 objective via Equation (15): `E‖xP‖² = Tr(R_XX P Pᵀ)` with
/// `P = W~ + C_k − W`.
pub fn expected_output_error(p: &Mat64, rxx: &Mat64) -> f64 {
    assert_eq!(p.r, rxx.r);
    // Tr(R P Pᵀ) = Σ_ij (R P)_ij P_ij
    let rp = rxx.matmul(p);
    rp.a.iter().zip(&p.a).map(|(x, y)| x * y).sum()
}

/// Same objective evaluated for a solved layer.
pub fn output_error_of(w: &Tensor, out: &SolveOutput, rxx: &Mat64) -> f64 {
    let p = Mat64::from_tensor(&out.merged()).sub(&Mat64::from_tensor(w));
    expected_output_error(&p, rxx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn trace_identity_vs_sampling() {
        // Equation (15): Tr(R P Pᵀ) == mean ‖xP‖² when R is the sample
        // autocorrelation of the same x.
        let mut rng = Rng::new(0);
        let (m, n, ns) = (8, 5, 2000);
        let x = Tensor::randn(vec![ns, m], 1.0, &mut rng);
        let p = Mat64::from_tensor(&Tensor::randn(vec![m, n], 1.0, &mut rng));
        let xm = Mat64::from_tensor(&x);
        let rxx = xm.matmul_tn(&xm).scale(1.0 / ns as f64);
        let lhs = expected_output_error(&p, &rxx);
        // direct: mean over rows of ||x_r P||²
        let xp = xm.matmul(&p);
        let rhs: f64 = xp.a.iter().map(|v| v * v).sum::<f64>() / ns as f64;
        assert!((lhs - rhs).abs() < 1e-8 * (1.0 + rhs), "{lhs} vs {rhs}");
    }

    #[test]
    fn zero_perturbation_zero_error() {
        let p = Mat64::zeros(6, 4);
        let rxx = Mat64::eye(6);
        assert_eq!(expected_output_error(&p, &rxx), 0.0);
    }

    #[test]
    fn identity_r_is_frobenius() {
        let mut rng = Rng::new(1);
        let p = Mat64::from_tensor(&Tensor::randn(vec![7, 3], 1.0, &mut rng));
        let e = expected_output_error(&p, &Mat64::eye(7));
        assert!((e - p.frob_norm().powi(2)).abs() < 1e-9);
    }

    #[test]
    fn weight_error_of_identity_quant_is_zero() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(vec![4, 8], 1.0, &mut rng);
        let out = SolveOutput::dense_only(w.clone());
        assert!(weight_error(&w, &out) < 1e-12);
    }
}
