//! LoftQ (Li et al. 2023), Algorithm 1: alternate quantizing the residual
//! `W − A B` and refitting `(A, B)` by SVD of the new weight error.
//!
//! The paper's §4.2 pitfall lives here: each iteration monotonically lowers
//! the *weight* error (Figure 6) yet the *model output* error can rise
//! (Figure 1) — reproduced by `benches/paper_figures.rs`.

use super::closed_form::{elapsed_ms, svd_rank_k};
use super::types::{LowRank, SolveOutput, SvdBackend};
use crate::linalg::Mat64;
use crate::quant::QFormat;
use crate::tensor::Tensor;

/// Run `iters` LoftQ iterations (paper recommends 5) with the exact SVD.
pub fn loftq(w: &Tensor, fmt: QFormat, rank: usize, iters: usize) -> SolveOutput {
    loftq_with(w, fmt, rank, iters, SvdBackend::Exact)
}

/// [`loftq`] with an explicit SVD backend (each iteration refits `(A, B)`
/// by a rank-k SVD, so the randomized fast path pays `iters` times over).
pub fn loftq_with(
    w: &Tensor,
    fmt: QFormat,
    rank: usize,
    iters: usize,
    svd: SvdBackend,
) -> SolveOutput {
    let t0 = std::time::Instant::now();
    let (m, n) = (w.rows(), w.cols());
    let wm = Mat64::from_tensor(w);
    let mut lr = LowRank::zeros(m, n, rank);
    let mut w_dq = fmt.qdq(w);
    for _ in 0..iters.max(1) {
        // W_q = q(W − A B)
        let resid = w.sub(&lr.to_tensor());
        w_dq = fmt.qdq(&resid);
        // SVD of the weight error; split Σ symmetrically (LoftQ's A√Σ, √ΣB)
        let err = wm.sub(&Mat64::from_tensor(&w_dq));
        let fac = svd_rank_k(&err, rank, svd);
        let k = rank.min(fac.s.len());
        let mut a = fac.u.cols_head(k);
        let mut b = fac.vt.rows_head(k);
        for j in 0..k {
            let sq = fac.s[j].max(0.0).sqrt();
            for i in 0..a.r {
                a.a[i * k + j] *= sq;
            }
            for c in 0..b.c {
                b.a[j * b.c + c] *= sq;
            }
        }
        lr = LowRank { a: a.to_tensor(), b: b.to_tensor() };
    }
    SolveOutput { w_dq, lowrank: Some(lr), wall_ms: elapsed_ms(t0) }
}

/// Per-iteration weight errors ‖W − W~ − C_k‖_F (Figure 6 series).
pub fn loftq_error_trace(w: &Tensor, fmt: QFormat, rank: usize, iters: usize) -> Vec<f64> {
    (1..=iters)
        .map(|t| {
            let out = loftq(w, fmt, rank, t);
            super::metrics::weight_error(w, &out)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::metrics::weight_error;
    use crate::util::rng::Rng;

    fn fmt() -> QFormat {
        QFormat::Mxint { bits: 2, block: 8 }
    }

    #[test]
    fn one_iteration_equals_zeroquant() {
        let mut rng = Rng::new(0);
        let w = Tensor::randn(vec![12, 8], 1.0, &mut rng);
        let lq = loftq(&w, fmt(), 3, 1);
        let zq = super::super::closed_form::zeroquant_v2(&w, fmt(), 3);
        // same C_k (A/B split differs by the √Σ balancing)
        let c1 = lq.lowrank.unwrap().to_mat();
        let c2 = zq.lowrank.unwrap().to_mat();
        assert!(c1.sub(&c2).frob_norm() < 1e-6 * (1.0 + c1.frob_norm()));
        assert_eq!(lq.w_dq, zq.w_dq);
    }

    #[test]
    fn weight_error_nonincreasing_over_iters() {
        // Figure 6's claim, on aggressive 2-bit quantization
        let mut rng = Rng::new(1);
        let w = Tensor::randn(vec![24, 16], 1.0, &mut rng);
        let trace = loftq_error_trace(&w, fmt(), 4, 6);
        for t in 1..trace.len() {
            assert!(
                trace[t] <= trace[t - 1] * 1.02 + 1e-9,
                "iteration {t}: {} > {}",
                trace[t],
                trace[t - 1]
            );
        }
        // and overall it should actually help vs iteration 1
        assert!(trace[trace.len() - 1] < trace[0]);
    }

    #[test]
    fn beats_zeroquant_on_weight_error() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(vec![24, 16], 1.0, &mut rng);
        let zq = super::super::closed_form::zeroquant_v2(&w, fmt(), 4);
        let lq = loftq(&w, fmt(), 4, 5);
        assert!(weight_error(&w, &lq) <= weight_error(&w, &zq) + 1e-9);
    }

    #[test]
    fn balanced_factors() {
        // LoftQ splits √Σ between A and B: their norms should be comparable
        let mut rng = Rng::new(3);
        let w = Tensor::randn(vec![16, 16], 1.0, &mut rng);
        let lr = loftq(&w, fmt(), 4, 3).lowrank.unwrap();
        let na = lr.a.frob_norm();
        let nb = lr.b.frob_norm();
        assert!(na / nb < 5.0 && nb / na < 5.0, "{na} vs {nb}");
    }
}
