//! Solver data types.

use crate::linalg::Mat64;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Low-rank correction `C_k = A B` (`a: [m,k]`, `b: [k,n]`).
#[derive(Clone, Debug)]
pub struct LowRank {
    pub a: Tensor,
    pub b: Tensor,
}

impl LowRank {
    pub fn zeros(m: usize, n: usize, k: usize) -> Self {
        LowRank { a: Tensor::zeros(vec![m, k]), b: Tensor::zeros(vec![k, n]) }
    }

    pub fn rank(&self) -> usize {
        self.a.cols()
    }

    /// Dense `C_k = A B` in f64.
    pub fn to_mat(&self) -> Mat64 {
        Mat64::from_tensor(&self.a).matmul(&Mat64::from_tensor(&self.b))
    }

    /// Dense `C_k` in f32.
    pub fn to_tensor(&self) -> Tensor {
        self.a.matmul(&self.b)
    }

    /// `W~ + A B` — the merged weight the evaluator feeds to `lm_fwd`.
    pub fn merged_with(&self, w_dq: &Tensor) -> Tensor {
        w_dq.add(&self.to_tensor())
    }

    /// Extra parameters the correction costs (paper's overhead accounting).
    pub fn n_params(&self) -> usize {
        self.a.numel() + self.b.numel()
    }
}

/// One solved layer.
#[derive(Clone, Debug)]
pub struct SolveOutput {
    /// Dequantized weight `W~ = dq(q(W))`.
    pub w_dq: Tensor,
    /// Rank-k correction, `None` for `w-only`.
    pub lowrank: Option<LowRank>,
    /// Solver wall time (Figure 8b / Tables 7-8).
    pub wall_ms: f64,
}

impl SolveOutput {
    pub fn dense_only(w_dq: Tensor) -> Self {
        SolveOutput { w_dq, lowrank: None, wall_ms: 0.0 }
    }

    /// Effective weight `W~ + C_k`.
    pub fn merged(&self) -> Tensor {
        match &self.lowrank {
            Some(lr) => lr.merged_with(&self.w_dq),
            None => self.w_dq.clone(),
        }
    }
}

/// Reconstruction method (paper Table 3's row set + QPEFT baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Quantize only (paper's `w-only`).
    WOnly,
    /// LoRA/QLoRA init: Gaussian A, zero B (no reconstruction).
    QloraZero,
    /// SVD of the weight error (Yao et al. 2023).
    ZeroQuantV2,
    /// Iterative re-quantized SVD (Li et al. 2023), default 5 iterations.
    Loftq { iters: usize },
    /// Activation abs-mean heuristic scale (Zhang et al. 2024a).
    Lqer,
    /// Theorem 2.
    QeraApprox,
    /// Theorem 1.
    QeraExact,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        let s = s.trim().to_lowercase();
        Ok(match s.as_str() {
            "w-only" | "wonly" | "none" => Method::WOnly,
            "qlora" | "qlora-zero" | "lora" => Method::QloraZero,
            "zeroquant-v2" | "zeroquant" | "zq" | "svd" => Method::ZeroQuantV2,
            "lqer" => Method::Lqer,
            "qera-approx" | "qera_approx" | "approx" => Method::QeraApprox,
            "qera-exact" | "qera_exact" | "exact" => Method::QeraExact,
            _ => {
                if let Some(rest) = s.strip_prefix("loftq") {
                    let iters = match rest.strip_prefix(':') {
                        Some(n) => n.parse()?,
                        None if rest.is_empty() => 5,
                        _ => bail!("bad loftq spec '{s}'"),
                    };
                    Method::Loftq { iters }
                } else {
                    bail!("unknown method '{s}'")
                }
            }
        })
    }

    pub fn name(&self) -> String {
        match self {
            Method::WOnly => "w-only".into(),
            Method::QloraZero => "qlora".into(),
            Method::ZeroQuantV2 => "zeroquant-v2".into(),
            Method::Loftq { iters } => format!("loftq:{iters}"),
            Method::Lqer => "lqer".into(),
            Method::QeraApprox => "qera-approx".into(),
            Method::QeraExact => "qera-exact".into(),
        }
    }

    /// Does this method consume calibration statistics?
    pub fn needs_stats(&self) -> bool {
        matches!(self, Method::Lqer | Method::QeraApprox | Method::QeraExact)
    }

    /// Does this method need the full `R_XX` (vs diagonal stats only)?
    pub fn needs_rxx(&self) -> bool {
        matches!(self, Method::QeraExact)
    }

    /// The paper's PTQ method grid (Tables 3/4 rows).
    pub fn ptq_grid() -> Vec<Method> {
        vec![
            Method::WOnly,
            Method::ZeroQuantV2,
            Method::Lqer,
            Method::QeraApprox,
            Method::QeraExact,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lowrank_merge() {
        let mut rng = Rng::new(0);
        let w = Tensor::randn(vec![6, 4], 1.0, &mut rng);
        let lr = LowRank {
            a: Tensor::randn(vec![6, 2], 1.0, &mut rng),
            b: Tensor::randn(vec![2, 4], 1.0, &mut rng),
        };
        let merged = lr.merged_with(&w);
        let want = w.add(&lr.a.matmul(&lr.b));
        assert_eq!(merged, want);
        assert_eq!(lr.rank(), 2);
        assert_eq!(lr.n_params(), 12 + 8);
    }

    #[test]
    fn names_roundtrip() {
        for m in [
            Method::WOnly,
            Method::QloraZero,
            Method::ZeroQuantV2,
            Method::Loftq { iters: 3 },
            Method::Lqer,
            Method::QeraApprox,
            Method::QeraExact,
        ] {
            assert_eq!(Method::parse(&m.name()).unwrap(), m);
        }
    }

    #[test]
    fn stats_flags() {
        assert!(Method::QeraExact.needs_rxx());
        assert!(!Method::QeraApprox.needs_rxx());
        assert!(Method::QeraApprox.needs_stats());
        assert!(!Method::ZeroQuantV2.needs_stats());
        assert_eq!(Method::ptq_grid().len(), 5);
    }
}
