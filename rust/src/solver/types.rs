//! Solver data types.

use crate::linalg::Mat64;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Low-rank correction `C_k = A B` (`a: [m,k]`, `b: [k,n]`).
#[derive(Clone, Debug)]
pub struct LowRank {
    pub a: Tensor,
    pub b: Tensor,
}

impl LowRank {
    pub fn zeros(m: usize, n: usize, k: usize) -> Self {
        LowRank { a: Tensor::zeros(vec![m, k]), b: Tensor::zeros(vec![k, n]) }
    }

    pub fn rank(&self) -> usize {
        self.a.cols()
    }

    /// Dense `C_k = A B` in f64.
    pub fn to_mat(&self) -> Mat64 {
        Mat64::from_tensor(&self.a).matmul(&Mat64::from_tensor(&self.b))
    }

    /// Dense `C_k` in f32.
    pub fn to_tensor(&self) -> Tensor {
        self.a.matmul(&self.b)
    }

    /// `W~ + A B` — the merged weight the evaluator feeds to `lm_fwd`.
    pub fn merged_with(&self, w_dq: &Tensor) -> Tensor {
        w_dq.add(&self.to_tensor())
    }

    /// Extra parameters the correction costs (paper's overhead accounting).
    pub fn n_params(&self) -> usize {
        self.a.numel() + self.b.numel()
    }
}

/// One solved layer.
#[derive(Clone, Debug)]
pub struct SolveOutput {
    /// Dequantized weight `W~ = dq(q(W))`.
    pub w_dq: Tensor,
    /// Rank-k correction, `None` for `w-only`.
    pub lowrank: Option<LowRank>,
    /// Solver wall time (Figure 8b / Tables 7-8).
    pub wall_ms: f64,
}

impl SolveOutput {
    pub fn dense_only(w_dq: Tensor) -> Self {
        SolveOutput { w_dq, lowrank: None, wall_ms: 0.0 }
    }

    /// Effective weight `W~ + C_k`.
    pub fn merged(&self) -> Tensor {
        match &self.lowrank {
            Some(lr) => lr.merged_with(&self.w_dq),
            None => self.w_dq.clone(),
        }
    }
}

/// SVD backend for the truncated factorizations inside the solvers.
///
/// Every closed-form method reduces to a rank-k SVD of a (scaled) error
/// matrix; `Exact` computes the full thin SVD and truncates, `Randomized`
/// uses the Halko sketch ([`crate::linalg::svd_randomized`]) which costs
/// O(mnk) instead of O(min(m,n)³).  `Auto` — the pipeline default — picks
/// the randomized path whenever `rank * 4 <= min(m, n)` (the regime where
/// the sketch wins and its accuracy loss is negligible) and falls back to
/// exact otherwise; `svd_randomized` itself additionally falls back to the
/// exact path when `rank + oversample >= min(m, n)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvdBackend {
    /// Randomized when `rank * 4 <= min(m, n)`, exact otherwise.
    Auto,
    /// Full thin SVD via the Gram trick ([`crate::linalg::svd_thin`]).
    Exact,
    /// Halko randomized range finder with explicit knobs.
    Randomized { oversample: usize, power_iters: usize },
}

impl Default for SvdBackend {
    fn default() -> SvdBackend {
        SvdBackend::Auto
    }
}

impl SvdBackend {
    pub const DEFAULT_OVERSAMPLE: usize = 8;
    pub const DEFAULT_POWER_ITERS: usize = 2;

    /// `auto`, `exact`, or `randomized[:oversample[:power_iters]]`.
    pub fn parse(s: &str) -> Result<SvdBackend> {
        let s = s.trim().to_lowercase();
        match s.as_str() {
            "auto" => return Ok(SvdBackend::Auto),
            "exact" | "thin" | "full" => return Ok(SvdBackend::Exact),
            _ => {}
        }
        let rest = s
            .strip_prefix("randomized")
            .or_else(|| s.strip_prefix("rand"));
        let Some(rest) = rest else {
            bail!(
                "unknown svd backend '{s}' (auto | exact | randomized[:oversample[:power_iters]])"
            )
        };
        let mut oversample = Self::DEFAULT_OVERSAMPLE;
        let mut power_iters = Self::DEFAULT_POWER_ITERS;
        if !rest.is_empty() {
            let Some(spec) = rest.strip_prefix(':') else {
                bail!("bad svd backend spec '{s}'")
            };
            let parts: Vec<&str> = spec.split(':').collect();
            if parts.len() > 2 {
                bail!("bad svd backend spec '{s}' (at most randomized:oversample:power_iters)");
            }
            oversample = parts[0].parse()?;
            if parts.len() == 2 {
                power_iters = parts[1].parse()?;
            }
        }
        Ok(SvdBackend::Randomized { oversample, power_iters })
    }

    pub fn name(&self) -> String {
        match self {
            SvdBackend::Auto => "auto".into(),
            SvdBackend::Exact => "exact".into(),
            SvdBackend::Randomized { oversample, power_iters } => {
                format!("randomized:{oversample}:{power_iters}")
            }
        }
    }

    /// Resolve `Auto` for an `m×n` problem at rank `rank`; `Exact` and
    /// `Randomized` pass through unchanged.
    pub fn resolve(self, m: usize, n: usize, rank: usize) -> SvdBackend {
        match self {
            SvdBackend::Auto => {
                if rank > 0 && rank * 4 <= m.min(n) {
                    SvdBackend::Randomized {
                        oversample: Self::DEFAULT_OVERSAMPLE,
                        power_iters: Self::DEFAULT_POWER_ITERS,
                    }
                } else {
                    SvdBackend::Exact
                }
            }
            b => b,
        }
    }
}

/// Reconstruction method (paper Table 3's row set + QPEFT baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Quantize only (paper's `w-only`).
    WOnly,
    /// LoRA/QLoRA init: Gaussian A, zero B (no reconstruction).
    QloraZero,
    /// SVD of the weight error (Yao et al. 2023).
    ZeroQuantV2,
    /// Iterative re-quantized SVD (Li et al. 2023), default 5 iterations.
    Loftq { iters: usize },
    /// Activation abs-mean heuristic scale (Zhang et al. 2024a).
    Lqer,
    /// Theorem 2.
    QeraApprox,
    /// Theorem 1.
    QeraExact,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        let s = s.trim().to_lowercase();
        Ok(match s.as_str() {
            "w-only" | "wonly" | "none" => Method::WOnly,
            "qlora" | "qlora-zero" | "lora" => Method::QloraZero,
            "zeroquant-v2" | "zeroquant" | "zq" | "svd" => Method::ZeroQuantV2,
            "lqer" => Method::Lqer,
            "qera-approx" | "qera_approx" | "approx" => Method::QeraApprox,
            "qera-exact" | "qera_exact" | "exact" => Method::QeraExact,
            _ => {
                if let Some(rest) = s.strip_prefix("loftq") {
                    let iters = match rest.strip_prefix(':') {
                        Some(n) => n.parse()?,
                        None if rest.is_empty() => 5,
                        _ => bail!("bad loftq spec '{s}'"),
                    };
                    Method::Loftq { iters }
                } else {
                    bail!("unknown method '{s}'")
                }
            }
        })
    }

    pub fn name(&self) -> String {
        match self {
            Method::WOnly => "w-only".into(),
            Method::QloraZero => "qlora".into(),
            Method::ZeroQuantV2 => "zeroquant-v2".into(),
            Method::Loftq { iters } => format!("loftq:{iters}"),
            Method::Lqer => "lqer".into(),
            Method::QeraApprox => "qera-approx".into(),
            Method::QeraExact => "qera-exact".into(),
        }
    }

    /// Does this method consume calibration statistics?
    pub fn needs_stats(&self) -> bool {
        matches!(self, Method::Lqer | Method::QeraApprox | Method::QeraExact)
    }

    /// Does this method need the full `R_XX` (vs diagonal stats only)?
    pub fn needs_rxx(&self) -> bool {
        matches!(self, Method::QeraExact)
    }

    /// The paper's PTQ method grid (Tables 3/4 rows).
    pub fn ptq_grid() -> Vec<Method> {
        vec![
            Method::WOnly,
            Method::ZeroQuantV2,
            Method::Lqer,
            Method::QeraApprox,
            Method::QeraExact,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lowrank_merge() {
        let mut rng = Rng::new(0);
        let w = Tensor::randn(vec![6, 4], 1.0, &mut rng);
        let lr = LowRank {
            a: Tensor::randn(vec![6, 2], 1.0, &mut rng),
            b: Tensor::randn(vec![2, 4], 1.0, &mut rng),
        };
        let merged = lr.merged_with(&w);
        let want = w.add(&lr.a.matmul(&lr.b));
        assert_eq!(merged, want);
        assert_eq!(lr.rank(), 2);
        assert_eq!(lr.n_params(), 12 + 8);
    }

    #[test]
    fn names_roundtrip() {
        for m in [
            Method::WOnly,
            Method::QloraZero,
            Method::ZeroQuantV2,
            Method::Loftq { iters: 3 },
            Method::Lqer,
            Method::QeraApprox,
            Method::QeraExact,
        ] {
            assert_eq!(Method::parse(&m.name()).unwrap(), m);
        }
    }

    #[test]
    fn stats_flags() {
        assert!(Method::QeraExact.needs_rxx());
        assert!(!Method::QeraApprox.needs_rxx());
        assert!(Method::QeraApprox.needs_stats());
        assert!(!Method::ZeroQuantV2.needs_stats());
        assert_eq!(Method::ptq_grid().len(), 5);
    }

    #[test]
    fn svd_backend_parse_and_name() {
        assert_eq!(SvdBackend::parse("auto").unwrap(), SvdBackend::Auto);
        assert_eq!(SvdBackend::parse("exact").unwrap(), SvdBackend::Exact);
        assert_eq!(
            SvdBackend::parse("randomized").unwrap(),
            SvdBackend::Randomized {
                oversample: SvdBackend::DEFAULT_OVERSAMPLE,
                power_iters: SvdBackend::DEFAULT_POWER_ITERS
            }
        );
        assert_eq!(
            SvdBackend::parse("randomized:4:1").unwrap(),
            SvdBackend::Randomized { oversample: 4, power_iters: 1 }
        );
        assert_eq!(
            SvdBackend::parse("rand:12").unwrap(),
            SvdBackend::Randomized {
                oversample: 12,
                power_iters: SvdBackend::DEFAULT_POWER_ITERS
            }
        );
        assert!(SvdBackend::parse("nope").is_err());
        assert!(SvdBackend::parse("randomized:a").is_err());
        assert!(SvdBackend::parse("randomized:1:2:3").is_err());
        for b in [
            SvdBackend::Auto,
            SvdBackend::Exact,
            SvdBackend::Randomized { oversample: 6, power_iters: 3 },
        ] {
            assert_eq!(SvdBackend::parse(&b.name()).unwrap(), b);
        }
        assert_eq!(SvdBackend::default(), SvdBackend::Auto);
    }

    #[test]
    fn svd_backend_auto_resolution() {
        // small rank relative to the matrix -> randomized
        let r = SvdBackend::Auto.resolve(64, 256, 8);
        assert!(matches!(r, SvdBackend::Randomized { .. }));
        // large rank or tiny matrix -> exact
        assert_eq!(SvdBackend::Auto.resolve(16, 16, 8), SvdBackend::Exact);
        assert_eq!(SvdBackend::Auto.resolve(64, 64, 0), SvdBackend::Exact);
        // explicit choices pass through
        assert_eq!(SvdBackend::Exact.resolve(1024, 1024, 1), SvdBackend::Exact);
        let fixed = SvdBackend::Randomized { oversample: 2, power_iters: 0 };
        assert_eq!(fixed.resolve(8, 8, 8), fixed);
    }
}
