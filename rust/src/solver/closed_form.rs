//! Closed-form solvers: ZeroQuant-V2, LQER, QERA-approx, QERA-exact.
//!
//! All share the scaled-SVD skeleton (Algorithm 2 generalized):
//!
//! ```text
//!   W~ = dq(q(W));  E = W − W~
//!   U Σ Vᵀ = SVD(S_left · E)
//!   A = S_left⁻¹ U_k,   B = Σ_k Vᵀ_k
//! ```
//!
//! with `S_left = I` (ZeroQuant-V2), `diag(E[|x|])` (LQER),
//! `diag(√E[x²])` (QERA-approx, Theorem 2), `R_XX^{1/2}` (QERA-exact,
//! Theorem 1 — the un-scale is `(R_XX^{1/2})⁻¹` with Remark 1's clamping).
//!
//! The truncated SVD itself goes through [`SvdBackend`], and QERA-exact's
//! `(R^{1/2}, R^{-1/2})` pair through [`PsdBackend`]: the `*_with` variants
//! take the backends explicitly (the pipeline threads its
//! `PipelineConfig::{svd, psd}` knobs down here).  The [`qera_exact`] and
//! [`qera_approx`] short names default to `Auto`, matching the pipeline,
//! so callers outside the pipeline get the rank-aware fast paths too
//! (`Auto` resolves to the exact path on small problems, preserving the
//! theorem-level guarantees the unit tests assert); [`zeroquant_v2`],
//! [`lqer`], and `loftq` keep the exact SVD for baseline fidelity.  Every
//! solve is wall-clock timed into [`SolveOutput::wall_ms`].

use super::types::{LowRank, SolveOutput, SvdBackend};
use crate::linalg::{psd_sqrt_pair_with, svd_randomized, svd_thin, Mat64, PsdBackend, SvdResult};
use crate::quant::QFormat;
use crate::tensor::Tensor;
use std::time::Instant;

/// Numerical floor for diagonal scales (Remark 2: E[x_i²] > 0 in practice;
/// the floor guards dead channels in synthetic corpora).
const DIAG_FLOOR: f64 = 1e-12;

/// Rank-k SVD with backend dispatch (`Auto` resolved per problem size).
pub(crate) fn svd_rank_k(e: &Mat64, rank: usize, svd: SvdBackend) -> SvdResult {
    match svd.resolve(e.r, e.c, rank) {
        SvdBackend::Randomized { oversample, power_iters } => {
            svd_randomized(e, rank, oversample, power_iters)
        }
        _ => svd_thin(e),
    }
}

pub(crate) fn elapsed_ms(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3
}

/// Plain SVD of the weight quantization error (Problem 1 / Eckart–Young).
pub fn zeroquant_v2(w: &Tensor, fmt: QFormat, rank: usize) -> SolveOutput {
    zeroquant_v2_with(w, fmt, rank, SvdBackend::Exact)
}

/// [`zeroquant_v2`] with an explicit SVD backend.
pub fn zeroquant_v2_with(w: &Tensor, fmt: QFormat, rank: usize, svd: SvdBackend) -> SolveOutput {
    let t0 = Instant::now();
    let w_dq = fmt.qdq(w);
    let err = Mat64::from_tensor(w).sub(&Mat64::from_tensor(&w_dq));
    let fac = svd_rank_k(&err, rank, svd);
    let (a, b) = fac.factors_k(rank);
    SolveOutput {
        w_dq,
        lowrank: Some(LowRank { a: a.to_tensor(), b: b.to_tensor() }),
        wall_ms: elapsed_ms(t0),
    }
}

/// Shared scaled-SVD core for the diagonal-scale methods.
fn diag_scaled(
    w: &Tensor,
    fmt: QFormat,
    rank: usize,
    scale: &[f64],
    svd: SvdBackend,
) -> SolveOutput {
    let t0 = Instant::now();
    let w_dq = fmt.qdq(w);
    let err = Mat64::from_tensor(w).sub(&Mat64::from_tensor(&w_dq));
    assert_eq!(scale.len(), err.r, "scale dim != weight rows");
    let s: Vec<f64> = scale.iter().map(|&v| v.max(DIAG_FLOOR)).collect();
    let scaled = err.scale_rows(&s);
    let fac = svd_rank_k(&scaled, rank, svd);
    let (mut a, b) = fac.factors_k(rank);
    // un-scale: A = S⁻¹ U_k
    let inv: Vec<f64> = s.iter().map(|&v| 1.0 / v).collect();
    a = a.scale_rows(&inv);
    SolveOutput {
        w_dq,
        lowrank: Some(LowRank { a: a.to_tensor(), b: b.to_tensor() }),
        wall_ms: elapsed_ms(t0),
    }
}

/// LQER (Zhang et al. 2024a): heuristic `S = diag(E[|x_i|])`.
pub fn lqer(w: &Tensor, fmt: QFormat, rank: usize, mean_abs: &[f64]) -> SolveOutput {
    lqer_with(w, fmt, rank, mean_abs, SvdBackend::Exact)
}

/// [`lqer`] with an explicit SVD backend.
pub fn lqer_with(
    w: &Tensor,
    fmt: QFormat,
    rank: usize,
    mean_abs: &[f64],
    svd: SvdBackend,
) -> SolveOutput {
    diag_scaled(w, fmt, rank, mean_abs, svd)
}

/// QERA-approx (Theorem 2): `S = diag(√E[x_i²])`.
///
/// Behavior change: this wrapper previously hardcoded [`SvdBackend::Exact`];
/// it now uses [`SvdBackend::Auto`] (the pipeline default), so standalone
/// callers get the randomized fast path on large layers.  `Auto` still
/// resolves to the exact SVD whenever `rank * 4 > min(m, n)`.
pub fn qera_approx(w: &Tensor, fmt: QFormat, rank: usize, mean_sq: &[f64]) -> SolveOutput {
    qera_approx_with(w, fmt, rank, mean_sq, SvdBackend::Auto)
}

/// [`qera_approx`] with an explicit SVD backend.
pub fn qera_approx_with(
    w: &Tensor,
    fmt: QFormat,
    rank: usize,
    mean_sq: &[f64],
    svd: SvdBackend,
) -> SolveOutput {
    let s: Vec<f64> = mean_sq.iter().map(|&v| v.max(0.0).sqrt()).collect();
    diag_scaled(w, fmt, rank, &s, svd)
}

/// QERA-exact (Theorem 1): `C_k = (R½)⁻¹ SVD_k(R½ (W − W~))`.
///
/// Behavior change: this wrapper previously hardcoded the exact backends;
/// it now uses the `Auto` ones (the pipeline defaults), so standalone
/// callers get both rank-aware fast paths — the randomized SVD and the
/// low-rank `(R^{1/2}, R^{-1/2})` split.  Both `Auto`s still resolve to
/// the exact algorithms whenever the rank is too close to the problem
/// size.
pub fn qera_exact(w: &Tensor, fmt: QFormat, rank: usize, rxx: &Mat64) -> SolveOutput {
    qera_exact_with(w, fmt, rank, rxx, SvdBackend::Auto, PsdBackend::Auto)
}

/// [`qera_exact`] with explicit SVD and PSD backends (the pipeline's
/// `PipelineConfig::{svd, psd}` knobs end up here).
pub fn qera_exact_with(
    w: &Tensor,
    fmt: QFormat,
    rank: usize,
    rxx: &Mat64,
    svd: SvdBackend,
    psd: PsdBackend,
) -> SolveOutput {
    let t0 = Instant::now();
    let w_dq = fmt.qdq(w);
    let err = Mat64::from_tensor(w).sub(&Mat64::from_tensor(&w_dq));
    assert_eq!(rxx.r, err.r, "R_XX dim != weight rows");
    let (rh, rh_inv) = psd_sqrt_pair_with(rxx, crate::linalg::psd::EIG_CLAMP_REL, psd, rank);
    let scaled = rh.matmul(&err);
    let fac = svd_rank_k(&scaled, rank, svd);
    let (u_k, b) = fac.factors_k(rank);
    let a = rh_inv.matmul(&u_k);
    SolveOutput {
        w_dq,
        lowrank: Some(LowRank { a: a.to_tensor(), b: b.to_tensor() }),
        wall_ms: elapsed_ms(t0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::metrics::expected_output_error;
    use crate::util::rng::Rng;

    fn fmt() -> QFormat {
        QFormat::Mxint { bits: 3, block: 8 }
    }

    #[test]
    fn identity_rxx_equals_zeroquant() {
        let mut rng = Rng::new(0);
        let w = Tensor::randn(vec![12, 8], 1.0, &mut rng);
        let eye = Mat64::eye(12);
        let zq = zeroquant_v2(&w, fmt(), 3);
        let ex = qera_exact(&w, fmt(), 3, &eye);
        let c1 = zq.lowrank.unwrap().to_mat();
        let c2 = ex.lowrank.unwrap().to_mat();
        assert!(c1.sub(&c2).frob_norm() < 1e-7 * (1.0 + c1.frob_norm()));
    }

    #[test]
    fn diagonal_rxx_approx_equals_exact() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(vec![16, 8], 1.0, &mut rng);
        let d: Vec<f64> = (0..16).map(|_| (rng.normal()).exp()).collect();
        let rxx = Mat64::diag(&d);
        let ex = qera_exact(&w, fmt(), 3, &rxx).lowrank.unwrap().to_mat();
        let ap = qera_approx(&w, fmt(), 3, &d).lowrank.unwrap().to_mat();
        assert!(ex.sub(&ap).frob_norm() < 1e-7 * (1.0 + ex.frob_norm()));
    }

    #[test]
    fn uniform_scale_lqer_equals_zeroquant() {
        // with constant activation magnitudes the LQER heuristic degenerates
        // to plain SVD
        let mut rng = Rng::new(2);
        let w = Tensor::randn(vec![8, 8], 1.0, &mut rng);
        let s = vec![0.7f64; 8];
        let lq = lqer(&w, fmt(), 2, &s).lowrank.unwrap().to_mat();
        let zq = zeroquant_v2(&w, fmt(), 2).lowrank.unwrap().to_mat();
        assert!(lq.sub(&zq).frob_norm() < 1e-7 * (1.0 + zq.frob_norm()));
    }

    #[test]
    fn exact_optimality_via_trace_objective() {
        // E||xP||² = Tr(R P Pᵀ): the exact solver's C must minimize it
        // against small perturbations of (A, B).
        let (w, _stats, rxx) = crate::solver::tests::instance(12, 8, 256, 3);
        let out = qera_exact(&w, fmt(), 3, &rxx);
        let wm = Mat64::from_tensor(&w);
        let base_p = Mat64::from_tensor(&out.merged()).sub(&wm);
        let base = expected_output_error(&base_p, &rxx);
        let lr = out.lowrank.unwrap();
        let mut rng = Rng::new(9);
        for _ in 0..6 {
            let da = Tensor::randn(vec![12, 3], 0.02, &mut rng);
            let db = Tensor::randn(vec![3, 8], 0.02, &mut rng);
            let mut a2 = lr.a.clone();
            a2.add_assign(&da);
            let mut b2 = lr.b.clone();
            b2.add_assign(&db);
            let pert = LowRank { a: a2, b: b2 };
            let p = Mat64::from_tensor(&pert.merged_with(&out.w_dq)).sub(&wm);
            let e = expected_output_error(&p, &rxx);
            assert!(e >= base - 1e-9, "perturbation improved the optimum: {e} < {base}");
        }
    }

    #[test]
    fn scales_cancel_in_reconstruction_at_full_rank() {
        // any invertible scale gives C_k == E at k = min(m,n)
        let mut rng = Rng::new(4);
        let w = Tensor::randn(vec![6, 8], 1.0, &mut rng);
        let werr = {
            let wdq = fmt().qdq(&w);
            Mat64::from_tensor(&w).sub(&Mat64::from_tensor(&wdq))
        };
        let s: Vec<f64> = (0..6).map(|i| 0.5 + i as f64).collect();
        let c = lqer(&w, fmt(), 6, &s).lowrank.unwrap().to_mat();
        assert!(c.sub(&werr).frob_norm() < 1e-6 * (1.0 + werr.frob_norm()));
    }

    #[test]
    fn dead_channel_floor_keeps_finite() {
        let mut rng = Rng::new(5);
        let w = Tensor::randn(vec![8, 8], 1.0, &mut rng);
        let mut s = vec![1.0f64; 8];
        s[3] = 0.0; // dead input channel
        let out = qera_approx(&w, fmt(), 2, &s);
        let lr = out.lowrank.unwrap();
        assert!(lr.a.data().iter().all(|v| v.is_finite()));
        assert!(lr.b.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn solves_report_nonzero_wall_time() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(vec![48, 48], 1.0, &mut rng);
        let out = zeroquant_v2(&w, fmt(), 4);
        assert!(out.wall_ms > 0.0, "{}", out.wall_ms);
        let ex = qera_exact(&w, fmt(), 4, &Mat64::eye(48));
        assert!(ex.wall_ms > 0.0);
    }

    #[test]
    fn randomized_backend_close_to_exact() {
        // explicit randomized backend on a matrix large enough to engage
        // the sketch; a flat quantization-noise spectrum is the worst case,
        // so allow a few percent over the Eckart–Young optimum
        let mut rng = Rng::new(7);
        let w = Tensor::randn(vec![64, 96], 1.0, &mut rng);
        let rank = 8;
        let exact = zeroquant_v2_with(&w, fmt(), rank, SvdBackend::Exact);
        let rand = zeroquant_v2_with(
            &w,
            fmt(),
            rank,
            SvdBackend::Randomized { oversample: 8, power_iters: 2 },
        );
        let wm = Mat64::from_tensor(&w);
        let e_exact = Mat64::from_tensor(&exact.merged()).sub(&wm).frob_norm();
        let e_rand = Mat64::from_tensor(&rand.merged()).sub(&wm).frob_norm();
        assert!(e_rand >= e_exact * (1.0 - 1e-9), "rand beat the optimum?");
        assert!(e_rand <= e_exact * 1.05, "{e_rand} vs {e_exact}");
    }

    #[test]
    fn lowrank_psd_backend_close_to_exact() {
        // the flat-tail whitening split must not move the Problem-2
        // objective: the head of R_XX (which decides the rank-k correction)
        // is represented exactly, so the gap to the optimum stays tiny
        let (w, _stats, rxx) = crate::solver::tests::instance(64, 48, 512, 10);
        let rank = 4;
        let exact =
            qera_exact_with(&w, fmt(), rank, &rxx, SvdBackend::Exact, PsdBackend::Exact);
        let low = qera_exact_with(
            &w,
            fmt(),
            rank,
            &rxx,
            SvdBackend::Exact,
            PsdBackend::LowRank { rank_mult: 4, power_iters: 32 },
        );
        let wm = Mat64::from_tensor(&w);
        let e_exact =
            expected_output_error(&Mat64::from_tensor(&exact.merged()).sub(&wm), &rxx);
        let e_low = expected_output_error(&Mat64::from_tensor(&low.merged()).sub(&wm), &rxx);
        // 1e-6 margin: merged() rounds through f32 (~1e-7 relative noise)
        assert!(e_low >= e_exact * (1.0 - 1e-6), "low-rank beat the optimum?");
        assert!(
            (e_low - e_exact).abs() <= 5e-2 * e_exact.max(1e-12),
            "{e_low} vs {e_exact}"
        );
    }

    #[test]
    fn auto_backend_resolves_by_shape() {
        // Auto on a tiny matrix must give bit-identical output to Exact
        let mut rng = Rng::new(8);
        let w = Tensor::randn(vec![12, 10], 1.0, &mut rng);
        let auto = zeroquant_v2_with(&w, fmt(), 4, SvdBackend::Auto);
        let exact = zeroquant_v2_with(&w, fmt(), 4, SvdBackend::Exact);
        let la = auto.lowrank.unwrap();
        let le = exact.lowrank.unwrap();
        assert_eq!(la.a, le.a);
        assert_eq!(la.b, le.b);
    }
}
