//! Quantization-error-reconstruction solvers.
//!
//! Every method takes a pretrained weight `W [m,n]`, a quantizer
//! [`crate::quant::QFormat`], a target rank `k` and (for the
//! activation-aware methods) per-site [`crate::stats::CalibStats`], and
//! produces the dequantized weight `W~` plus low-rank terms `(A_k, B_k)`
//! with `C_k = A_k B_k`:
//!
//! | method        | objective                  | scale matrix            |
//! |---------------|----------------------------|-------------------------|
//! | `w-only`      | —                          | —                       |
//! | `zeroquant-v2`| min ‖W−W~−C‖_F (Problem 1) | I                       |
//! | `loftq`       | Problem 1, iterated        | I (re-quantizing)       |
//! | `lqer`        | heuristic                  | diag(E[\|x\|])          |
//! | `qera-approx` | Problem 2 + Assumption 1   | diag(√E[x²]) (Thm 2)    |
//! | `qera-exact`  | Problem 2                  | R_XX^{1/2}   (Thm 1)    |

pub mod types;
pub mod closed_form;
pub mod loftq;
pub mod metrics;

pub use closed_form::{
    lqer, lqer_with, qera_approx, qera_approx_with, qera_exact, qera_exact_with, zeroquant_v2,
    zeroquant_v2_with,
};
pub use loftq::{loftq, loftq_with};
pub use metrics::{expected_output_error, weight_error};
pub use types::{LowRank, Method, SolveOutput, SvdBackend};

pub use crate::linalg::PsdBackend;

use crate::quant::QFormat;
use crate::stats::CalibStats;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Solve one layer with the given method and the exact SVD/PSD backends
/// (theorem-grade results, no rank-aware approximations).
///
/// `stats` is required for `lqer` / `qera-*`; `rng_seed` only affects
/// `qlora` (Gaussian A, zero B).  The pipeline goes through [`solve_with`]
/// to select the rank-aware randomized fast paths.
pub fn solve(
    method: Method,
    w: &Tensor,
    fmt: QFormat,
    rank: usize,
    stats: Option<&CalibStats>,
    rng_seed: u64,
) -> Result<SolveOutput> {
    solve_with(method, w, fmt, rank, stats, rng_seed, SvdBackend::Exact, PsdBackend::Exact)
}

/// [`solve`] with explicit [`SvdBackend`] / [`PsdBackend`] knobs (the
/// `PipelineConfig::{svd, psd}` knobs end up here; `psd` only affects
/// `qera-exact`).  Every solve reports a real wall time.
pub fn solve_with(
    method: Method,
    w: &Tensor,
    fmt: QFormat,
    rank: usize,
    stats: Option<&CalibStats>,
    rng_seed: u64,
    svd: SvdBackend,
    psd: PsdBackend,
) -> Result<SolveOutput> {
    let t0 = std::time::Instant::now();
    let mut out = match method {
        Method::WOnly => SolveOutput::dense_only(fmt.qdq(w)),
        Method::QloraZero => {
            let wdq = fmt.qdq(w);
            let (m, n) = (w.rows(), w.cols());
            let mut rng = crate::util::rng::Rng::new(rng_seed);
            // LoRA init: A ~ N(0, 1/rank), B = 0 (adapter starts as a no-op)
            let a = Tensor::randn(vec![m, rank], (1.0 / rank as f32).sqrt(), &mut rng);
            let b = Tensor::zeros(vec![rank, n]);
            SolveOutput { w_dq: wdq, lowrank: Some(LowRank { a, b }), wall_ms: 0.0 }
        }
        Method::ZeroQuantV2 => zeroquant_v2_with(w, fmt, rank, svd),
        Method::Loftq { iters } => loftq_with(w, fmt, rank, iters, svd),
        Method::Lqer => {
            let st = need_stats(stats, "lqer")?;
            lqer_with(w, fmt, rank, &st.mean_abs(), svd)
        }
        Method::QeraApprox => {
            let st = need_stats(stats, "qera-approx")?;
            qera_approx_with(w, fmt, rank, &st.mean_sq(), svd)
        }
        Method::QeraExact => {
            let st = need_stats(stats, "qera-exact")?;
            let rxx = match st.rxx_mean() {
                Some(r) => r,
                None => bail!("qera-exact needs R_XX tracking enabled in calibration"),
            };
            qera_exact_with(w, fmt, rank, &rxx, svd, psd)
        }
    };
    // the closed-form solvers time themselves; cover the dense-only and
    // qlora branches here so nothing reports a zero wall time
    if out.wall_ms == 0.0 {
        out.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    }
    Ok(out)
}

fn need_stats<'a>(stats: Option<&'a CalibStats>, who: &str) -> Result<&'a CalibStats> {
    match stats {
        Some(s) if s.count > 0 => Ok(s),
        _ => bail!("{who} requires calibration statistics"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat64;
    use crate::util::rng::Rng;

    /// Anisotropic correlated activations + a weight matrix — the shape of
    /// a real LLM layer (mirrors python/tests/test_qera_theory.py).
    pub(crate) fn instance(
        m: usize,
        n: usize,
        nsamp: usize,
        seed: u64,
    ) -> (Tensor, CalibStats, Mat64) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(vec![m, n], 1.0, &mut rng);
        let mut mix = Mat64::zeros(m, m);
        let scales: Vec<f64> = (0..m).map(|_| (rng.normal() * 1.2).exp()).collect();
        for i in 0..m {
            for j in 0..m {
                mix.set(i, j, rng.normal() / (m as f64).sqrt() * scales[j]);
            }
        }
        let mut stats = CalibStats::new(m, true);
        let mut xs = Vec::with_capacity(nsamp * m);
        for _ in 0..nsamp {
            let z: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            for j in 0..m {
                let mut v = 0.0;
                for i in 0..m {
                    v += z[i] * mix.at(i, j);
                }
                xs.push(v as f32);
            }
        }
        let x = Tensor::new(vec![nsamp, m], xs);
        stats.update(&x);
        let xm = Mat64::from_tensor(&x);
        let rxx = xm.matmul_tn(&xm).scale(1.0 / nsamp as f64);
        (w, stats, rxx)
    }

    fn fmt() -> QFormat {
        QFormat::Mxint { bits: 3, block: 8 }
    }

    fn out_err(w: &Tensor, out: &SolveOutput, rxx: &Mat64) -> f64 {
        let mut approx = Mat64::from_tensor(&out.w_dq);
        if let Some(lr) = &out.lowrank {
            approx = approx.add(&lr.to_mat());
        }
        let p = approx.sub(&Mat64::from_tensor(w));
        expected_output_error(&p, rxx)
    }

    #[test]
    fn qera_exact_optimal_among_methods() {
        for seed in 0..3 {
            let (w, stats, rxx) = instance(16, 16, 256, seed);
            let k = 4;
            let e_zq =
                out_err(&w, &solve(Method::ZeroQuantV2, &w, fmt(), k, None, 0).unwrap(), &rxx);
            let e_lq =
                out_err(&w, &solve(Method::Lqer, &w, fmt(), k, Some(&stats), 0).unwrap(), &rxx);
            let e_ap = out_err(
                &w,
                &solve(Method::QeraApprox, &w, fmt(), k, Some(&stats), 0).unwrap(),
                &rxx,
            );
            let e_ex = out_err(
                &w,
                &solve(Method::QeraExact, &w, fmt(), k, Some(&stats), 0).unwrap(),
                &rxx,
            );
            assert!(e_ex <= e_zq * (1.0 + 1e-9), "seed {seed}: exact {e_ex} vs zq {e_zq}");
            assert!(e_ex <= e_lq * (1.0 + 1e-9), "seed {seed}: exact {e_ex} vs lqer {e_lq}");
            assert!(e_ex <= e_ap * (1.0 + 1e-9), "seed {seed}: exact {e_ex} vs approx {e_ap}");
        }
    }

    #[test]
    fn zeroquant_minimizes_weight_error() {
        let (w, stats, _) = instance(16, 16, 128, 7);
        let k = 3;
        let zq = solve(Method::ZeroQuantV2, &w, fmt(), k, None, 0).unwrap();
        let ex = solve(Method::QeraExact, &w, fmt(), k, Some(&stats), 0).unwrap();
        let we_zq = weight_error(&w, &zq);
        let we_ex = weight_error(&w, &ex);
        assert!(we_zq <= we_ex + 1e-9, "zq {we_zq} vs exact {we_ex}");
    }

    #[test]
    fn wonly_has_no_lowrank() {
        let (w, _, _) = instance(8, 8, 32, 1);
        let out = solve(Method::WOnly, &w, fmt(), 4, None, 0).unwrap();
        assert!(out.lowrank.is_none());
    }

    #[test]
    fn qlora_adapter_is_noop_at_init() {
        let (w, _, _) = instance(8, 8, 32, 2);
        let out = solve(Method::QloraZero, &w, fmt(), 4, None, 42).unwrap();
        let lr = out.lowrank.unwrap();
        assert!(lr.b.frob_norm() == 0.0);
        assert!(lr.a.frob_norm() > 0.0);
    }

    #[test]
    fn missing_stats_errors() {
        let (w, _, _) = instance(8, 8, 32, 3);
        assert!(solve(Method::QeraExact, &w, fmt(), 2, None, 0).is_err());
        assert!(solve(Method::QeraApprox, &w, fmt(), 2, None, 0).is_err());
        let empty = CalibStats::new(8, true);
        assert!(solve(Method::Lqer, &w, fmt(), 2, Some(&empty), 0).is_err());
    }

    #[test]
    fn rank_monotone_for_qera() {
        let (w, stats, rxx) = instance(16, 16, 256, 4);
        let mut prev = f64::INFINITY;
        for k in [1usize, 2, 4, 8, 16] {
            let e = out_err(
                &w,
                &solve(Method::QeraExact, &w, fmt(), k, Some(&stats), 0).unwrap(),
                &rxx,
            );
            assert!(e <= prev + 1e-9, "k={k}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn full_rank_recovers_everything() {
        let (w, stats, rxx) = instance(8, 8, 128, 5);
        let k = 8; // = min(m,n)
        let e =
            out_err(&w, &solve(Method::QeraExact, &w, fmt(), k, Some(&stats), 0).unwrap(), &rxx);
        assert!(e < 1e-8, "{e}");
        let e2 = out_err(&w, &solve(Method::ZeroQuantV2, &w, fmt(), k, None, 0).unwrap(), &rxx);
        assert!(e2 < 1e-8, "{e2}");
    }

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("qera-exact").unwrap(), Method::QeraExact);
        assert_eq!(Method::parse("loftq:5").unwrap(), Method::Loftq { iters: 5 });
        assert_eq!(Method::parse("loftq").unwrap(), Method::Loftq { iters: 5 });
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn randomized_backend_tracks_exact_output_error() {
        // large-ish layer so Randomized actually engages (l = rank + 8 < m)
        let (w, stats, rxx) = instance(48, 48, 384, 6);
        let rank = 6;
        let rand = SvdBackend::Randomized { oversample: 8, power_iters: 2 };
        for method in [Method::QeraExact, Method::QeraApprox] {
            let st = if method.needs_stats() { Some(&stats) } else { None };
            let e_exact = out_err(
                &w,
                &solve_with(method, &w, fmt(), rank, st, 0, SvdBackend::Exact, PsdBackend::Exact)
                    .unwrap(),
                &rxx,
            );
            let e_rand = out_err(
                &w,
                &solve_with(method, &w, fmt(), rank, st, 0, rand, PsdBackend::Exact).unwrap(),
                &rxx,
            );
            assert!(
                (e_rand - e_exact).abs() <= 5e-2 * e_exact.max(1e-12),
                "{}: rand {e_rand} vs exact {e_exact}",
                method.name()
            );
        }
    }

    #[test]
    fn solve_reports_wall_time_for_every_method() {
        let (w, stats, _) = instance(16, 16, 64, 8);
        for method in [Method::WOnly, Method::QloraZero, Method::ZeroQuantV2, Method::QeraExact] {
            let st = if method.needs_stats() { Some(&stats) } else { None };
            let out = solve(method, &w, fmt(), 4, st, 1).unwrap();
            assert!(out.wall_ms > 0.0, "{} reported zero wall time", method.name());
        }
    }
}
