//! Synthetic data substrate (the offline stand-ins for WikiText2 /
//! SlimPajama / GLUE / GSM8K — see DESIGN.md §6).

pub mod corpus;
pub mod tokenizer;
pub mod tasks;
pub mod batch;

pub use batch::{lm_batches, BatchIter};
pub use corpus::Corpus;
pub use tasks::{ClsExample, Task, TASK_NAMES};
pub use tokenizer::Tokenizer;
