//! The downstream-task suite: GLUE-like classification tasks (Table 1 /
//! Tables 9-10), a regression-style analog of STSB (Figure 2), and an
//! arithmetic-QA generation task standing in for GSM8K (Table 2).
//!
//! Design constraints (so results mean something):
//! * every label is computable from the token sequence alone — the task is
//!   noiseless, so fine-tuned accuracy differences reflect optimization
//!   quality (the paper's QPEFT comparison), not label noise;
//! * tasks span a difficulty range: some are learnable by pooled linear
//!   probes (SST-like), some need positional reasoning (RTE/CoLA-like);
//! * samples are drawn on top of the pretraining corpus statistics so the
//!   quantized backbone's features are in-distribution.

use super::corpus::CorpusModel;
use crate::util::rng::Rng;

/// One classification example.
#[derive(Clone, Debug)]
pub struct ClsExample {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// The eight GLUE-analog tasks.
pub const TASK_NAMES: [&str; 8] =
    ["parity", "majority", "firstclass", "pattern", "maxrun", "ordered", "count", "pairdist"];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Task {
    pub id: usize,
}

impl Task {
    pub fn by_name(name: &str) -> Option<Task> {
        TASK_NAMES.iter().position(|&n| n == name).map(|id| Task { id })
    }

    pub fn name(&self) -> &'static str {
        TASK_NAMES[self.id]
    }

    pub fn n_classes(&self) -> usize {
        match self.name() {
            "pairdist" => 4,
            _ => 2,
        }
    }

    /// Nominal dataset size (mirrors GLUE's spread: MNLI is ~100x RTE —
    /// drives the paper's small-task convergence story, Figure 2).
    pub fn train_size(&self) -> usize {
        match self.name() {
            "parity" | "majority" => 2048, // the "big" tasks
            "pattern" | "count" => 1024,
            _ => 256, // the "small" tasks (RTE/MRPC/STSB-like)
        }
    }

    /// Generate a labelled sample set over corpus-like text.
    pub fn generate(&self, n: usize, vocab: usize, seq: usize, seed: u64) -> Vec<ClsExample> {
        let model = CorpusModel::new(vocab, 1234);
        let mut rng = Rng::new(seed ^ (self.id as u64) << 32);
        let marked_a = 7 % vocab as i32; // frequent marker tokens
        let marked_b = 11 % vocab as i32;
        (0..n)
            .map(|_| {
                // corpus-distributed body
                let mut tokens = Vec::with_capacity(seq);
                let mut state = rng.below(vocab);
                for _ in 0..seq {
                    state = sample_state(&model, state, &mut rng);
                    tokens.push(state as i32);
                }
                // plant task-relevant structure + compute the label
                let label = self.plant_and_label(&mut tokens, marked_a, marked_b, vocab, &mut rng);
                ClsExample { tokens, label }
            })
            .collect()
    }

    fn plant_and_label(
        &self,
        tokens: &mut [i32],
        a: i32,
        b: i32,
        vocab: usize,
        rng: &mut Rng,
    ) -> i32 {
        let seq = tokens.len();
        match self.name() {
            "parity" => {
                // plant 0..8 copies of `a` at random positions
                let k = rng.below(9);
                for _ in 0..k {
                    tokens[rng.below(seq)] = a;
                }
                (tokens.iter().filter(|&&t| t == a).count() % 2) as i32
            }
            "majority" => {
                let ka = rng.below(10);
                let kb = rng.below(10);
                for _ in 0..ka {
                    tokens[rng.below(seq)] = a;
                }
                for _ in 0..kb {
                    tokens[rng.below(seq)] = b;
                }
                let ca = tokens.iter().filter(|&&t| t == a).count();
                let cb = tokens.iter().filter(|&&t| t == b).count();
                (ca > cb) as i32
            }
            "firstclass" => {
                // class of the first token: low half vs high half of vocab
                let t = rng.below(vocab) as i32;
                tokens[0] = t;
                (t as usize >= vocab / 2) as i32
            }
            "pattern" => {
                // does the bigram (a, b) occur?
                let has = rng.below(2) == 1;
                if has {
                    let p = rng.below(seq - 1);
                    tokens[p] = a;
                    tokens[p + 1] = b;
                } else {
                    // scrub accidental occurrences
                    for i in 0..seq - 1 {
                        if tokens[i] == a && tokens[i + 1] == b {
                            tokens[i + 1] = (b + 1) % vocab as i32;
                        }
                    }
                }
                let mut found = 0;
                for i in 0..seq - 1 {
                    if tokens[i] == a && tokens[i + 1] == b {
                        found = 1;
                        break;
                    }
                }
                found
            }
            "maxrun" => {
                // plant a run of `a` of length 2..6; label: run >= 4
                let len = 2 + rng.below(5);
                let p = rng.below(seq - len);
                for i in 0..len {
                    tokens[p + i] = a;
                }
                let mut best = 0;
                let mut cur = 0;
                for &t in tokens.iter() {
                    if t == a {
                        cur += 1;
                        best = best.max(cur);
                    } else {
                        cur = 0;
                    }
                }
                (best >= 4) as i32
            }
            "ordered" => {
                // three probe tokens at fixed slots; label: strictly increasing
                let s0 = seq / 4;
                let vals: Vec<i32> =
                    (0..3).map(|_| rng.below(vocab) as i32).collect();
                tokens[s0] = vals[0];
                tokens[2 * s0] = vals[1];
                tokens[3 * s0] = vals[2];
                (vals[0] < vals[1] && vals[1] < vals[2]) as i32
            }
            "count" => {
                let k = rng.below(11);
                for _ in 0..k {
                    tokens[rng.below(seq)] = a;
                }
                (tokens.iter().filter(|&&t| t == a).count() > 5) as i32
            }
            "pairdist" => {
                // distance between the planted a and b, bucketed into 4
                let d = 1 + rng.below(seq - 2);
                let p = rng.below(seq - d);
                // scrub other copies so "first occurrence" is well defined
                for t in tokens.iter_mut() {
                    if *t == a || *t == b {
                        *t = (a + b + 1) % vocab as i32;
                    }
                }
                tokens[p] = a;
                tokens[p + d] = b;
                let bucket = (d * 4 / seq).min(3);
                bucket as i32
            }
            _ => unreachable!(),
        }
    }
}

fn sample_state(model: &CorpusModel, state: usize, rng: &mut Rng) -> usize {
    model.sample(state, rng.f32())
}

/// STSB-analog regression pairs: similarity = overlap between two halves,
/// label in [0, 1] (the trainer buckets it for the CE head and reports a
/// correlation metric like the paper's P/S Corr).
pub fn stsb_like(n: usize, vocab: usize, seq: usize, seed: u64) -> Vec<(Vec<i32>, f32)> {
    let mut rng = Rng::new(seed ^ 0x57_5b);
    (0..n)
        .map(|_| {
            let half = seq / 2;
            let mut tokens = vec![0i32; seq];
            for t in tokens.iter_mut().take(half) {
                *t = rng.below(vocab) as i32;
            }
            // second half: copy a fraction `sim` of the first half
            let sim = rng.f32();
            for i in 0..half {
                tokens[half + i] = if rng.f32() < sim {
                    tokens[i]
                } else {
                    rng.below(vocab) as i32
                };
            }
            let overlap = (0..half).filter(|&i| tokens[i] == tokens[half + i]).count();
            (tokens, overlap as f32 / half as f32)
        })
        .collect()
}

/// GSM8K-analog: modular arithmetic rendered in token space:
/// `[Q] a [+] b [=] c0 c1` where c = (a + b) mod M is spelled in two digit
/// tokens.  Accuracy = exact match of the answer tokens under teacher
/// forcing (argmax).
pub struct ArithmeticQA {
    pub modulus: usize,
    pub q_tok: i32,
    pub plus_tok: i32,
    pub eq_tok: i32,
    pub digit_base: i32,
}

impl ArithmeticQA {
    pub fn new(vocab: usize) -> Self {
        // digits live in a reserved sub-range; modulus chosen so answers
        // need two digit tokens
        let base = (vocab / 2) as i32;
        ArithmeticQA {
            modulus: 100,
            q_tok: 1,
            plus_tok: 2,
            eq_tok: 3,
            digit_base: base,
        }
    }

    /// (tokens, answer positions) — answers occupy the two slots after `=`.
    pub fn generate(&self, n: usize, seq: usize, seed: u64) -> Vec<(Vec<i32>, Vec<usize>)> {
        let mut rng = Rng::new(seed ^ 0xA517);
        (0..n)
            .map(|_| {
                let a = rng.below(self.modulus);
                let b = rng.below(self.modulus);
                let c = (a + b) % self.modulus;
                let mut tokens = vec![0i32; seq];
                // filler prefix keeps the question at a fixed tail position
                for t in tokens.iter_mut() {
                    *t = 4 + rng.below(30) as i32;
                }
                let p = seq - 9;
                tokens[p] = self.q_tok;
                tokens[p + 1] = self.digit_base + (a / 10) as i32;
                tokens[p + 2] = self.digit_base + (a % 10) as i32;
                tokens[p + 3] = self.plus_tok;
                tokens[p + 4] = self.digit_base + (b / 10) as i32;
                tokens[p + 5] = self.digit_base + (b % 10) as i32;
                tokens[p + 6] = self.eq_tok;
                tokens[p + 7] = self.digit_base + (c / 10) as i32;
                tokens[p + 8] = self.digit_base + (c % 10) as i32; // = seq-1
                // the two answer tokens are the teacher-forced targets of
                // positions seq-3 and seq-2
                let answer_positions = vec![seq - 2, seq - 1];
                (tokens, answer_positions)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate() {
        for name in TASK_NAMES {
            let task = Task::by_name(name).unwrap();
            let data = task.generate(64, 256, 32, 42);
            assert_eq!(data.len(), 64);
            for ex in &data {
                assert_eq!(ex.tokens.len(), 32);
                assert!(ex.tokens.iter().all(|&t| (0..256).contains(&t)), "{name}");
                assert!((0..task.n_classes() as i32).contains(&ex.label), "{name}");
            }
        }
    }

    #[test]
    fn labels_balanced_enough() {
        for name in TASK_NAMES {
            let task = Task::by_name(name).unwrap();
            let data = task.generate(512, 256, 32, 1);
            let mut counts = vec![0usize; task.n_classes()];
            for ex in &data {
                counts[ex.label as usize] += 1;
            }
            let min = *counts.iter().min().unwrap();
            assert!(
                min * task.n_classes() >= 512 / 8,
                "{name}: degenerate label distribution {counts:?}"
            );
        }
    }

    #[test]
    fn labels_deterministic() {
        let task = Task::by_name("parity").unwrap();
        let a = task.generate(32, 128, 16, 7);
        let b = task.generate(32, 128, 16, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.label, y.label);
        }
    }

    #[test]
    fn labels_consistent_with_tokens() {
        // recompute parity labels from tokens
        let task = Task::by_name("parity").unwrap();
        let data = task.generate(128, 256, 32, 3);
        for ex in &data {
            let c = ex.tokens.iter().filter(|&&t| t == 7).count();
            assert_eq!(ex.label, (c % 2) as i32);
        }
    }

    #[test]
    fn stsb_scores_in_range() {
        let data = stsb_like(100, 128, 32, 5);
        for (tokens, y) in &data {
            assert_eq!(tokens.len(), 32);
            assert!((0.0..=1.0).contains(y));
        }
        // scores should spread over the range
        let lo = data.iter().filter(|(_, y)| *y < 0.3).count();
        let hi = data.iter().filter(|(_, y)| *y > 0.7).count();
        assert!(lo > 5 && hi > 5);
    }

    #[test]
    fn arithmetic_layout() {
        let qa = ArithmeticQA::new(256);
        let data = qa.generate(16, 64, 9);
        for (tokens, pos) in &data {
            assert_eq!(tokens.len(), 64);
            assert_eq!(pos, &vec![62, 63]);
            assert_eq!(tokens[64 - 9], qa.q_tok);
            assert_eq!(tokens[64 - 6], qa.plus_tok);
            assert_eq!(tokens[64 - 3], qa.eq_tok);
            // answer digits encode (a + b) % 100
            let a = (tokens[64 - 8] - qa.digit_base) * 10 + (tokens[64 - 7] - qa.digit_base);
            let b = (tokens[64 - 5] - qa.digit_base) * 10 + (tokens[64 - 4] - qa.digit_base);
            let c = (tokens[64 - 2] - qa.digit_base) * 10 + (tokens[64 - 1] - qa.digit_base);
            assert_eq!(c, (a + b) % 100, "{a} + {b} != {c}");
        }
    }
}
