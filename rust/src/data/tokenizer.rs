//! Word-level tokenizer over the synthetic vocabulary.
//!
//! The corpus is generated directly in token space; the tokenizer gives the
//! serving path human-readable text: token `t` ↔ a deterministic pseudo-word
//! whose length follows the Zipf rank (frequent tokens are short, like real
//! text).  Round-trip exact.

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    words: Vec<String>,
    lookup: HashMap<String, i32>,
}

const CONSONANTS: &[u8] = b"bcdfghjklmnprstvz";
const VOWELS: &[u8] = b"aeiou";

fn word_for(t: usize) -> String {
    // syllabic pseudo-word; length grows with rank
    let syllables = 1 + (t / 48).min(3);
    let mut s = String::new();
    let mut x = (t as u64).wrapping_mul(0x9E3779B97F4A7C15) | 1;
    for _ in 0..syllables {
        x ^= x >> 13;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        let c = CONSONANTS[(x % 17) as usize] as char;
        let v = VOWELS[((x >> 8) % 5) as usize] as char;
        s.push(c);
        s.push(v);
    }
    // disambiguate collisions with a rank suffix
    s.push_str(&format!("{}", t % 97));
    s
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Tokenizer {
        let mut words = Vec::with_capacity(vocab);
        let mut lookup = HashMap::with_capacity(vocab);
        for t in 0..vocab {
            let mut w = word_for(t);
            while lookup.contains_key(&w) {
                w.push('x');
            }
            lookup.insert(w.clone(), t as i32);
            words.push(w);
        }
        Tokenizer { words, lookup }
    }

    pub fn vocab(&self) -> usize {
        self.words.len()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| self.words.get(t as usize).map(String::as_str).unwrap_or("<unk>"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .filter_map(|w| self.lookup.get(w).copied())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tok = Tokenizer::new(256);
        let ids: Vec<i32> = vec![0, 5, 17, 255, 100, 3];
        let text = tok.decode(&ids);
        assert_eq!(tok.encode(&text), ids);
    }

    #[test]
    fn unique_words() {
        let tok = Tokenizer::new(512);
        let mut set = std::collections::HashSet::new();
        for w in &tok.words {
            assert!(set.insert(w.clone()), "duplicate word {w}");
        }
    }

    #[test]
    fn frequent_tokens_short() {
        let tok = Tokenizer::new(512);
        assert!(tok.words[0].len() < tok.words[400].len());
    }

    #[test]
    fn unknown_words_skipped() {
        let tok = Tokenizer::new(64);
        assert!(tok.encode("zzz-not-a-word qqq").is_empty());
    }
}
