//! Batching: fixed-shape (B, S) windows over token streams and shuffled
//! classification minibatches (the artifacts have static shapes; everything
//! here pads/packs to them).

use super::corpus::Corpus;
use super::tasks::ClsExample;
use crate::util::rng::Rng;

/// Contiguous non-overlapping LM batches: tokens [B,S], targets [B,S]
/// (next-token).  Deterministic order.
pub struct BatchIter<'a> {
    corpus: &'a Corpus,
    batch: usize,
    seq: usize,
    cursor: usize,
}

impl<'a> BatchIter<'a> {
    pub fn n_batches(&self) -> usize {
        let per = self.batch * (self.seq + 1);
        self.corpus.len() / per
    }
}

pub fn lm_batches(corpus: &Corpus, batch: usize, seq: usize) -> BatchIter<'_> {
    BatchIter { corpus, batch, seq, cursor: 0 }
}

impl<'a> Iterator for BatchIter<'a> {
    /// (tokens [B*S], targets [B*S]) flat row-major.
    type Item = (Vec<i32>, Vec<i32>);

    fn next(&mut self) -> Option<Self::Item> {
        let need = self.batch * (self.seq + 1);
        if self.cursor + need > self.corpus.len() {
            return None;
        }
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for b in 0..self.batch {
            let start = self.cursor + b * (self.seq + 1);
            let window = &self.corpus.tokens[start..start + self.seq + 1];
            tokens.extend_from_slice(&window[..self.seq]);
            targets.extend_from_slice(&window[1..]);
        }
        self.cursor += need;
        Some((tokens, targets))
    }
}

/// Random-order LM batches for training (windows sampled with replacement).
pub fn lm_batch_random(
    corpus: &Corpus,
    batch: usize,
    seq: usize,
    rng: &mut Rng,
) -> (Vec<i32>, Vec<i32>) {
    let mut tokens = Vec::with_capacity(batch * seq);
    let mut targets = Vec::with_capacity(batch * seq);
    let span = corpus.len() - seq - 1;
    for _ in 0..batch {
        let start = rng.below(span);
        let window = &corpus.tokens[start..start + seq + 1];
        tokens.extend_from_slice(&window[..seq]);
        targets.extend_from_slice(&window[1..]);
    }
    (tokens, targets)
}

/// Shuffled epoch of classification minibatches, final ragged batch padded
/// by repeating earlier examples (labels carried so accuracy can mask them).
pub struct ClsBatch {
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
    /// How many rows are real (non-padding).
    pub real: usize,
}

pub fn cls_epoch(data: &[ClsExample], batch: usize, rng: &mut Rng) -> Vec<ClsBatch> {
    assert!(!data.is_empty());
    let mut order: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut order);
    let seq = data[0].tokens.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i < order.len() {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut labels = Vec::with_capacity(batch);
        let real = (order.len() - i).min(batch);
        for b in 0..batch {
            let idx = if b < real { order[i + b] } else { order[(i + b) % order.len()] };
            tokens.extend_from_slice(&data[idx].tokens);
            labels.push(data[idx].label);
        }
        out.push(ClsBatch { tokens, labels, real });
        i += real;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::Task;

    #[test]
    fn lm_batches_cover_stream() {
        let c = Corpus::generate(64, 1000, 0);
        let it = lm_batches(&c, 2, 16);
        let n = it.n_batches();
        let batches: Vec<_> = lm_batches(&c, 2, 16).collect();
        assert_eq!(batches.len(), n);
        assert!(n >= 1000 / (2 * 17) - 1);
        for (t, y) in &batches {
            assert_eq!(t.len(), 32);
            assert_eq!(y.len(), 32);
        }
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let c = Corpus::generate(64, 200, 1);
        let (t, y) = lm_batches(&c, 1, 16).next().unwrap();
        assert_eq!(&t[1..], &y[..15]);
        assert_eq!(t[..], c.tokens[..16]);
        assert_eq!(y[15], c.tokens[16]);
    }

    #[test]
    fn random_batches_shaped() {
        let c = Corpus::generate(64, 500, 2);
        let mut rng = Rng::new(0);
        let (t, y) = lm_batch_random(&c, 4, 8, &mut rng);
        assert_eq!(t.len(), 32);
        assert_eq!(y.len(), 32);
    }

    #[test]
    fn cls_epoch_covers_all_once() {
        let task = Task::by_name("parity").unwrap();
        let data = task.generate(50, 64, 16, 0);
        let mut rng = Rng::new(1);
        let batches = cls_epoch(&data, 8, &mut rng);
        let total_real: usize = batches.iter().map(|b| b.real).sum();
        assert_eq!(total_real, 50);
        for b in &batches {
            assert_eq!(b.labels.len(), 8);
            assert_eq!(b.tokens.len(), 8 * 16);
        }
    }
}
