//! Zipf–Markov synthetic corpus — the pretraining/calibration text.
//!
//! Token statistics of natural language that matter for this paper:
//! heavy-tailed unigram frequencies (→ anisotropic embedding statistics,
//! outlier channels — exactly what separates QERA from plain SVD) and
//! learnable local structure (→ a pretrained LM beats the unigram entropy,
//! so perplexity deltas between quantization methods are meaningful).
//!
//! Construction: unigram base `p(t) ∝ 1/(t+3)^1.08`; each state `s` (the
//! previous token) mixes the base with a sparse "grammar" of ~8 preferred
//! successors chosen pseudo-randomly per state.  Sampling uses per-state
//! cumulative tables + binary search.  Fully deterministic from the seed.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Corpus {
    pub vocab: usize,
    pub tokens: Vec<i32>,
}

/// Markov chain over the vocabulary with Zipf marginals.
pub struct CorpusModel {
    vocab: usize,
    /// Per-state cumulative transition table [vocab * vocab].
    cum: Vec<f32>,
}

impl CorpusModel {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 16);
        let mut rng = Rng::new(seed ^ 0xC0_7B05);
        // Zipf base
        let base: Vec<f64> = (0..vocab).map(|t| 1.0 / ((t + 3) as f64).powf(1.08)).collect();
        let base_sum: f64 = base.iter().sum();
        // cumulative of the base for Zipf-distributed grammar choices
        let mut base_cum = Vec::with_capacity(vocab);
        let mut acc0 = 0.0f64;
        for b in &base {
            acc0 += b / base_sum;
            base_cum.push(acc0);
        }
        let zipf_pick = |u: f64| -> usize {
            base_cum.partition_point(|&c| c < u).min(vocab - 1)
        };
        let mut cum = vec![0.0f32; vocab * vocab];
        for s in 0..vocab {
            // sparse grammar: 8 preferred successors (Zipf-distributed so the
            // marginals stay heavy-tailed) with geometric weights
            let mut extra = vec![0.0f64; vocab];
            let mut st = rng.fork(s as u64);
            let mut wgt = 1.0f64;
            for _ in 0..8 {
                let t = zipf_pick(st.f64());
                extra[t] += wgt;
                wgt *= 0.7;
            }
            let extra_sum: f64 = extra.iter().sum();
            let mut acc = 0.0f64;
            for t in 0..vocab {
                let p = 0.6 * base[t] / base_sum + 0.4 * extra[t] / extra_sum;
                acc += p;
                cum[s * vocab + t] = acc as f32;
            }
            // normalize the tail exactly to 1
            let norm = acc as f32;
            for t in 0..vocab {
                cum[s * vocab + t] /= norm;
            }
            cum[s * vocab + vocab - 1] = 1.0;
        }
        CorpusModel { vocab, cum }
    }

    /// Sample the successor of `state` given uniform `u in [0,1)`.
    #[inline]
    pub fn sample(&self, state: usize, u: f32) -> usize {
        let row = &self.cum[state * self.vocab..(state + 1) * self.vocab];
        // binary search for the first cum >= u
        let mut lo = 0usize;
        let mut hi = self.vocab - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if row[mid] >= u {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    }

    /// True next-token distribution entropy averaged over the stationary-ish
    /// sample — a lower bound for achievable LM loss (diagnostics).
    pub fn conditional_entropy_estimate(&self, n_states: usize) -> f64 {
        let mut h = 0.0f64;
        let states = n_states.min(self.vocab);
        for s in 0..states {
            let row = &self.cum[s * self.vocab..(s + 1) * self.vocab];
            let mut prev = 0.0f32;
            let mut hs = 0.0f64;
            for &c in row {
                let p = (c - prev) as f64;
                if p > 0.0 {
                    hs -= p * p.ln();
                }
                prev = c;
            }
            h += hs;
        }
        h / states as f64
    }
}

impl Corpus {
    /// Generate `n_tokens` tokens.
    pub fn generate(vocab: usize, n_tokens: usize, seed: u64) -> Corpus {
        let model = CorpusModel::new(vocab, seed);
        let mut rng = Rng::new(seed);
        let mut tokens = Vec::with_capacity(n_tokens);
        let mut state = rng.below(vocab);
        for _ in 0..n_tokens {
            state = model.sample(state, rng.f32());
            tokens.push(state as i32);
        }
        Corpus { vocab, tokens }
    }

    /// Split into train/validation token streams.
    pub fn split(&self, val_frac: f64) -> (Corpus, Corpus) {
        let cut = ((self.tokens.len() as f64) * (1.0 - val_frac)) as usize;
        (
            Corpus { vocab: self.vocab, tokens: self.tokens[..cut].to_vec() },
            Corpus { vocab: self.vocab, tokens: self.tokens[cut..].to_vec() },
        )
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Empirical unigram entropy (nats) — sanity metric.
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Corpus::generate(64, 1000, 42);
        let b = Corpus::generate(64, 1000, 42);
        assert_eq!(a.tokens, b.tokens);
        let c = Corpus::generate(64, 1000, 43);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::generate(128, 5000, 0);
        assert!(c.tokens.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn zipf_marginals() {
        // frequent tokens should be much more common than the tail
        let c = Corpus::generate(256, 100_000, 1);
        let mut counts = vec![0usize; 256];
        for &t in &c.tokens {
            counts[t as usize] += 1;
        }
        let head: usize = counts[..16].iter().sum();
        let tail: usize = counts[128..].iter().sum();
        assert!(head > 3 * tail, "head {head} vs tail {tail}");
    }

    #[test]
    fn structure_is_learnable() {
        // conditional entropy must sit well below unigram entropy: a bigram
        // model (and hence the transformer) can beat the unigram baseline
        let c = Corpus::generate(256, 50_000, 2);
        let model = CorpusModel::new(256, 2);
        let h_cond = model.conditional_entropy_estimate(256);
        let h_uni = c.unigram_entropy();
        assert!(
            h_cond < h_uni - 0.3,
            "conditional {h_cond} not much below unigram {h_uni}"
        );
    }

    #[test]
    fn split_preserves_tokens() {
        let c = Corpus::generate(64, 1000, 3);
        let (tr, va) = c.split(0.1);
        assert_eq!(tr.len() + va.len(), 1000);
        assert_eq!(va.len(), 100);
        assert_eq!(&c.tokens[..900], &tr.tokens[..]);
    }

    #[test]
    fn cumulative_rows_valid() {
        let m = CorpusModel::new(64, 7);
        for s in 0..64 {
            let row = &m.cum[s * 64..(s + 1) * 64];
            assert!(row.windows(2).all(|w| w[1] >= w[0]));
            assert!((row[63] - 1.0).abs() < 1e-6);
        }
    }
}
