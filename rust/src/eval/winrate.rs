//! AlpacaEval-analog win rate (Figure 4).
//!
//! The paper judges instruction-following quality of quantized models
//! against the `w-only` counterpart with GPT-4.  Offline substitute: the
//! *reference-agreement judge* — for each prompt, a method "wins" if its
//! per-token NLL of the BF16 reference continuation is lower than the
//! opponent's (i.e. its distribution stays closer to the full-precision
//! model where it matters: on the tokens the reference model would emit).
//! Deterministic, and preserves the comparative structure of the metric.

use crate::data::batch::lm_batches;
use crate::data::corpus::Corpus;
use crate::model::ModelSpec;
use crate::runtime::{exec::lm_inputs, Registry};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Per-prompt NLL of `params` against greedy continuations of `reference`.
fn prompt_scores(
    reg: &Registry,
    spec: &ModelSpec,
    reference: &[Tensor],
    params: &[Tensor],
    corpus: &Corpus,
    max_batches: usize,
) -> Result<Vec<f64>> {
    let fwd = reg.load(&format!("lm_fwd.{}", spec.name))?;
    let nll = reg.load(&format!("lm_nll.{}", spec.name))?;
    let shape = [spec.batch, spec.seq];
    let v = spec.vocab;
    let mut scores = Vec::new();
    for (bi, (tokens, _)) in lm_batches(corpus, spec.batch, spec.seq).enumerate() {
        if bi >= max_batches {
            break;
        }
        // reference greedy "continuation": argmax of the reference logits at
        // each position = the tokens the BF16 model prefers
        let r = fwd.run(&lm_inputs(&tokens, None, &shape, reference))?;
        let mut ref_targets = Vec::with_capacity(spec.batch * spec.seq);
        for row in 0..spec.batch * spec.seq {
            let l = &r[0].data()[row * v..(row + 1) * v];
            let mut best = 0;
            for j in 1..v {
                if l[j] > l[best] {
                    best = j;
                }
            }
            ref_targets.push(best as i32);
        }
        // candidate's NLL of those targets, per prompt (= batch row)
        let out = nll.run(&lm_inputs(&tokens, Some((&ref_targets, &shape)), &shape, params))?;
        for b in 0..spec.batch {
            let row = &out[0].data()[b * spec.seq..(b + 1) * spec.seq];
            scores.push(row.iter().map(|&x| x as f64).sum::<f64>() / spec.seq as f64);
        }
    }
    ensure!(!scores.is_empty(), "no prompts evaluated");
    Ok(scores)
}

/// Length-controlled-style win rate of `candidate` vs `opponent`, judged by
/// closeness to `reference`.  Ties count half.
pub fn win_rate(
    reg: &Registry,
    spec: &ModelSpec,
    reference: &[Tensor],
    candidate: &[Tensor],
    opponent: &[Tensor],
    corpus: &Corpus,
    max_batches: usize,
) -> Result<f64> {
    let c = prompt_scores(reg, spec, reference, candidate, corpus, max_batches)?;
    let o = prompt_scores(reg, spec, reference, opponent, corpus, max_batches)?;
    let mut wins = 0.0f64;
    for (a, b) in c.iter().zip(&o) {
        if a < b {
            wins += 1.0;
        } else if a == b {
            wins += 0.5;
        }
    }
    Ok(wins / c.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    #[test]
    fn lighter_quantization_wins() {
        // Figure 4's comparative structure: a 4-bit model must stay closer
        // to the reference than its 2-bit counterpart
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let reference = init_params(&spec, &mut Rng::new(0));
        let ckpt = crate::model::Checkpoint::new(spec.clone(), reference.clone());
        let q4 = crate::coordinator::quantize(
            &ckpt,
            &crate::coordinator::PipelineConfig::new(
                crate::solver::Method::WOnly,
                crate::quant::QFormat::Mxint { bits: 4, block: 32 },
                0,
            ),
            None,
        )
        .unwrap();
        let q2 = crate::coordinator::quantize(
            &ckpt,
            &crate::coordinator::PipelineConfig::new(
                crate::solver::Method::WOnly,
                crate::quant::QFormat::Mxint { bits: 2, block: 16 },
                0,
            ),
            None,
        )
        .unwrap();
        let corpus = Corpus::generate(spec.vocab, 8192, 1);
        let wr = win_rate(&reg, &spec, &reference, &q4.merged, &q2.merged, &corpus, 4).unwrap();
        assert!(wr > 0.7, "4-bit should beat 2-bit: {wr}");
        // symmetric: candidate == opponent -> exactly 0.5
        let wr2 = win_rate(&reg, &spec, &reference, &q2.merged, &q2.merged, &corpus, 2).unwrap();
        assert!((wr2 - 0.5).abs() < 1e-12, "{wr2}");
    }
}
