//! Perplexity evaluator (WikiText2-analog, Table 3).
//!
//! Streams (tokens, targets) windows through `lm_nll.<cfg>` — the artifact
//! returns per-token NLL so only B·S floats cross the device boundary per
//! batch — and reports `exp(mean NLL)` (word ppl in the paper's terms).

use crate::data::batch::lm_batches;
use crate::data::corpus::Corpus;
use crate::model::ModelSpec;
use crate::runtime::{
    exec::{lm_inputs, rc_params},
    NativeModel, Registry,
};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Perplexity of `params` over (up to) `max_batches` of `corpus`.
pub fn perplexity(
    reg: &Registry,
    spec: &ModelSpec,
    params: &[Tensor],
    corpus: &Corpus,
    max_batches: usize,
) -> Result<f64> {
    let exec = reg.load(&format!("lm_nll.{}", spec.name))?;
    let shape = [spec.batch, spec.seq];
    // wrap once; each batch then passes params by refcount, not by copy
    let params = rc_params(params);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (bi, (tokens, targets)) in lm_batches(corpus, spec.batch, spec.seq).enumerate() {
        if bi >= max_batches {
            break;
        }
        let out = exec.run(&lm_inputs(&tokens, Some((&targets, &shape)), &shape, &params))?;
        total += out[0].data().iter().map(|&v| v as f64).sum::<f64>();
        count += out[0].numel();
    }
    ensure!(count > 0, "corpus too small for one evaluation batch");
    Ok((total / count as f64).exp())
}

/// [`perplexity`] on the native backend — no artifacts needed, and a
/// quantized [`NativeModel`] streams NLL straight from packed weights.
pub fn perplexity_native(
    model: &NativeModel,
    corpus: &Corpus,
    max_batches: usize,
) -> Result<f64> {
    let (b, s) = (model.spec.batch, model.spec.seq);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (bi, (tokens, targets)) in lm_batches(corpus, b, s).enumerate() {
        if bi >= max_batches {
            break;
        }
        let nll = model.nll(&tokens, &targets, b, s);
        total += nll.iter().map(|&v| v as f64).sum::<f64>();
        count += nll.len();
    }
    ensure!(count > 0, "corpus too small for one evaluation batch");
    Ok((total / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    #[test]
    fn native_ppl_near_uniform_without_artifacts() {
        let spec = crate::model::ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut Rng::new(0));
        let corpus = Corpus::generate(spec.vocab, 4096, 1);
        let model = NativeModel::from_dense(spec.clone(), params);
        let ppl = perplexity_native(&model, &corpus, 4).unwrap();
        assert!(ppl.is_finite());
        assert!(ppl > spec.vocab as f64 * 0.3, "{ppl}");
        assert!(ppl < spec.vocab as f64 * 3.0, "{ppl}");
        // deterministic
        assert_eq!(ppl, perplexity_native(&model, &corpus, 4).unwrap());
    }

    #[test]
    fn native_quantized_ppl_finite_and_tracks_merged() {
        let spec = crate::model::ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut Rng::new(5));
        let corpus = Corpus::generate(spec.vocab, 2048, 6);
        let ckpt = crate::model::Checkpoint::new(spec.clone(), params);
        let cfg = crate::coordinator::PipelineConfig::new(
            crate::solver::Method::WOnly,
            crate::quant::QFormat::Mxint { bits: 4, block: 32 },
            0,
        );
        let qm = crate::coordinator::quantize(&ckpt, &cfg, None).unwrap();
        // fused-from-packed vs dense execution of the same merged weights
        let q_native = NativeModel::from_quant(&qm.ckpt);
        let d_native = NativeModel::from_dense(spec, qm.merged.clone());
        let qp = perplexity_native(&q_native, &corpus, 2).unwrap();
        let dp = perplexity_native(&d_native, &corpus, 2).unwrap();
        assert!(qp.is_finite() && dp.is_finite());
        assert!((qp - dp).abs() / dp < 1e-3, "packed {qp} vs dense {dp}");
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(0));
        let corpus = Corpus::generate(spec.vocab, 4096, 1);
        let ppl = perplexity(&reg, &spec, &params, &corpus, 4).unwrap();
        // untrained model ≈ uniform over vocab (LN+small init keep it close)
        assert!(ppl > spec.vocab as f64 * 0.3, "{ppl}");
        assert!(ppl < spec.vocab as f64 * 3.0, "{ppl}");
    }

    #[test]
    fn ppl_deterministic() {
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(1));
        let corpus = Corpus::generate(spec.vocab, 4096, 2);
        let a = perplexity(&reg, &spec, &params, &corpus, 2).unwrap();
        let b = perplexity(&reg, &spec, &params, &corpus, 2).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn quantization_increases_ppl_of_untrained_model_slightly() {
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(2));
        let corpus = Corpus::generate(spec.vocab, 4096, 3);
        let base = perplexity(&reg, &spec, &params, &corpus, 2).unwrap();
        // crush the weights to 2 bits
        let ckpt = crate::model::Checkpoint::new(spec.clone(), params.clone());
        let cfg = crate::coordinator::PipelineConfig::new(
            crate::solver::Method::WOnly,
            crate::quant::QFormat::Mxint { bits: 2, block: 16 },
            0,
        );
        let qm = crate::coordinator::quantize(&ckpt, &cfg, None).unwrap();
        let qppl = perplexity(&reg, &spec, &qm.merged, &corpus, 2).unwrap();
        // both finite; they must differ (quantization does something)
        assert!(qppl.is_finite() && base.is_finite());
        assert_ne!(qppl, base);
    }
}
