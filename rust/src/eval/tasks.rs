//! Downstream-task evaluators: classification accuracy (`cls_fwd.<cfg>.r<k>`
//! artifacts) and arithmetic-QA exact match (GSM8K analog, via `lm_fwd`).

use crate::data::batch::cls_epoch;
use crate::data::tasks::{ArithmeticQA, ClsExample};
use crate::model::ModelSpec;
use crate::runtime::{exec::lm_inputs, Registry, Value};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Accuracy of a (base + lora + head) classifier over `data`.
///
/// `lora` empty + `rank == 0` selects the adapter-free artifact.
pub fn cls_accuracy(
    reg: &Registry,
    spec: &ModelSpec,
    base: &[Tensor],
    lora: &[Tensor],
    rank: usize,
    head: (&Tensor, &Tensor),
    data: &[ClsExample],
) -> Result<f64> {
    ensure!(!data.is_empty());
    let exec = reg.load(&format!("cls_fwd.{}.r{}", spec.name, rank))?;
    let mut rng = crate::util::rng::Rng::new(0); // eval order irrelevant
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in cls_epoch(data, spec.batch, &mut rng) {
        let mut inputs: Vec<Value> =
            vec![Value::I32(b.tokens.clone(), vec![spec.batch, data[0].tokens.len()])];
        inputs.extend(base.iter().cloned().map(Value::from));
        inputs.extend(lora.iter().cloned().map(Value::from));
        inputs.push(Value::from(head.0.clone()));
        inputs.push(Value::from(head.1.clone()));
        let out = exec.run(&inputs)?;
        let preds = out[0].argmax_rows();
        for i in 0..b.real {
            correct += (preds[i] as i32 == b.labels[i]) as usize;
            total += 1;
        }
    }
    Ok(correct as f64 / total as f64)
}

/// Exact-match accuracy on the arithmetic-QA set: both answer-digit targets
/// must be the argmax continuation under teacher forcing.
pub fn qa_exact_match(
    reg: &Registry,
    spec: &ModelSpec,
    params: &[Tensor],
    data: &[(Vec<i32>, Vec<usize>)],
) -> Result<f64> {
    ensure!(!data.is_empty());
    let exec = reg.load(&format!("lm_fwd.{}", spec.name))?;
    let shape = [spec.batch, spec.seq];
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in data.chunks(spec.batch) {
        // pad the final chunk by repeating the first element
        let mut tokens = Vec::with_capacity(spec.batch * spec.seq);
        for i in 0..spec.batch {
            let (t, _) = &chunk[i.min(chunk.len() - 1)];
            ensure!(t.len() == spec.seq, "QA seq mismatch");
            tokens.extend_from_slice(t);
        }
        let out = exec.run(&lm_inputs(&tokens, None, &shape, params))?;
        let logits = &out[0]; // [B,S,V]
        let v = spec.vocab;
        for (i, (t, answer_pos)) in chunk.iter().enumerate() {
            // answer token at position p is predicted by logits at p-1
            let ok = answer_pos.iter().all(|&p| {
                let row = &logits.data()[(i * spec.seq + p - 1) * v..(i * spec.seq + p) * v];
                let mut best = 0;
                for j in 1..v {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best as i32 == t[p]
            });
            correct += ok as usize;
            total += 1;
        }
    }
    Ok(correct as f64 / total as f64)
}

/// Per-digit accuracy on the arithmetic-QA set (graded variant of exact
/// match — visible progress before the model nails both digits).
pub fn qa_digit_accuracy(
    reg: &Registry,
    spec: &ModelSpec,
    params: &[Tensor],
    data: &[(Vec<i32>, Vec<usize>)],
) -> Result<f64> {
    ensure!(!data.is_empty());
    let exec = reg.load(&format!("lm_fwd.{}", spec.name))?;
    let shape = [spec.batch, spec.seq];
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in data.chunks(spec.batch) {
        let mut tokens = Vec::with_capacity(spec.batch * spec.seq);
        for i in 0..spec.batch {
            let (t, _) = &chunk[i.min(chunk.len() - 1)];
            tokens.extend_from_slice(t);
        }
        let out = exec.run(&lm_inputs(&tokens, None, &shape, params))?;
        let logits = &out[0];
        let v = spec.vocab;
        for (i, (t, answer_pos)) in chunk.iter().enumerate() {
            for &p in answer_pos {
                let row = &logits.data()[(i * spec.seq + p - 1) * v..(i * spec.seq + p) * v];
                let mut best = 0;
                for j in 1..v {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                correct += (best as i32 == t[p]) as usize;
                total += 1;
            }
        }
    }
    Ok(correct as f64 / total as f64)
}

/// Convenience: build the QA dataset for a spec.
pub fn qa_dataset(spec: &ModelSpec, n: usize, seed: u64) -> Vec<(Vec<i32>, Vec<usize>)> {
    ArithmeticQA::new(spec.vocab).generate(n, spec.seq, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::Task;
    use crate::model::init::{init_head, init_params};
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    #[test]
    fn untrained_classifier_near_chance() {
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let mut rng = Rng::new(0);
        let base = init_params(&spec, &mut rng);
        let (hw, hb) = init_head(&spec, &mut rng);
        let task = Task::by_name("parity").unwrap();
        let data = task.generate(64, spec.vocab, spec.seq, 7);
        let acc = cls_accuracy(&reg, &spec, &base, &[], 0, (&hw, &hb), &data).unwrap();
        // the head has n_classes=8 outputs but parity has 2 labels: an
        // untrained classifier mostly predicts classes that never occur
        assert!((0.0..0.9).contains(&acc), "{acc}");
    }

    #[test]
    fn qa_exact_match_runs() {
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(1));
        let data = qa_dataset(&spec, 20, 3);
        let acc = qa_exact_match(&reg, &spec, &params, &data).unwrap();
        // untrained: essentially zero, but must be a valid fraction
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn perfect_head_gets_perfect_accuracy() {
        // cheat: a head reading a planted signal via the first token's class
        // is hard to build by hand; instead verify accuracy is deterministic
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let mut rng = Rng::new(2);
        let base = init_params(&spec, &mut rng);
        let (hw, hb) = init_head(&spec, &mut rng);
        let task = Task::by_name("majority").unwrap();
        let data = task.generate(40, spec.vocab, spec.seq, 8);
        let a = cls_accuracy(&reg, &spec, &base, &[], 0, (&hw, &hb), &data).unwrap();
        let b = cls_accuracy(&reg, &spec, &base, &[], 0, (&hw, &hb), &data).unwrap();
        assert_eq!(a, b);
    }
}
