//! Linear-probe downstream evaluation (Table 4 analog).
//!
//! The paper reports zero-shot accuracy of quantized LLMs on six tasks.
//! Offline substitute: freeze the (quantized) backbone, extract mean-pooled
//! features via the `lm_pool.<cfg>` artifact, and fit a multinomial logistic
//! regression probe per task with a fixed budget — identical probe, so
//! accuracy differences isolate how much task-relevant signal quantization
//! destroyed in the backbone.

use crate::data::batch::cls_epoch;
use crate::data::tasks::ClsExample;
use crate::model::ModelSpec;
use crate::runtime::{exec::lm_inputs, Registry};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

/// Extract pooled features [n, D] for a dataset.
pub fn pooled_features(
    reg: &Registry,
    spec: &ModelSpec,
    params: &[Tensor],
    data: &[ClsExample],
) -> Result<(Vec<Vec<f32>>, Vec<i32>)> {
    ensure!(!data.is_empty());
    let exec = reg.load(&format!("lm_pool.{}", spec.name))?;
    let seq = data[0].tokens.len();
    ensure!(seq == spec.seq);
    let mut feats = Vec::with_capacity(data.len());
    let mut labels = Vec::with_capacity(data.len());
    let mut rng = Rng::new(0);
    for b in cls_epoch(data, spec.batch, &mut rng) {
        let out = exec.run(&lm_inputs(&b.tokens, None, &[spec.batch, seq], params))?;
        for i in 0..b.real {
            feats.push(out[0].row(i).to_vec());
            labels.push(b.labels[i]);
        }
    }
    Ok((feats, labels))
}

/// Multinomial logistic regression trained with full-batch gradient descent.
pub struct Probe {
    pub w: Vec<Vec<f64>>, // [classes][dim+1] (last = bias)
    pub classes: usize,
}

impl Probe {
    pub fn fit(feats: &[Vec<f32>], labels: &[i32], classes: usize, iters: usize) -> Probe {
        let n = feats.len();
        let d = feats[0].len();
        let mut w = vec![vec![0.0f64; d + 1]; classes];
        // feature standardization for stable GD
        let mut mean = vec![0.0f64; d];
        let mut var = vec![0.0f64; d];
        for f in feats {
            for j in 0..d {
                mean[j] += f[j] as f64;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        for f in feats {
            for j in 0..d {
                var[j] += (f[j] as f64 - mean[j]).powi(2);
            }
        }
        let std: Vec<f64> = var.iter().map(|v| (v / n as f64).sqrt().max(1e-8)).collect();

        let lr = 0.5;
        let mut probs = vec![0.0f64; classes];
        let mut grad = vec![vec![0.0f64; d + 1]; classes];
        for _ in 0..iters {
            for g in grad.iter_mut() {
                for v in g.iter_mut() {
                    *v = 0.0;
                }
            }
            for (f, &y) in feats.iter().zip(labels) {
                let mut maxl = f64::NEG_INFINITY;
                for (c, pc) in probs.iter_mut().enumerate().take(classes) {
                    let mut s = w[c][d];
                    for j in 0..d {
                        s += w[c][j] * (f[j] as f64 - mean[j]) / std[j];
                    }
                    *pc = s;
                    maxl = maxl.max(s);
                }
                let mut z = 0.0;
                for pc in probs.iter_mut() {
                    *pc = (*pc - maxl).exp();
                    z += *pc;
                }
                for c in 0..classes {
                    let p = probs[c] / z;
                    let err = p - if c as i32 == y { 1.0 } else { 0.0 };
                    for j in 0..d {
                        grad[c][j] += err * (f[j] as f64 - mean[j]) / std[j];
                    }
                    grad[c][d] += err;
                }
            }
            for c in 0..classes {
                for j in 0..=d {
                    w[c][j] -= lr * grad[c][j] / n as f64;
                }
            }
        }
        // fold standardization into the weights
        let mut folded = vec![vec![0.0f64; d + 1]; classes];
        for c in 0..classes {
            let mut bias = w[c][d];
            for j in 0..d {
                folded[c][j] = w[c][j] / std[j];
                bias -= w[c][j] * mean[j] / std[j];
            }
            folded[c][d] = bias;
        }
        Probe { w: folded, classes }
    }

    pub fn predict(&self, f: &[f32]) -> i32 {
        let d = f.len();
        let mut best = 0;
        let mut best_s = f64::NEG_INFINITY;
        for c in 0..self.classes {
            let mut s = self.w[c][d];
            for j in 0..d {
                s += self.w[c][j] * f[j] as f64;
            }
            if s > best_s {
                best_s = s;
                best = c;
            }
        }
        best as i32
    }

    pub fn accuracy(&self, feats: &[Vec<f32>], labels: &[i32]) -> f64 {
        let correct = feats
            .iter()
            .zip(labels)
            .filter(|(f, &y)| self.predict(f) == y)
            .count();
        correct as f64 / feats.len() as f64
    }
}

/// End-to-end probe accuracy: fit on `train`, report on `test`.
pub fn probe_accuracy(
    reg: &Registry,
    spec: &ModelSpec,
    params: &[Tensor],
    train: &[ClsExample],
    test: &[ClsExample],
    classes: usize,
) -> Result<f64> {
    let (ftr, ltr) = pooled_features(reg, spec, params, train)?;
    let (fte, lte) = pooled_features(reg, spec, params, test)?;
    let probe = Probe::fit(&ftr, &ltr, classes, 300);
    Ok(probe.accuracy(&fte, &lte))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_learns_separable_data() {
        let mut rng = Rng::new(0);
        let mut feats = Vec::new();
        let mut labels = Vec::new();
        for i in 0..200 {
            let y = (i % 2) as i32;
            let mut f: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            f[3] += if y == 1 { 2.0 } else { -2.0 };
            feats.push(f);
            labels.push(y);
        }
        let p = Probe::fit(&feats, &labels, 2, 200);
        assert!(p.accuracy(&feats, &labels) > 0.95);
    }

    #[test]
    fn probe_multiclass() {
        let mut rng = Rng::new(1);
        let mut feats = Vec::new();
        let mut labels = Vec::new();
        for i in 0..300 {
            let y = (i % 3) as i32;
            let mut f: Vec<f32> = (0..6).map(|_| rng.normal_f32() * 0.5).collect();
            f[y as usize] += 2.0;
            feats.push(f);
            labels.push(y);
        }
        let p = Probe::fit(&feats, &labels, 3, 200);
        assert!(p.accuracy(&feats, &labels) > 0.9);
    }

    #[test]
    fn probe_chance_on_noise() {
        let mut rng = Rng::new(2);
        let feats: Vec<Vec<f32>> =
            (0..200).map(|_| (0..8).map(|_| rng.normal_f32()).collect()).collect();
        let labels: Vec<i32> = (0..200).map(|_| rng.below(2) as i32).collect();
        let p = Probe::fit(&feats, &labels, 2, 100);
        let acc = p.accuracy(&feats, &labels);
        assert!(acc < 0.8, "{acc}"); // cannot be much better than chance+memorization
    }
}
