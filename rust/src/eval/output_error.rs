//! Model output error (Figure 1): MSE between the logits of an adapted /
//! quantized model and the full-precision reference, measured on
//! pretraining-distribution batches *before* any fine-tuning — the paper's
//! §4.2 diagnostic separating "low weight error" from "low output error".

use crate::data::batch::lm_batches;
use crate::data::corpus::Corpus;
use crate::model::ModelSpec;
use crate::runtime::{exec::lm_inputs, Registry};
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Mean squared logit error of `params_q` w.r.t. `params_ref`.
pub fn model_output_error(
    reg: &Registry,
    spec: &ModelSpec,
    params_ref: &[Tensor],
    params_q: &[Tensor],
    corpus: &Corpus,
    max_batches: usize,
) -> Result<f64> {
    let exec = reg.load(&format!("lm_fwd.{}", spec.name))?;
    let shape = [spec.batch, spec.seq];
    let mut total = 0.0f64;
    let mut batches = 0usize;
    for (bi, (tokens, _)) in lm_batches(corpus, spec.batch, spec.seq).enumerate() {
        if bi >= max_batches {
            break;
        }
        let r = exec.run(&lm_inputs(&tokens, None, &shape, params_ref))?;
        let q = exec.run(&lm_inputs(&tokens, None, &shape, params_q))?;
        total += r[0].mse(&q[0]);
        batches += 1;
    }
    ensure!(batches > 0, "corpus too small");
    Ok(total / batches as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{quantize, PipelineConfig};
    use crate::model::init::init_params;
    use crate::model::Checkpoint;
    use crate::quant::QFormat;
    use crate::solver::Method;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    #[test]
    fn identical_params_zero_error() {
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(0));
        let corpus = Corpus::generate(spec.vocab, 2048, 1);
        let e = model_output_error(&reg, &spec, &params, &params, &corpus, 2).unwrap();
        assert_eq!(e, 0.0);
    }

    #[test]
    fn reconstruction_lowers_output_error() {
        // the repo's core end-to-end claim, on an untrained nano model:
        // w-only > zeroquant-v2 on model output error at 2 bits
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(1));
        let ckpt = Checkpoint::new(spec.clone(), params.clone());
        let corpus = Corpus::generate(spec.vocab, 4096, 2);
        let fmt = QFormat::Mxint { bits: 2, block: 16 };
        let wonly = quantize(&ckpt, &PipelineConfig::new(Method::WOnly, fmt, 0), None).unwrap();
        let zq = quantize(&ckpt, &PipelineConfig::new(Method::ZeroQuantV2, fmt, 16), None).unwrap();
        let e_wonly =
            model_output_error(&reg, &spec, &params, &wonly.merged, &corpus, 2).unwrap();
        let e_zq = model_output_error(&reg, &spec, &params, &zq.merged, &corpus, 2).unwrap();
        assert!(e_zq < e_wonly, "zq {e_zq} !< w-only {e_wonly}");
        assert!(e_wonly > 0.0);
    }
}
