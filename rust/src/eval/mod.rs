//! Evaluation harness: perplexity (Table 3), downstream-task accuracy
//! (Tables 1/4/11-17), model output error (Figure 1), and the
//! AlpacaEval-analog win rate (Figure 4).

pub mod ppl;
pub mod probe;
pub mod output_error;
pub mod tasks;
pub mod winrate;

pub use output_error::model_output_error;
pub use ppl::{perplexity, perplexity_native};
pub use probe::probe_accuracy;
pub use tasks::{cls_accuracy, qa_digit_accuracy, qa_exact_match};
pub use winrate::win_rate;
