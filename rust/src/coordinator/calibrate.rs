//! Calibration orchestrator.
//!
//! Streams calibration batches through the `lm_fwd_taps.<cfg>` artifact and
//! folds every tap (the input of each quantizable linear) into per-site
//! [`CalibStats`] — f32 on device, f64 accumulation here (App. A.7).
//!
//! Sites sharing inputs share statistics: `wq`/`wk`/`wv` all read the
//! `attn_in` tap (exactly the grouping the paper uses).
//!
//! Tap sites are independent, so each batch's taps fold in parallel on the
//! worker pool ([`fold_taps`]); when there are fewer sites than workers,
//! the surplus threads each site's banded SYRK fold instead of idling.
//! Both levels partition output entries only (the per-entry accumulation
//! order is fixed), so the result is bit-identical to the serial fold for
//! every worker count.  `QERA_CALIB_WORKERS` sizes the fold independently
//! of the solver pool's `QERA_THREADS`.

use crate::data::corpus::Corpus;
use crate::data::batch::lm_batches;
use crate::model::ModelSpec;
use crate::runtime::{
    exec::{lm_inputs, rc_params},
    NativeModel, Registry,
};
use crate::obs::lazy::Lazy;
use crate::obs::metrics::{self, Counter};
use crate::stats::{offdiag_element_ratio_of, offdiag_ratio_of, CalibStats};
use crate::tensor::Tensor;
use crate::util::pool;
use anyhow::{ensure, Result};

/// Calibration batches folded, across both backends
/// (`qera_calib_batches_total`).
static CALIB_BATCHES: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_calib_batches_total", &[]));

/// Fold one batch of per-tap activations into the per-site accumulators.
/// Sites are embarrassingly parallel (each owns its [`CalibStats`]), so
/// they fold concurrently on the worker pool.  When a model has fewer tap
/// sites than workers (wide-layer/few-site models), the surplus workers go
/// *inside* each site's fold as an explicit SYRK band count — the banded
/// kernel partitions output entries only, never the accumulation order, so
/// the result is **bit-identical to a serial loop for every worker count**
/// (sharded `merge`-based folds would change the f64 reduction order per
/// shard count, which is why [`CalibStats::update_sharded`] is not used
/// here).  `workers == 0` picks `QERA_CALIB_WORKERS` / the pool default.
pub fn fold_taps(stats: &mut [CalibStats], taps: &[Tensor], workers: usize) {
    assert_eq!(stats.len(), taps.len(), "tap/site count mismatch");
    let w = if workers == 0 { pool::default_calib_workers() } else { workers };
    let n = stats.len().max(1);
    // surplus workers per site once tap-level parallelism is exhausted
    let inner = (w + n - 1) / n;
    pool::parallel_for_each_mut(stats, w.min(n), |i, st| st.update_workers(&taps[i], inner));
}

/// Per-tap-site statistics for one model.
pub struct CalibResult {
    pub spec: ModelSpec,
    /// Indexed by `spec.tap_index(block, tap)`.
    pub stats: Vec<CalibStats>,
    /// Number of calibration sequences consumed.
    pub n_sequences: usize,
}

impl CalibResult {
    /// Stats feeding a given linear site.
    pub fn for_site(&self, site: &crate::model::LinearSite) -> &CalibStats {
        &self.stats[self.spec.tap_index(site.block, site.tap)]
    }

    /// Deterministic synthetic calibration statistics — correlated Gaussian
    /// activations `x = z M` with a random mixing matrix and per-channel
    /// scale spread per tap — for tests and benches that have no PJRT
    /// artifacts.  Gives every site a full (non-diagonal) `R_XX` with the
    /// anisotropy real activations show (Figure 5), so the activation-aware
    /// solvers exercise their whole path.
    pub fn synthetic(spec: &ModelSpec, rows: usize, seed: u64) -> CalibResult {
        // taps are seeded independently, so they generate and fold in
        // parallel; the per-tap RNG streams (and therefore the stats) are
        // identical to a serial loop in (block, tap) order
        let n_sites = crate::model::TAP_SITES.len();
        let stats = pool::parallel_map_auto(spec.n_taps(), |idx| {
            let (b, ti) = (idx / n_sites, idx % n_sites);
            let tap = crate::model::TAP_SITES[ti];
            let dim = spec.tap_dim(tap);
            let mut rng =
                crate::util::rng::Rng::new(seed ^ ((b as u64) << 24) ^ ((ti as u64) << 16));
            let scales: Vec<f64> = (0..dim).map(|_| (rng.normal() * 0.8).exp()).collect();
            let mut mix = crate::linalg::Mat64::zeros(dim, dim);
            for i in 0..dim {
                for j in 0..dim {
                    mix.set(i, j, rng.normal() / (dim as f64).sqrt() * scales[j]);
                }
            }
            let z = crate::linalg::Mat64::from_vec(
                rows,
                dim,
                (0..rows * dim).map(|_| rng.normal()).collect(),
            );
            let x = z.matmul(&mix);
            let mut st = CalibStats::new(dim, true);
            st.update(&x.to_tensor());
            st
        });
        CalibResult { spec: spec.clone(), stats, n_sequences: rows }
    }

    /// Assumption-1 diagnostic per tap (Figure 5):
    /// (name, Frobenius-mass ratio, per-element ratio).  `R_XX` is
    /// materialized once per site and shared by both ratios.
    pub fn offdiag_report(&self) -> Vec<(String, f64, f64)> {
        let mut out = Vec::new();
        for b in 0..self.spec.n_layers {
            for &tap in crate::model::TAP_SITES.iter() {
                let st = &self.stats[self.spec.tap_index(b, tap)];
                if let Some(r) = st.rxx_mean() {
                    out.push((
                        format!("blk{b}.{tap}"),
                        offdiag_ratio_of(&r),
                        offdiag_element_ratio_of(&r),
                    ));
                }
            }
        }
        out
    }
}

/// Run calibration over (up to) `max_batches` batches of the corpus.
///
/// `track_rxx=false` skips the O(m²) accumulators (enough for LQER /
/// QERA-approx; Table 8's cheap-init mode).
pub fn calibrate(
    reg: &Registry,
    spec: &ModelSpec,
    params: &[Tensor],
    corpus: &Corpus,
    max_batches: usize,
    track_rxx: bool,
) -> Result<CalibResult> {
    ensure!(max_batches > 0, "need at least one calibration batch");
    let exec = reg.load(&format!("lm_fwd_taps.{}", spec.name))?;
    let mut stats: Vec<CalibStats> = (0..spec.n_layers)
        .flat_map(|_| {
            crate::model::TAP_SITES
                .iter()
                .map(|&tap| CalibStats::new(spec.tap_dim(tap), track_rxx))
        })
        .collect();

    // wrap once; each batch then passes params by refcount, not by copy
    let params = rc_params(params);
    let mut n_sequences = 0usize;
    for (bi, (tokens, _targets)) in lm_batches(corpus, spec.batch, spec.seq).enumerate() {
        if bi >= max_batches {
            break;
        }
        let fwd_sp = crate::obs::trace::span("calib.forward").attr("batch", bi);
        let outputs = exec.run(&lm_inputs(&tokens, None, &[spec.batch, spec.seq], &params))?;
        drop(fwd_sp);
        // outputs[0] = logits; outputs[1..] = taps in (block, tap) order,
        // folded in parallel (bit-identical to the serial fold)
        ensure!(outputs.len() == 1 + spec.n_taps(), "tap count mismatch");
        let fold_sp = crate::obs::trace::span("calib.fold").attr("batch", bi);
        fold_taps(&mut stats, &outputs[1..], 0);
        drop(fold_sp);
        CALIB_BATCHES.inc();
        n_sequences += spec.batch;
    }
    ensure!(n_sequences > 0, "corpus too small for a single calibration batch");
    crate::info!(
        "calibrated {} sites over {} sequences (rxx={})",
        stats.len(),
        n_sequences,
        track_rxx
    );
    Ok(CalibResult { spec: spec.clone(), stats, n_sequences })
}

/// Run calibration on the **native** backend — no PJRT artifacts required.
/// Identical streaming structure to [`calibrate`] (same batching, same
/// per-batch [`fold_taps`], same f64 accumulation), but the taps come from
/// [`NativeModel::forward_taps`], so any dense checkpoint can calibrate on
/// a plain CPU box.  Statistics are bit-identical across worker counts.
pub fn calibrate_native(
    model: &NativeModel,
    corpus: &Corpus,
    max_batches: usize,
    track_rxx: bool,
) -> Result<CalibResult> {
    ensure!(max_batches > 0, "need at least one calibration batch");
    let spec = &model.spec;
    let mut stats: Vec<CalibStats> = (0..spec.n_layers)
        .flat_map(|_| {
            crate::model::TAP_SITES
                .iter()
                .map(|&tap| CalibStats::new(spec.tap_dim(tap), track_rxx))
        })
        .collect();

    let mut n_sequences = 0usize;
    for (bi, (tokens, _targets)) in lm_batches(corpus, spec.batch, spec.seq).enumerate() {
        if bi >= max_batches {
            break;
        }
        let fwd_sp = crate::obs::trace::span("calib.forward").attr("batch", bi);
        let taps = model.forward_taps(&tokens, spec.batch, spec.seq);
        drop(fwd_sp);
        ensure!(taps.len() == spec.n_taps(), "tap count mismatch");
        let fold_sp = crate::obs::trace::span("calib.fold").attr("batch", bi);
        fold_taps(&mut stats, &taps, 0);
        drop(fold_sp);
        CALIB_BATCHES.inc();
        n_sequences += spec.batch;
    }
    ensure!(n_sequences > 0, "corpus too small for a single calibration batch");
    crate::info!(
        "calibrated {} sites over {} sequences on the native backend (rxx={})",
        stats.len(),
        n_sequences,
        track_rxx
    );
    Ok(CalibResult { spec: spec.clone(), stats, n_sequences })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    #[test]
    fn parallel_tap_fold_matches_serial_exactly() {
        // no artifacts needed: fold_taps is the per-batch kernel calibrate()
        // uses; every site must come out bit-identical to the serial loop
        // for any worker count, across multiple streamed batches
        let dims = [8usize, 5, 8, 12, 5, 16];
        for workers in [1usize, 4, 8] {
            let mut par: Vec<CalibStats> =
                dims.iter().map(|&d| CalibStats::new(d, true)).collect();
            let mut ser: Vec<CalibStats> =
                dims.iter().map(|&d| CalibStats::new(d, true)).collect();
            let mut batch_rng = Rng::new(21);
            for _batch in 0..3 {
                let taps: Vec<Tensor> = dims
                    .iter()
                    .map(|&d| Tensor::randn(vec![7, d], 1.0, &mut batch_rng))
                    .collect();
                fold_taps(&mut par, &taps, workers);
                for (st, t) in ser.iter_mut().zip(&taps) {
                    st.update(t);
                }
            }
            for (i, (p, s)) in par.iter().zip(&ser).enumerate() {
                assert_eq!(p.count, s.count, "site {i} w={workers}");
                assert_eq!(p.sum_abs, s.sum_abs, "site {i} w={workers}");
                assert_eq!(p.sum_sq, s.sum_sq, "site {i} w={workers}");
                assert_eq!(
                    p.rxx.as_ref().unwrap().a,
                    s.rxx.as_ref().unwrap().a,
                    "site {i} w={workers}"
                );
            }
        }
    }

    #[test]
    fn few_sites_saturate_pool_bit_identically() {
        // wide-layer/few-site shape: 2 taps, up to 8 workers — the surplus
        // workers thread each site's SYRK bands, and the result must stay
        // bit-identical to the serial fold for every worker count
        let dims = [48usize, 33];
        let mut ser: Vec<CalibStats> = dims.iter().map(|&d| CalibStats::new(d, true)).collect();
        let mut rng = Rng::new(31);
        let mk_taps = |rng: &mut Rng| -> Vec<Tensor> {
            dims.iter().map(|&d| Tensor::randn(vec![9, d], 1.0, rng)).collect()
        };
        let batches: Vec<Vec<Tensor>> = (0..3).map(|_| mk_taps(&mut rng)).collect();
        for taps in &batches {
            for (st, t) in ser.iter_mut().zip(taps) {
                st.update_workers(t, 1);
            }
        }
        for workers in [1usize, 2, 3, 8] {
            let mut par: Vec<CalibStats> =
                dims.iter().map(|&d| CalibStats::new(d, true)).collect();
            for taps in &batches {
                fold_taps(&mut par, taps, workers);
            }
            for (i, (p, s)) in par.iter().zip(&ser).enumerate() {
                assert_eq!(p.count, s.count, "site {i} w={workers}");
                assert_eq!(p.sum_abs, s.sum_abs, "site {i} w={workers}");
                assert_eq!(p.sum_sq, s.sum_sq, "site {i} w={workers}");
                assert_eq!(
                    p.rxx.as_ref().unwrap().a,
                    s.rxx.as_ref().unwrap().a,
                    "site {i} w={workers}"
                );
            }
        }
    }

    #[test]
    fn synthetic_stats_cover_every_site() {
        // no artifacts needed: the synthetic path must satisfy the same
        // invariants real calibration does
        let spec = ModelSpec::builtin("nano").unwrap();
        let res = CalibResult::synthetic(&spec, 96, 3);
        assert_eq!(res.stats.len(), spec.n_taps());
        assert_eq!(res.n_sequences, 96);
        for (i, st) in res.stats.iter().enumerate() {
            assert!(st.count > 0, "site {i}");
            assert!(st.mean_sq().iter().all(|&v| v > 0.0), "site {i}");
            let r = st.rxx_mean().unwrap();
            assert!(r.is_symmetric(1e-6), "site {i}");
            // genuinely correlated (Assumption-1 shape), not diagonal
            assert!(st.offdiag_ratio().unwrap() > 0.05, "site {i}");
        }
        // q/k/v share the attn_in tap stats
        let sites = spec.linear_sites();
        assert!(std::ptr::eq(res.for_site(&sites[0]), res.for_site(&sites[1])));
        // deterministic
        let again = CalibResult::synthetic(&spec, 96, 3);
        assert_eq!(res.stats[0].sum_sq, again.stats[0].sum_sq);
    }

    #[test]
    fn native_calibration_satisfies_artifact_invariants() {
        // no artifacts needed: the native backend computes taps in Rust,
        // and the results must satisfy everything the PJRT path does
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut Rng::new(5));
        let model = crate::runtime::NativeModel::from_dense(spec.clone(), params);
        let corpus = Corpus::generate(spec.vocab, 256, 7);
        let res = calibrate_native(&model, &corpus, 2, true).unwrap();
        assert_eq!(res.stats.len(), spec.n_taps());
        assert_eq!(res.n_sequences, 2 * spec.batch);
        for (i, st) in res.stats.iter().enumerate() {
            assert!(st.count > 0, "site {i}");
            // every E[x²] strictly positive (Remark 2)
            assert!(st.mean_sq().iter().all(|&v| v > 0.0), "site {i}");
            assert!(st.rxx_mean().unwrap().is_symmetric(1e-6), "site {i}");
        }
        // q/k/v share the attn_in tap stats
        let sites = spec.linear_sites();
        assert!(std::ptr::eq(res.for_site(&sites[0]), res.for_site(&sites[1])));
        // offdiag report covers all sites, and the run is deterministic
        assert_eq!(res.offdiag_report().len(), spec.n_taps());
        let again = calibrate_native(&model, &corpus, 2, true).unwrap();
        assert_eq!(res.stats[0].sum_sq, again.stats[0].sum_sq);
        assert!(calibrate_native(&model, &corpus, 0, true).is_err());
    }

    #[test]
    fn calibration_produces_positive_stats() {
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(0));
        let corpus = Corpus::generate(spec.vocab, 4096, 1);
        let res = calibrate(&reg, &spec, &params, &corpus, 3, true).unwrap();
        assert_eq!(res.stats.len(), spec.n_taps());
        assert_eq!(res.n_sequences, 3 * spec.batch);
        for (i, st) in res.stats.iter().enumerate() {
            assert!(st.count > 0, "site {i}");
            // every E[x²] strictly positive (Remark 2)
            assert!(st.mean_sq().iter().all(|&v| v > 0.0), "site {i}");
            let r = st.rxx_mean().unwrap();
            assert!(r.is_symmetric(1e-6), "site {i}");
        }
        // q/k/v share attn_in
        let sites = spec.linear_sites();
        let a = res.for_site(&sites[0]) as *const _;
        let b = res.for_site(&sites[1]) as *const _;
        assert!(std::ptr::eq(a, b));
        // offdiag report covers all sites
        assert_eq!(res.offdiag_report().len(), spec.n_taps());
    }

    #[test]
    fn no_rxx_mode_cheaper() {
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(0));
        let corpus = Corpus::generate(spec.vocab, 2048, 2);
        let res = calibrate(&reg, &spec, &params, &corpus, 2, false).unwrap();
        assert!(res.stats.iter().all(|s| s.rxx_mean().is_none()));
        assert!(res.offdiag_report().is_empty());
    }

    #[test]
    fn stats_scale_with_batches() {
        let Some(reg) = registry() else {
            return;
        };
        let spec = reg.spec("nano").unwrap().clone();
        let params = init_params(&spec, &mut Rng::new(3));
        let corpus = Corpus::generate(spec.vocab, 8192, 4);
        let r1 = calibrate(&reg, &spec, &params, &corpus, 1, false).unwrap();
        let r4 = calibrate(&reg, &spec, &params, &corpus, 4, false).unwrap();
        assert_eq!(r4.stats[0].count, 4 * r1.stats[0].count);
        // means should be consistent (same distribution)
        let m1 = r1.stats[0].mean_sq();
        let m4 = r4.stats[0].mean_sq();
        let rel: f64 = m1
            .iter()
            .zip(&m4)
            .map(|(a, b)| (a - b).abs() / (a + b + 1e-9))
            .sum::<f64>()
            / m1.len() as f64;
        assert!(rel < 0.5, "{rel}");
    }
}
