//! The quantization pipeline: per-layer solve → quantized model.
//!
//! Layers are independent (App. A.7), so the solver jobs run on the worker
//! pool; PJRT is not touched here (calibration already happened), keeping
//! the pool free of thread-affine handles.

use super::calibrate::CalibResult;
use crate::budget::BudgetPlan;
use crate::model::{Checkpoint, LinearSite, ModelSpec, QuantCheckpoint};
use crate::quant::QFormat;
use crate::solver::{self, Method, PsdBackend, SvdBackend};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::pool;
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub method: Method,
    pub fmt: QFormat,
    pub rank: usize,
    pub seed: u64,
    /// Worker threads for the solver jobs (0 = auto).
    pub workers: usize,
    /// SVD backend for the per-layer solves.  `Auto` (the default) takes
    /// the randomized fast path whenever `rank * 4 <= min(m, n)`.
    pub svd: SvdBackend,
    /// PSD backend for QERA-exact's `(R^{1/2}, R^{-1/2})` pair.  `Auto`
    /// (the default) takes the low-rank + diagonal split whenever the
    /// reconstruction rank is small relative to the layer width.
    pub psd: PsdBackend,
    /// Per-layer `(format, rank)` overrides from the budget allocator.
    /// When set, it must cover every linear site; `fmt` / `rank` above are
    /// ignored, the plan's method replaces `method`, and rank-0 cells
    /// execute as plain `w-only`.
    pub plan: Option<BudgetPlan>,
}

impl PipelineConfig {
    pub fn new(method: Method, fmt: QFormat, rank: usize) -> Self {
        PipelineConfig {
            method,
            fmt,
            rank,
            seed: 42,
            workers: 0,
            svd: SvdBackend::Auto,
            psd: PsdBackend::Auto,
            plan: None,
        }
    }

    /// Builder-style override of the SVD backend.
    pub fn with_svd(mut self, svd: SvdBackend) -> Self {
        self.svd = svd;
        self
    }

    /// Builder-style override of the PSD backend.
    pub fn with_psd(mut self, psd: PsdBackend) -> Self {
        self.psd = psd;
        self
    }

    /// Builder-style attachment of a budget plan.
    pub fn with_plan(mut self, plan: BudgetPlan) -> Self {
        self.plan = Some(plan);
        self
    }
}

/// Per-layer diagnostics (drives Tables 7-8 / Figure 8b).
#[derive(Clone, Debug)]
pub struct LayerDiag {
    pub name: String,
    pub weight_error: f64,
    pub wall_ms: f64,
}

/// A quantized model ready for evaluation/serving.
#[derive(Debug)]
pub struct QuantizedModel {
    pub ckpt: QuantCheckpoint,
    /// Merged `W~ + A B` params in canonical order (the evaluator's input).
    pub merged: Vec<Tensor>,
    pub diags: Vec<LayerDiag>,
    pub config: PipelineConfig,
    /// Total solver wall time (sequential sum, as the paper reports).
    pub solve_ms_total: f64,
}

impl QuantizedModel {
    /// Average W-bits including the low-rank overhead (paper's accounting:
    /// low-rank params are high-precision extras on top of `fmt.avg_bits()`).
    /// With a budget plan, each layer is priced at its own format.
    pub fn effective_bits(&self) -> f64 {
        let mut wbits = 0.0f64;
        let mut elems = 0.0f64;
        for site in self.ckpt.spec.linear_sites() {
            let n = (site.shape[0] * site.shape[1]) as f64;
            elems += n;
            let fmt = self
                .config
                .plan
                .as_ref()
                .and_then(|p| p.cell(&site.name))
                .map(|c| c.fmt)
                .unwrap_or(self.config.fmt);
            wbits += n * fmt.avg_bits();
        }
        let lr_bits: f64 =
            self.ckpt.lowrank.values().map(|l| (l.n_params() * 32) as f64).sum();
        (wbits + lr_bits) / elems
    }
}

/// Method + backends after budget-plan and calibration resolution —
/// everything `quantize` and the streaming pipeline share per run.
pub(crate) struct Resolved {
    pub method: Method,
    pub svd: SvdBackend,
    pub psd: PsdBackend,
}

/// Validate plan coverage / calibration compatibility and resolve the
/// effective method and backends.  Shared by the in-memory and streaming
/// pipelines so both fail with identical messages and solve identically.
pub(crate) fn resolve(
    cfg: &PipelineConfig,
    spec: &ModelSpec,
    sites: &[LinearSite],
    calib: Option<&CalibResult>,
) -> Result<Resolved> {
    if let Some(plan) = &cfg.plan {
        ensure!(
            plan.model == spec.name,
            "budget plan is for model '{}', checkpoint is '{}'",
            plan.model,
            spec.name
        );
        for site in sites {
            ensure!(plan.cell(&site.name).is_some(), "budget plan missing layer '{}'", site.name);
        }
    }
    let method = cfg.plan.as_ref().map(|p| p.method).unwrap_or(cfg.method);
    // a plan replays the profile's exact solves: its backends override the
    // session's, so --plan-in reproduces the checkpoint regardless of the
    // current --svd/--psd flags
    let (svd, psd) = match &cfg.plan {
        Some(p) => (p.svd, p.psd),
        None => (cfg.svd, cfg.psd),
    };
    if method.needs_stats() {
        ensure!(calib.is_some(), "{} requires calibration", method.name());
        ensure!(
            calib.unwrap().spec == *spec,
            "calibration spec does not match checkpoint"
        );
    }
    Ok(Resolved { method, svd, psd })
}

/// Effective `(format, rank)` for one site under `cfg` (plan cell if a
/// plan is attached, the global pair otherwise).
pub(crate) fn site_plan(cfg: &PipelineConfig, name: &str) -> (QFormat, usize) {
    match &cfg.plan {
        Some(p) => {
            let c = p.cell(name).unwrap();
            (c.fmt, c.rank)
        }
        None => (cfg.fmt, cfg.rank),
    }
}

/// Per-site solver seed, derived from the run seed and the site's GLOBAL
/// index in `spec.linear_sites()` order.  The resume journal records
/// global site-index ranges per shard precisely so a resumed streaming
/// run re-derives these exact seeds for the sites it re-solves — any
/// change here breaks crash-resume bit-identity with old journals.
pub(crate) fn site_seed(seed: u64, i: usize) -> u64 {
    seed ^ (i as u64) << 8
}

/// Solve one site.  `i` is the site's GLOBAL index in
/// `spec.linear_sites()` order — the per-site seed derives from it (see
/// [`site_seed`]), so the streaming pipeline must pass the same index the
/// in-memory one would for bit-identical results.
pub(crate) fn solve_site(
    cfg: &PipelineConfig,
    rp: &Resolved,
    site: &LinearSite,
    i: usize,
    w: &Tensor,
    calib: Option<&CalibResult>,
) -> Result<solver::SolveOutput> {
    let stats = calib.map(|c| c.for_site(site));
    let (fmt, rank) = site_plan(cfg, &site.name);
    let solve_method =
        if cfg.plan.is_some() && rank == 0 { Method::WOnly } else { rp.method };
    solver::solve_with(
        solve_method,
        w,
        fmt,
        rank,
        stats,
        site_seed(cfg.seed, i),
        rp.svd,
        rp.psd,
    )
}

/// Checkpoint meta recorded by both pipelines (exact key order matters for
/// byte-identical manifests/containers across the two paths).
pub(crate) fn build_meta(cfg: &PipelineConfig, rp: &Resolved) -> Json {
    // with a plan, format/rank vary per layer — the per-layer cells live in
    // the plan artifact, so the meta says "per-layer" instead of recording
    // the ignored global pair
    let mut meta_pairs = vec![
        ("method", Json::str(rp.method.name())),
        (
            "format",
            match &cfg.plan {
                Some(_) => Json::str("per-layer"),
                None => Json::str(cfg.fmt.name()),
            },
        ),
        (
            "rank",
            match &cfg.plan {
                Some(_) => Json::Null,
                None => Json::Num(cfg.rank as f64),
            },
        ),
        ("seed", Json::Num(cfg.seed as f64)),
        ("svd", Json::str(rp.svd.name())),
        ("psd", Json::str(rp.psd.name())),
    ];
    if let Some(p) = &cfg.plan {
        meta_pairs.push(("plan_strategy", Json::str(p.strategy.name())));
        meta_pairs.push(("budget_bits", Json::Num(p.budget_bits)));
        meta_pairs.push(("plan_bits", Json::Num(p.achieved_bits)));
    }
    Json::obj(meta_pairs)
}

/// Quantize every linear layer of `ckpt`.
///
/// `calib` may be `None` for methods that don't need statistics.  With a
/// budget plan attached (`PipelineConfig::with_plan`), each layer solves
/// at its planned `(format, rank)` under the plan's method (rank-0 cells
/// run as plain `w-only`) and packs at its own format.
pub fn quantize(
    ckpt: &Checkpoint,
    cfg: &PipelineConfig,
    calib: Option<&CalibResult>,
) -> Result<QuantizedModel> {
    let spec = &ckpt.spec;
    let sites = spec.linear_sites();
    let rp = resolve(cfg, spec, &sites, calib)?;
    let workers = if cfg.workers == 0 { pool::default_workers() } else { cfg.workers };

    let t0 = std::time::Instant::now();
    let results: Vec<Result<(String, solver::SolveOutput)>> =
        pool::parallel_map(sites.len(), workers, |i| {
            let site = &sites[i];
            let w = &ckpt.params[site.param_idx];
            let out = solve_site(cfg, &rp, site, i, w, calib)?;
            Ok((site.name.clone(), out))
        });

    let mut solved: BTreeMap<String, (Tensor, Option<crate::solver::LowRank>)> = BTreeMap::new();
    let mut diags = Vec::with_capacity(sites.len());
    let mut solve_ms_total = 0.0;
    for (site, res) in sites.iter().zip(results) {
        let (name, out) = res?;
        let w = &ckpt.params[site.param_idx];
        diags.push(LayerDiag {
            name: name.clone(),
            weight_error: solver::weight_error(w, &out),
            wall_ms: out.wall_ms,
        });
        solve_ms_total += out.wall_ms;
        solved.insert(name, (out.w_dq, out.lowrank));
    }

    let meta = build_meta(cfg, &rp);
    let fmts: BTreeMap<String, QFormat> =
        sites.iter().map(|s| (s.name.clone(), site_plan(cfg, &s.name).0)).collect();
    let qckpt = QuantCheckpoint::from_solved_per_site(ckpt, &fmts, &solved, meta);
    let merged = qckpt.materialize_merged();
    match &cfg.plan {
        Some(p) => crate::info!(
            "quantized {} layers ({}, {} plan, {:.3} bits/weight) in {:.2}s wall / {:.2}s solver",
            sites.len(),
            rp.method.name(),
            p.strategy.name(),
            p.achieved_bits,
            t0.elapsed().as_secs_f64(),
            solve_ms_total / 1e3,
        ),
        None => crate::info!(
            "quantized {} layers ({}, {}, rank {}) in {:.2}s wall / {:.2}s solver",
            sites.len(),
            rp.method.name(),
            cfg.fmt.name(),
            cfg.rank,
            t0.elapsed().as_secs_f64(),
            solve_ms_total / 1e3,
        ),
    }
    Ok(QuantizedModel { ckpt: qckpt, merged, diags, config: cfg.clone(), solve_ms_total })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::model::ModelSpec;
    use crate::util::rng::Rng;

    fn nano_ckpt(seed: u64) -> Checkpoint {
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut Rng::new(seed));
        Checkpoint::new(spec, params)
    }

    fn fmt() -> QFormat {
        QFormat::Mxint { bits: 4, block: 32 }
    }

    #[test]
    fn wonly_pipeline_runs_without_calibration() {
        let ckpt = nano_ckpt(0);
        let qm = quantize(&ckpt, &PipelineConfig::new(Method::WOnly, fmt(), 0), None).unwrap();
        assert_eq!(qm.diags.len(), 12);
        assert!(qm.ckpt.lowrank.is_empty());
        // merged weights differ from the original weights but by a bounded amount
        let site = &ckpt.spec.linear_sites()[0];
        let diff = qm.merged[site.param_idx].sub(&ckpt.params[site.param_idx]).frob_norm();
        assert!(diff > 0.0);
        let rel = diff / ckpt.params[site.param_idx].frob_norm();
        assert!(rel < 0.2, "{rel}"); // MXINT4 RMS err ~0.12 on gaussian weights
        // non-linear params untouched
        assert_eq!(qm.merged[0], ckpt.params[0]);
    }

    #[test]
    fn stats_methods_fail_fast_without_calibration() {
        let ckpt = nano_ckpt(1);
        let err =
            quantize(&ckpt, &PipelineConfig::new(Method::QeraApprox, fmt(), 8), None).unwrap_err();
        assert!(err.to_string().contains("calibration"));
    }

    #[test]
    fn zeroquant_reduces_weight_error() {
        let ckpt = nano_ckpt(2);
        let fmt2 = QFormat::Mxint { bits: 2, block: 16 };
        let w_only = quantize(&ckpt, &PipelineConfig::new(Method::WOnly, fmt2, 0), None).unwrap();
        let zq =
            quantize(&ckpt, &PipelineConfig::new(Method::ZeroQuantV2, fmt2, 8), None).unwrap();
        for (a, b) in w_only.diags.iter().zip(&zq.diags) {
            assert!(b.weight_error < a.weight_error, "{}", a.name);
        }
        assert_eq!(zq.ckpt.lowrank.len(), 12);
    }

    #[test]
    fn effective_bits_accounting() {
        let ckpt = nano_ckpt(3);
        let w_only = quantize(&ckpt, &PipelineConfig::new(Method::WOnly, fmt(), 0), None).unwrap();
        assert!((w_only.effective_bits() - 4.25).abs() < 1e-9);
        let zq =
            quantize(&ckpt, &PipelineConfig::new(Method::ZeroQuantV2, fmt(), 8), None).unwrap();
        assert!(zq.effective_bits() > 4.25);
        assert!(zq.effective_bits() < 16.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let ckpt = nano_ckpt(4);
        let cfg = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4);
        let a = quantize(&ckpt, &cfg, None).unwrap();
        let b = quantize(&ckpt, &cfg, None).unwrap();
        for (x, y) in a.merged.iter().zip(&b.merged) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let ckpt = nano_ckpt(5);
        let mut cfg = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4);
        cfg.workers = 1;
        let serial = quantize(&ckpt, &cfg, None).unwrap();
        cfg.workers = 4;
        let parallel = quantize(&ckpt, &cfg, None).unwrap();
        for (x, y) in serial.merged.iter().zip(&parallel.merged) {
            assert_eq!(x, y);
        }
        // and under the explicit randomized SVD backend (the blocked
        // threaded matmuls + seeded sketch must stay bit-deterministic)
        let mut rcfg = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4)
            .with_svd(SvdBackend::Randomized { oversample: 8, power_iters: 2 });
        rcfg.workers = 1;
        let rserial = quantize(&ckpt, &rcfg, None).unwrap();
        rcfg.workers = 4;
        let rparallel = quantize(&ckpt, &rcfg, None).unwrap();
        for (x, y) in rserial.merged.iter().zip(&rparallel.merged) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn solver_wall_times_are_reported() {
        let ckpt = nano_ckpt(6);
        let qm =
            quantize(&ckpt, &PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4), None).unwrap();
        assert!(qm.solve_ms_total > 0.0);
        for d in &qm.diags {
            assert!(d.wall_ms > 0.0, "{} reported zero wall time", d.name);
        }
    }

    #[test]
    fn svd_backend_recorded_in_meta() {
        let ckpt = nano_ckpt(7);
        let cfg = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4)
            .with_svd(SvdBackend::Randomized { oversample: 4, power_iters: 1 });
        let qm = quantize(&ckpt, &cfg, None).unwrap();
        assert_eq!(
            qm.ckpt.meta.get("svd").and_then(crate::util::json::Json::as_str),
            Some("randomized:4:1")
        );
        assert_eq!(
            qm.ckpt.meta.get("psd").and_then(crate::util::json::Json::as_str),
            Some("auto")
        );
    }

    #[test]
    fn plan_overrides_format_and_rank_per_layer() {
        use crate::budget::{allocate, profile, AllocStrategy, CandidateGrid};
        let ckpt = nano_ckpt(9);
        let calib = super::CalibResult::synthetic(&ckpt.spec, 96, 17);
        let grid = CandidateGrid {
            formats: vec![
                QFormat::Mxint { bits: 2, block: 16 },
                QFormat::Mxint { bits: 4, block: 32 },
            ],
            ranks: vec![0, 4],
        };
        let base = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 3, block: 32 }, 8);
        let prof = profile(&ckpt, &calib, &base, &grid).unwrap();
        let plan = allocate(&prof, 3.6, AllocStrategy::Greedy).unwrap();
        let qm =
            quantize(&ckpt, &base.clone().with_plan(plan.clone()), Some(&calib)).unwrap();
        // the executed model costs exactly what the plan priced
        assert!(
            (qm.effective_bits() - plan.achieved_bits).abs() < 1e-9,
            "{} vs {}",
            qm.effective_bits(),
            plan.achieved_bits
        );
        assert!(qm.effective_bits() <= 3.6 + 1e-9);
        // low-rank terms exist exactly where the plan bought rank
        for site in ckpt.spec.linear_sites() {
            let cell = plan.cell(&site.name).unwrap();
            assert_eq!(
                qm.ckpt.lowrank.contains_key(&site.name),
                cell.rank > 0,
                "{}",
                site.name
            );
            if let Some(lr) = qm.ckpt.lowrank.get(&site.name) {
                assert_eq!(lr.rank(), cell.rank, "{}", site.name);
            }
        }
        // plan provenance lands in the checkpoint meta
        assert_eq!(
            qm.ckpt.meta.get("plan_strategy").and_then(crate::util::json::Json::as_str),
            Some("greedy")
        );
    }

    #[test]
    fn plan_must_cover_every_site() {
        use crate::budget::{allocate, profile, AllocStrategy, CandidateGrid};
        let ckpt = nano_ckpt(10);
        let calib = super::CalibResult::synthetic(&ckpt.spec, 64, 18);
        let base = PipelineConfig::new(Method::QeraExact, fmt(), 4);
        let grid = CandidateGrid {
            formats: vec![QFormat::Mxint { bits: 3, block: 32 }],
            ranks: vec![0, 4],
        };
        let prof = profile(&ckpt, &calib, &base, &grid).unwrap();
        let mut plan = allocate(&prof, 4.0, AllocStrategy::Uniform).unwrap();
        plan.layers.remove("blk0.wq");
        let err = quantize(&ckpt, &base.with_plan(plan), Some(&calib)).unwrap_err();
        assert!(err.to_string().contains("missing layer"), "{err}");
    }

    #[test]
    fn psd_backend_recorded_in_meta() {
        let ckpt = nano_ckpt(8);
        let cfg = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4)
            .with_psd(PsdBackend::LowRank { rank_mult: 2, power_iters: 16 });
        let qm = quantize(&ckpt, &cfg, None).unwrap();
        assert_eq!(
            qm.ckpt.meta.get("psd").and_then(crate::util::json::Json::as_str),
            Some("lowrank:2:16")
        );
    }
}
