//! The quantization pipeline coordinator — the L3 system contribution.
//!
//! ```text
//!   corpus ──► calibrate (lm_fwd_taps, streaming f64 stats per site)
//!          ──► solve     (per-layer closed-form solvers, worker pool)
//!          ──► emit      (QuantCheckpoint + merged weights + diagnostics)
//! ```

pub mod calibrate;
pub mod pipeline;
pub mod stream;

pub use calibrate::{calibrate, calibrate_native, fold_taps, CalibResult};
pub use pipeline::{quantize, PipelineConfig, QuantizedModel};
pub use stream::{quantize_streaming, quantize_streaming_with, StreamOptions, StreamSummary};
