//! Bounded-memory streaming quantization: load layer shard → solve → pack
//! → write shard → drop.
//!
//! [`quantize_streaming`] never materializes the model.  A prefetch thread
//! reads one parameter group ahead (through [`crate::model::ckpt::open`],
//! so both monolithic and sharded sources stream), the main thread runs
//! the per-layer solves on the worker pool, and a writer thread emits
//! finished shards with integrity hashes — three stages overlapped through
//! capacity-1 channels, so peak live tensor memory is a small constant
//! number of layer groups regardless of model depth.
//!
//! Bit-identity with the in-memory path is a hard invariant: the same
//! `resolve`/`solve_site`/`build_meta` plumbing runs with the same GLOBAL
//! site indices (the per-site solver seed derives from them), so a
//! streamed checkpoint round-trips identically to
//! `coordinator::quantize` + `save_sharded`.
//!
//! Peak memory is tracked by a per-run [`LiveSet`] (an atomic live-bytes
//! counter with RAII guards) and reported in
//! [`StreamSummary::peak_live_bytes`]; the integration suite asserts it
//! stays flat as the layer count grows.

use super::calibrate::CalibResult;
use super::pipeline::{self, LayerDiag, PipelineConfig};
use crate::model::ckpt::{open_with, CkptReader, QWeight};
use crate::model::shard::{param_groups, CkptKind, ShardParam, ShardWriter};
use crate::obs::lazy::Lazy;
use crate::obs::metrics::{self, Counter, Gauge};
use crate::obs::trace;
use crate::quant::PackedWeight;
use crate::solver::{self, SolveOutput};
use crate::tensor::Tensor;
use crate::util::fault;
use crate::util::fsio::CkptIo;
use crate::util::pool;
use crate::util::retry::RetryPolicy;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

// Registry-backed stream counters.  They accumulate across runs in the
// process (Prometheus counter semantics); each run adds exactly the values
// it reports in its `StreamSummary`, so a single-run CLI invocation's
// metrics dump reconciles exactly with the printed summary.  The per-run
// sources stay authoritative for tests, which run many streams in parallel
// in one process.
static M_IO_RETRIES: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_stream_io_retries_total", &[]));
static M_FAULTS: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_stream_faults_injected_total", &[]));
static M_SKIPPED: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_stream_shards_skipped_resume_total", &[]));
static M_SHARDS: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_stream_shards_written_total", &[]));
static M_SITES: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_stream_sites_solved_total", &[]));
static M_PAYLOAD: Lazy<Counter> =
    Lazy::new(|| metrics::counter("qera_stream_payload_bytes_total", &[]));
static M_LIVE: Lazy<Gauge> = Lazy::new(|| metrics::gauge("qera_stream_live_bytes", &[]));
static M_PEAK: Lazy<Gauge> = Lazy::new(|| metrics::gauge("qera_stream_peak_live_bytes", &[]));

/// Knobs for a streaming quantization run beyond the pipeline config.
#[derive(Clone)]
pub struct StreamOptions {
    /// Resume a crashed run from the resume journal next to the output
    /// manifest: journaled shards are re-verified (size + sha256) and
    /// their solves skipped; the run continues after the verified prefix
    /// and produces a manifest bit-identical to an uncrashed one.
    pub resume: bool,
    /// Retry policy for checkpoint reads and shard/journal writes.
    pub retry: RetryPolicy,
    /// Explicit I/O layer (tests inject faults here); `None` uses the
    /// ambient `QERA_FAULTS`-aware layer.
    pub io: Option<Arc<dyn CkptIo>>,
}

impl Default for StreamOptions {
    fn default() -> Self {
        StreamOptions { resume: false, retry: RetryPolicy::io_default(), io: None }
    }
}

/// Result of a streaming quantization run.
#[derive(Debug)]
pub struct StreamSummary {
    /// Path of the written manifest.
    pub manifest: PathBuf,
    /// Number of shards in the finished checkpoint (including any
    /// resume-verified ones).
    pub n_shards: usize,
    /// Per-layer diagnostics in global site order for the sites solved in
    /// THIS run — resume-skipped shards' sites were solved (and their
    /// diagnostics reported) by the crashed run.
    pub diags: Vec<LayerDiag>,
    /// Total solver wall time of this run (sequential sum, as the paper
    /// reports); excludes resume-skipped solves.
    pub solve_ms_total: f64,
    /// Serialized weight payload across the shards written by this run.
    pub payload_bytes: usize,
    /// High-water mark of live tensor bytes across all pipeline stages —
    /// bounded by a constant number of layer groups, not the model.
    pub peak_live_bytes: usize,
    /// Journaled shards verified on disk and skipped by `--resume`.
    pub shards_skipped_resume: usize,
    /// I/O retries taken (source reads + shard/journal/manifest writes).
    pub io_retries: usize,
    /// Faults the I/O layer injected (0 outside chaos runs).
    pub faults_injected: usize,
}

/// Per-run live-bytes accounting: `add` bumps the counter and returns a
/// guard that decrements on drop, so every pipeline stage's working set is
/// tracked for exactly as long as it is actually held.
struct LiveSet {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl LiveSet {
    fn new() -> Arc<LiveSet> {
        Arc::new(LiveSet { current: AtomicUsize::new(0), peak: AtomicUsize::new(0) })
    }

    fn add(self: &Arc<LiveSet>, bytes: usize) -> LiveGuard {
        let cur = self.current.fetch_add(bytes, Ordering::SeqCst) + bytes;
        self.peak.fetch_max(cur, Ordering::SeqCst);
        // mirror into the process-global gauges (advisory: concurrent runs
        // share them; the per-run peak below stays authoritative)
        M_LIVE.add(bytes as i64);
        M_PEAK.set_max(cur as i64);
        LiveGuard { set: Arc::clone(self), bytes }
    }

    fn peak(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }
}

struct LiveGuard {
    set: Arc<LiveSet>,
    bytes: usize,
}

impl Drop for LiveGuard {
    fn drop(&mut self) {
        self.set.current.fetch_sub(self.bytes, Ordering::SeqCst);
        M_LIVE.sub(self.bytes as i64);
    }
}

/// Read one parameter group's dense tensors, registering their bytes with
/// the live-set for as long as the returned guard lives.
fn load_group(
    reader: &CkptReader,
    names: &[String],
    live: &Arc<LiveSet>,
) -> Result<(Vec<(String, Tensor)>, LiveGuard)> {
    let params = reader.read_params(names)?;
    let mut tensors = Vec::with_capacity(names.len());
    let mut bytes = 0usize;
    for (name, p) in names.iter().zip(params) {
        let ShardParam::Dense(t) = p else {
            bail!("quantized entry '{name}' in streaming quantization source");
        };
        bytes += t.numel() * 4;
        tensors.push((name.clone(), t));
    }
    Ok((tensors, live.add(bytes)))
}

/// Quantize `src` (monolithic `QKPT1` or a sharded dense manifest) into a
/// sharded quantized checkpoint at `out_manifest`, holding only a bounded
/// number of layer groups in memory: shard reads, per-layer solves, and
/// shard writes overlap on three stages.
///
/// `shard_layers` sets both the output sharding and the streaming
/// granularity (transformer blocks per group; `0` is treated as `1`).
/// The result is bit-identical to `coordinator::quantize` followed by
/// `QuantCheckpoint::save_sharded` with the same config.
pub fn quantize_streaming(
    src: impl AsRef<Path>,
    cfg: &PipelineConfig,
    calib: Option<&CalibResult>,
    out_manifest: impl AsRef<Path>,
    shard_layers: usize,
) -> Result<StreamSummary> {
    quantize_streaming_with(src, cfg, calib, out_manifest, shard_layers, &StreamOptions::default())
}

/// [`quantize_streaming`] with explicit [`StreamOptions`]: crash resume,
/// retry policy, and an injectable I/O layer.
pub fn quantize_streaming_with(
    src: impl AsRef<Path>,
    cfg: &PipelineConfig,
    calib: Option<&CalibResult>,
    out_manifest: impl AsRef<Path>,
    shard_layers: usize,
    opts: &StreamOptions,
) -> Result<StreamSummary> {
    let t0 = std::time::Instant::now();
    let _run_sp = trace::span("stream.quantize");
    let io = match &opts.io {
        Some(io) => Arc::clone(io),
        None => fault::io_from_env()?,
    };
    let reader = open_with(src.as_ref(), Arc::clone(&io), opts.retry)?;
    ensure!(
        reader.kind() == CkptKind::Dense,
        "streaming quantization needs a dense source checkpoint, got a quantized one"
    );
    let spec = reader.spec().clone();
    let sites = spec.linear_sites();
    let rp = pipeline::resolve(cfg, &spec, &sites, calib)?;
    let workers = if cfg.workers == 0 { pool::default_workers() } else { cfg.workers };
    // param name -> global site index: the solver seed derives from the
    // global index, which keeps streamed solves bit-identical to in-memory
    // AND lets a resumed run re-derive the exact seeds of skipped sites
    let site_index: BTreeMap<&str, usize> =
        sites.iter().enumerate().map(|(i, s)| (s.name.as_str(), i)).collect();

    let layout = spec.param_layout();
    let groups = param_groups(&spec, shard_layers);
    let group_names: Vec<Vec<String>> = groups
        .iter()
        .map(|g| g.iter().map(|&i| layout[i].0.clone()).collect())
        .collect();
    let n_groups = groups.len();
    // global site-index range each group covers, journaled with its shard
    let group_ranges: Vec<(usize, usize)> = group_names
        .iter()
        .map(|names| {
            let mut lo = usize::MAX;
            let mut hi = 0usize;
            for n in names {
                if let Some(&si) = site_index.get(n.as_str()) {
                    lo = lo.min(si);
                    hi = hi.max(si + 1);
                }
            }
            if lo == usize::MAX {
                (0, 0)
            } else {
                (lo, hi)
            }
        })
        .collect();

    let meta = pipeline::build_meta(cfg, &rp);
    let (writer, verified) = if opts.resume {
        ShardWriter::resume(
            out_manifest.as_ref(),
            CkptKind::Quant,
            spec.clone(),
            meta,
            Arc::clone(&io),
            opts.retry,
        )?
    } else {
        let w = ShardWriter::create_with(
            out_manifest.as_ref(),
            CkptKind::Quant,
            spec.clone(),
            meta,
            Arc::clone(&io),
            opts.retry,
        )?;
        (w, Vec::new())
    };
    let journal_path = writer.journal_path().to_path_buf();
    ensure!(
        verified.len() <= n_groups,
        "resume journal lists {} shards but this run produces {n_groups}; delete {} to start \
         fresh",
        verified.len(),
        journal_path.display()
    );
    for (i, (info, range)) in verified.iter().enumerate() {
        ensure!(
            info.params == group_names[i] && *range == group_ranges[i],
            "resume journal shard {i} does not match this run's layer grouping (was it written \
             with a different --shard-layers?); delete {} to start fresh",
            journal_path.display()
        );
    }
    let skip = verified.len();
    if skip > 0 {
        crate::info!(
            "resume: {skip} of {n_groups} journaled shard(s) verified on disk; their solves are \
             skipped"
        );
    }

    let live = LiveSet::new();

    // stage 1: prefetch reads one group ahead of the solver, starting
    // after the resume-verified prefix; returns the reader so its retry
    // count survives the thread
    type InMsg = Result<(Vec<(String, Tensor)>, LiveGuard)>;
    let (tx_in, rx_in) = mpsc::sync_channel::<InMsg>(1);
    let live_in = Arc::clone(&live);
    let prefetch = std::thread::spawn(move || -> CkptReader {
        for (gi, names) in (skip..).zip(&group_names[skip..]) {
            let sp = trace::span("stream.load").attr("shard", gi);
            let res = load_group(&reader, names, &live_in);
            drop(sp);
            let failed = res.is_err();
            if tx_in.send(res).is_err() || failed {
                return reader;
            }
        }
        reader
    });

    // stage 3: writer streams finished shards out while the next solves run
    type OutMsg = (Vec<(String, ShardParam)>, (usize, usize), LiveGuard);
    let (tx_out, rx_out) = mpsc::sync_channel::<OutMsg>(1);
    let writer_handle = std::thread::spawn(move || -> Result<ShardWriter> {
        let mut w = writer;
        for (si, (entries, range, guard)) in (skip..).zip(rx_out) {
            let sp = trace::span("stream.write").attr("shard", si);
            w.write_shard_ranged(entries, range)?;
            drop(sp);
            M_SHARDS.inc();
            drop(guard);
        }
        Ok(w)
    });

    // stage 2 (this thread): solve each group's sites on the pool, pack,
    // and hand the shard to the writer
    let mut diags = Vec::with_capacity(sites.len());
    let mut solve_ms_total = 0.0f64;
    let mut payload_bytes = 0usize;
    let mut err: Option<anyhow::Error> = None;
    for (gi, msg) in (skip..).zip(rx_in.iter()) {
        let (tensors, in_guard) = match msg {
            Ok(v) => v,
            Err(e) => {
                err = Some(e);
                break;
            }
        };
        // (position in group, global site index) for the group's linears
        let group_sites: Vec<(usize, usize)> = tensors
            .iter()
            .enumerate()
            .filter_map(|(k, (name, _))| site_index.get(name.as_str()).map(|&si| (k, si)))
            .collect();
        let solve_sp =
            trace::span("stream.solve").attr("shard", gi).attr("sites", group_sites.len());
        let results: Vec<Result<SolveOutput>> =
            pool::parallel_map(group_sites.len(), workers, |j| {
                let (k, si) = group_sites[j];
                pipeline::solve_site(cfg, &rp, &sites[si], si, &tensors[k].1, calib)
            });
        drop(solve_sp);
        let mut outs: BTreeMap<usize, SolveOutput> = BTreeMap::new();
        let mut group_err = None;
        for (&(k, si), res) in group_sites.iter().zip(results) {
            match res {
                Ok(out) => {
                    diags.push(LayerDiag {
                        name: sites[si].name.clone(),
                        weight_error: solver::weight_error(&tensors[k].1, &out),
                        wall_ms: out.wall_ms,
                    });
                    solve_ms_total += out.wall_ms;
                    outs.insert(k, out);
                }
                Err(e) => {
                    group_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = group_err {
            err = Some(e);
            break;
        }
        M_SITES.add(group_sites.len() as u64);
        let pack_sp = trace::span("stream.pack").attr("shard", gi);
        let mut entries = Vec::with_capacity(tensors.len());
        let mut group_payload = 0usize;
        for (k, (name, w)) in tensors.into_iter().enumerate() {
            let p = match outs.remove(&k) {
                Some(out) => {
                    // pack from the ORIGINAL weight, exactly like
                    // `from_solved_per_site`; identity formats fall back to
                    // the dense dequantized solve
                    let (fmt, _) = pipeline::site_plan(cfg, &name);
                    let qw = match PackedWeight::quantize(w.data(), &fmt) {
                        Some(pw) => QWeight::Packed { shape: w.shape().to_vec(), pw },
                        None => QWeight::Dense(out.w_dq),
                    };
                    ShardParam::Quant { qw, lr: out.lowrank }
                }
                None => ShardParam::Dense(w),
            };
            group_payload += p.payload_bytes();
            entries.push((name, p));
        }
        drop(pack_sp);
        payload_bytes += group_payload;
        let out_guard = live.add(group_payload);
        drop(in_guard); // source tensors are packed or moved into entries
        if tx_out.send((entries, group_ranges[gi], out_guard)).is_err() {
            // writer bailed; its error surfaces at join below
            break;
        }
    }
    drop(rx_in); // unblocks the prefetcher if it is mid-send
    drop(tx_out); // closes the writer's queue

    let reader = prefetch.join().map_err(|_| anyhow!("prefetch thread panicked"))?;
    let writer_res =
        writer_handle.join().map_err(|_| anyhow!("shard writer thread panicked"))?;
    if let Some(e) = err {
        return Err(e);
    }
    let writer = writer_res?;
    let io_retries = reader.io_retries() + writer.io_retries();
    // the manifest is written last: a failed run leaves no loadable
    // output, and the resume journal keeps every completed shard reusable
    let manifest = writer.finish()?;
    let faults_injected = io.faults_injected();

    // push the run's recovery bookkeeping into the global registry so a
    // `--metrics-out` dump reconciles exactly with this `StreamSummary`
    M_IO_RETRIES.add(io_retries as u64);
    M_FAULTS.add(faults_injected as u64);
    M_SKIPPED.add(skip as u64);
    M_PAYLOAD.add(payload_bytes as u64);

    crate::info!(
        "stream-quantized {} layers into {} shards ({:.1} KiB peak live) in {:.2}s wall / {:.2}s solver",
        sites.len(),
        n_groups,
        live.peak() as f64 / 1024.0,
        t0.elapsed().as_secs_f64(),
        solve_ms_total / 1e3,
    );

    Ok(StreamSummary {
        manifest,
        n_shards: n_groups,
        diags,
        solve_ms_total,
        payload_bytes,
        peak_live_bytes: live.peak(),
        shards_skipped_resume: skip,
        io_retries,
        faults_injected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::quantize;
    use crate::model::init::init_params;
    use crate::model::{Checkpoint, ModelSpec, QuantCheckpoint};
    use crate::quant::QFormat;
    use crate::solver::Method;
    use crate::util::rng::Rng;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("qera_stream_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn nano_ckpt(seed: u64) -> Checkpoint {
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut Rng::new(seed));
        Checkpoint::new(spec, params)
    }

    fn fmt() -> QFormat {
        QFormat::Mxint { bits: 4, block: 32 }
    }

    fn assert_same_model(a: &QuantCheckpoint, b: &QuantCheckpoint) {
        assert_eq!(a.spec, b.spec);
        assert_eq!(a.meta, b.meta);
        assert_eq!(a.dense, b.dense);
        assert_eq!(a.lowrank.len(), b.lowrank.len());
        assert_eq!(a.materialize_merged(), b.materialize_merged());
        assert_eq!(a.payload_bytes(), b.payload_bytes());
    }

    #[test]
    fn streamed_matches_in_memory_bit_for_bit() {
        let dir = tmpdir("match");
        let ckpt = nano_ckpt(21);
        let src = dir.join("src.qkpt");
        ckpt.save(&src).unwrap();
        let cfg = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4);

        let qm = quantize(&ckpt, &cfg, None).unwrap();
        let sum =
            quantize_streaming(&src, &cfg, None, dir.join("out.manifest.json"), 1).unwrap();
        let streamed = QuantCheckpoint::load(&sum.manifest).unwrap();
        assert_same_model(&qm.ckpt, &streamed);

        // diagnostics line up with the in-memory run, site for site
        assert_eq!(sum.diags.len(), qm.diags.len());
        for (a, b) in sum.diags.iter().zip(&qm.diags) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.weight_error, b.weight_error, "{}", a.name);
        }
        assert_eq!(sum.payload_bytes, qm.ckpt.payload_bytes());
        assert!(sum.peak_live_bytes > 0);
    }

    #[test]
    fn streams_from_sharded_sources_too() {
        let dir = tmpdir("sharded_src");
        let ckpt = nano_ckpt(22);
        let src = ckpt.save_sharded(dir.join("src.manifest.json"), 2).unwrap();
        let cfg = PipelineConfig::new(Method::WOnly, fmt(), 0);

        let qm = quantize(&ckpt, &cfg, None).unwrap();
        let sum =
            quantize_streaming(&src, &cfg, None, dir.join("out.manifest.json"), 1).unwrap();
        assert_same_model(&qm.ckpt, &QuantCheckpoint::load(&sum.manifest).unwrap());
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let dir = tmpdir("workers");
        let ckpt = nano_ckpt(23);
        let src = dir.join("src.qkpt");
        ckpt.save(&src).unwrap();
        let mut cfg = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4);

        cfg.workers = 1;
        let serial =
            quantize_streaming(&src, &cfg, None, dir.join("serial.manifest.json"), 1).unwrap();
        cfg.workers = 4;
        let parallel =
            quantize_streaming(&src, &cfg, None, dir.join("par.manifest.json"), 1).unwrap();
        assert_same_model(
            &QuantCheckpoint::load(&serial.manifest).unwrap(),
            &QuantCheckpoint::load(&parallel.manifest).unwrap(),
        );
    }

    #[test]
    fn failed_runs_leave_no_manifest() {
        let dir = tmpdir("no_partial");
        let ckpt = nano_ckpt(24);
        let src = dir.join("src.qkpt");
        ckpt.save(&src).unwrap();
        // qera-approx without calibration fails in resolve()…
        let out = dir.join("out.manifest.json");
        let err = quantize_streaming(
            &src,
            &PipelineConfig::new(Method::QeraApprox, fmt(), 4),
            None,
            &out,
            1,
        )
        .unwrap_err();
        assert!(err.to_string().contains("calibration"), "{err}");
        // …and no manifest appears (shards without a manifest are inert)
        assert!(!out.exists());
    }

    #[test]
    fn crashed_run_resumes_bit_identically() {
        use crate::util::fault::{FaultKind, FaultOp, FaultSpec, FaultyIo};

        let dir = tmpdir("resume");
        let ckpt = nano_ckpt(25);
        let src = dir.join("src.qkpt");
        ckpt.save(&src).unwrap();
        let cfg = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4);

        let base = dir.join("base.manifest.json");
        quantize_streaming(&src, &cfg, None, &base, 1).unwrap();
        let base_bytes = std::fs::read(&base).unwrap();

        // crash the write of shard 002 (disk full => fail fast)
        let out = dir.join("out.manifest.json");
        let faulty = StreamOptions {
            io: Some(Arc::new(FaultyIo::std(
                vec![FaultSpec::new(FaultKind::Enospc, FaultOp::Write, "out.shard-002")],
                7,
            ))),
            ..Default::default()
        };
        let err = quantize_streaming_with(&src, &cfg, None, &out, 1, &faulty).unwrap_err();
        assert!(format!("{err:#}").contains("no space"), "{err:#}");
        assert!(!out.exists(), "failed run must not leave a manifest");
        let journal = dir.join("out.manifest.json.journal");
        assert!(journal.exists(), "crash leaves the journal for resume");

        // resume: the two completed shards are verified and skipped, and
        // the finished manifest is bit-identical to the uncrashed run
        let resume = StreamOptions { resume: true, ..Default::default() };
        let sum = quantize_streaming_with(&src, &cfg, None, &out, 1, &resume).unwrap();
        assert_eq!(sum.shards_skipped_resume, 2);
        assert!(!journal.exists(), "finish removes the journal");
        let out_bytes = std::fs::read(&out).unwrap();
        // manifests name different files (base.* vs out.*) but must agree
        // shard-for-shard on bytes and sha256 once prefixes are aligned
        assert_eq!(
            String::from_utf8(out_bytes).unwrap().replace("out.shard", "base.shard"),
            String::from_utf8(base_bytes).unwrap(),
        );
        for i in 0..sum.n_shards {
            assert_eq!(
                std::fs::read(dir.join(format!("out.shard-{i:03}.bin"))).unwrap(),
                std::fs::read(dir.join(format!("base.shard-{i:03}.bin"))).unwrap(),
                "shard {i}"
            );
        }

        // a second resume with everything finished starts fresh (journal
        // gone) and still converges to the same bytes
        let sum2 = quantize_streaming_with(&src, &cfg, None, &out, 1, &resume).unwrap();
        assert_eq!(sum2.shards_skipped_resume, 0);
    }

    #[test]
    fn resume_refuses_a_journal_from_another_config() {
        use crate::util::fault::{FaultKind, FaultOp, FaultSpec, FaultyIo};

        let dir = tmpdir("resume_mismatch");
        let ckpt = nano_ckpt(26);
        let src = dir.join("src.qkpt");
        ckpt.save(&src).unwrap();
        let out = dir.join("out.manifest.json");

        let faulty = StreamOptions {
            io: Some(Arc::new(FaultyIo::std(
                vec![FaultSpec::new(FaultKind::Enospc, FaultOp::Write, "out.shard-002")],
                7,
            ))),
            ..Default::default()
        };
        let cfg4 = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4);
        quantize_streaming_with(&src, &cfg4, None, &out, 1, &faulty).unwrap_err();

        // same spec, different quantization config -> refuse the journal
        let cfg2 =
            PipelineConfig::new(Method::ZeroQuantV2, QFormat::Mxint { bits: 2, block: 32 }, 4);
        let resume = StreamOptions { resume: true, ..Default::default() };
        let err = quantize_streaming_with(&src, &cfg2, None, &out, 1, &resume).unwrap_err();
        assert!(err.to_string().contains("different quantization config"), "{err:#}");

        // matching config resumes cleanly
        let sum = quantize_streaming_with(&src, &cfg4, None, &out, 1, &resume).unwrap();
        assert_eq!(sum.shards_skipped_resume, 2);
    }

    /// Tracing is observe-only: the same run with the global tracer
    /// enabled must produce byte-identical outputs, while the trace
    /// records load/solve/pack/write spans for every shard.
    #[test]
    fn instrumented_run_is_bit_identical_and_traces_all_stages() {
        use crate::obs::trace;
        use crate::util::json::Json;

        let dir = tmpdir("instrumented");
        let ckpt = nano_ckpt(28);
        let src = dir.join("src.qkpt");
        ckpt.save(&src).unwrap();
        let cfg = PipelineConfig::new(Method::ZeroQuantV2, fmt(), 4);

        // uninstrumented baseline (same manifest stem so bytes can match)
        let base_dir = dir.join("base");
        std::fs::create_dir_all(&base_dir).unwrap();
        let base = base_dir.join("out.manifest.json");
        let sum_a = quantize_streaming(&src, &cfg, None, &base, 1).unwrap();

        // identical run with tracing on
        let tr_dir = dir.join("traced");
        std::fs::create_dir_all(&tr_dir).unwrap();
        let out = tr_dir.join("out.manifest.json");
        let trace_path = dir.join("trace.json");
        trace::global().enable_to(&trace_path);
        let sum_b = quantize_streaming(&src, &cfg, None, &out, 1).unwrap();
        trace::global().flush_to(&trace_path).unwrap();
        trace::global().disable();

        assert_eq!(std::fs::read(&base).unwrap(), std::fs::read(&out).unwrap());
        for i in 0..sum_b.n_shards {
            assert_eq!(
                std::fs::read(base_dir.join(format!("out.shard-{i:03}.bin"))).unwrap(),
                std::fs::read(tr_dir.join(format!("out.shard-{i:03}.bin"))).unwrap(),
                "shard {i}"
            );
        }
        assert_eq!(sum_a.payload_bytes, sum_b.payload_bytes);

        // the trace parses as Chrome trace-event JSON and covers every
        // stage of every shard (other parallel tests may add more events)
        let body = std::fs::read_to_string(&trace_path).unwrap();
        let parsed = Json::parse(&body).unwrap();
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        for stage in ["stream.load", "stream.solve", "stream.pack", "stream.write"] {
            let n = events
                .iter()
                .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some(stage))
                .count();
            assert!(n >= sum_b.n_shards, "{stage}: {n} spans for {} shards", sum_b.n_shards);
        }
    }

    #[test]
    fn transient_faults_ride_out_and_are_counted() {
        use crate::util::fault::{FaultKind, FaultOp, FaultSpec, FaultyIo};

        let dir = tmpdir("transient");
        let ckpt = nano_ckpt(27);
        let src = dir.join("src.qkpt");
        ckpt.save(&src).unwrap();
        let cfg = PipelineConfig::new(Method::WOnly, fmt(), 0);

        let base = dir.join("base.manifest.json");
        quantize_streaming(&src, &cfg, None, &base, 2).unwrap();

        // a transient source read + a silently corrupted shard write, both
        // survivable; the run must succeed and report the recovery work
        let out = dir.join("out.manifest.json");
        let opts = StreamOptions {
            io: Some(Arc::new(FaultyIo::std(
                vec![
                    FaultSpec::new(FaultKind::Transient, FaultOp::Read, "src.qkpt"),
                    FaultSpec::new(FaultKind::Flip, FaultOp::Write, "out.shard-001"),
                ],
                13,
            ))),
            ..Default::default()
        };
        let sum = quantize_streaming_with(&src, &cfg, None, &out, 2, &opts).unwrap();
        assert!(sum.io_retries >= 2, "retries: {}", sum.io_retries);
        assert_eq!(sum.faults_injected, 2);
        assert_eq!(sum.shards_skipped_resume, 0);
        for i in 0..sum.n_shards {
            assert_eq!(
                std::fs::read(dir.join(format!("out.shard-{i:03}.bin"))).unwrap(),
                std::fs::read(dir.join(format!("base.shard-{i:03}.bin"))).unwrap(),
                "shard {i}"
            );
        }
    }
}
