//! QPEFT experiments: Table 1 (GLUE-analog fine-tuning), Table 2 (LM +
//! arithmetic-QA fine-tuning), Tables 7/8 (init-time trade-off), Tables
//! 9/10 (rank sweep), Figures 1 (output error vs rank/iters), 2
//! (convergence) and 7 (calibration-set choice).

use super::common::{corpus_for, subject_model, Scale};
use crate::bench_util::Table;
use crate::coordinator::calibrate;
use crate::data::tasks::Task;
use crate::data::Corpus;
use crate::eval::{model_output_error, perplexity, qa_digit_accuracy};
use crate::quant::QFormat;
use crate::runtime::Registry;
use crate::solver::Method;
use crate::train::lora::{lora_init, LoraClsTrainer, LoraLmTrainer};
use crate::util::rng::Rng;
use anyhow::Result;

fn qpeft_methods() -> Vec<Method> {
    vec![Method::QloraZero, Method::Loftq { iters: 5 }, Method::QeraApprox]
}

/// Table 1: fine-tuned accuracy across the task suite at three precisions.
pub fn table1(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train_corpus, _) = corpus_for(&spec);
    let calib = calibrate(reg, &spec, &ckpt.params, &train_corpus, 12, false)?;

    let precisions: Vec<(QFormat, usize, &str)> = vec![
        (QFormat::Mxint { bits: 4, block: 32 }, 8, "4.25"),
        (QFormat::Mxint { bits: 2, block: 16 }, 8, "2.50"),
    ];
    let tasks: Vec<Task> = match scale {
        Scale::Quick => ["majority", "firstclass", "count", "pattern"]
            .iter()
            .filter_map(|n| Task::by_name(n))
            .collect(),
        Scale::Full => (0..crate::data::TASK_NAMES.len()).map(|id| Task { id }).collect(),
    };
    let epochs = match scale {
        Scale::Quick => 5,
        Scale::Full => 8,
    };

    let mut headers = vec!["w-bits".to_string(), "method".to_string()];
    headers.extend(tasks.iter().map(|t| t.name().to_string()));
    headers.push("avg".into());
    let mut table = Table::new(
        &format!("Table 1 analog: fine-tuned accuracy ({model}, {epochs} epochs, seeds avg)"),
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    // 16-bit LoRA upper bound
    for (fmt, rank, wbits) in std::iter::once((QFormat::None, 8usize, "16"))
        .chain(precisions.iter().map(|(f, r, w)| (*f, *r, *w)))
    {
        let methods: Vec<Method> =
            if fmt == QFormat::None { vec![Method::QloraZero] } else { qpeft_methods() };
        for method in methods {
            let label =
                if fmt == QFormat::None { "lora (16-bit)".to_string() } else { method.name() };
            let mut row = vec![wbits.to_string(), label];
            let mut sum = 0.0;
            for task in &tasks {
                let n = task.train_size().min(match scale {
                    Scale::Quick => 384,
                    Scale::Full => 1024,
                });
                let train = task.generate(n, spec.vocab, spec.seq, 10 + task.id as u64);
                let test = task.generate(256, spec.vocab, spec.seq, 900 + task.id as u64);
                let mut accs = Vec::new();
                for seed in scale.seeds() {
                    let init = lora_init(&ckpt, method, fmt, rank, Some(&calib), seed)?;
                    let mut tr =
                        LoraClsTrainer::new(spec.clone(), init, 3e-3, &mut Rng::new(seed));
                    let mut rng = Rng::new(seed ^ 0xF1);
                    for _ in 0..epochs {
                        tr.train_epoch(reg, &train, &mut rng)?;
                    }
                    accs.push(tr.accuracy(reg, &test)?);
                }
                let acc = accs.iter().sum::<f64>() / accs.len() as f64;
                sum += acc;
                row.push(format!("{:.1}", acc * 100.0));
            }
            row.push(format!("{:.2}", 100.0 * sum / tasks.len() as f64));
            table.row(row);
        }
    }
    Ok(table)
}

/// Table 2: continued-pretraining ppl + arithmetic-QA accuracy after QPEFT.
pub fn table2(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train, val) = corpus_for(&spec);
    let calib = calibrate(reg, &spec, &ckpt.params, &train, 12, false)?;
    let steps = match scale {
        Scale::Quick => 150,
        Scale::Full => 500,
    };
    let qa_steps = steps * 3; // arithmetic needs more optimization to emerge
    let rank = 8;

    // QA fine-tuning corpus: arithmetic sequences as LM text
    let qa_train = crate::eval::tasks::qa_dataset(&spec, 512, 5);
    let qa_tokens: Vec<i32> = qa_train.iter().flat_map(|(t, _)| t.clone()).collect();
    let qa_corpus = Corpus { vocab: spec.vocab, tokens: qa_tokens };
    let qa_test = crate::eval::tasks::qa_dataset(&spec, 128, 99);

    let base_ppl = perplexity(reg, &spec, &ckpt.params, &val, 8)?;
    let mut table = Table::new(
        &format!("Table 2 analog: QPEFT LM ppl + arithmetic-QA acc ({model}, rank {rank})"),
        &["w-bits", "method", "ppl", "delta-ppl", "qa-digit-acc %"],
    );
    table.row(vec![
        "16".into(),
        "bf16 (no ft)".into(),
        format!("{base_ppl:.3}"),
        "-".into(),
        "-".into(),
    ]);

    for (fmt, wbits) in [
        (QFormat::Mxint { bits: 4, block: 32 }, "4.25"),
        (QFormat::Mxint { bits: 2, block: 32 }, "2.25"),
    ] {
        for method in qpeft_methods() {
            let init = lora_init(&ckpt, method, fmt, rank, Some(&calib), 42)?;
            // continued pretraining on the corpus
            let mut tr = LoraLmTrainer::new(spec.clone(), init.clone(), 2e-3);
            tr.train(reg, &train, steps, &mut Rng::new(7))?;
            let ppl = perplexity(reg, &spec, &tr.merged(), &val, 8)?;
            // separate run: QA fine-tune, measure exact match
            let mut qa_tr = LoraLmTrainer::new(spec.clone(), init, 3e-3);
            qa_tr.train(reg, &qa_corpus, qa_steps, &mut Rng::new(8))?;
            let qa_acc = qa_digit_accuracy(reg, &spec, &qa_tr.merged(), &qa_test)?;
            table.row(vec![
                wbits.to_string(),
                method.name(),
                format!("{ppl:.3}"),
                format!("{:+.3}", ppl - base_ppl),
                format!("{:.1}", qa_acc * 100.0),
            ]);
        }
    }
    Ok(table)
}

/// Figure 1: model output error vs rank (a) and vs LoftQ iterations (b).
pub fn fig1(reg: &Registry, model: &str, scale: Scale) -> Result<(Table, Table)> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train, _) = corpus_for(&spec);
    let calib = calibrate(reg, &spec, &ckpt.params, &train, 12, false)?;
    let fmt = QFormat::Mxint { bits: 2, block: 32 }; // "3-bit regime" for nano

    let merged_err = |method: Method, rank: usize| -> Result<f64> {
        let init = lora_init(&ckpt, method, fmt, rank, Some(&calib), 42)?;
        model_output_error(reg, &spec, &ckpt.params, &init.merged(&spec), &train, 4)
    };

    // (a) error vs rank
    let mut ta = Table::new(
        "Figure 1a analog: model output error vs rank (before fine-tuning)",
        &["rank", "qlora", "loftq:1", "loftq:5", "qera-approx"],
    );
    for rank in [2usize, 4, 8, 16] {
        ta.row(vec![
            rank.to_string(),
            format!("{:.5}", merged_err(Method::QloraZero, rank)?),
            format!("{:.5}", merged_err(Method::Loftq { iters: 1 }, rank)?),
            format!("{:.5}", merged_err(Method::Loftq { iters: 5 }, rank)?),
            format!("{:.5}", merged_err(Method::QeraApprox, rank)?),
        ]);
    }

    // (b) error vs LoftQ iterations at fixed ranks
    let mut tb = Table::new(
        "Figure 1b analog: model output error vs LoftQ iterations",
        &["iters", "loftq r4", "loftq r8", "loftq r16", "qera-approx r8"],
    );
    let qera8 = merged_err(Method::QeraApprox, 8)?;
    for iters in 1..=5 {
        tb.row(vec![
            iters.to_string(),
            format!("{:.5}", merged_err(Method::Loftq { iters }, 4)?),
            format!("{:.5}", merged_err(Method::Loftq { iters }, 8)?),
            format!("{:.5}", merged_err(Method::Loftq { iters }, 16)?),
            format!("{qera8:.5}"),
        ]);
    }
    Ok((ta, tb))
}

/// Figure 2: eval-accuracy-per-epoch convergence curves on a small task.
pub fn fig2(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train_corpus, _) = corpus_for(&spec);
    let calib = calibrate(reg, &spec, &ckpt.params, &train_corpus, 12, false)?;
    let task = Task::by_name("majority").unwrap();
    let train = task.generate(256, spec.vocab, spec.seq, 21); // small-task regime
    let test = task.generate(256, spec.vocab, spec.seq, 922);
    let fmt = QFormat::Mxint { bits: 2, block: 16 };
    let epochs = match scale {
        Scale::Quick => 8,
        Scale::Full => 12,
    };

    let mut table = Table::new(
        "Figure 2 analog: eval accuracy per epoch (small task, 2.50 W-bits)",
        &["epoch", "qlora", "loftq:5", "qera-approx"],
    );
    let mut curves: Vec<Vec<f64>> = Vec::new();
    for method in qpeft_methods() {
        let init = lora_init(&ckpt, method, fmt, 8, Some(&calib), 42)?;
        let mut tr = LoraClsTrainer::new(spec.clone(), init, 3e-3, &mut Rng::new(42));
        let mut rng = Rng::new(0xF2);
        let mut curve = Vec::new();
        for _ in 0..epochs {
            tr.train_epoch(reg, &train, &mut rng)?;
            curve.push(tr.accuracy(reg, &test)?);
        }
        curves.push(curve);
    }
    for e in 0..epochs {
        table.row(vec![
            (e + 1).to_string(),
            format!("{:.3}", curves[0][e]),
            format!("{:.3}", curves[1][e]),
            format!("{:.3}", curves[2][e]),
        ]);
    }
    Ok(table)
}

/// Tables 7/8: init-time vs quality trade-off of exact vs approx.
pub fn table7(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train, val) = corpus_for(&spec);
    let fmt = QFormat::Mxint { bits: 2, block: 16 };
    let steps = match scale {
        Scale::Quick => 100,
        Scale::Full => 300,
    };

    let mut table = Table::new(
        "Tables 7/8 analog: init time vs fine-tuned ppl (exact vs approx)",
        &["method", "rank", "calib+init ms", "train steps", "ppl"],
    );
    // ranks constrained to the lowered lora_lm_step artifact set
    let (r_lo, r_hi): (usize, usize) = if spec.name == "nano" { (4, 8) } else { (8, 16) };
    for (method, rank, track_rxx) in [
        (Method::QeraExact, r_lo, true),
        (Method::QeraApprox, r_lo, false),
        (Method::QeraApprox, r_hi, false),
    ] {
        let t0 = std::time::Instant::now();
        let calib = calibrate(reg, &spec, &ckpt.params, &train, 12, track_rxx)?;
        let init = lora_init(&ckpt, method, fmt, rank, Some(&calib), 42)?;
        let init_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut tr = LoraLmTrainer::new(spec.clone(), init, 2e-3);
        tr.train(reg, &train, steps, &mut Rng::new(9))?;
        let ppl = perplexity(reg, &spec, &tr.merged(), &val, 8)?;
        table.row(vec![
            method.name(),
            rank.to_string(),
            format!("{init_ms:.0}"),
            steps.to_string(),
            format!("{ppl:.3}"),
        ]);
    }
    Ok(table)
}

/// Tables 9/10: LoRA rank sweep (over-parameterization check), 16-bit LoRA.
pub fn table9(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let ranks: Vec<usize> = vec![4, 8, 12, 16, 20];
    let epochs = match scale {
        Scale::Quick => 5,
        Scale::Full => 8,
    };
    let mut table = Table::new(
        "Tables 9/10 analog: 16-bit LoRA rank sweep",
        &["task", "rank", "accuracy"],
    );
    for tname in ["majority", "pattern"] {
        let task = Task::by_name(tname).unwrap();
        let train = task.generate(384, spec.vocab, spec.seq, 31);
        let test = task.generate(256, spec.vocab, spec.seq, 932);
        for &rank in &ranks {
            // rank-specific artifacts exist for the cls rank set only
            if reg.load(&format!("lora_cls_step.{}.r{}", spec.name, rank)).is_err() {
                continue;
            }
            let init = lora_init(&ckpt, Method::QloraZero, QFormat::None, rank, None, 42)?;
            let mut tr = LoraClsTrainer::new(spec.clone(), init, 3e-3, &mut Rng::new(42));
            let mut rng = Rng::new(0xF3);
            for _ in 0..epochs {
                tr.train_epoch(reg, &train, &mut rng)?;
            }
            let acc = tr.accuracy(reg, &test)?;
            table.row(vec![tname.to_string(), rank.to_string(), format!("{:.3}", acc)]);
        }
    }
    Ok(table)
}

/// Figure 7: calibration-set choice — pretraining corpus vs padded task data.
pub fn fig7(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train_corpus, _) = corpus_for(&spec);
    let fmt = QFormat::Mxint { bits: 2, block: 16 };
    let task = Task::by_name("majority").unwrap();
    let task_train = task.generate(256, spec.vocab, spec.seq, 41);

    // "downstream" calibration stream: task token sequences, heavily
    // repetitive (the analog of padded SST2 samples)
    let mut task_tokens: Vec<i32> = Vec::new();
    for ex in &task_train {
        task_tokens.extend(&ex.tokens);
        task_tokens.extend(std::iter::repeat(0).take(spec.seq)); // "padding" runs
    }
    let task_corpus = Corpus { vocab: spec.vocab, tokens: task_tokens };

    let epochs = match scale {
        Scale::Quick => 6,
        Scale::Full => 10,
    };
    let mut table = Table::new(
        "Figure 7 analog: fine-tuning loss per epoch vs calibration source",
        &["epoch", "calib=pretraining-corpus", "calib=padded-task-data"],
    );
    let mut curves = Vec::new();
    for corpus in [&train_corpus, &task_corpus] {
        let calib = calibrate(reg, &spec, &ckpt.params, corpus, 12, false)?;
        let init = lora_init(&ckpt, Method::QeraApprox, fmt, 8, Some(&calib), 42)?;
        let mut tr = LoraClsTrainer::new(spec.clone(), init, 3e-3, &mut Rng::new(42));
        let mut rng = Rng::new(0xF4);
        let mut curve = Vec::new();
        for _ in 0..epochs {
            curve.push(tr.train_epoch(reg, &task_train, &mut rng)?);
        }
        curves.push(curve);
    }
    for e in 0..epochs {
        table.row(vec![
            (e + 1).to_string(),
            format!("{:.4}", curves[0][e]),
            format!("{:.4}", curves[1][e]),
        ]);
    }
    Ok(table)
}
