//! Analysis experiments: Figure 5 (Assumption-1 test), Figure 6 (LoftQ
//! weight-error trace), Figure 8 (matrix-sqrt scalability + solver
//! wall-time).

use super::common::{corpus_for, subject_model, Scale};
use crate::bench_util::Table;
use crate::coordinator::{calibrate, quantize, PipelineConfig};
use crate::linalg::{psd, Mat64};
use crate::quant::QFormat;
use crate::runtime::Registry;
use crate::solver::{loftq::loftq_error_trace, Method};
use crate::util::rng::Rng;
use anyhow::Result;

/// Figure 5: normalized off-diagonal mass of R_XX per site (Assumption 1).
pub fn fig5(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train, _) = corpus_for(&spec);
    let calib = calibrate(reg, &spec, &ckpt.params, &train, 16, true)?;
    let mut table = Table::new(
        "Figure 5 analog: Assumption-1 diagnostics of R_XX per tap site",
        &["site", "frob-mass-ratio", "mean|offdiag|/mean(diag)", "assumption-1"],
    );
    for (name, frob, elem) in calib.offdiag_report() {
        // the paper's visual criterion is per-element darkness; <~0.3 means
        // typical off-diagonal entries are well below the diagonal
        let verdict = if elem < 0.3 { "holds" } else { "strained" };
        table.row(vec![name, format!("{frob:.3}"), format!("{elem:.3}"), verdict.to_string()]);
    }
    Ok(table)
}

/// Figure 6: LoftQ weight error per iteration per layer (always decreasing —
/// contrasted with Figure 1b's non-monotone *output* error).
pub fn fig6(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let fmt = QFormat::Mxint { bits: 2, block: 32 };
    let mut table = Table::new(
        "Figure 6 analog: LoftQ weight error ||W - W~ - C_k||_F per iteration",
        &["layer", "iter1", "iter2", "iter3", "iter4", "iter5"],
    );
    for site in spec.linear_sites().iter().take(6) {
        let w = &ckpt.params[site.param_idx];
        let trace = loftq_error_trace(w, fmt, 8, 5);
        let mut row = vec![site.name.clone()];
        row.extend(trace.iter().map(|e| format!("{e:.4}")));
        table.row(row);
    }
    Ok(table)
}

/// Figure 8a: relative error of the PSD matrix square root vs dimension.
pub fn fig8a(scale: Scale) -> Result<Table> {
    let dims: Vec<usize> = match scale {
        Scale::Quick => vec![32, 64, 128, 256],
        Scale::Full => vec![32, 64, 128, 256, 512],
    };
    let mut table = Table::new(
        "Figure 8a analog: ||(R^1/2)^2 - R||_F / ||R||_F vs hidden size",
        &["dim", "sqrt-error-ratio", "wall-ms"],
    );
    for &d in &dims {
        // synthetic anisotropic R_XX like a real layer's
        let mut rng = Rng::new(d as u64);
        let mut m = Mat64::zeros(d, 2 * d);
        let scales: Vec<f64> = (0..d).map(|_| (rng.normal() * 1.5).exp()).collect();
        for i in 0..d {
            for j in 0..2 * d {
                m.a[i * 2 * d + j] = rng.normal() * scales[i];
            }
        }
        let r = m.matmul_nt(&m).scale(1.0 / (2 * d) as f64);
        let t0 = std::time::Instant::now();
        let ratio = psd::sqrt_error_ratio(&r);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        table.row(vec![d.to_string(), format!("{ratio:.3e}"), format!("{ms:.1}")]);
    }
    Ok(table)
}

/// Figure 8b: whole-model quantization wall time, QERA-approx vs QERA-exact
/// (the exact solver pays for eigendecompositions of every R_XX).
pub fn fig8b(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train, _) = corpus_for(&spec);
    let calib = calibrate(reg, &spec, &ckpt.params, &train, 16, true)?;
    let fmt = QFormat::Mxint { bits: 3, block: 32 };
    let mut table = Table::new(
        "Figure 8b analog: quantization wall time per method",
        &["method", "solver-ms (sequential sum)", "max layer ms"],
    );
    for method in [Method::ZeroQuantV2, Method::Lqer, Method::QeraApprox, Method::QeraExact] {
        let qm = quantize(&ckpt, &PipelineConfig::new(method, fmt, 8), Some(&calib))?;
        let max_ms =
            qm.diags.iter().map(|d| d.wall_ms).fold(0.0f64, f64::max);
        table.row(vec![
            method.name(),
            format!("{:.1}", qm.solve_ms_total),
            format!("{max_ms:.1}"),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8a_runs_and_errors_are_tiny() {
        let t = fig8a(Scale::Quick).unwrap();
        assert_eq!(t.rows.len(), 4);
        for row in &t.rows {
            let ratio: f64 = row[1].parse().unwrap();
            assert!(ratio < 1e-6, "{ratio}");
        }
    }
}
