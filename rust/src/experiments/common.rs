//! Shared experiment plumbing: scale knob + cached subject models.

use crate::data::Corpus;
use crate::model::{Checkpoint, ModelSpec};
use crate::runtime::Registry;
use crate::train::{pretrain, PretrainConfig};
use anyhow::Result;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("QERA_BENCH_SCALE").as_deref() {
            Ok("full") => Scale::Full,
            _ => Scale::Quick,
        }
    }

    pub fn seeds(&self) -> Vec<u64> {
        match self {
            Scale::Quick => vec![42],
            Scale::Full => vec![42, 1, 2], // the paper's seeds
        }
    }

    pub fn pretrain_steps(&self, spec: &ModelSpec) -> usize {
        let base = match spec.name.as_str() {
            "nano" => 2500,
            "small" => 1500,
            _ => 800,
        };
        match self {
            Scale::Quick => base,
            Scale::Full => base * 2,
        }
    }
}

/// Corpus used everywhere (seeded; split 95/5 train/val).
pub fn corpus_for(spec: &ModelSpec) -> (Corpus, Corpus) {
    let n = match spec.name.as_str() {
        "nano" => 600_000,
        "small" => 1_200_000,
        _ => 2_000_000,
    };
    Corpus::generate(spec.vocab, n, 42).split(0.05)
}

/// Pretrained subject model, cached on disk under `results/`.
pub fn subject_model(reg: &Registry, spec: &ModelSpec, scale: Scale) -> Result<Checkpoint> {
    let steps = scale.pretrain_steps(spec);
    let path = format!("results/{}-s{}.qkpt", spec.name, steps);
    if let Ok(ckpt) = crate::model::open(&path).and_then(|r| r.into_dense()) {
        if ckpt.spec == *spec {
            crate::info!("subject model cache hit: {path}");
            return Ok(ckpt);
        }
    }
    let (train, _) = corpus_for(spec);
    let pcfg = PretrainConfig {
        steps,
        lr: 2e-3,
        warmup: (steps / 25).max(10),
        seed: 42,
        log_every: (steps / 5).max(1),
    };
    let (ckpt, report) = pretrain(reg, spec, &train, &pcfg)?;
    crate::info!("pretrained {} to loss {:.3}", spec.name, report.final_loss);
    std::fs::create_dir_all("results")?;
    ckpt.save(&path)?;
    Ok(ckpt)
}
