//! Paper-experiment reproductions: one function per table/figure.
//!
//! Benches (`cargo bench`) are thin wrappers over these; results print as
//! markdown and land as CSV under `results/`.  DESIGN.md §5 maps each
//! function to the paper's table/figure it regenerates.
//!
//! Scale: `QERA_BENCH_SCALE=quick|full` (quick = 1 seed, smaller grids —
//! the default; full = 3 seeds, full grids, the EXPERIMENTS.md numbers).

pub mod common;
pub mod ptq;
pub mod qpeft;
pub mod analysis;
pub mod budget;

pub use common::{subject_model, Scale};
