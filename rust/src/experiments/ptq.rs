//! PTQ experiments: Table 3 (perplexity), Table 4 (downstream probes),
//! Figure 3 (calibration-size sweep), Figure 4 (win rate).

use super::common::{corpus_for, subject_model, Scale};
use crate::bench_util::Table;
use crate::coordinator::{calibrate, quantize, PipelineConfig};
use crate::data::tasks::Task;
use crate::eval::{perplexity, probe_accuracy, win_rate};
use crate::quant::QFormat;
use crate::runtime::Registry;
use crate::solver::Method;
use anyhow::Result;

/// The PTQ method rows of Tables 3/4 (+ HQQ).
fn method_rows() -> Vec<(String, Method, QFormat, usize)> {
    // (label, method, format override?, rank) — HQQ uses its own format
    let hqq = QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 };
    vec![
        ("hqq".into(), Method::WOnly, hqq, 0),
        ("w-only".into(), Method::WOnly, QFormat::None, 0),
        ("zeroquant-v2".into(), Method::ZeroQuantV2, QFormat::None, usize::MAX),
        ("lqer".into(), Method::Lqer, QFormat::None, usize::MAX),
        ("qera-approx".into(), Method::QeraApprox, QFormat::None, usize::MAX),
        ("qera-exact".into(), Method::QeraExact, QFormat::None, usize::MAX),
    ]
}

/// Table 3: WikiText2-analog perplexity across models × precisions.
pub fn table3(reg: &Registry, models: &[&str], scale: Scale) -> Result<Table> {
    let precisions = [
        (QFormat::Mxint { bits: 3, block: 32 }, 8usize, "3.25"),
        (QFormat::Mxint { bits: 2, block: 16 }, 16, "2.50"),
    ];
    let mut headers = vec!["w-bits".to_string(), "method".to_string(), "rank".to_string()];
    headers.extend(models.iter().map(|m| m.to_string()));
    let mut table = Table::new(
        "Table 3 analog: perplexity on the synthetic-WikiText2 corpus",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    // bf16 row
    let mut bf16 = vec!["16".to_string(), "bf16".to_string(), "-".to_string()];
    let mut cols: Vec<Vec<String>> = Vec::new();
    for &m in models {
        let spec = reg.spec(m)?.clone();
        let ckpt = subject_model(reg, &spec, scale)?;
        let (train, val) = corpus_for(&spec);
        let calib = calibrate(reg, &spec, &ckpt.params, &train, 16, true)?;
        let ppl = perplexity(reg, &spec, &ckpt.params, &val, 8)?;
        bf16.push(format!("{ppl:.3}"));
        let mut col = Vec::new();
        for (fmt, rank, _) in precisions.iter() {
            for (label, method, fmt_ovr, r) in method_rows() {
                let f = if fmt_ovr == QFormat::None { *fmt } else { fmt_ovr };
                let r = if r == usize::MAX { *rank } else { r };
                let qm = quantize(&ckpt, &PipelineConfig::new(method, f, r), Some(&calib))?;
                let ppl = perplexity(reg, &spec, &qm.merged, &val, 8)?;
                let _ = label;
                col.push(format!("{ppl:.3}"));
            }
        }
        cols.push(col);
    }
    table.rows.push(bf16);
    let per_prec = method_rows().len();
    for (pi, (_fmt, rank, wbits)) in precisions.iter().enumerate() {
        for (mi, (label, _m, fmt_ovr, r)) in method_rows().into_iter().enumerate() {
            let shown_bits = if fmt_ovr == QFormat::None {
                wbits.to_string()
            } else {
                format!("{:.2}", fmt_ovr.avg_bits())
            };
            let shown_rank =
                if r == usize::MAX { format!("{rank}") } else { "-".to_string() };
            let mut row = vec![shown_bits, label, shown_rank];
            for col in &cols {
                row.push(col[pi * per_prec + mi].clone());
            }
            table.rows.push(row);
        }
    }
    Ok(table)
}

/// Table 4: downstream linear-probe accuracy, averaged over the task suite.
pub fn table4(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train, _) = corpus_for(&spec);
    let calib = calibrate(reg, &spec, &ckpt.params, &train, 16, true)?;
    let fmt = QFormat::Mxint { bits: 2, block: 16 };
    let rank = 16;

    let tasks: Vec<Task> = match scale {
        Scale::Quick => ["majority", "firstclass", "count", "pattern", "maxrun", "pairdist"]
            .iter()
            .filter_map(|n| Task::by_name(n))
            .collect(),
        Scale::Full => (0..crate::data::TASK_NAMES.len()).map(|id| Task { id }).collect(),
    };
    let n_train = match scale {
        Scale::Quick => 256,
        Scale::Full => 512,
    };

    let mut headers = vec!["method".to_string()];
    headers.extend(tasks.iter().map(|t| t.name().to_string()));
    headers.push("avg".to_string());
    let mut table = Table::new(
        "Table 4 analog: linear-probe accuracy on the downstream suite (2.50 W-bits)",
        &headers.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let mut datasets = Vec::new();
    for t in &tasks {
        let tr = t.generate(n_train, spec.vocab, spec.seq, 10 + t.id as u64);
        let te = t.generate(256, spec.vocab, spec.seq, 900 + t.id as u64);
        datasets.push((tr, te, t.n_classes()));
    }

    let eval_params =
        |label: &str, params: &[crate::tensor::Tensor], table: &mut Table| -> Result<()> {
            let mut row = vec![label.to_string()];
            let mut sum = 0.0;
            for (tr, te, classes) in &datasets {
                let acc = probe_accuracy(reg, &spec, params, tr, te, *classes)?;
                sum += acc;
                row.push(format!("{:.1}", acc * 100.0));
            }
            row.push(format!("{:.2}", 100.0 * sum / datasets.len() as f64));
            table.row(row);
            Ok(())
        };

    eval_params("bf16", &ckpt.params, &mut table)?;
    for (label, method, fmt_ovr, r) in method_rows() {
        let f = if fmt_ovr == QFormat::None { fmt } else { fmt_ovr };
        let r = if r == usize::MAX { rank } else { r };
        let qm = quantize(&ckpt, &PipelineConfig::new(method, f, r), Some(&calib))?;
        eval_params(&label, &qm.merged, &mut table)?;
    }
    Ok(table)
}

/// Figure 3: recovered perplexity vs number of calibration samples —
/// LQER wobbles, QERA improves monotonically (to noise).
pub fn fig3(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train, val) = corpus_for(&spec);
    let fmt = QFormat::Mxint { bits: 2, block: 16 };
    let rank = 16;
    let sizes: Vec<usize> = match scale {
        Scale::Quick => vec![1, 2, 4, 8, 16, 32],
        Scale::Full => vec![1, 2, 4, 8, 16, 32, 64],
    };
    let mut table = Table::new(
        "Figure 3 analog: ppl vs calibration batches (lower is better)",
        &["calib-batches", "calib-seqs", "lqer", "qera-approx", "qera-exact"],
    );
    for &n in &sizes {
        let calib = calibrate(reg, &spec, &ckpt.params, &train, n, true)?;
        let mut row = vec![n.to_string(), format!("{}", calib.n_sequences)];
        for method in [Method::Lqer, Method::QeraApprox, Method::QeraExact] {
            let qm = quantize(&ckpt, &PipelineConfig::new(method, fmt, rank), Some(&calib))?;
            let ppl = perplexity(reg, &spec, &qm.merged, &val, 8)?;
            row.push(format!("{ppl:.4}"));
        }
        table.row(row);
    }
    Ok(table)
}

/// Figure 4: win rate of each reconstruction method vs the w-only model.
pub fn fig4(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train, val) = corpus_for(&spec);
    let calib = calibrate(reg, &spec, &ckpt.params, &train, 16, true)?;
    let fmt = QFormat::Mxint { bits: 2, block: 16 };
    let rank = 16;
    let wonly = quantize(&ckpt, &PipelineConfig::new(Method::WOnly, fmt, 0), Some(&calib))?;
    let mut table = Table::new(
        "Figure 4 analog: win rate vs w-only (reference-agreement judge)",
        &["method", "win-rate"],
    );
    for method in [Method::ZeroQuantV2, Method::Lqer, Method::QeraApprox, Method::QeraExact] {
        let qm = quantize(&ckpt, &PipelineConfig::new(method, fmt, rank), Some(&calib))?;
        let wr = win_rate(reg, &spec, &ckpt.params, &qm.merged, &wonly.merged, &val, 6)?;
        table.row(vec![method.name(), format!("{:.3}", wr)]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn registry() -> Option<Registry> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
    }

    #[test]
    fn method_rows_cover_paper_grid() {
        let rows = method_rows();
        assert_eq!(rows.len(), 6);
        assert!(rows.iter().any(|(l, ..)| l == "hqq"));
        assert!(rows.iter().any(|(l, ..)| l == "qera-exact"));
    }

    #[test]
    fn fig4_structure() {
        // smoke-level: the function runs end-to-end on the cached nano model
        let Some(reg) = registry() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        // keep it cheap: only run if a cached subject model exists
        let spec = reg.spec("nano").unwrap().clone();
        let steps = Scale::Quick.pretrain_steps(&spec);
        if !PathBuf::from(format!("results/{}-s{}.qkpt", spec.name, steps)).exists() {
            eprintln!("skipped: no cached subject model");
            return;
        }
        let t = fig4(&reg, "nano", Scale::Quick).unwrap();
        assert_eq!(t.rows.len(), 4);
    }
}
