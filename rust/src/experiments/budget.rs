//! Budget-allocator sweep: uniform vs greedy vs Lagrangian plans at
//! matched average bits/weight.
//!
//! For each budget the three strategies allocate over one shared profile,
//! then each plan is executed end to end (quantize → perplexity), so the
//! table shows both the *predicted* output error the allocator optimized
//! and the realized perplexity at the same memory spend.

use super::common::{corpus_for, subject_model, Scale};
use crate::bench_util::Table;
use crate::budget::{allocate, profile, AllocStrategy, CandidateGrid};
use crate::coordinator::{calibrate, quantize, PipelineConfig};
use crate::eval::perplexity;
use crate::quant::QFormat;
use crate::runtime::Registry;
use crate::solver::Method;
use anyhow::Result;

/// Budgets swept per scale (average bits/weight; the grid's cheapest
/// uniform cell is 2.50, so every budget is feasible for all strategies).
pub fn budgets(scale: Scale) -> Vec<f64> {
    match scale {
        Scale::Quick => vec![3.25, 3.75],
        Scale::Full => vec![2.75, 3.25, 3.75, 4.5],
    }
}

/// Uniform-vs-greedy-vs-lagrangian comparison at matched bits/weight.
pub fn budget_sweep(reg: &Registry, model: &str, scale: Scale) -> Result<Table> {
    let spec = reg.spec(model)?.clone();
    let ckpt = subject_model(reg, &spec, scale)?;
    let (train, val) = corpus_for(&spec);
    let calib = calibrate(reg, &spec, &ckpt.params, &train, 16, true)?;

    let grid = CandidateGrid::default_ptq();
    let base = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 4, block: 32 }, 8);
    let prof = profile(&ckpt, &calib, &base, &grid)?;

    let base_ppl = perplexity(reg, &spec, &ckpt.params, &val, 8)?;
    let title =
        format!("budget sweep {model}: plans at matched bits/weight (bf16 ppl {base_ppl:.3})");
    let mut table = Table::new(
        &title,
        &["budget", "strategy", "achieved-bits", "pred-error", "ppl", "delta-vs-bf16"],
    );
    for &b in &budgets(scale) {
        for strat in AllocStrategy::all() {
            let plan = allocate(&prof, b, strat)?;
            let qm = quantize(&ckpt, &base.clone().with_plan(plan.clone()), Some(&calib))?;
            let ppl = perplexity(reg, &spec, &qm.merged, &val, 8)?;
            table.row(vec![
                format!("{b:.2}"),
                strat.name(),
                format!("{:.3}", plan.achieved_bits),
                format!("{:.4}", plan.total_error),
                format!("{ppl:.3}"),
                format!("{:+.3}", ppl - base_ppl),
            ]);
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_are_feasible_for_the_default_grid() {
        // cheapest default-grid cell is mxint2:16 rank 0 = 2.50 bits/weight;
        // every swept budget must sit above it or the sweep would bail
        let cheapest = QFormat::Mxint { bits: 2, block: 16 }.avg_bits();
        for scale in [Scale::Quick, Scale::Full] {
            for b in budgets(scale) {
                assert!(b >= cheapest, "{b} below {cheapest}");
            }
        }
    }
}
