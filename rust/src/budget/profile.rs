//! Layer × candidate-cell profiler.
//!
//! For every quantizable layer and every `(QFormat, rank)` candidate the
//! profiler runs the configured closed-form solver and evaluates the
//! paper's Problem-2 objective `Tr(R_XX P Pᵀ)` via
//! [`crate::solver::metrics::output_error_of`] — a *prediction* of the
//! layer's expected output error under that cell, no forward pass needed.
//! Jobs are independent, so they run on the worker pool (one job per
//! layer × cell, nested kernels stay serial as usual); each tap site's
//! `R_XX` is materialized once and shared across all its cells.
//!
//! Seeds match the pipeline (`seed ^ (site_index << 8)`), so for the
//! deterministic backends a plan's predicted error is exactly the error
//! the executed pipeline realizes.

use crate::coordinator::{CalibResult, PipelineConfig};
use crate::linalg::Mat64;
use crate::model::Checkpoint;
use crate::quant::QFormat;
use crate::solver::{self, Method};
use crate::stats::CalibStats;
use crate::tensor::Tensor;
use crate::util::pool;
use anyhow::{ensure, Result};

/// Candidate `(format, rank)` grid, shared by every layer.
#[derive(Clone, Debug)]
pub struct CandidateGrid {
    pub formats: Vec<QFormat>,
    pub ranks: Vec<usize>,
}

impl CandidateGrid {
    /// The paper's PTQ precision ladder (2.50 / 3.25 / 4.25 W-bits) crossed
    /// with a small rank ladder (0 = quantize only, no reconstruction).
    pub fn default_ptq() -> CandidateGrid {
        CandidateGrid {
            formats: vec![
                QFormat::Mxint { bits: 2, block: 16 },
                QFormat::Mxint { bits: 3, block: 32 },
                QFormat::Mxint { bits: 4, block: 32 },
            ],
            ranks: vec![0, 4, 8, 16],
        }
    }

    /// Flattened format-major cell list (the profiler's column order).
    pub fn cells(&self) -> Vec<(QFormat, usize)> {
        let mut out = Vec::with_capacity(self.formats.len() * self.ranks.len());
        for &fmt in &self.formats {
            for &rank in &self.ranks {
                out.push((fmt, rank));
            }
        }
        out
    }
}

/// Average bits per weight element a cell costs on an `[m, n]` layer: the
/// quantizer's W-bits plus the f32 low-rank overhead
/// `rank · (m + n) · 32 / (m · n)` — the paper's accounting, matching
/// [`crate::coordinator::QuantizedModel::effective_bits`] exactly.
pub fn cell_bits(fmt: QFormat, rank: usize, shape: [usize; 2]) -> f64 {
    let (m, n) = (shape[0] as f64, shape[1] as f64);
    fmt.avg_bits() + rank as f64 * (m + n) * 32.0 / (m * n)
}

/// One scored candidate cell.
#[derive(Clone, Debug)]
pub struct CellScore {
    pub fmt: QFormat,
    pub rank: usize,
    /// Average bits/weight this cell costs on its layer ([`cell_bits`]).
    pub bits: f64,
    /// Predicted expected output error `Tr(R_XX P Pᵀ)`.
    pub error: f64,
}

/// All candidate scores for one layer.
#[derive(Clone, Debug)]
pub struct LayerProfile {
    pub name: String,
    /// `[in_dim, out_dim]`.
    pub shape: [usize; 2],
    /// Scores in the grid's [`CandidateGrid::cells`] order.
    pub cells: Vec<CellScore>,
}

impl LayerProfile {
    /// Weight elements in this layer.
    pub fn elems(&self) -> f64 {
        (self.shape[0] * self.shape[1]) as f64
    }
}

/// The full layer × cell score table the allocator consumes.
#[derive(Clone, Debug)]
pub struct BudgetProfile {
    pub model: String,
    /// Reconstruction method the cells were scored with (rank-0 cells score
    /// as plain `w-only`).
    pub method: Method,
    /// Backends the cells were scored with — carried into the plan so that
    /// executing the plan replays the exact same solves.
    pub svd: solver::SvdBackend,
    pub psd: solver::PsdBackend,
    pub layers: Vec<LayerProfile>,
}

impl BudgetProfile {
    /// Total quantizable weight elements across all layers.
    pub fn total_elems(&self) -> f64 {
        self.layers.iter().map(LayerProfile::elems).sum()
    }
}

/// Solve one cell and price it: predicted output error + bits/weight.
/// Rank 0 means "no reconstruction", so it always solves as `w-only`.
fn score_cell(
    w: &Tensor,
    stats: &CalibStats,
    rxx: &Mat64,
    method: Method,
    fmt: QFormat,
    rank: usize,
    seed: u64,
    svd: solver::SvdBackend,
    psd: solver::PsdBackend,
) -> Result<CellScore> {
    let m = if rank == 0 { Method::WOnly } else { method };
    let out = match m {
        // reuse the caller's materialized R_XX instead of letting
        // solve_with re-materialize it from the stats for every cell
        Method::QeraExact => solver::qera_exact_with(w, fmt, rank, rxx, svd, psd),
        _ => solver::solve_with(m, w, fmt, rank, Some(stats), seed, svd, psd)?,
    };
    let error = solver::metrics::output_error_of(w, &out, rxx);
    Ok(CellScore { fmt, rank, bits: cell_bits(fmt, rank, [w.rows(), w.cols()]), error })
}

/// Score every grid cell on one weight matrix (serially — callers fan out
/// across layers; the hotpath bench times this directly on synthetic wide
/// layers).
pub fn score_layer(
    name: &str,
    w: &Tensor,
    stats: &CalibStats,
    rxx: &Mat64,
    cfg: &PipelineConfig,
    seed: u64,
    grid: &CandidateGrid,
) -> Result<LayerProfile> {
    let mut cells = Vec::with_capacity(grid.formats.len() * grid.ranks.len());
    for (fmt, rank) in grid.cells() {
        cells.push(score_cell(w, stats, rxx, cfg.method, fmt, rank, seed, cfg.svd, cfg.psd)?);
    }
    Ok(LayerProfile { name: name.to_string(), shape: [w.rows(), w.cols()], cells })
}

/// Profile every quantizable layer of `ckpt` against `grid`.
///
/// Needs calibration with `R_XX` tracking (the predicted error is the
/// trace objective).  `cfg` supplies the method/backends/seed/worker
/// count; its `fmt` / `rank` / `plan` fields are ignored.
pub fn profile(
    ckpt: &Checkpoint,
    calib: &CalibResult,
    cfg: &PipelineConfig,
    grid: &CandidateGrid,
) -> Result<BudgetProfile> {
    let spec = &ckpt.spec;
    ensure!(calib.spec == *spec, "calibration spec does not match checkpoint");
    let sites = spec.linear_sites();
    let cells = grid.cells();
    ensure!(!cells.is_empty(), "empty candidate grid");

    // materialize each tap's R_XX once; shared by every cell of every site
    // fed by that tap (wq/wk/wv share attn_in, exactly like the solvers)
    let rxx: Vec<Option<Mat64>> =
        pool::parallel_map_auto(spec.n_taps(), |t| calib.stats[t].rxx_mean());
    for site in &sites {
        ensure!(
            rxx[spec.tap_index(site.block, site.tap)].is_some(),
            "budget profiling needs R_XX tracking in calibration (site {})",
            site.name
        );
    }

    let workers = if cfg.workers == 0 { pool::default_workers() } else { cfg.workers };
    let n_cells = cells.len();
    let scores: Vec<Result<CellScore>> =
        pool::parallel_map(sites.len() * n_cells, workers, |j| {
            let (si, ci) = (j / n_cells, j % n_cells);
            let site = &sites[si];
            let w = &ckpt.params[site.param_idx];
            let stats = calib.for_site(site);
            let r = rxx[spec.tap_index(site.block, site.tap)].as_ref().unwrap();
            let (fmt, rank) = cells[ci];
            score_cell(
                w,
                stats,
                r,
                cfg.method,
                fmt,
                rank,
                cfg.seed ^ ((si as u64) << 8),
                cfg.svd,
                cfg.psd,
            )
        });

    let mut layers = Vec::with_capacity(sites.len());
    let mut it = scores.into_iter();
    for site in &sites {
        let mut cs = Vec::with_capacity(n_cells);
        for _ in 0..n_cells {
            cs.push(it.next().unwrap()?);
        }
        layers.push(LayerProfile { name: site.name.clone(), shape: site.shape, cells: cs });
    }
    crate::info!(
        "profiled {} layers x {} cells ({}, grid {} formats x {} ranks)",
        layers.len(),
        n_cells,
        cfg.method.name(),
        grid.formats.len(),
        grid.ranks.len()
    );
    Ok(BudgetProfile {
        model: spec.name.clone(),
        method: cfg.method,
        svd: cfg.svd,
        psd: cfg.psd,
        layers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CalibResult;
    use crate::model::init::init_params;
    use crate::model::ModelSpec;
    use crate::util::rng::Rng;

    fn micro_setup(seed: u64) -> (Checkpoint, CalibResult) {
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut Rng::new(seed));
        let calib = CalibResult::synthetic(&spec, 64, seed ^ 0x5eed);
        (Checkpoint::new(spec, params), calib)
    }

    fn small_grid() -> CandidateGrid {
        CandidateGrid {
            formats: vec![
                QFormat::Mxint { bits: 2, block: 16 },
                QFormat::Mxint { bits: 4, block: 32 },
            ],
            ranks: vec![0, 4],
        }
    }

    #[test]
    fn cell_bits_accounting() {
        // rank 0: the quantizer's W-bits alone
        let f = QFormat::Mxint { bits: 4, block: 32 };
        assert!((cell_bits(f, 0, [64, 64]) - 4.25).abs() < 1e-12);
        // rank overhead: k (m + n) f32 params over m*n elements
        let b = cell_bits(f, 8, [64, 64]);
        assert!((b - (4.25 + 8.0 * 128.0 * 32.0 / 4096.0)).abs() < 1e-12);
        // wider layers amortize the same rank better
        assert!(cell_bits(f, 8, [64, 256]) < cell_bits(f, 8, [64, 64]));
    }

    #[test]
    fn grid_cells_are_format_major() {
        let g = small_grid();
        let cells = g.cells();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0], (g.formats[0], 0));
        assert_eq!(cells[1], (g.formats[0], 4));
        assert_eq!(cells[2], (g.formats[1], 0));
        assert_eq!(cells[3], (g.formats[1], 4));
    }

    #[test]
    fn profile_covers_every_layer_and_cell() {
        let (ckpt, calib) = micro_setup(1);
        let cfg = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 3, block: 32 }, 4);
        let prof = profile(&ckpt, &calib, &cfg, &small_grid()).unwrap();
        assert_eq!(prof.layers.len(), ckpt.spec.linear_sites().len());
        for lp in &prof.layers {
            assert_eq!(lp.cells.len(), 4);
            for c in &lp.cells {
                assert!(c.error.is_finite() && c.error >= 0.0, "{}", lp.name);
                assert!(c.bits > 0.0);
            }
            // more bits at the same rank must not hurt the predicted error
            // by much, and adding rank at the same format strictly helps
            let e_r0 = lp.cells[2].error; // mxint4 rank 0
            let e_r4 = lp.cells[3].error; // mxint4 rank 4
            assert!(e_r4 <= e_r0 * (1.0 + 1e-9), "{}: {e_r4} vs {e_r0}", lp.name);
        }
    }

    #[test]
    fn profile_deterministic_across_worker_counts() {
        let (ckpt, calib) = micro_setup(2);
        let mut cfg =
            PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 3, block: 32 }, 4);
        cfg.workers = 1;
        let a = profile(&ckpt, &calib, &cfg, &small_grid()).unwrap();
        cfg.workers = 4;
        let b = profile(&ckpt, &calib, &cfg, &small_grid()).unwrap();
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.name, lb.name);
            for (ca, cb) in la.cells.iter().zip(&lb.cells) {
                assert_eq!(ca.error.to_bits(), cb.error.to_bits(), "{}", la.name);
                assert_eq!(ca.bits.to_bits(), cb.bits.to_bits());
            }
        }
    }

    #[test]
    fn profile_requires_rxx_tracking() {
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut Rng::new(3));
        let ckpt = Checkpoint::new(spec.clone(), params);
        // diag-only stats: every site folded without R_XX
        let mut calib = CalibResult::synthetic(&spec, 32, 4);
        for st in &mut calib.stats {
            st.rxx = None;
        }
        let cfg = PipelineConfig::new(Method::QeraApprox, QFormat::Mxint { bits: 3, block: 32 }, 4);
        let err = profile(&ckpt, &calib, &cfg, &small_grid()).unwrap_err();
        assert!(err.to_string().contains("R_XX"), "{err}");
    }
}
