//! Budget allocation over a [`BudgetProfile`] and the [`BudgetPlan`]
//! artifact.
//!
//! The constraint is the paper's memory accounting: average bits per
//! quantizable weight element, low-rank overhead included.  Three
//! strategies:
//!
//! * [`AllocStrategy::Uniform`] — every layer gets the same grid cell; the
//!   best single cell that fits the budget (the repo's pre-PR-5 behavior,
//!   as a controlled baseline).
//! * [`AllocStrategy::Greedy`] — steepest-descent cell upgrades: start at
//!   the cheapest per-layer cells and repeatedly buy the upgrade with the
//!   best predicted Δerror per Δbit until the next-best upgrade no longer
//!   fits.  The upgrade trajectory never looks at the budget, so the plan
//!   for budget `B` is a prefix of the plan for any `B' > B` — predicted
//!   error is monotone non-increasing in the budget by construction.
//!   Tie-breaks are deterministic (layer name, then cell index).
//! * [`AllocStrategy::Lagrangian`] — sweep a multiplier λ over the
//!   per-layer `(bits, error)` frontiers: each layer picks
//!   `argmin error + λ · bits·elems`, which touches exactly the lower
//!   convex hull of its frontier; bisection on λ meets the budget.
//!
//! All three are pure f64 arithmetic over the profile — deterministic for
//! a fixed profile, independent of worker counts.

use super::profile::BudgetProfile;
use crate::quant::QFormat;
use crate::solver::{Method, PsdBackend, SvdBackend};
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// Allocation strategy for [`allocate`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllocStrategy {
    Uniform,
    Greedy,
    Lagrangian,
}

impl AllocStrategy {
    /// `uniform`, `greedy`, or `lagrangian`.
    pub fn parse(s: &str) -> Result<AllocStrategy> {
        match s.trim().to_lowercase().as_str() {
            "uniform" => Ok(AllocStrategy::Uniform),
            "greedy" => Ok(AllocStrategy::Greedy),
            "lagrangian" | "lagrange" => Ok(AllocStrategy::Lagrangian),
            other => bail!("unknown alloc strategy '{other}' (uniform | greedy | lagrangian)"),
        }
    }

    pub fn name(&self) -> String {
        match self {
            AllocStrategy::Uniform => "uniform".into(),
            AllocStrategy::Greedy => "greedy".into(),
            AllocStrategy::Lagrangian => "lagrangian".into(),
        }
    }

    /// All strategies, in comparison-table order.
    pub fn all() -> [AllocStrategy; 3] {
        [AllocStrategy::Uniform, AllocStrategy::Greedy, AllocStrategy::Lagrangian]
    }
}

impl Default for AllocStrategy {
    fn default() -> AllocStrategy {
        AllocStrategy::Greedy
    }
}

/// One layer's assignment in a plan.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanCell {
    pub fmt: QFormat,
    pub rank: usize,
    /// Bits/weight this cell costs on its layer (incl. low-rank overhead).
    pub bits: f64,
    /// Predicted expected output error for this layer under the cell.
    pub predicted_error: f64,
}

/// A serializable per-layer `(format, rank)` plan.
///
/// The JSON form round-trips exactly (`from_json(to_json(p)) == p`): the
/// serializer prints shortest-round-trip f64s, so `--plan-out` followed by
/// `--plan-in` reproduces the identical quantized checkpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct BudgetPlan {
    pub model: String,
    /// Reconstruction method for rank > 0 cells (rank 0 executes `w-only`).
    pub method: Method,
    /// Solver backends the profile was scored with; plan execution uses
    /// these (not the session's flags) so a saved plan replays the exact
    /// same solves regardless of later `--svd`/`--psd` settings.
    pub svd: SvdBackend,
    pub psd: PsdBackend,
    pub strategy: AllocStrategy,
    /// The requested budget (average bits/weight).
    pub budget_bits: f64,
    /// What the allocation actually spends (≤ `budget_bits`).
    pub achieved_bits: f64,
    /// Total predicted output error across layers.
    pub total_error: f64,
    pub layers: BTreeMap<String, PlanCell>,
}

impl BudgetPlan {
    /// Assignment for a layer, if present.
    pub fn cell(&self, name: &str) -> Option<&PlanCell> {
        self.layers.get(name)
    }

    pub fn to_json(&self) -> Json {
        let layers: BTreeMap<String, Json> = self
            .layers
            .iter()
            .map(|(k, c)| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("format", Json::str(c.fmt.name())),
                        ("rank", Json::Num(c.rank as f64)),
                        ("bits", Json::Num(c.bits)),
                        ("predicted_error", Json::Num(c.predicted_error)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("method", Json::str(self.method.name())),
            ("svd", Json::str(self.svd.name())),
            ("psd", Json::str(self.psd.name())),
            ("strategy", Json::str(self.strategy.name())),
            ("budget_bits", Json::Num(self.budget_bits)),
            ("achieved_bits", Json::Num(self.achieved_bits)),
            ("total_error", Json::Num(self.total_error)),
            ("layers", Json::Obj(layers)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<BudgetPlan> {
        let lobj = j.get("layers").and_then(Json::as_obj).context("missing 'layers' object")?;
        let mut layers = BTreeMap::new();
        for (k, v) in lobj {
            layers.insert(
                k.clone(),
                PlanCell {
                    fmt: QFormat::parse(v.req_str("format")?)?,
                    rank: v.req_usize("rank")?,
                    bits: v.req_f64("bits")?,
                    predicted_error: v.req_f64("predicted_error")?,
                },
            );
        }
        Ok(BudgetPlan {
            model: j.req_str("model")?.to_string(),
            method: Method::parse(j.req_str("method")?)?,
            svd: SvdBackend::parse(j.req_str("svd")?)?,
            psd: PsdBackend::parse(j.req_str("psd")?)?,
            strategy: AllocStrategy::parse(j.req_str("strategy")?)?,
            budget_bits: j.req_f64("budget_bits")?,
            achieved_bits: j.req_f64("achieved_bits")?,
            total_error: j.req_f64("total_error")?,
            layers,
        })
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        crate::util::fsio::write_atomic(path.as_ref(), self.to_json().dump_pretty().as_bytes())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<BudgetPlan> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading plan {}", path.as_ref().display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Choose one cell per layer minimizing total predicted output error under
/// the budget (average bits/weight over all quantizable elements).
pub fn allocate(
    prof: &BudgetProfile,
    budget_bits: f64,
    strategy: AllocStrategy,
) -> Result<BudgetPlan> {
    ensure!(!prof.layers.is_empty(), "empty profile");
    ensure!(
        budget_bits.is_finite() && budget_bits > 0.0,
        "budget must be a positive bits/weight value, got {budget_bits}"
    );
    for lp in &prof.layers {
        ensure!(!lp.cells.is_empty(), "layer '{}' has no candidate cells", lp.name);
    }
    let pick = match strategy {
        AllocStrategy::Uniform => alloc_uniform(prof, budget_bits)?,
        AllocStrategy::Greedy => alloc_greedy(prof, budget_bits)?,
        AllocStrategy::Lagrangian => alloc_lagrangian(prof, budget_bits)?,
    };

    let total_elems = prof.total_elems();
    let mut layers = BTreeMap::new();
    let mut total_bits = 0.0f64;
    let mut total_error = 0.0f64;
    for (lp, &ci) in prof.layers.iter().zip(&pick) {
        let c = &lp.cells[ci];
        total_bits += c.bits * lp.elems();
        total_error += c.error;
        layers.insert(
            lp.name.clone(),
            PlanCell { fmt: c.fmt, rank: c.rank, bits: c.bits, predicted_error: c.error },
        );
    }
    let achieved_bits = total_bits / total_elems;
    ensure!(
        achieved_bits <= budget_bits + 1e-9,
        "{} allocation exceeded the budget: {achieved_bits} > {budget_bits}",
        strategy.name()
    );
    Ok(BudgetPlan {
        model: prof.model.clone(),
        method: prof.method,
        svd: prof.svd,
        psd: prof.psd,
        strategy,
        budget_bits,
        achieved_bits,
        total_error,
        layers,
    })
}

/// Same grid cell for every layer: the best single cell that fits.
fn alloc_uniform(prof: &BudgetProfile, budget_bits: f64) -> Result<Vec<usize>> {
    let n_cells = prof.layers[0].cells.len();
    for lp in &prof.layers {
        ensure!(
            lp.cells.len() == n_cells,
            "uniform allocation needs one shared candidate grid (layer '{}')",
            lp.name
        );
        for (a, b) in lp.cells.iter().zip(&prof.layers[0].cells) {
            ensure!(
                a.fmt == b.fmt && a.rank == b.rank,
                "uniform allocation needs one shared candidate grid (layer '{}')",
                lp.name
            );
        }
    }
    let total_elems = prof.total_elems();
    let mut best: Option<(f64, f64, usize)> = None; // (error, bits, cell)
    for ci in 0..n_cells {
        let bits: f64 =
            prof.layers.iter().map(|lp| lp.cells[ci].bits * lp.elems()).sum::<f64>() / total_elems;
        if bits > budget_bits + 1e-12 {
            continue;
        }
        let err: f64 = prof.layers.iter().map(|lp| lp.cells[ci].error).sum();
        let better = match best {
            None => true,
            Some((be, bb, _)) => (err, bits) < (be, bb),
        };
        if better {
            best = Some((err, bits, ci));
        }
    }
    match best {
        Some((_, _, ci)) => Ok(vec![ci; prof.layers.len()]),
        None => bail!(
            "budget {budget_bits} bits/weight is below the cheapest uniform candidate cell"
        ),
    }
}

/// Cheapest cell per layer (tie: lower error, then cell index).
fn floor_pick(prof: &BudgetProfile) -> Vec<usize> {
    prof.layers
        .iter()
        .map(|lp| {
            let mut best = 0usize;
            for i in 1..lp.cells.len() {
                let (c, b) = (&lp.cells[i], &lp.cells[best]);
                if (c.bits, c.error) < (b.bits, b.error) {
                    best = i;
                }
            }
            best
        })
        .collect()
}

/// Steepest-descent upgrades from the per-layer floor.  The trajectory is
/// budget-independent; execution stops at the first upgrade that does not
/// fit, so larger budgets replay a strict superset of the same steps.
fn alloc_greedy(prof: &BudgetProfile, budget_bits: f64) -> Result<Vec<usize>> {
    let total_elems = prof.total_elems();
    let budget_total = budget_bits * total_elems;
    let eps = 1e-9 * total_elems;

    let mut pick = floor_pick(prof);
    let used: f64 =
        pick.iter().zip(&prof.layers).map(|(&ci, lp)| lp.cells[ci].bits * lp.elems()).sum();
    ensure!(
        used <= budget_total + eps,
        "budget {budget_bits} bits/weight is below the cheapest per-layer plan ({:.4})",
        used / total_elems
    );
    greedy_fill(prof, &mut pick, used, budget_total, eps);
    Ok(pick)
}

/// Steepest-descent upgrade loop shared by the greedy allocator (from the
/// floor) and the Lagrangian slack fill (from a hull allocation): apply
/// the best Δerror/Δbit upgrade until the next-best no longer fits.
fn greedy_fill(
    prof: &BudgetProfile,
    pick: &mut [usize],
    mut used: f64,
    budget_total: f64,
    eps: f64,
) {
    loop {
        // best upgrade across layers: max predicted Δerror per Δ(total bit)
        let mut cand: Option<(f64, usize, usize, f64)> = None; // (ratio, layer, cell, Δbits)
        for (li, lp) in prof.layers.iter().enumerate() {
            let cur = &lp.cells[pick[li]];
            for (ci, c) in lp.cells.iter().enumerate() {
                let dbits = (c.bits - cur.bits) * lp.elems();
                let derr = cur.error - c.error;
                if dbits <= 0.0 || derr <= 0.0 {
                    continue;
                }
                let ratio = derr / dbits;
                let better = match &cand {
                    None => true,
                    Some((r, bli, bci, _)) => {
                        ratio > *r
                            || (ratio == *r
                                && (lp.name.as_str(), ci)
                                    < (prof.layers[*bli].name.as_str(), *bci))
                    }
                };
                if better {
                    cand = Some((ratio, li, ci, dbits));
                }
            }
        }
        match cand {
            Some((_, li, ci, dbits)) => {
                if used + dbits > budget_total + eps {
                    break; // budget exhausted: keep the feasible prefix
                }
                used += dbits;
                pick[li] = ci;
            }
            None => break, // nothing left that reduces error
        }
    }
}

/// Multiplier sweep: each layer picks `argmin error + λ · bits · elems`
/// (which touches exactly the lower convex hull of its `(bits, error)`
/// frontier); bisection on λ finds the least-penalized allocation that
/// fits the budget.  Hull sweeps can leave bit slack when the budget falls
/// in a gap between hull allocations, so a final greedy fill spends the
/// remainder on the best-ratio upgrades that still fit.
fn alloc_lagrangian(prof: &BudgetProfile, budget_bits: f64) -> Result<Vec<usize>> {
    let total_elems = prof.total_elems();
    let budget_total = budget_bits * total_elems;
    let eps = 1e-9 * total_elems;

    let pick_at = |lam: f64| -> (Vec<usize>, f64) {
        let mut pick = Vec::with_capacity(prof.layers.len());
        let mut total_bits = 0.0f64;
        for lp in &prof.layers {
            let mut best = 0usize;
            let mut best_obj = f64::INFINITY;
            for (ci, c) in lp.cells.iter().enumerate() {
                let obj = c.error + lam * c.bits * lp.elems();
                // tie: prefer fewer bits (keeps bits(λ) monotone), then index
                let better = obj < best_obj || (obj == best_obj && c.bits < lp.cells[best].bits);
                if better {
                    best = ci;
                    best_obj = obj;
                }
            }
            total_bits += lp.cells[best].bits * lp.elems();
            pick.push(best);
        }
        (pick, total_bits)
    };

    let (p0, b0) = pick_at(0.0);
    if b0 <= budget_total + eps {
        return Ok(p0); // the unconstrained optimum already fits
    }
    let floor = floor_pick(prof);
    let floor_bits: f64 =
        floor.iter().zip(&prof.layers).map(|(&ci, lp)| lp.cells[ci].bits * lp.elems()).sum();
    ensure!(
        floor_bits <= budget_total + eps,
        "budget {budget_bits} bits/weight is below the cheapest per-layer plan ({:.4})",
        floor_bits / total_elems
    );

    // grow λ until the allocation fits, then bisect
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    let mut grew = 0usize;
    while pick_at(hi).1 > budget_total + eps {
        hi *= 2.0;
        grew += 1;
        ensure!(grew < 200, "lagrangian sweep failed to converge");
    }
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if pick_at(mid).1 > budget_total + eps {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let (mut pick, bits) = pick_at(hi);
    ensure!(bits <= budget_total + eps, "lagrangian sweep failed to meet the budget");
    // spend any hull-gap slack on the best remaining upgrades
    greedy_fill(prof, &mut pick, bits, budget_total, eps);
    Ok(pick)
}

#[cfg(test)]
mod tests {
    use super::super::profile::{profile, CandidateGrid, CellScore, LayerProfile};
    use super::*;
    use crate::coordinator::{CalibResult, PipelineConfig};
    use crate::model::init::init_params;
    use crate::model::{Checkpoint, ModelSpec};
    use crate::util::rng::Rng;

    /// Hand-built two-layer profile with transparent numbers.
    fn toy_profile() -> BudgetProfile {
        let fmt2 = QFormat::Mxint { bits: 2, block: 16 };
        let fmt4 = QFormat::Mxint { bits: 4, block: 32 };
        let mk = |name: &str, shape: [usize; 2], errs: [f64; 4]| LayerProfile {
            name: name.into(),
            shape,
            cells: vec![
                CellScore { fmt: fmt2, rank: 0, bits: 2.5, error: errs[0] },
                CellScore { fmt: fmt2, rank: 4, bits: 3.5, error: errs[1] },
                CellScore { fmt: fmt4, rank: 0, bits: 4.25, error: errs[2] },
                CellScore { fmt: fmt4, rank: 4, bits: 5.25, error: errs[3] },
            ],
        };
        BudgetProfile {
            model: "toy".into(),
            method: Method::QeraExact,
            svd: SvdBackend::Auto,
            psd: PsdBackend::Auto,
            layers: vec![
                // layer a: very sensitive (big wins from spending)
                mk("a", [32, 32], [10.0, 2.0, 1.0, 0.2]),
                // layer b: nearly flat (spending is wasted here)
                mk("b", [32, 32], [1.0, 0.9, 0.85, 0.8]),
            ],
        }
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in AllocStrategy::all() {
            assert_eq!(AllocStrategy::parse(&s.name()).unwrap(), s);
        }
        assert_eq!(AllocStrategy::parse("lagrange").unwrap(), AllocStrategy::Lagrangian);
        assert!(AllocStrategy::parse("nope").is_err());
        assert_eq!(AllocStrategy::default(), AllocStrategy::Greedy);
    }

    #[test]
    fn uniform_picks_best_single_cell_that_fits() {
        let prof = toy_profile();
        // budget 4.0: fitting cells are 2.5 and 3.5; 3.5 has lower error
        let plan = allocate(&prof, 4.0, AllocStrategy::Uniform).unwrap();
        for c in plan.layers.values() {
            assert_eq!(c.rank, 4);
            assert_eq!(c.fmt, QFormat::Mxint { bits: 2, block: 16 });
        }
        assert!((plan.achieved_bits - 3.5).abs() < 1e-12);
        assert!((plan.total_error - 2.9).abs() < 1e-12);
        // budget below the cheapest cell fails loudly
        assert!(allocate(&prof, 2.0, AllocStrategy::Uniform).is_err());
    }

    #[test]
    fn greedy_spends_where_the_error_drops() {
        let prof = toy_profile();
        // budget 3.875 total-bits: uniform can only afford 2.5+rank (3.5 avg);
        // greedy should upgrade layer a aggressively and leave b at the floor
        let plan = allocate(&prof, 3.875, AllocStrategy::Greedy).unwrap();
        assert!(plan.achieved_bits <= 3.875 + 1e-12);
        let a = &plan.layers["a"];
        let b = &plan.layers["b"];
        assert!(a.bits > b.bits, "a {:?} b {:?}", a.bits, b.bits);
        let uni = allocate(&prof, 3.875, AllocStrategy::Uniform).unwrap();
        assert!(plan.total_error < uni.total_error);
    }

    #[test]
    fn greedy_error_monotone_in_budget() {
        let prof = toy_profile();
        let mut prev = f64::INFINITY;
        for budget in [2.6, 3.0, 3.5, 4.0, 4.6, 5.25] {
            let plan = allocate(&prof, budget, AllocStrategy::Greedy).unwrap();
            assert!(plan.achieved_bits <= budget + 1e-12, "budget {budget}");
            assert!(
                plan.total_error <= prev + 1e-12,
                "budget {budget}: {} > {prev}",
                plan.total_error
            );
            prev = plan.total_error;
        }
    }

    #[test]
    fn lagrangian_feasible_and_competitive() {
        let prof = toy_profile();
        for budget in [2.6, 3.5, 4.0, 4.6] {
            let lag = allocate(&prof, budget, AllocStrategy::Lagrangian).unwrap();
            assert!(lag.achieved_bits <= budget + 1e-12, "budget {budget}");
            if let Ok(uni) = allocate(&prof, budget, AllocStrategy::Uniform) {
                assert!(
                    lag.total_error <= uni.total_error + 1e-12,
                    "budget {budget}: lag {} vs uni {}",
                    lag.total_error,
                    uni.total_error
                );
            }
        }
        // an unconstrained budget takes the minimum-error cells everywhere
        let all = allocate(&prof, 100.0, AllocStrategy::Lagrangian).unwrap();
        assert!((all.total_error - (0.2 + 0.8)).abs() < 1e-12);
    }

    #[test]
    fn plan_json_roundtrips_exactly() {
        let prof = toy_profile();
        let plan = allocate(&prof, 3.9, AllocStrategy::Greedy).unwrap();
        let back = BudgetPlan::from_json(&Json::parse(&plan.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, plan);
        let pretty =
            BudgetPlan::from_json(&Json::parse(&plan.to_json().dump_pretty()).unwrap()).unwrap();
        assert_eq!(pretty, plan);
    }

    #[test]
    fn plan_save_load_roundtrip() {
        let dir = std::env::temp_dir().join("qera_budget_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        let plan = allocate(&toy_profile(), 4.5, AllocStrategy::Lagrangian).unwrap();
        plan.save(&path).unwrap();
        assert_eq!(BudgetPlan::load(&path).unwrap(), plan);
    }

    /// Real profile on the micro model: greedy must land within a few
    /// percent of the exhaustive optimum (greedy marginal-ratio upgrades
    /// are optimal up to the last discrete step), and never beat it.
    #[test]
    fn greedy_close_to_exhaustive_on_micro_model() {
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut Rng::new(5));
        let ckpt = Checkpoint::new(spec.clone(), params);
        let calib = CalibResult::synthetic(&spec, 64, 6);
        let grid = CandidateGrid {
            formats: vec![
                QFormat::Mxint { bits: 2, block: 16 },
                QFormat::Mxint { bits: 4, block: 32 },
            ],
            ranks: vec![0, 4],
        };
        let cfg = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 3, block: 32 }, 4);
        let prof = profile(&ckpt, &calib, &cfg, &grid).unwrap();
        let n_cells = 4usize;
        let n_layers = prof.layers.len();
        assert_eq!(n_layers, 6);
        let total_elems = prof.total_elems();

        for budget in [3.0f64, 3.75, 4.5] {
            // exhaustive search over all 4^6 assignments
            let mut best_err = f64::INFINITY;
            for combo in 0..n_cells.pow(n_layers as u32) {
                let (mut bits, mut err, mut c) = (0.0f64, 0.0f64, combo);
                for lp in &prof.layers {
                    let cell = &lp.cells[c % n_cells];
                    c /= n_cells;
                    bits += cell.bits * lp.elems();
                    err += cell.error;
                }
                if bits / total_elems <= budget + 1e-12 && err < best_err {
                    best_err = err;
                }
            }
            let greedy = allocate(&prof, budget, AllocStrategy::Greedy).unwrap();
            assert!(
                greedy.total_error >= best_err - 1e-9,
                "budget {budget}: greedy beat the exhaustive optimum?"
            );
            assert!(
                greedy.total_error <= best_err * 1.10 + 1e-12,
                "budget {budget}: greedy {} vs exhaustive {best_err}",
                greedy.total_error
            );
            let lag = allocate(&prof, budget, AllocStrategy::Lagrangian).unwrap();
            assert!(lag.total_error >= best_err - 1e-9, "budget {budget}");
        }
    }

    #[test]
    fn greedy_deterministic_across_runs_and_worker_counts() {
        let spec = ModelSpec::builtin("micro").unwrap();
        let params = init_params(&spec, &mut Rng::new(7));
        let ckpt = Checkpoint::new(spec.clone(), params);
        let calib = CalibResult::synthetic(&spec, 64, 8);
        let grid = CandidateGrid::default_ptq();
        let mut cfg =
            PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 3, block: 32 }, 4);
        cfg.workers = 1;
        let prof1 = profile(&ckpt, &calib, &cfg, &grid).unwrap();
        let p1 = allocate(&prof1, 3.75, AllocStrategy::Greedy).unwrap();
        cfg.workers = 4;
        let prof4 = profile(&ckpt, &calib, &cfg, &grid).unwrap();
        let p4 = allocate(&prof4, 3.75, AllocStrategy::Greedy).unwrap();
        assert_eq!(p1, p4);
        let again =
            allocate(&profile(&ckpt, &calib, &cfg, &grid).unwrap(), 3.75, AllocStrategy::Greedy)
                .unwrap();
        assert_eq!(p4, again);
    }
}
