//! Analytical mixed-precision budget allocator.
//!
//! QERA's closed-form machinery prices any candidate `(QFormat, rank)` cell
//! on any layer for the cost of one solve: the expected layer output error
//! `Tr(R_XX P Pᵀ)` (Equation 15) is computable from calibration statistics
//! alone, no forward passes.  This subsystem turns that price list into a
//! budget-aware quantization plan:
//!
//! 1. [`profile`] scores every layer × candidate cell with the existing
//!    solvers (threaded over the worker pool, reusing the per-site
//!    `CalibStats` / `rxx_mean` calibration already produced);
//! 2. [`allocate`] picks one cell per layer minimizing total predicted
//!    output error subject to a global memory budget (average bits per
//!    weight, low-rank overhead included) under an [`AllocStrategy`]
//!    (`Uniform` / `Greedy` / `Lagrangian`);
//! 3. the resulting [`BudgetPlan`] is a serializable JSON artifact that
//!    [`crate::coordinator::quantize`] executes via per-layer format/rank
//!    overrides (`PipelineConfig::with_plan`), and that the CLI round-trips
//!    through `--plan-out` / `--plan-in`.
//!
//! Unlike the hand-crafted per-layer heuristics in related work
//! (saliency-weighted capacity, balanced rank budgets), the allocation here
//! descends the paper's own objective: every upgrade is bought at the cell
//! with the best predicted Δerror per Δbit.

pub mod alloc;
pub mod profile;

pub use alloc::{allocate, AllocStrategy, BudgetPlan, PlanCell};
pub use profile::{
    cell_bits, profile, score_layer, BudgetProfile, CandidateGrid, CellScore, LayerProfile,
};
