//! Benchmark harness (criterion is not available offline).
//!
//! * [`time_stats`] — repeated timing with warmup → mean / p50 / p95;
//! * [`Table`] — collects rows, prints a GitHub-markdown table, writes CSV
//!   under `results/` so EXPERIMENTS.md can reference the raw numbers.

use std::fmt::Write as _;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct TimeStats {
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn time_stats(warmup: usize, iters: usize, mut f: impl FnMut()) -> TimeStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    TimeStats {
        iters: samples.len(),
        mean_ms: mean,
        p50_ms: pct(0.5),
        p95_ms: pct(0.95),
        min_ms: samples[0],
    }
}

/// Markdown/CSV result table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "\n### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }

    pub fn csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.join(","));
        }
        out
    }

    /// Print the markdown and persist the CSV under `results/<slug>.csv`.
    pub fn emit(&self, slug: &str) {
        println!("{}", self.markdown());
        let path = std::path::Path::new("results").join(format!("{slug}.csv"));
        if let Err(e) = crate::util::fsio::write_atomic(&path, self.csv().as_bytes()) {
            crate::warn_!("could not write {}: {e}", path.display());
        } else {
            println!("[csv] results/{slug}.csv");
        }
    }

    /// JSON form `{title, headers, rows}` for machine-readable reports.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("headers", Json::Arr(self.headers.iter().cloned().map(Json::Str).collect())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().cloned().map(Json::Str).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Write a named collection of tables as one JSON report (e.g. the hotpath
/// bench's `BENCH_solver.json` feeding the perf trajectory).
pub fn emit_json_report(path: &str, tables: &[(&str, &Table)]) {
    use crate::util::json::Json;
    let obj = Json::obj(tables.iter().map(|(k, t)| (*k, t.to_json())).collect());
    if let Err(e) = crate::util::fsio::write_atomic(path, obj.dump_pretty().as_bytes()) {
        crate::warn_!("could not write {path}: {e}");
    } else {
        println!("[json] {path}");
    }
}

/// `f64` formatting helpers used by every bench.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}
pub fn sci(x: f64) -> String {
    format!("{x:.3e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_stats_ordering() {
        let s = time_stats(1, 20, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(s.min_ms <= s.p50_ms);
        assert!(s.p50_ms <= s.p95_ms);
        assert!(s.mean_ms > 0.0);
        assert_eq!(s.iters, 20);
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new("Test", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["x".into(), "y".into()]);
        let md = t.markdown();
        assert!(md.contains("### Test"));
        assert!(md.contains("| 1 | 2 |"));
        let csv = t.csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("a,b"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_roundtrips_through_parser() {
        use crate::util::json::Json;
        let mut t = Table::new("Perf", &["name", "p50"]);
        t.row(vec!["svd".into(), "1.25".into()]);
        let j = t.to_json();
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back.get("title").and_then(Json::as_str), Some("Perf"));
        let rows = back.get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].as_arr().unwrap()[1].as_str(), Some("1.25"));
    }
}
