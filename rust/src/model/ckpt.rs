//! Checkpoint formats and the unified reader entry point.
//!
//! Three on-disk layouts, one `open()`:
//!
//! * [`Checkpoint`] — dense f32 (`QKPT1`): the pretrained subject models and
//!   fine-tuned outputs.
//! * [`QuantCheckpoint`] — quantized (`QQKP1`): every quantized format
//!   (mxint / intq / fp4) stored as bit-packed codes + per-group side
//!   params via [`PackedWeight`] (true W-bits on disk); low-rank `(A, B)`
//!   pairs stored f32.  The native execution backend runs straight from
//!   the packed payloads; dense materialization remains for the stub/LoRA
//!   paths.
//! * Sharded — a JSON manifest plus integrity-hashed shard files (see
//!   [`super::shard`]), for models that should never be materialized
//!   whole.
//!
//! [`open`] sniffs the format from the first bytes and returns a
//! [`CkptReader`] that can load the whole model, one shard, or one named
//! parameter at a time.  `Checkpoint::load` / `QuantCheckpoint::load`
//! remain as thin compat wrappers over `open()`.
//!
//! All three layouts share the same per-parameter record encodings (the
//! `write_*_record` helpers below), so sharded round-trips are
//! bit-identical to monolithic ones.

use super::shard::{param_groups, CkptKind, ShardParam, ShardSet, ShardWriter};
use super::spec::ModelSpec;
use crate::quant::{PackedWeight, QFormat};
use crate::solver::LowRank;
use crate::tensor::Tensor;
use crate::util::fault;
use crate::util::fsio::*;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::retry::{self, RetryPolicy};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const DENSE_MAGIC: &[u8; 5] = b"QKPT1";
const QUANT_MAGIC: &[u8; 5] = b"QQKP1";

/// Dense checkpoint: spec + parameters in canonical order + free-form meta.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub spec: ModelSpec,
    pub params: Vec<Tensor>,
    pub meta: Json,
}

pub(crate) fn spec_json(spec: &ModelSpec) -> Json {
    Json::obj(vec![
        ("name", Json::str(spec.name.clone())),
        ("vocab", Json::Num(spec.vocab as f64)),
        ("d_model", Json::Num(spec.d_model as f64)),
        ("n_layers", Json::Num(spec.n_layers as f64)),
        ("n_heads", Json::Num(spec.n_heads as f64)),
        ("d_ff", Json::Num(spec.d_ff as f64)),
        ("seq", Json::Num(spec.seq as f64)),
        ("batch", Json::Num(spec.batch as f64)),
        ("n_classes", Json::Num(spec.n_classes as f64)),
    ])
}

pub(crate) fn spec_from_json(j: &Json) -> Result<ModelSpec> {
    Ok(ModelSpec {
        name: j.req_str("name")?.to_string(),
        vocab: j.req_usize("vocab")?,
        d_model: j.req_usize("d_model")?,
        n_layers: j.req_usize("n_layers")?,
        n_heads: j.req_usize("n_heads")?,
        d_ff: j.req_usize("d_ff")?,
        seq: j.req_usize("seq")?,
        batch: j.req_usize("batch")?,
        n_classes: j.req_usize("n_classes")?,
    })
}

fn write_shape(w: &mut impl Write, shape: &[usize]) -> Result<()> {
    write_u32(w, shape.len() as u32)?;
    for &d in shape {
        write_u64(w, d as u64)?;
    }
    Ok(())
}

fn read_shape(r: &mut impl Read) -> Result<Vec<usize>> {
    let ndim = read_u32(r)? as usize;
    ensure!(ndim <= 8, "tensor rank too large: {ndim}");
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        dims.push(read_u64(r)? as usize);
    }
    Ok(dims)
}

// ------------------------------------------------------------------------
// Shared per-parameter record encodings.  Monolithic containers and shard
// files both serialize through these, which is what makes sharded and
// monolithic round-trips bit-identical.

/// Dense record: name + shape + f32 payload (the `QKPT1` body encoding).
pub(crate) fn write_dense_record(w: &mut impl Write, name: &str, t: &Tensor) -> Result<()> {
    write_str(w, name)?;
    write_shape(w, t.shape())?;
    write_f32s(w, t.data())?;
    Ok(())
}

/// Read one dense record, validating name and shape against the layout.
pub(crate) fn read_dense_record(r: &mut impl Read, name: &str, shape: &[usize]) -> Result<Tensor> {
    let got = read_str(r)?;
    ensure!(got == name, "param order mismatch: {got} != {name}");
    let dims = read_shape(r)?;
    ensure!(dims == shape, "shape mismatch for {name}");
    Ok(Tensor::new(dims, read_f32s(r)?))
}

/// Tagged quantized-checkpoint record (the `QQKP1` body encoding): exactly
/// one of `dense` (tag 0) or `qw` (tags 1/3/4 packed, tag 2 dense
/// fallback) must be set.
pub(crate) fn write_quant_record(
    w: &mut impl Write,
    name: &str,
    dense: Option<&Tensor>,
    qw: Option<&QWeight>,
) -> Result<()> {
    match (dense, qw) {
        (Some(t), None) => {
            write_u32(w, 0)?; // dense tag
            write_str(w, name)?;
            write_shape(w, t.shape())?;
            write_f32s(w, t.data())?;
        }
        (None, Some(QWeight::Packed { shape, pw })) => match pw {
            PackedWeight::Mxint { bits, block, packed, exps } => {
                write_u32(w, 1)?; // mxint tag
                write_str(w, name)?;
                write_u32(w, *bits as u32)?;
                write_u32(w, *block as u32)?;
                write_shape(w, shape)?;
                write_bytes(w, packed)?;
                let eb: Vec<u8> = exps.iter().map(|&e| e as u8).collect();
                write_bytes(w, &eb)?;
            }
            PackedWeight::IntAffine { bits, group, packed, scales, zeros } => {
                write_u32(w, 3)?; // affine-int tag
                write_str(w, name)?;
                write_u32(w, *bits as u32)?;
                write_u32(w, *group as u32)?;
                write_shape(w, shape)?;
                write_bytes(w, packed)?;
                write_f32s(w, scales)?;
                write_f32s(w, zeros)?;
            }
            PackedWeight::Fp4 { group, packed, scales } => {
                write_u32(w, 4)?; // fp4 tag
                write_str(w, name)?;
                write_u32(w, *group as u32)?;
                write_shape(w, shape)?;
                write_bytes(w, packed)?;
                write_f32s(w, scales)?;
            }
        },
        (None, Some(QWeight::Dense(t))) => {
            write_u32(w, 2)?; // quantized-dense tag
            write_str(w, name)?;
            write_shape(w, t.shape())?;
            write_f32s(w, t.data())?;
        }
        _ => bail!("exactly one of dense/qweight must be set for {name}"),
    }
    Ok(())
}

/// Read one tagged record; returns `(Some(t), None)` for an unquantized
/// dense entry or `(None, Some(qw))` for a quantized one.  Validates name,
/// shape, and packed payload sizes.
pub(crate) fn read_quant_record(
    r: &mut impl Read,
    name: &str,
    shape: &[usize],
) -> Result<(Option<Tensor>, Option<QWeight>)> {
    let tag = read_u32(r)?;
    let got = read_str(r)?;
    ensure!(got == name, "param order mismatch: {got} vs {name}");
    match tag {
        0 | 2 => {
            let dims = read_shape(r)?;
            ensure!(dims == shape, "shape mismatch for {name}");
            let t = Tensor::new(dims, read_f32s(r)?);
            if tag == 0 {
                Ok((Some(t), None))
            } else {
                Ok((None, Some(QWeight::Dense(t))))
            }
        }
        1 | 3 | 4 => {
            let (pw, dims) = match tag {
                1 => {
                    let bits = read_u32(r)? as u8;
                    let block = read_u32(r)? as usize;
                    let dims = read_shape(r)?;
                    let packed = read_bytes(r)?;
                    let exps: Vec<i8> = read_bytes(r)?.iter().map(|&b| b as i8).collect();
                    (PackedWeight::Mxint { bits, block, packed, exps }, dims)
                }
                3 => {
                    let bits = read_u32(r)? as u8;
                    let group = read_u32(r)? as usize;
                    let dims = read_shape(r)?;
                    let packed = read_bytes(r)?;
                    let scales = read_f32s(r)?;
                    let zeros = read_f32s(r)?;
                    (PackedWeight::IntAffine { bits, group, packed, scales, zeros }, dims)
                }
                _ => {
                    let group = read_u32(r)? as usize;
                    let dims = read_shape(r)?;
                    let packed = read_bytes(r)?;
                    let scales = read_f32s(r)?;
                    (PackedWeight::Fp4 { group, packed, scales }, dims)
                }
            };
            ensure!(dims == shape, "shape mismatch for {name}");
            pw.validate(dims.iter().product())
                .with_context(|| format!("packed payload for {name}"))?;
            Ok((None, Some(QWeight::Packed { shape: dims, pw })))
        }
        t => bail!("unknown param tag {t}"),
    }
}

/// Low-rank pair body: `m, k, n` dims + f32 `A` + f32 `B` (name is stored
/// by the caller — inline in shard records, in the trailing section of the
/// monolithic container).
pub(crate) fn write_lowrank_record(w: &mut impl Write, lr: &LowRank) -> Result<()> {
    write_u64(w, lr.a.rows() as u64)?;
    write_u64(w, lr.a.cols() as u64)?;
    write_u64(w, lr.b.cols() as u64)?;
    write_f32s(w, lr.a.data())?;
    write_f32s(w, lr.b.data())?;
    Ok(())
}

pub(crate) fn read_lowrank_record(r: &mut impl Read) -> Result<LowRank> {
    let m = read_u64(r)? as usize;
    let k = read_u64(r)? as usize;
    let n = read_u64(r)? as usize;
    let a = Tensor::new(vec![m, k], read_f32s(r)?);
    let b = Tensor::new(vec![k, n], read_f32s(r)?);
    Ok(LowRank { a, b })
}

impl Checkpoint {
    pub fn new(spec: ModelSpec, params: Vec<Tensor>) -> Self {
        assert_eq!(params.len(), spec.param_layout().len());
        Checkpoint { spec, params, meta: Json::obj(vec![]) }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(DENSE_MAGIC)?;
        write_str(&mut w, &spec_json(&self.spec).dump())?;
        write_str(&mut w, &self.meta.dump())?;
        write_u32(&mut w, self.params.len() as u32)?;
        for (p, (name, _)) in self.params.iter().zip(self.spec.param_layout()) {
            write_dense_record(&mut w, &name, p)?;
        }
        w.flush()?;
        Ok(())
    }

    /// Save as a sharded checkpoint (`shard_layers` transformer blocks per
    /// shard) next to the manifest at `manifest_path`.  Returns the
    /// manifest path.  This is the in-memory compat path; the streaming
    /// quantization pipeline writes shards without ever holding the model.
    pub fn save_sharded(
        &self,
        manifest_path: impl AsRef<Path>,
        shard_layers: usize,
    ) -> Result<PathBuf> {
        let layout = self.spec.param_layout();
        let mut w = ShardWriter::create(
            manifest_path,
            CkptKind::Dense,
            self.spec.clone(),
            self.meta.clone(),
        )?;
        for group in param_groups(&self.spec, shard_layers) {
            let entries = group
                .iter()
                .map(|&i| (layout[i].0.clone(), ShardParam::Dense(self.params[i].clone())))
                .collect();
            w.write_shard(entries)?;
        }
        w.finish()
    }

    /// Compat wrapper: `open(path)?.into_dense()`.  Loads monolithic
    /// `QKPT1` files and sharded manifests alike.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        open(path)?.into_dense()
    }

    /// Parameter by name.
    pub fn param(&self, name: &str) -> Option<&Tensor> {
        let idx = self.spec.param_layout().iter().position(|(n, _)| n == name)?;
        Some(&self.params[idx])
    }
}

fn load_dense_monolithic(bytes: &[u8]) -> Result<Checkpoint> {
    let mut r = bytes;
    let mut magic = [0u8; 5];
    r.read_exact(&mut magic)?;
    ensure!(&magic == DENSE_MAGIC, "not a dense qera checkpoint");
    let spec = spec_from_json(&Json::parse(&read_str(&mut r)?)?)?;
    let meta = Json::parse(&read_str(&mut r)?)?;
    let n = read_u32(&mut r)? as usize;
    let layout = spec.param_layout();
    ensure!(n == layout.len(), "param count mismatch");
    let mut params = Vec::with_capacity(n);
    for (name, shape) in &layout {
        params.push(read_dense_record(&mut r, name, shape)?);
    }
    Ok(Checkpoint { spec, params, meta })
}

/// Storage of one quantized weight.
#[derive(Clone, Debug)]
pub enum QWeight {
    /// Bit-packed codes + per-group side params — any [`PackedWeight`]
    /// format (mxint / intq / fp4), decodable group-by-group by the fused
    /// execution kernels without materializing the dense tensor.
    Packed { shape: Vec<usize>, pw: PackedWeight },
    /// Dense dequantized fallback (identity formats only).
    Dense(Tensor),
}

impl QWeight {
    pub fn dequantize(&self) -> Tensor {
        match self {
            QWeight::Dense(t) => t.clone(),
            QWeight::Packed { shape, pw } => {
                let n: usize = shape.iter().product();
                Tensor::new(shape.clone(), pw.dequantize(n))
            }
        }
    }

    pub fn payload_bytes(&self) -> usize {
        match self {
            QWeight::Dense(t) => t.numel() * 4,
            QWeight::Packed { pw, .. } => pw.payload_bytes(),
        }
    }
}

/// Quantized checkpoint: quantized linears (+ low-rank terms) over a dense
/// base for everything else (embeddings, LayerNorms).
#[derive(Clone, Debug)]
pub struct QuantCheckpoint {
    pub spec: ModelSpec,
    /// Dense params for non-quantized entries, in canonical order; entries
    /// covered by `qweights` hold an empty placeholder tensor.
    pub dense: Vec<Option<Tensor>>,
    /// Quantized weights by param name.
    pub qweights: BTreeMap<String, QWeight>,
    /// Low-rank corrections by param name.
    pub lowrank: BTreeMap<String, LowRank>,
    pub meta: Json,
}

impl QuantCheckpoint {
    /// Build from a dense checkpoint + solved layers, one shared format.
    pub fn from_solved(
        ckpt: &Checkpoint,
        fmt: QFormat,
        solved: &BTreeMap<String, (Tensor, Option<LowRank>)>,
        meta: Json,
    ) -> Self {
        let fmts: BTreeMap<String, QFormat> =
            solved.keys().map(|k| (k.clone(), fmt)).collect();
        Self::from_solved_per_site(ckpt, &fmts, solved, meta)
    }

    /// Build from a dense checkpoint + solved layers with per-layer formats
    /// (the budget-plan execution path): `fmts` must name a format for
    /// every solved layer, so each MXINT layer bit-packs at its own width.
    pub fn from_solved_per_site(
        ckpt: &Checkpoint,
        fmts: &BTreeMap<String, QFormat>,
        solved: &BTreeMap<String, (Tensor, Option<LowRank>)>,
        meta: Json,
    ) -> Self {
        let layout = ckpt.spec.param_layout();
        let mut dense: Vec<Option<Tensor>> = Vec::with_capacity(layout.len());
        let mut qweights = BTreeMap::new();
        let mut lowrank = BTreeMap::new();
        for (p, (name, _)) in ckpt.params.iter().zip(&layout) {
            if let Some((w_dq, lr)) = solved.get(name) {
                let fmt = *fmts.get(name).expect("format for every solved layer");
                let qw = match PackedWeight::quantize(p.data(), &fmt) {
                    Some(pw) => QWeight::Packed { shape: p.shape().to_vec(), pw },
                    None => QWeight::Dense(w_dq.clone()),
                };
                qweights.insert(name.clone(), qw);
                if let Some(lr) = lr {
                    lowrank.insert(name.clone(), lr.clone());
                }
                dense.push(None);
            } else {
                dense.push(Some(p.clone()));
            }
        }
        QuantCheckpoint { spec: ckpt.spec.clone(), dense, qweights, lowrank, meta }
    }

    /// Budget-plan provenance recorded by the allocator at quantize time:
    /// `(plan_bits, plan_strategy)` from `meta`, or `(None, None)` for
    /// checkpoints not produced through a `BudgetPlan`.  Surfaced in serving
    /// telemetry so operators can see which plan a hot-swapped model came
    /// from.
    pub fn plan_telemetry(&self) -> (Option<f64>, Option<String>) {
        let bits = self.meta.get("plan_bits").and_then(Json::as_f64);
        let strategy =
            self.meta.get("plan_strategy").and_then(Json::as_str).map(|s| s.to_string());
        (bits, strategy)
    }

    /// Materialize merged dense params (`W~ + A B`) in canonical order —
    /// what the evaluator feeds to `lm_fwd`.
    pub fn materialize_merged(&self) -> Vec<Tensor> {
        let layout = self.spec.param_layout();
        layout
            .iter()
            .zip(&self.dense)
            .map(|((name, _), d)| match d {
                Some(t) => t.clone(),
                None => {
                    let w_dq = self.qweights[name].dequantize();
                    match self.lowrank.get(name) {
                        Some(lr) => lr.merged_with(&w_dq),
                        None => w_dq,
                    }
                }
            })
            .collect()
    }

    /// Dequantized base (without low-rank merge) — what the LoRA fine-tune
    /// driver uses as frozen weights.
    pub fn materialize_base(&self) -> Vec<Tensor> {
        let layout = self.spec.param_layout();
        layout
            .iter()
            .zip(&self.dense)
            .map(|((name, _), d)| match d {
                Some(t) => t.clone(),
                None => self.qweights[name].dequantize(),
            })
            .collect()
    }

    /// Total serialized weight payload (the paper's memory accounting).
    pub fn payload_bytes(&self) -> usize {
        let dense: usize =
            self.dense.iter().flatten().map(|t| t.numel() * 4).sum();
        let q: usize = self.qweights.values().map(QWeight::payload_bytes).sum();
        let lr: usize = self.lowrank.values().map(|l| l.n_params() * 4).sum();
        dense + q + lr
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())?;
        let mut w = BufWriter::new(f);
        w.write_all(QUANT_MAGIC)?;
        write_str(&mut w, &spec_json(&self.spec).dump())?;
        write_str(&mut w, &self.meta.dump())?;
        let layout = self.spec.param_layout();
        for ((name, _), d) in layout.iter().zip(&self.dense) {
            match d {
                Some(t) => write_quant_record(&mut w, name, Some(t), None)?,
                None => write_quant_record(&mut w, name, None, Some(&self.qweights[name]))?,
            }
        }
        // low-rank section
        write_u32(&mut w, self.lowrank.len() as u32)?;
        for (name, lr) in &self.lowrank {
            write_str(&mut w, name)?;
            write_lowrank_record(&mut w, lr)?;
        }
        w.flush()?;
        Ok(())
    }

    /// Save as a sharded checkpoint; see [`Checkpoint::save_sharded`].
    pub fn save_sharded(
        &self,
        manifest_path: impl AsRef<Path>,
        shard_layers: usize,
    ) -> Result<PathBuf> {
        let layout = self.spec.param_layout();
        let mut w = ShardWriter::create(
            manifest_path,
            CkptKind::Quant,
            self.spec.clone(),
            self.meta.clone(),
        )?;
        for group in param_groups(&self.spec, shard_layers) {
            let entries = group
                .iter()
                .map(|&i| {
                    let name = layout[i].0.clone();
                    let p = match &self.dense[i] {
                        Some(t) => ShardParam::Dense(t.clone()),
                        None => ShardParam::Quant {
                            qw: self.qweights[&name].clone(),
                            lr: self.lowrank.get(&name).cloned(),
                        },
                    };
                    (name, p)
                })
                .collect();
            w.write_shard(entries)?;
        }
        w.finish()
    }

    /// Compat wrapper: `open(path)?.into_quant()`.  Loads monolithic
    /// `QQKP1` files and sharded manifests alike.
    pub fn load(path: impl AsRef<Path>) -> Result<QuantCheckpoint> {
        open(path)?.into_quant()
    }
}

fn load_quant_monolithic(bytes: &[u8]) -> Result<QuantCheckpoint> {
    let mut r = bytes;
    let mut magic = [0u8; 5];
    r.read_exact(&mut magic)?;
    ensure!(&magic == QUANT_MAGIC, "not a quantized qera checkpoint");
    let spec = spec_from_json(&Json::parse(&read_str(&mut r)?)?)?;
    let meta = Json::parse(&read_str(&mut r)?)?;
    let layout = spec.param_layout();
    let mut dense = Vec::with_capacity(layout.len());
    let mut qweights = BTreeMap::new();
    for (name, shape) in &layout {
        match read_quant_record(&mut r, name, shape)? {
            (Some(t), None) => dense.push(Some(t)),
            (None, Some(qw)) => {
                dense.push(None);
                qweights.insert(name.clone(), qw);
            }
            _ => bail!("malformed record for {name}"),
        }
    }
    let n_lr = read_u32(&mut r)? as usize;
    let mut lowrank = BTreeMap::new();
    for _ in 0..n_lr {
        let name = read_str(&mut r)?;
        lowrank.insert(name, read_lowrank_record(&mut r)?);
    }
    Ok(QuantCheckpoint { spec, dense, qweights, lowrank, meta })
}

// ------------------------------------------------------------------------
// Unified reader.

/// Where a [`CkptReader`] gets its data.
enum Source {
    DenseMono(Checkpoint),
    QuantMono(Box<QuantCheckpoint>),
    Sharded(ShardSet),
}

/// Versioned checkpoint reader behind [`open`]: one API over monolithic
/// dense, monolithic quantized, and sharded checkpoints.  Monolithic
/// sources are held in memory (they were read whole to sniff anyway);
/// sharded sources load and sha256-verify shards on demand, so callers can
/// stream one layer group at a time.
pub struct CkptReader {
    source: Source,
    /// I/O retries taken while reading/sniffing the file at open time.
    open_retries: usize,
}

/// Open any checkpoint — monolithic `QKPT1`/`QQKP1` or a sharded manifest
/// — sniffing the format from the leading bytes, on the ambient I/O layer
/// (`QERA_FAULTS`-aware) with default retries.
pub fn open(path: impl AsRef<Path>) -> Result<CkptReader> {
    let io = fault::io_from_env()?;
    open_with(path.as_ref(), io, RetryPolicy::io_default())
}

/// [`open`] with an explicit I/O layer and retry policy, threaded through
/// to shard loads for sharded sources.  Transient read faults retry with
/// backoff; permanent failures surface typed.
pub fn open_with(path: &Path, io: Arc<dyn CkptIo>, retry: RetryPolicy) -> Result<CkptReader> {
    let mut rng = Rng::new(0x0cea_0bea);
    let (res, tries) = retry::retry_io(&retry, &mut rng, || io.read(path));
    let bytes = res.with_context(|| format!("opening {}", path.display()))?;
    let head = bytes.get(..5).unwrap_or(&bytes[..]);
    let source = if head == DENSE_MAGIC {
        Source::DenseMono(load_dense_monolithic(&bytes)?)
    } else if head == QUANT_MAGIC {
        Source::QuantMono(Box::new(load_quant_monolithic(&bytes)?))
    } else if head.iter().find(|b| !b.is_ascii_whitespace()) == Some(&b'{') {
        Source::Sharded(ShardSet::open_manifest_with(path, io, retry)?)
    } else {
        bail!("unrecognized checkpoint format: {}", path.display());
    };
    Ok(CkptReader { source, open_retries: tries as usize })
}

impl CkptReader {
    pub fn kind(&self) -> CkptKind {
        match &self.source {
            Source::DenseMono(_) => CkptKind::Dense,
            Source::QuantMono(_) => CkptKind::Quant,
            Source::Sharded(s) => s.kind(),
        }
    }

    pub fn spec(&self) -> &ModelSpec {
        match &self.source {
            Source::DenseMono(c) => &c.spec,
            Source::QuantMono(q) => &q.spec,
            Source::Sharded(s) => s.spec(),
        }
    }

    pub fn meta(&self) -> &Json {
        match &self.source {
            Source::DenseMono(c) => &c.meta,
            Source::QuantMono(q) => &q.meta,
            Source::Sharded(s) => s.meta(),
        }
    }

    pub fn is_sharded(&self) -> bool {
        matches!(self.source, Source::Sharded(_))
    }

    /// Total I/O retries taken so far: the open-time read plus every
    /// shard load of a sharded source.
    pub fn io_retries(&self) -> usize {
        self.open_retries
            + match &self.source {
                Source::Sharded(s) => s.io_retries(),
                _ => 0,
            }
    }

    /// Faults the I/O layer injected so far (0 outside chaos runs).
    pub fn faults_injected(&self) -> usize {
        match &self.source {
            Source::Sharded(s) => s.faults_injected(),
            _ => 0,
        }
    }

    /// Number of independently loadable units (1 for monolithic files).
    pub fn n_shards(&self) -> usize {
        match &self.source {
            Source::Sharded(s) => s.n_shards(),
            _ => 1,
        }
    }

    /// Load one shard's parameters (verified for sharded sources).  A
    /// monolithic file is a single shard holding the whole model.
    pub fn read_shard(&self, idx: usize) -> Result<Vec<(String, ShardParam)>> {
        match &self.source {
            Source::Sharded(s) => Ok(s.load_shard(idx)?),
            _ => {
                ensure!(idx == 0, "monolithic checkpoint has a single shard");
                let names: Vec<String> =
                    self.spec().param_layout().into_iter().map(|(n, _)| n).collect();
                let params = self.read_params(&names)?;
                Ok(names.into_iter().zip(params).collect())
            }
        }
    }

    /// Load named parameters, in the order given.  Sharded sources read
    /// (and verify) each backing shard at most once per call, so callers
    /// that group requests by layer keep peak memory at one group.
    pub fn read_params(&self, names: &[String]) -> Result<Vec<ShardParam>> {
        match &self.source {
            Source::DenseMono(c) => {
                let layout = c.spec.param_layout();
                let index: BTreeMap<&str, usize> =
                    layout.iter().enumerate().map(|(i, (n, _))| (n.as_str(), i)).collect();
                names
                    .iter()
                    .map(|name| {
                        let &i = index
                            .get(name.as_str())
                            .ok_or_else(|| anyhow!("unknown param '{name}'"))?;
                        Ok(ShardParam::Dense(c.params[i].clone()))
                    })
                    .collect()
            }
            Source::QuantMono(q) => {
                let layout = q.spec.param_layout();
                let index: BTreeMap<&str, usize> =
                    layout.iter().enumerate().map(|(i, (n, _))| (n.as_str(), i)).collect();
                names
                    .iter()
                    .map(|name| {
                        let &i = index
                            .get(name.as_str())
                            .ok_or_else(|| anyhow!("unknown param '{name}'"))?;
                        Ok(match &q.dense[i] {
                            Some(t) => ShardParam::Dense(t.clone()),
                            None => ShardParam::Quant {
                                qw: q.qweights[name].clone(),
                                lr: q.lowrank.get(name).cloned(),
                            },
                        })
                    })
                    .collect()
            }
            Source::Sharded(set) => {
                let mut cache: BTreeMap<usize, BTreeMap<String, ShardParam>> = BTreeMap::new();
                let mut out = Vec::with_capacity(names.len());
                for name in names {
                    let si = set
                        .shard_of(name)
                        .ok_or_else(|| anyhow!("unknown param '{name}'"))?;
                    if !cache.contains_key(&si) {
                        cache.insert(si, set.load_shard(si)?.into_iter().collect());
                    }
                    let p = cache
                        .get_mut(&si)
                        .unwrap()
                        .remove(name)
                        .ok_or_else(|| anyhow!("param '{name}' requested twice"))?;
                    out.push(p);
                }
                Ok(out)
            }
        }
    }

    /// Load a single named parameter.
    pub fn read_param(&self, name: &str) -> Result<ShardParam> {
        let mut v = self.read_params(&[name.to_string()])?;
        Ok(v.pop().unwrap())
    }

    /// Materialize the whole checkpoint as dense.  Sharded sources load
    /// shards in parallel on the pool, each sha256-verified; any shard
    /// failure fails the whole load.
    pub fn into_dense(self) -> Result<Checkpoint> {
        match self.source {
            Source::DenseMono(c) => Ok(c),
            Source::QuantMono(_) => {
                bail!("expected a dense checkpoint, found a quantized one")
            }
            Source::Sharded(set) => {
                ensure!(
                    set.kind() == CkptKind::Dense,
                    "expected a dense checkpoint, found a quantized one"
                );
                let loaded = load_shards_parallel(&set)?;
                let layout = set.spec().param_layout();
                let index: BTreeMap<&str, usize> =
                    layout.iter().enumerate().map(|(i, (n, _))| (n.as_str(), i)).collect();
                let mut params: Vec<Option<Tensor>> = vec![None; layout.len()];
                for shard in loaded {
                    for (name, p) in shard {
                        let ShardParam::Dense(t) = p else {
                            bail!("quantized entry '{name}' in a dense checkpoint");
                        };
                        params[index[name.as_str()]] = Some(t);
                    }
                }
                let params =
                    params.into_iter().map(|p| p.expect("coverage checked at open")).collect();
                Ok(Checkpoint { spec: set.spec().clone(), params, meta: set.meta().clone() })
            }
        }
    }

    /// Materialize the whole checkpoint as quantized.  Sharded sources
    /// load shards in parallel with sha256 verification.
    pub fn into_quant(self) -> Result<QuantCheckpoint> {
        match self.source {
            Source::QuantMono(q) => Ok(*q),
            Source::DenseMono(_) => {
                bail!("expected a quantized checkpoint, found a dense one")
            }
            Source::Sharded(set) => {
                ensure!(
                    set.kind() == CkptKind::Quant,
                    "expected a quantized checkpoint, found a dense one"
                );
                let loaded = load_shards_parallel(&set)?;
                let layout = set.spec().param_layout();
                let index: BTreeMap<&str, usize> =
                    layout.iter().enumerate().map(|(i, (n, _))| (n.as_str(), i)).collect();
                let mut dense: Vec<Option<Tensor>> = vec![None; layout.len()];
                let mut covered = vec![false; layout.len()];
                let mut qweights = BTreeMap::new();
                let mut lowrank = BTreeMap::new();
                for shard in loaded {
                    for (name, p) in shard {
                        let i = index[name.as_str()];
                        covered[i] = true;
                        match p {
                            ShardParam::Dense(t) => dense[i] = Some(t),
                            ShardParam::Quant { qw, lr } => {
                                qweights.insert(name.clone(), qw);
                                if let Some(lr) = lr {
                                    lowrank.insert(name, lr);
                                }
                            }
                        }
                    }
                }
                ensure!(covered.iter().all(|&c| c), "incomplete sharded checkpoint");
                Ok(QuantCheckpoint {
                    spec: set.spec().clone(),
                    dense,
                    qweights,
                    lowrank,
                    meta: set.meta().clone(),
                })
            }
        }
    }
}

/// Load every shard of `set` in parallel on the pool; each load verifies
/// size + sha256 before decoding, and any failure fails the whole load.
fn load_shards_parallel(set: &ShardSet) -> Result<Vec<Vec<(String, ShardParam)>>> {
    let n = set.n_shards();
    let workers = pool::default_workers().min(n.max(1));
    let results = pool::parallel_map(n, workers, |i| set.load_shard(i));
    results.into_iter().collect::<Result<Vec<_>, _>>().map_err(Into::into)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("qera_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("qera_ckpt_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn nano_ckpt(seed: u64) -> Checkpoint {
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut Rng::new(seed));
        Checkpoint::new(spec, params)
    }

    fn mixed_quant(seed: u64) -> (Checkpoint, QuantCheckpoint) {
        // all three packed formats + low-rank terms in one checkpoint
        let ckpt = nano_ckpt(seed);
        let fmts_cycle = [
            QFormat::Mxint { bits: 4, block: 32 },
            QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 },
            QFormat::Fp4 { group: 64 },
        ];
        let mut solved = BTreeMap::new();
        let mut fmts = BTreeMap::new();
        let mut rng = Rng::new(seed ^ 0xabc);
        for (i, site) in ckpt.spec.linear_sites().iter().enumerate() {
            let fmt = fmts_cycle[i % fmts_cycle.len()];
            let w = &ckpt.params[site.param_idx];
            let lr = (i % 2 == 0).then(|| LowRank {
                a: Tensor::randn(vec![site.shape[0], 3], 0.02, &mut rng),
                b: Tensor::randn(vec![3, site.shape[1]], 0.02, &mut rng),
            });
            solved.insert(site.name.clone(), (fmt.qdq(w), lr));
            fmts.insert(site.name.clone(), fmt);
        }
        let q = QuantCheckpoint::from_solved_per_site(&ckpt, &fmts, &solved, Json::obj(vec![]));
        (ckpt, q)
    }

    #[test]
    fn dense_roundtrip() {
        let ckpt = nano_ckpt(42);
        let path = tmpfile("dense.qkpt");
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.spec, ckpt.spec);
        assert_eq!(back.params, ckpt.params);
    }

    #[test]
    fn param_by_name() {
        let ckpt = nano_ckpt(1);
        assert!(ckpt.param("blk0.wq").is_some());
        assert!(ckpt.param("blk9.wq").is_none());
        assert_eq!(ckpt.param("embed").unwrap().shape(), &[256, 64]);
    }

    #[test]
    fn quant_roundtrip_mxint() {
        let ckpt = nano_ckpt(2);
        let fmt = QFormat::Mxint { bits: 4, block: 32 };
        let mut solved = BTreeMap::new();
        let mut rng = Rng::new(3);
        for site in ckpt.spec.linear_sites() {
            let w = &ckpt.params[site.param_idx];
            let w_dq = fmt.qdq(w);
            let lr = LowRank {
                a: Tensor::randn(vec![site.shape[0], 4], 0.01, &mut rng),
                b: Tensor::randn(vec![4, site.shape[1]], 0.01, &mut rng),
            };
            solved.insert(site.name.clone(), (w_dq, Some(lr)));
        }
        let q = QuantCheckpoint::from_solved(&ckpt, fmt, &solved, Json::obj(vec![]));
        let path = tmpfile("quant.qkpt");
        q.save(&path).unwrap();
        let back = QuantCheckpoint::load(&path).unwrap();

        // merged weights identical through the packed round-trip
        let m1 = q.materialize_merged();
        let m2 = back.materialize_merged();
        assert_eq!(m1, m2);

        // packed dequantization == direct qdq
        for site in ckpt.spec.linear_sites() {
            let w = &ckpt.params[site.param_idx];
            let direct = fmt.qdq(w);
            let viapack = back.qweights[&site.name].dequantize();
            assert_eq!(direct, viapack, "{}", site.name);
        }
    }

    #[test]
    fn quant_roundtrip_per_site_formats() {
        // budget plans quantize different layers at different widths; the
        // packed checkpoint must round-trip each layer at its own format
        let ckpt = nano_ckpt(7);
        let f2 = QFormat::Mxint { bits: 2, block: 16 };
        let f4 = QFormat::Mxint { bits: 4, block: 32 };
        let mut solved = BTreeMap::new();
        let mut fmts = BTreeMap::new();
        for (i, site) in ckpt.spec.linear_sites().iter().enumerate() {
            let fmt = if i % 2 == 0 { f2 } else { f4 };
            let w = &ckpt.params[site.param_idx];
            solved.insert(site.name.clone(), (fmt.qdq(w), None));
            fmts.insert(site.name.clone(), fmt);
        }
        let q = QuantCheckpoint::from_solved_per_site(&ckpt, &fmts, &solved, Json::obj(vec![]));
        let path = tmpfile("quant_mixed.qkpt");
        q.save(&path).unwrap();
        let back = QuantCheckpoint::load(&path).unwrap();
        assert_eq!(q.materialize_merged(), back.materialize_merged());
        for site in ckpt.spec.linear_sites() {
            let fmt = fmts[&site.name];
            let direct = fmt.qdq(&ckpt.params[site.param_idx]);
            assert_eq!(direct, back.qweights[&site.name].dequantize(), "{}", site.name);
            match &back.qweights[&site.name] {
                QWeight::Packed { pw: PackedWeight::Mxint { bits, .. }, .. } => {
                    let want = if let QFormat::Mxint { bits: b, .. } = fmt { b } else { 0 };
                    assert_eq!(*bits, want, "{}", site.name);
                }
                _ => panic!("{} should be mxint-packed", site.name),
            }
        }
    }

    #[test]
    fn quant_roundtrip_intq_and_fp4() {
        // the non-mxint formats are now truly bit-packed on disk (tags 3/4)
        let ckpt = nano_ckpt(8);
        let fi = QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 };
        let ff = QFormat::Fp4 { group: 64 };
        let mut solved = BTreeMap::new();
        let mut fmts = BTreeMap::new();
        for (i, site) in ckpt.spec.linear_sites().iter().enumerate() {
            let fmt = if i % 2 == 0 { fi } else { ff };
            let w = &ckpt.params[site.param_idx];
            solved.insert(site.name.clone(), (fmt.qdq(w), None));
            fmts.insert(site.name.clone(), fmt);
        }
        let q = QuantCheckpoint::from_solved_per_site(&ckpt, &fmts, &solved, Json::obj(vec![]));
        for site in ckpt.spec.linear_sites() {
            assert!(
                matches!(q.qweights[&site.name], QWeight::Packed { .. }),
                "{} should be packed",
                site.name
            );
        }
        let path = tmpfile("quant_intq_fp4.qkpt");
        q.save(&path).unwrap();
        let back = QuantCheckpoint::load(&path).unwrap();
        assert_eq!(q.materialize_merged(), back.materialize_merged());
        // packed dequantization == direct qdq for both formats
        for site in ckpt.spec.linear_sites() {
            let direct = fmts[&site.name].qdq(&ckpt.params[site.param_idx]);
            assert_eq!(direct, back.qweights[&site.name].dequantize(), "{}", site.name);
        }
        // and the payload is genuinely small: ≤ 4.25/32 of f32 + ε
        let linear_f32: usize =
            ckpt.spec.linear_sites().iter().map(|s| s.shape[0] * s.shape[1] * 4).sum();
        let q_linear: usize = q.qweights.values().map(QWeight::payload_bytes).sum();
        assert!((q_linear as f64) < 0.15 * linear_f32 as f64, "{q_linear} vs {linear_f32}");
    }

    #[test]
    fn quant_payload_smaller_than_dense() {
        let ckpt = nano_ckpt(4);
        let fmt = QFormat::Mxint { bits: 4, block: 32 };
        let mut solved = BTreeMap::new();
        for site in ckpt.spec.linear_sites() {
            let w = &ckpt.params[site.param_idx];
            solved.insert(site.name.clone(), (fmt.qdq(w), None));
        }
        let q = QuantCheckpoint::from_solved(&ckpt, fmt, &solved, Json::obj(vec![]));
        // linear payload should be ~4.25/32 of f32
        let linear_f32: usize = ckpt
            .spec
            .linear_sites()
            .iter()
            .map(|s| s.shape[0] * s.shape[1] * 4)
            .sum();
        let q_linear: usize = q.qweights.values().map(QWeight::payload_bytes).sum();
        let ratio = q_linear as f64 / linear_f32 as f64;
        assert!((ratio - 4.25 / 32.0).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn merged_equals_base_plus_lowrank() {
        let ckpt = nano_ckpt(5);
        let fmt = QFormat::Mxint { bits: 3, block: 32 };
        let mut solved = BTreeMap::new();
        let mut rng = Rng::new(6);
        let site = &ckpt.spec.linear_sites()[0];
        let w = &ckpt.params[site.param_idx];
        let lr = LowRank {
            a: Tensor::randn(vec![site.shape[0], 2], 0.1, &mut rng),
            b: Tensor::randn(vec![2, site.shape[1]], 0.1, &mut rng),
        };
        solved.insert(site.name.clone(), (fmt.qdq(w), Some(lr.clone())));
        let q = QuantCheckpoint::from_solved(&ckpt, fmt, &solved, Json::obj(vec![]));
        let merged = q.materialize_merged();
        let base = q.materialize_base();
        let want = lr.merged_with(&base[site.param_idx]);
        assert_eq!(merged[site.param_idx], want);
        // other params untouched
        assert_eq!(merged[0], ckpt.params[0]);
    }

    #[test]
    fn wrong_magic_rejected() {
        let path = tmpfile("bogus.qkpt");
        std::fs::write(&path, b"NOPE!xxxxxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        assert!(QuantCheckpoint::load(&path).is_err());
        assert!(open(&path).is_err());
    }

    #[test]
    fn sharded_dense_roundtrip_matches_monolithic() {
        let dir = tmpdir("shard_dense");
        let ckpt = nano_ckpt(11);
        let mono = dir.join("m.qkpt");
        ckpt.save(&mono).unwrap();
        let manifest = ckpt.save_sharded(dir.join("m.manifest.json"), 2).unwrap();

        let via_mono = Checkpoint::load(&mono).unwrap();
        let via_shards = Checkpoint::load(&manifest).unwrap();
        assert_eq!(via_mono.spec, via_shards.spec);
        assert_eq!(via_mono.params, via_shards.params);

        let r = open(&manifest).unwrap();
        assert!(r.is_sharded());
        assert_eq!(r.kind(), CkptKind::Dense);
        assert!(r.n_shards() > 1);
    }

    #[test]
    fn sharded_quant_roundtrip_all_formats() {
        // all three packed formats + low-rank: sharded load must be
        // bit-identical to the monolithic one
        let dir = tmpdir("shard_quant");
        let (_, q) = mixed_quant(12);
        let mono = dir.join("q.qqkp");
        q.save(&mono).unwrap();
        let manifest = q.save_sharded(dir.join("q.manifest.json"), 1).unwrap();

        let via_mono = QuantCheckpoint::load(&mono).unwrap();
        let via_shards = QuantCheckpoint::load(&manifest).unwrap();
        assert_eq!(via_mono.spec, via_shards.spec);
        assert_eq!(via_mono.dense, via_shards.dense);
        assert_eq!(via_mono.lowrank.len(), via_shards.lowrank.len());
        assert_eq!(via_mono.materialize_merged(), via_shards.materialize_merged());
        assert_eq!(via_mono.payload_bytes(), via_shards.payload_bytes());
    }

    #[test]
    fn open_reads_single_params_from_any_source() {
        let dir = tmpdir("read_param");
        let (ckpt, q) = mixed_quant(13);
        let mono_d = dir.join("d.qkpt");
        ckpt.save(&mono_d).unwrap();
        let manifest = q.save_sharded(dir.join("q.manifest.json"), 1).unwrap();

        // dense monolithic: one named tensor without loading order context
        let r = open(&mono_d).unwrap();
        match r.read_param("blk0.wq").unwrap() {
            ShardParam::Dense(t) => assert_eq!(&t, ckpt.param("blk0.wq").unwrap()),
            _ => panic!("dense expected"),
        }

        // sharded quant: a packed site with its low-rank term
        let r = open(&manifest).unwrap();
        match r.read_param("blk0.wq").unwrap() {
            ShardParam::Quant { qw, lr } => {
                assert_eq!(qw.dequantize(), q.qweights["blk0.wq"].dequantize());
                assert_eq!(lr.is_some(), q.lowrank.contains_key("blk0.wq"));
            }
            _ => panic!("quant expected"),
        }

        // kind mismatches are typed failures, not partial loads
        assert!(open(&mono_d).unwrap().into_quant().is_err());
        assert!(open(&manifest).unwrap().into_dense().is_err());
        assert!(r.read_param("blk9.nope").is_err());
    }

    #[test]
    fn pre_shard_fixture_still_loads() {
        // Hand-built QKPT1 bytes (no writer involvement): guards the
        // monolithic container layout against accidental format drift now
        // that save/load go through the shared record helpers.
        let spec = ModelSpec {
            name: "fixture".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            n_heads: 1,
            d_ff: 8,
            seq: 4,
            batch: 1,
            n_classes: 2,
        };
        let layout = spec.param_layout();
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"QKPT1");
        let spec_str = "{\"batch\":1,\"d_ff\":8,\"d_model\":4,\"n_classes\":2,\
                        \"n_heads\":1,\"n_layers\":1,\"name\":\"fixture\",\
                        \"seq\":4,\"vocab\":8}";
        write_str(&mut buf, spec_str).unwrap();
        write_str(&mut buf, "{\"epoch\":3}").unwrap();
        write_u32(&mut buf, layout.len() as u32).unwrap();
        let mut want = Vec::new();
        for (name, shape) in &layout {
            let numel: usize = shape.iter().product();
            let data: Vec<f32> = (0..numel).map(|j| j as f32 * 0.5 - 1.0).collect();
            write_str(&mut buf, name).unwrap();
            write_u32(&mut buf, shape.len() as u32).unwrap();
            for &d in shape {
                write_u64(&mut buf, d as u64).unwrap();
            }
            write_f32s(&mut buf, &data).unwrap();
            want.push(Tensor::new(shape.clone(), data));
        }
        let path = tmpfile("fixture_v0.qkpt");
        std::fs::write(&path, &buf).unwrap();

        let back = open(&path).unwrap();
        assert!(!back.is_sharded());
        assert_eq!(back.meta().req_usize("epoch").unwrap(), 3);
        let back = back.into_dense().unwrap();
        assert_eq!(back.spec, spec);
        assert_eq!(back.params, want);
        // and the compat wrapper sees the same bytes
        assert_eq!(Checkpoint::load(&path).unwrap().params, want);
    }
}
