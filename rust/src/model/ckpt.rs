//! Checkpoint formats.
//!
//! * [`Checkpoint`] — dense f32 (`QKPT1`): the pretrained subject models and
//!   fine-tuned outputs.
//! * [`QuantCheckpoint`] — quantized (`QQKP1`): every quantized format
//!   (mxint / intq / fp4) stored as bit-packed codes + per-group side
//!   params via [`PackedWeight`] (true W-bits on disk); low-rank `(A, B)`
//!   pairs stored f32.  The native execution backend runs straight from
//!   the packed payloads; dense materialization remains for the stub/LoRA
//!   paths.

use super::spec::ModelSpec;
use crate::quant::{PackedWeight, QFormat};
use crate::solver::LowRank;
use crate::tensor::Tensor;
use crate::util::fsio::*;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const DENSE_MAGIC: &[u8; 5] = b"QKPT1";
const QUANT_MAGIC: &[u8; 5] = b"QQKP1";

/// Dense checkpoint: spec + parameters in canonical order + free-form meta.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub spec: ModelSpec,
    pub params: Vec<Tensor>,
    pub meta: Json,
}

fn spec_json(spec: &ModelSpec) -> Json {
    Json::obj(vec![
        ("name", Json::str(spec.name.clone())),
        ("vocab", Json::Num(spec.vocab as f64)),
        ("d_model", Json::Num(spec.d_model as f64)),
        ("n_layers", Json::Num(spec.n_layers as f64)),
        ("n_heads", Json::Num(spec.n_heads as f64)),
        ("d_ff", Json::Num(spec.d_ff as f64)),
        ("seq", Json::Num(spec.seq as f64)),
        ("batch", Json::Num(spec.batch as f64)),
        ("n_classes", Json::Num(spec.n_classes as f64)),
    ])
}

fn spec_from_json(j: &Json) -> Result<ModelSpec> {
    Ok(ModelSpec {
        name: j.req_str("name")?.to_string(),
        vocab: j.req_usize("vocab")?,
        d_model: j.req_usize("d_model")?,
        n_layers: j.req_usize("n_layers")?,
        n_heads: j.req_usize("n_heads")?,
        d_ff: j.req_usize("d_ff")?,
        seq: j.req_usize("seq")?,
        batch: j.req_usize("batch")?,
        n_classes: j.req_usize("n_classes")?,
    })
}

impl Checkpoint {
    pub fn new(spec: ModelSpec, params: Vec<Tensor>) -> Self {
        assert_eq!(params.len(), spec.param_layout().len());
        Checkpoint { spec, params, meta: Json::obj(vec![]) }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(DENSE_MAGIC)?;
        write_str(&mut w, &spec_json(&self.spec).dump())?;
        write_str(&mut w, &self.meta.dump())?;
        write_u32(&mut w, self.params.len() as u32)?;
        for (p, (name, _)) in self.params.iter().zip(self.spec.param_layout()) {
            write_str(&mut w, &name)?;
            write_u32(&mut w, p.shape().len() as u32)?;
            for &d in p.shape() {
                write_u64(&mut w, d as u64)?;
            }
            write_f32s(&mut w, p.data())?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 5];
        r.read_exact(&mut magic)?;
        ensure!(&magic == DENSE_MAGIC, "not a dense qera checkpoint");
        let spec = spec_from_json(&Json::parse(&read_str(&mut r)?)?)?;
        let meta = Json::parse(&read_str(&mut r)?)?;
        let n = read_u32(&mut r)? as usize;
        let layout = spec.param_layout();
        ensure!(n == layout.len(), "param count mismatch");
        let mut params = Vec::with_capacity(n);
        for (name, shape) in &layout {
            let got = read_str(&mut r)?;
            ensure!(&got == name, "param order mismatch: {got} != {name}");
            let ndim = read_u32(&mut r)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u64(&mut r)? as usize);
            }
            ensure!(&dims == shape, "shape mismatch for {name}");
            params.push(Tensor::new(dims, read_f32s(&mut r)?));
        }
        Ok(Checkpoint { spec, params, meta })
    }

    /// Parameter by name.
    pub fn param(&self, name: &str) -> Option<&Tensor> {
        let idx = self.spec.param_layout().iter().position(|(n, _)| n == name)?;
        Some(&self.params[idx])
    }
}

/// Storage of one quantized weight.
#[derive(Clone, Debug)]
pub enum QWeight {
    /// Bit-packed codes + per-group side params — any [`PackedWeight`]
    /// format (mxint / intq / fp4), decodable group-by-group by the fused
    /// execution kernels without materializing the dense tensor.
    Packed { shape: Vec<usize>, pw: PackedWeight },
    /// Dense dequantized fallback (identity formats only).
    Dense(Tensor),
}

impl QWeight {
    pub fn dequantize(&self) -> Tensor {
        match self {
            QWeight::Dense(t) => t.clone(),
            QWeight::Packed { shape, pw } => {
                let n: usize = shape.iter().product();
                Tensor::new(shape.clone(), pw.dequantize(n))
            }
        }
    }

    pub fn payload_bytes(&self) -> usize {
        match self {
            QWeight::Dense(t) => t.numel() * 4,
            QWeight::Packed { pw, .. } => pw.payload_bytes(),
        }
    }
}

fn write_shape(w: &mut impl Write, shape: &[usize]) -> Result<()> {
    write_u32(w, shape.len() as u32)?;
    for &d in shape {
        write_u64(w, d as u64)?;
    }
    Ok(())
}

fn read_shape(r: &mut impl Read) -> Result<Vec<usize>> {
    let ndim = read_u32(r)? as usize;
    ensure!(ndim <= 8, "tensor rank too large: {ndim}");
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        dims.push(read_u64(r)? as usize);
    }
    Ok(dims)
}

/// Quantized checkpoint: quantized linears (+ low-rank terms) over a dense
/// base for everything else (embeddings, LayerNorms).
#[derive(Clone, Debug)]
pub struct QuantCheckpoint {
    pub spec: ModelSpec,
    /// Dense params for non-quantized entries, in canonical order; entries
    /// covered by `qweights` hold an empty placeholder tensor.
    pub dense: Vec<Option<Tensor>>,
    /// Quantized weights by param name.
    pub qweights: BTreeMap<String, QWeight>,
    /// Low-rank corrections by param name.
    pub lowrank: BTreeMap<String, LowRank>,
    pub meta: Json,
}

impl QuantCheckpoint {
    /// Build from a dense checkpoint + solved layers, one shared format.
    pub fn from_solved(
        ckpt: &Checkpoint,
        fmt: QFormat,
        solved: &BTreeMap<String, (Tensor, Option<LowRank>)>,
        meta: Json,
    ) -> Self {
        let fmts: BTreeMap<String, QFormat> =
            solved.keys().map(|k| (k.clone(), fmt)).collect();
        Self::from_solved_per_site(ckpt, &fmts, solved, meta)
    }

    /// Build from a dense checkpoint + solved layers with per-layer formats
    /// (the budget-plan execution path): `fmts` must name a format for
    /// every solved layer, so each MXINT layer bit-packs at its own width.
    pub fn from_solved_per_site(
        ckpt: &Checkpoint,
        fmts: &BTreeMap<String, QFormat>,
        solved: &BTreeMap<String, (Tensor, Option<LowRank>)>,
        meta: Json,
    ) -> Self {
        let layout = ckpt.spec.param_layout();
        let mut dense: Vec<Option<Tensor>> = Vec::with_capacity(layout.len());
        let mut qweights = BTreeMap::new();
        let mut lowrank = BTreeMap::new();
        for (p, (name, _)) in ckpt.params.iter().zip(&layout) {
            if let Some((w_dq, lr)) = solved.get(name) {
                let fmt = *fmts.get(name).expect("format for every solved layer");
                let qw = match PackedWeight::quantize(p.data(), &fmt) {
                    Some(pw) => QWeight::Packed { shape: p.shape().to_vec(), pw },
                    None => QWeight::Dense(w_dq.clone()),
                };
                qweights.insert(name.clone(), qw);
                if let Some(lr) = lr {
                    lowrank.insert(name.clone(), lr.clone());
                }
                dense.push(None);
            } else {
                dense.push(Some(p.clone()));
            }
        }
        QuantCheckpoint { spec: ckpt.spec.clone(), dense, qweights, lowrank, meta }
    }

    /// Budget-plan provenance recorded by the allocator at quantize time:
    /// `(plan_bits, plan_strategy)` from `meta`, or `(None, None)` for
    /// checkpoints not produced through a `BudgetPlan`.  Surfaced in serving
    /// telemetry so operators can see which plan a hot-swapped model came
    /// from.
    pub fn plan_telemetry(&self) -> (Option<f64>, Option<String>) {
        let bits = self.meta.get("plan_bits").and_then(Json::as_f64);
        let strategy =
            self.meta.get("plan_strategy").and_then(Json::as_str).map(|s| s.to_string());
        (bits, strategy)
    }

    /// Materialize merged dense params (`W~ + A B`) in canonical order —
    /// what the evaluator feeds to `lm_fwd`.
    pub fn materialize_merged(&self) -> Vec<Tensor> {
        let layout = self.spec.param_layout();
        layout
            .iter()
            .zip(&self.dense)
            .map(|((name, _), d)| match d {
                Some(t) => t.clone(),
                None => {
                    let w_dq = self.qweights[name].dequantize();
                    match self.lowrank.get(name) {
                        Some(lr) => lr.merged_with(&w_dq),
                        None => w_dq,
                    }
                }
            })
            .collect()
    }

    /// Dequantized base (without low-rank merge) — what the LoRA fine-tune
    /// driver uses as frozen weights.
    pub fn materialize_base(&self) -> Vec<Tensor> {
        let layout = self.spec.param_layout();
        layout
            .iter()
            .zip(&self.dense)
            .map(|((name, _), d)| match d {
                Some(t) => t.clone(),
                None => self.qweights[name].dequantize(),
            })
            .collect()
    }

    /// Total serialized weight payload (the paper's memory accounting).
    pub fn payload_bytes(&self) -> usize {
        let dense: usize =
            self.dense.iter().flatten().map(|t| t.numel() * 4).sum();
        let q: usize = self.qweights.values().map(QWeight::payload_bytes).sum();
        let lr: usize = self.lowrank.values().map(|l| l.n_params() * 4).sum();
        dense + q + lr
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())?;
        let mut w = BufWriter::new(f);
        w.write_all(QUANT_MAGIC)?;
        write_str(&mut w, &spec_json(&self.spec).dump())?;
        write_str(&mut w, &self.meta.dump())?;
        let layout = self.spec.param_layout();
        for ((name, _), d) in layout.iter().zip(&self.dense) {
            match d {
                Some(t) => {
                    write_u32(&mut w, 0)?; // dense tag
                    write_str(&mut w, name)?;
                    write_shape(&mut w, t.shape())?;
                    write_f32s(&mut w, t.data())?;
                }
                None => match &self.qweights[name] {
                    QWeight::Packed { shape, pw } => match pw {
                        PackedWeight::Mxint { bits, block, packed, exps } => {
                            write_u32(&mut w, 1)?; // mxint tag
                            write_str(&mut w, name)?;
                            write_u32(&mut w, *bits as u32)?;
                            write_u32(&mut w, *block as u32)?;
                            write_shape(&mut w, shape)?;
                            write_bytes(&mut w, packed)?;
                            let eb: Vec<u8> = exps.iter().map(|&e| e as u8).collect();
                            write_bytes(&mut w, &eb)?;
                        }
                        PackedWeight::IntAffine { bits, group, packed, scales, zeros } => {
                            write_u32(&mut w, 3)?; // affine-int tag
                            write_str(&mut w, name)?;
                            write_u32(&mut w, *bits as u32)?;
                            write_u32(&mut w, *group as u32)?;
                            write_shape(&mut w, shape)?;
                            write_bytes(&mut w, packed)?;
                            write_f32s(&mut w, scales)?;
                            write_f32s(&mut w, zeros)?;
                        }
                        PackedWeight::Fp4 { group, packed, scales } => {
                            write_u32(&mut w, 4)?; // fp4 tag
                            write_str(&mut w, name)?;
                            write_u32(&mut w, *group as u32)?;
                            write_shape(&mut w, shape)?;
                            write_bytes(&mut w, packed)?;
                            write_f32s(&mut w, scales)?;
                        }
                    },
                    QWeight::Dense(t) => {
                        write_u32(&mut w, 2)?; // quantized-dense tag
                        write_str(&mut w, name)?;
                        write_shape(&mut w, t.shape())?;
                        write_f32s(&mut w, t.data())?;
                    }
                },
            }
        }
        // low-rank section
        write_u32(&mut w, self.lowrank.len() as u32)?;
        for (name, lr) in &self.lowrank {
            write_str(&mut w, name)?;
            write_u64(&mut w, lr.a.rows() as u64)?;
            write_u64(&mut w, lr.a.cols() as u64)?;
            write_u64(&mut w, lr.b.cols() as u64)?;
            write_f32s(&mut w, lr.a.data())?;
            write_f32s(&mut w, lr.b.data())?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<QuantCheckpoint> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 5];
        r.read_exact(&mut magic)?;
        ensure!(&magic == QUANT_MAGIC, "not a quantized qera checkpoint");
        let spec = spec_from_json(&Json::parse(&read_str(&mut r)?)?)?;
        let meta = Json::parse(&read_str(&mut r)?)?;
        let layout = spec.param_layout();
        let mut dense = Vec::with_capacity(layout.len());
        let mut qweights = BTreeMap::new();
        for (name, shape) in &layout {
            let tag = read_u32(&mut r)?;
            let got = read_str(&mut r)?;
            ensure!(&got == name, "param order mismatch: {got} vs {name}");
            match tag {
                0 | 2 => {
                    let dims = read_shape(&mut r)?;
                    ensure!(&dims == shape, "shape mismatch for {name}");
                    let t = Tensor::new(dims, read_f32s(&mut r)?);
                    if tag == 0 {
                        dense.push(Some(t));
                    } else {
                        dense.push(None);
                        qweights.insert(name.clone(), QWeight::Dense(t));
                    }
                }
                1 | 3 | 4 => {
                    let (pw, dims) = match tag {
                        1 => {
                            let bits = read_u32(&mut r)? as u8;
                            let block = read_u32(&mut r)? as usize;
                            let dims = read_shape(&mut r)?;
                            let packed = read_bytes(&mut r)?;
                            let exps: Vec<i8> =
                                read_bytes(&mut r)?.iter().map(|&b| b as i8).collect();
                            (PackedWeight::Mxint { bits, block, packed, exps }, dims)
                        }
                        3 => {
                            let bits = read_u32(&mut r)? as u8;
                            let group = read_u32(&mut r)? as usize;
                            let dims = read_shape(&mut r)?;
                            let packed = read_bytes(&mut r)?;
                            let scales = read_f32s(&mut r)?;
                            let zeros = read_f32s(&mut r)?;
                            (PackedWeight::IntAffine { bits, group, packed, scales, zeros }, dims)
                        }
                        _ => {
                            let group = read_u32(&mut r)? as usize;
                            let dims = read_shape(&mut r)?;
                            let packed = read_bytes(&mut r)?;
                            let scales = read_f32s(&mut r)?;
                            (PackedWeight::Fp4 { group, packed, scales }, dims)
                        }
                    };
                    ensure!(&dims == shape, "shape mismatch for {name}");
                    pw.validate(dims.iter().product())
                        .with_context(|| format!("packed payload for {name}"))?;
                    dense.push(None);
                    qweights.insert(name.clone(), QWeight::Packed { shape: dims, pw });
                }
                t => bail!("unknown param tag {t}"),
            }
        }
        let n_lr = read_u32(&mut r)? as usize;
        let mut lowrank = BTreeMap::new();
        for _ in 0..n_lr {
            let name = read_str(&mut r)?;
            let m = read_u64(&mut r)? as usize;
            let k = read_u64(&mut r)? as usize;
            let n = read_u64(&mut r)? as usize;
            let a = Tensor::new(vec![m, k], read_f32s(&mut r)?);
            let b = Tensor::new(vec![k, n], read_f32s(&mut r)?);
            lowrank.insert(name, LowRank { a, b });
        }
        Ok(QuantCheckpoint { spec, dense, qweights, lowrank, meta })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("qera_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn nano_ckpt(seed: u64) -> Checkpoint {
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut Rng::new(seed));
        Checkpoint::new(spec, params)
    }

    #[test]
    fn dense_roundtrip() {
        let ckpt = nano_ckpt(42);
        let path = tmpfile("dense.qkpt");
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.spec, ckpt.spec);
        assert_eq!(back.params, ckpt.params);
    }

    #[test]
    fn param_by_name() {
        let ckpt = nano_ckpt(1);
        assert!(ckpt.param("blk0.wq").is_some());
        assert!(ckpt.param("blk9.wq").is_none());
        assert_eq!(ckpt.param("embed").unwrap().shape(), &[256, 64]);
    }

    #[test]
    fn quant_roundtrip_mxint() {
        let ckpt = nano_ckpt(2);
        let fmt = QFormat::Mxint { bits: 4, block: 32 };
        let mut solved = BTreeMap::new();
        let mut rng = Rng::new(3);
        for site in ckpt.spec.linear_sites() {
            let w = &ckpt.params[site.param_idx];
            let w_dq = fmt.qdq(w);
            let lr = LowRank {
                a: Tensor::randn(vec![site.shape[0], 4], 0.01, &mut rng),
                b: Tensor::randn(vec![4, site.shape[1]], 0.01, &mut rng),
            };
            solved.insert(site.name.clone(), (w_dq, Some(lr)));
        }
        let q = QuantCheckpoint::from_solved(&ckpt, fmt, &solved, Json::obj(vec![]));
        let path = tmpfile("quant.qkpt");
        q.save(&path).unwrap();
        let back = QuantCheckpoint::load(&path).unwrap();

        // merged weights identical through the packed round-trip
        let m1 = q.materialize_merged();
        let m2 = back.materialize_merged();
        assert_eq!(m1, m2);

        // packed dequantization == direct qdq
        for site in ckpt.spec.linear_sites() {
            let w = &ckpt.params[site.param_idx];
            let direct = fmt.qdq(w);
            let viapack = back.qweights[&site.name].dequantize();
            assert_eq!(direct, viapack, "{}", site.name);
        }
    }

    #[test]
    fn quant_roundtrip_per_site_formats() {
        // budget plans quantize different layers at different widths; the
        // packed checkpoint must round-trip each layer at its own format
        let ckpt = nano_ckpt(7);
        let f2 = QFormat::Mxint { bits: 2, block: 16 };
        let f4 = QFormat::Mxint { bits: 4, block: 32 };
        let mut solved = BTreeMap::new();
        let mut fmts = BTreeMap::new();
        for (i, site) in ckpt.spec.linear_sites().iter().enumerate() {
            let fmt = if i % 2 == 0 { f2 } else { f4 };
            let w = &ckpt.params[site.param_idx];
            solved.insert(site.name.clone(), (fmt.qdq(w), None));
            fmts.insert(site.name.clone(), fmt);
        }
        let q = QuantCheckpoint::from_solved_per_site(&ckpt, &fmts, &solved, Json::obj(vec![]));
        let path = tmpfile("quant_mixed.qkpt");
        q.save(&path).unwrap();
        let back = QuantCheckpoint::load(&path).unwrap();
        assert_eq!(q.materialize_merged(), back.materialize_merged());
        for site in ckpt.spec.linear_sites() {
            let fmt = fmts[&site.name];
            let direct = fmt.qdq(&ckpt.params[site.param_idx]);
            assert_eq!(direct, back.qweights[&site.name].dequantize(), "{}", site.name);
            match &back.qweights[&site.name] {
                QWeight::Packed { pw: PackedWeight::Mxint { bits, .. }, .. } => {
                    let want = if let QFormat::Mxint { bits: b, .. } = fmt { b } else { 0 };
                    assert_eq!(*bits, want, "{}", site.name);
                }
                _ => panic!("{} should be mxint-packed", site.name),
            }
        }
    }

    #[test]
    fn quant_roundtrip_intq_and_fp4() {
        // the non-mxint formats are now truly bit-packed on disk (tags 3/4)
        let ckpt = nano_ckpt(8);
        let fi = QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 };
        let ff = QFormat::Fp4 { group: 64 };
        let mut solved = BTreeMap::new();
        let mut fmts = BTreeMap::new();
        for (i, site) in ckpt.spec.linear_sites().iter().enumerate() {
            let fmt = if i % 2 == 0 { fi } else { ff };
            let w = &ckpt.params[site.param_idx];
            solved.insert(site.name.clone(), (fmt.qdq(w), None));
            fmts.insert(site.name.clone(), fmt);
        }
        let q = QuantCheckpoint::from_solved_per_site(&ckpt, &fmts, &solved, Json::obj(vec![]));
        for site in ckpt.spec.linear_sites() {
            assert!(
                matches!(q.qweights[&site.name], QWeight::Packed { .. }),
                "{} should be packed",
                site.name
            );
        }
        let path = tmpfile("quant_intq_fp4.qkpt");
        q.save(&path).unwrap();
        let back = QuantCheckpoint::load(&path).unwrap();
        assert_eq!(q.materialize_merged(), back.materialize_merged());
        // packed dequantization == direct qdq for both formats
        for site in ckpt.spec.linear_sites() {
            let direct = fmts[&site.name].qdq(&ckpt.params[site.param_idx]);
            assert_eq!(direct, back.qweights[&site.name].dequantize(), "{}", site.name);
        }
        // and the payload is genuinely small: ≤ 4.25/32 of f32 + ε
        let linear_f32: usize =
            ckpt.spec.linear_sites().iter().map(|s| s.shape[0] * s.shape[1] * 4).sum();
        let q_linear: usize = q.qweights.values().map(QWeight::payload_bytes).sum();
        assert!((q_linear as f64) < 0.15 * linear_f32 as f64, "{q_linear} vs {linear_f32}");
    }

    #[test]
    fn quant_payload_smaller_than_dense() {
        let ckpt = nano_ckpt(4);
        let fmt = QFormat::Mxint { bits: 4, block: 32 };
        let mut solved = BTreeMap::new();
        for site in ckpt.spec.linear_sites() {
            let w = &ckpt.params[site.param_idx];
            solved.insert(site.name.clone(), (fmt.qdq(w), None));
        }
        let q = QuantCheckpoint::from_solved(&ckpt, fmt, &solved, Json::obj(vec![]));
        // linear payload should be ~4.25/32 of f32
        let linear_f32: usize = ckpt
            .spec
            .linear_sites()
            .iter()
            .map(|s| s.shape[0] * s.shape[1] * 4)
            .sum();
        let q_linear: usize = q.qweights.values().map(QWeight::payload_bytes).sum();
        let ratio = q_linear as f64 / linear_f32 as f64;
        assert!((ratio - 4.25 / 32.0).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn merged_equals_base_plus_lowrank() {
        let ckpt = nano_ckpt(5);
        let fmt = QFormat::Mxint { bits: 3, block: 32 };
        let mut solved = BTreeMap::new();
        let mut rng = Rng::new(6);
        let site = &ckpt.spec.linear_sites()[0];
        let w = &ckpt.params[site.param_idx];
        let lr = LowRank {
            a: Tensor::randn(vec![site.shape[0], 2], 0.1, &mut rng),
            b: Tensor::randn(vec![2, site.shape[1]], 0.1, &mut rng),
        };
        solved.insert(site.name.clone(), (fmt.qdq(w), Some(lr.clone())));
        let q = QuantCheckpoint::from_solved(&ckpt, fmt, &solved, Json::obj(vec![]));
        let merged = q.materialize_merged();
        let base = q.materialize_base();
        let want = lr.merged_with(&base[site.param_idx]);
        assert_eq!(merged[site.param_idx], want);
        // other params untouched
        assert_eq!(merged[0], ckpt.params[0]);
    }

    #[test]
    fn wrong_magic_rejected() {
        let path = tmpfile("bogus.qkpt");
        std::fs::write(&path, b"NOPE!xxxxxxxx").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        assert!(QuantCheckpoint::load(&path).is_err());
    }
}
