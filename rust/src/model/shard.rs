//! Sharded checkpoint storage: a JSON manifest + integrity-hashed shard
//! files.
//!
//! The monolithic `QKPT1`/`QQKP1` containers assume the whole model fits
//! in RAM; at the paper's flagship scale (4-bit Llama-3.1-70B) neither the
//! quantization pipeline nor serving can afford that.  A sharded
//! checkpoint is a directory of shard files — each holding the parameters
//! of a few transformer blocks — described by a manifest:
//!
//! ```json
//! {
//!   "format": "qera-ckpt-manifest",
//!   "version": 1,
//!   "kind": "quant",
//!   "spec": { "name": "nano", ... },
//!   "meta": { "method": "qera-exact", ... },
//!   "shards": [
//!     { "file": "nano.shard-000.bin", "bytes": 16520,
//!       "sha256": "9f2c…", "params": ["embed", "pos_embed"] },
//!     ...
//!   ]
//! }
//! ```
//!
//! Every shard records its byte size and sha256, so readers verify
//! integrity before deserializing, shards load independently (and
//! therefore in parallel), and a partial or corrupted transfer fails with
//! a typed [`ShardError`] instead of a partially-loaded model.  Shard
//! payloads reuse the exact per-parameter record encodings of the
//! monolithic containers, so a sharded round-trip is bit-identical to a
//! monolithic one.
//!
//! [`ShardWriter`] streams shards out one group at a time (peak memory =
//! one shard, not one model); [`ShardSet`] is the verified reader behind
//! [`super::ckpt::open`].

use super::ckpt::{
    read_dense_record, read_lowrank_record, read_quant_record, spec_from_json, spec_json,
    write_dense_record, write_lowrank_record, write_quant_record, QWeight,
};
use super::spec::ModelSpec;
use crate::solver::LowRank;
use crate::tensor::Tensor;
use crate::util::fsio::{read_u32, write_atomic, write_u32};
use crate::util::json::Json;
use crate::util::sha256;
use anyhow::{bail, ensure, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};

/// Manifest `format` discriminator.
pub const MANIFEST_FORMAT: &str = "qera-ckpt-manifest";
/// Current manifest + shard container version.
pub const MANIFEST_VERSION: u32 = 1;
/// Magic prefix of every shard file.
const SHARD_MAGIC: &[u8; 5] = b"QSHD1";

/// What a checkpoint holds: dense f32 params or quantized weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptKind {
    Dense,
    Quant,
}

impl CkptKind {
    pub fn name(&self) -> &'static str {
        match self {
            CkptKind::Dense => "dense",
            CkptKind::Quant => "quant",
        }
    }

    fn parse(s: &str) -> Option<CkptKind> {
        match s {
            "dense" => Some(CkptKind::Dense),
            "quant" => Some(CkptKind::Quant),
            _ => None,
        }
    }

    fn code(&self) -> u32 {
        match self {
            CkptKind::Dense => 0,
            CkptKind::Quant => 1,
        }
    }
}

/// One parameter's payload inside a shard.
#[derive(Clone, Debug)]
pub enum ShardParam {
    /// Dense f32 tensor — every entry of a dense checkpoint, and the
    /// unquantized entries (embeddings, LayerNorms) of a quantized one.
    Dense(Tensor),
    /// Quantized weight plus its optional low-rank correction.
    Quant { qw: QWeight, lr: Option<LowRank> },
}

impl ShardParam {
    /// Serialized weight payload under the paper's memory accounting
    /// (mirrors `QuantCheckpoint::payload_bytes` per entry).
    pub fn payload_bytes(&self) -> usize {
        match self {
            ShardParam::Dense(t) => t.numel() * 4,
            ShardParam::Quant { qw, lr } => {
                qw.payload_bytes() + lr.as_ref().map(|l| l.n_params() * 4).unwrap_or(0)
            }
        }
    }

    /// Approximate live f32 bytes this entry holds in memory.
    pub fn live_bytes(&self) -> usize {
        self.payload_bytes()
    }
}

/// Typed failure modes of sharded checkpoint I/O.  Every load either
/// returns a fully-verified result or one of these — never a partial
/// model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardError {
    /// Manifest references a shard file that cannot be read.
    MissingShard { file: String, reason: String },
    /// Shard file size differs from the manifest's `bytes`.
    Truncated { file: String, expect: u64, got: u64 },
    /// Shard content hash differs from the manifest's `sha256`.
    ShaMismatch { file: String, expect: String, got: String },
    /// Two manifest entries name the same shard file.
    DuplicateShard { file: String },
    /// A parameter appears in more than one shard.
    DuplicateParam { name: String },
    /// A parameter of the model spec is covered by no shard.
    MissingParam { name: String },
    /// Manifest is not valid (json, schema, version, or unknown params).
    BadManifest { reason: String },
    /// Shard bytes hash correctly but do not decode (wrong magic/version/
    /// kind, malformed records, trailing bytes).
    BadShard { file: String, reason: String },
}

impl fmt::Display for ShardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardError::MissingShard { file, reason } => {
                write!(f, "missing shard file '{file}': {reason}")
            }
            ShardError::Truncated { file, expect, got } => {
                write!(f, "shard '{file}' truncated: {got} bytes on disk, manifest says {expect}")
            }
            ShardError::ShaMismatch { file, expect, got } => {
                write!(
                    f,
                    "sha256 mismatch for shard '{file}': computed {got}, manifest says {expect}"
                )
            }
            ShardError::DuplicateShard { file } => {
                write!(f, "duplicate shard file '{file}' in manifest")
            }
            ShardError::DuplicateParam { name } => {
                write!(f, "param '{name}' appears in more than one shard")
            }
            ShardError::MissingParam { name } => {
                write!(f, "param '{name}' missing from every shard in the manifest")
            }
            ShardError::BadManifest { reason } => {
                write!(f, "invalid checkpoint manifest: {reason}")
            }
            ShardError::BadShard { file, reason } => {
                write!(f, "invalid shard '{file}': {reason}")
            }
        }
    }
}

impl std::error::Error for ShardError {}

/// One manifest entry: a shard file with its integrity data and the
/// parameters it contains.
#[derive(Clone, Debug)]
pub struct ShardInfo {
    pub file: String,
    pub bytes: u64,
    pub sha256: String,
    pub params: Vec<String>,
}

/// Group the canonical parameter layout into shard-sized index groups:
/// `[embed, pos_embed]`, then `shard_layers` transformer blocks per group,
/// then `[lnf_g, lnf_b]`.  `shard_layers == 0` is treated as 1.
pub fn param_groups(spec: &ModelSpec, shard_layers: usize) -> Vec<Vec<usize>> {
    let per = shard_layers.max(1);
    let mut groups = vec![vec![0usize, 1]];
    let mut b = 0;
    while b < spec.n_layers {
        let hi = (b + per).min(spec.n_layers);
        groups.push((2 + b * 10..2 + hi * 10).collect());
        b = hi;
    }
    let tail = 2 + spec.n_layers * 10;
    groups.push(vec![tail, tail + 1]);
    groups
}

/// Streaming shard writer: serialize one parameter group at a time, hash
/// it while writing, then emit the manifest on [`ShardWriter::finish`].
/// Peak memory is one shard's worth of serialized bytes, never the model.
///
/// The manifest is written last and atomically, so a crashed or failed
/// write never leaves a loadable-but-incomplete checkpoint behind.
pub struct ShardWriter {
    manifest_path: PathBuf,
    dir: PathBuf,
    /// Shard file name prefix (the manifest's stem, `.manifest` stripped).
    prefix: String,
    kind: CkptKind,
    spec: ModelSpec,
    meta: Json,
    layout: BTreeMap<String, Vec<usize>>,
    shards: Vec<ShardInfo>,
    written: BTreeSet<String>,
}

impl ShardWriter {
    /// Start a sharded checkpoint at `manifest_path` (shard files are
    /// created next to it, named `<prefix>.shard-NNN.bin`).
    pub fn create(
        manifest_path: impl AsRef<Path>,
        kind: CkptKind,
        spec: ModelSpec,
        meta: Json,
    ) -> Result<ShardWriter> {
        let manifest_path = manifest_path.as_ref().to_path_buf();
        let dir = manifest_path.parent().map(Path::to_path_buf).unwrap_or_else(|| ".".into());
        std::fs::create_dir_all(&dir)?;
        let stem =
            manifest_path.file_stem().and_then(|s| s.to_str()).unwrap_or("ckpt").to_string();
        let prefix = stem.strip_suffix(".manifest").unwrap_or(&stem).to_string();
        let layout = spec.param_layout().into_iter().collect();
        Ok(ShardWriter {
            manifest_path,
            dir,
            prefix,
            kind,
            spec,
            meta,
            layout,
            shards: Vec::new(),
            written: BTreeSet::new(),
        })
    }

    /// Serialize `entries` as the next shard, hashing while writing.
    /// Every entry must name a parameter of the spec, exactly once across
    /// the whole checkpoint, with a layout-matching shape.
    pub fn write_shard(&mut self, entries: Vec<(String, ShardParam)>) -> Result<()> {
        ensure!(!entries.is_empty(), "empty shard");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(SHARD_MAGIC);
        write_u32(&mut buf, MANIFEST_VERSION)?;
        write_u32(&mut buf, self.kind.code())?;
        write_u32(&mut buf, entries.len() as u32)?;
        let mut names = Vec::with_capacity(entries.len());
        for (name, param) in &entries {
            let Some(shape) = self.layout.get(name) else {
                bail!("shard entry '{name}' is not a parameter of model '{}'", self.spec.name);
            };
            if !self.written.insert(name.clone()) {
                return Err(ShardError::DuplicateParam { name: name.clone() }.into());
            }
            match (self.kind, param) {
                (CkptKind::Dense, ShardParam::Dense(t)) => {
                    ensure!(t.shape() == &shape[..], "shape mismatch for {name}");
                    write_dense_record(&mut buf, name, t)?;
                }
                (CkptKind::Dense, ShardParam::Quant { .. }) => {
                    bail!("quantized entry '{name}' in a dense checkpoint shard");
                }
                (CkptKind::Quant, ShardParam::Dense(t)) => {
                    ensure!(t.shape() == &shape[..], "shape mismatch for {name}");
                    write_quant_record(&mut buf, name, Some(t), None)?;
                    write_u32(&mut buf, 0)?; // no low-rank
                }
                (CkptKind::Quant, ShardParam::Quant { qw, lr }) => {
                    write_quant_record(&mut buf, name, None, Some(qw))?;
                    match lr {
                        Some(lr) => {
                            write_u32(&mut buf, 1)?;
                            write_lowrank_record(&mut buf, lr)?;
                        }
                        None => write_u32(&mut buf, 0)?,
                    }
                }
            }
            names.push(name.clone());
        }
        let file = format!("{}.shard-{:03}.bin", self.prefix, self.shards.len());
        let sha = sha256::hex_digest(&buf);
        write_atomic(self.dir.join(&file), &buf)?;
        self.shards.push(ShardInfo { file, bytes: buf.len() as u64, sha256: sha, params: names });
        Ok(())
    }

    /// Check full parameter coverage and atomically write the manifest.
    /// Returns the manifest path.
    pub fn finish(self) -> Result<PathBuf> {
        for name in self.layout.keys() {
            if !self.written.contains(name) {
                return Err(ShardError::MissingParam { name: name.clone() }.into());
            }
        }
        let shards = Json::Arr(
            self.shards
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("file", Json::str(s.file.clone())),
                        ("bytes", Json::Num(s.bytes as f64)),
                        ("sha256", Json::str(s.sha256.clone())),
                        ("params", Json::Arr(s.params.iter().map(Json::str).collect())),
                    ])
                })
                .collect(),
        );
        let manifest = Json::obj(vec![
            ("format", Json::str(MANIFEST_FORMAT)),
            ("version", Json::Num(MANIFEST_VERSION as f64)),
            ("kind", Json::str(self.kind.name())),
            ("spec", spec_json(&self.spec)),
            ("meta", self.meta.clone()),
            ("shards", shards),
        ]);
        write_atomic(&self.manifest_path, manifest.dump_pretty().as_bytes())?;
        Ok(self.manifest_path)
    }
}

/// A parsed, schema-validated sharded checkpoint: the typed low-level
/// reader behind `ckpt::open`.  Construction validates the manifest
/// (version, kind, spec, shard uniqueness, exact parameter coverage);
/// [`ShardSet::load_shard`] verifies size + sha256 before decoding.
pub struct ShardSet {
    dir: PathBuf,
    pub(crate) kind: CkptKind,
    pub(crate) spec: ModelSpec,
    pub(crate) meta: Json,
    shards: Vec<ShardInfo>,
    layout: BTreeMap<String, Vec<usize>>,
    /// Parameter name → index of the shard containing it.
    by_param: BTreeMap<String, usize>,
}

fn bad(reason: impl Into<String>) -> ShardError {
    ShardError::BadManifest { reason: reason.into() }
}

impl ShardSet {
    /// Parse and validate a manifest file.
    pub fn open_manifest(path: &Path) -> Result<ShardSet, ShardError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| bad(format!("reading {}: {e}", path.display())))?;
        let j = Json::parse(&text).map_err(|e| bad(format!("{e:?}")))?;
        Self::from_json(path, &j)
    }

    fn from_json(path: &Path, j: &Json) -> Result<ShardSet, ShardError> {
        let fmt = j.req_str("format").map_err(|e| bad(format!("{e:#}")))?;
        if fmt != MANIFEST_FORMAT {
            return Err(bad(format!("unknown format '{fmt}'")));
        }
        let version = j.req_usize("version").map_err(|e| bad(format!("{e:#}")))? as u32;
        if version != MANIFEST_VERSION {
            return Err(bad(format!(
                "unsupported manifest version {version} (this build reads {MANIFEST_VERSION})"
            )));
        }
        let kind_s = j.req_str("kind").map_err(|e| bad(format!("{e:#}")))?;
        let kind = CkptKind::parse(kind_s).ok_or_else(|| bad(format!("unknown kind '{kind_s}'")))?;
        let spec = spec_from_json(j.get("spec").ok_or_else(|| bad("missing 'spec'"))?)
            .map_err(|e| bad(format!("{e:#}")))?;
        let meta = j.get("meta").cloned().unwrap_or_else(|| Json::obj(vec![]));
        let layout: BTreeMap<String, Vec<usize>> = spec.param_layout().into_iter().collect();

        let mut shards = Vec::new();
        let mut files = BTreeSet::new();
        let mut by_param = BTreeMap::new();
        for entry in j.req_arr("shards").map_err(|e| bad(format!("{e:#}")))? {
            let file = entry.req_str("file").map_err(|e| bad(format!("{e:#}")))?.to_string();
            let bytes = entry.req_f64("bytes").map_err(|e| bad(format!("{e:#}")))? as u64;
            let sha256 = entry.req_str("sha256").map_err(|e| bad(format!("{e:#}")))?.to_string();
            if !files.insert(file.clone()) {
                return Err(ShardError::DuplicateShard { file });
            }
            let mut params = Vec::new();
            for p in entry.req_arr("params").map_err(|e| bad(format!("{e:#}")))? {
                let name = p.as_str().ok_or_else(|| bad("non-string param name"))?.to_string();
                if !layout.contains_key(&name) {
                    return Err(bad(format!(
                        "shard '{file}' lists unknown param '{name}' for model '{}'",
                        spec.name
                    )));
                }
                if by_param.insert(name.clone(), shards.len()).is_some() {
                    return Err(ShardError::DuplicateParam { name });
                }
                params.push(name);
            }
            shards.push(ShardInfo { file, bytes, sha256, params });
        }
        for name in layout.keys() {
            if !by_param.contains_key(name) {
                return Err(ShardError::MissingParam { name: name.clone() });
            }
        }
        let dir = path.parent().map(Path::to_path_buf).unwrap_or_else(|| ".".into());
        Ok(ShardSet { dir, kind, spec, meta, shards, layout, by_param })
    }

    pub fn kind(&self) -> CkptKind {
        self.kind
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn meta(&self) -> &Json {
        &self.meta
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, idx: usize) -> &ShardInfo {
        &self.shards[idx]
    }

    /// Index of the shard holding `name` (validated total at open time).
    pub fn shard_of(&self, name: &str) -> Option<usize> {
        self.by_param.get(name).copied()
    }

    /// Read, verify (size + sha256), and decode one shard.  Fails with a
    /// typed [`ShardError`] before any partial result escapes.
    pub fn load_shard(&self, idx: usize) -> Result<Vec<(String, ShardParam)>, ShardError> {
        let info = &self.shards[idx];
        let path = self.dir.join(&info.file);
        let bytes = std::fs::read(&path).map_err(|e| ShardError::MissingShard {
            file: info.file.clone(),
            reason: e.to_string(),
        })?;
        if bytes.len() as u64 != info.bytes {
            return Err(ShardError::Truncated {
                file: info.file.clone(),
                expect: info.bytes,
                got: bytes.len() as u64,
            });
        }
        let got = sha256::hex_digest(&bytes);
        if got != info.sha256 {
            return Err(ShardError::ShaMismatch {
                file: info.file.clone(),
                expect: info.sha256.clone(),
                got,
            });
        }
        self.decode_shard(info, &bytes)
            .map_err(|e| ShardError::BadShard { file: info.file.clone(), reason: format!("{e:#}") })
    }

    fn decode_shard(&self, info: &ShardInfo, bytes: &[u8]) -> Result<Vec<(String, ShardParam)>> {
        ensure!(bytes.len() >= 5 && &bytes[..5] == SHARD_MAGIC, "bad shard magic");
        let mut r = &bytes[5..];
        let version = read_u32(&mut r)?;
        ensure!(version == MANIFEST_VERSION, "unsupported shard version {version}");
        let kind_code = read_u32(&mut r)?;
        ensure!(kind_code == self.kind.code(), "shard kind does not match manifest");
        let n = read_u32(&mut r)? as usize;
        ensure!(
            n == info.params.len(),
            "entry count {} != manifest params {}",
            n,
            info.params.len()
        );
        let mut out = Vec::with_capacity(n);
        for name in &info.params {
            let shape = &self.layout[name];
            let param = match self.kind {
                CkptKind::Dense => ShardParam::Dense(read_dense_record(&mut r, name, shape)?),
                CkptKind::Quant => {
                    let (dense, qw) = read_quant_record(&mut r, name, shape)?;
                    let has_lr = read_u32(&mut r)?;
                    let lr = match has_lr {
                        0 => None,
                        1 => Some(read_lowrank_record(&mut r)?),
                        v => bail!("bad low-rank flag {v} for {name}"),
                    };
                    match (dense, qw) {
                        (Some(t), None) => {
                            ensure!(lr.is_none(), "low-rank on unquantized param {name}");
                            ShardParam::Dense(t)
                        }
                        (None, Some(qw)) => ShardParam::Quant { qw, lr },
                        _ => bail!("malformed record for {name}"),
                    }
                }
            };
            out.push((name.clone(), param));
        }
        ensure!(r.is_empty(), "{} trailing bytes after the last record", r.len());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ckpt::{open, Checkpoint};
    use crate::model::init::init_params;
    use crate::util::rng::Rng;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("qera_shard_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn nano_ckpt(seed: u64) -> Checkpoint {
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut Rng::new(seed));
        Checkpoint::new(spec, params)
    }

    #[test]
    fn param_groups_cover_layout_exactly_once() {
        let spec = ModelSpec::builtin("nano").unwrap();
        for per in [0usize, 1, 2, 5] {
            let groups = param_groups(&spec, per);
            let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
            seen.sort_unstable();
            let want: Vec<usize> = (0..spec.param_layout().len()).collect();
            assert_eq!(seen, want, "shard_layers={per}");
        }
        // one block per shard: head + n_layers + tail groups
        assert_eq!(param_groups(&spec, 1).len(), spec.n_layers + 2);
    }

    #[test]
    fn manifest_validation_catches_schema_abuse() {
        let dir = tmpdir("schema");
        let ckpt = nano_ckpt(1);
        let manifest = dir.join("m.manifest.json");
        ckpt.save_sharded(&manifest, 1).unwrap();
        let text = std::fs::read_to_string(&manifest).unwrap();

        // duplicate shard file entries
        let j = Json::parse(&text).unwrap();
        let mut obj = j.as_obj().unwrap().clone();
        let mut shards = obj["shards"].as_arr().unwrap().to_vec();
        shards.push(shards[0].clone());
        obj.insert("shards".into(), Json::Arr(shards));
        let err = ShardSet::from_json(&manifest, &Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, ShardError::DuplicateShard { .. }), "{err}");

        // a shard dropped from the manifest -> params uncovered
        let j = Json::parse(&text).unwrap();
        let mut obj = j.as_obj().unwrap().clone();
        let shards = obj["shards"].as_arr().unwrap()[1..].to_vec();
        obj.insert("shards".into(), Json::Arr(shards));
        let err = ShardSet::from_json(&manifest, &Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, ShardError::MissingParam { .. }), "{err}");

        // future version refused
        let j = Json::parse(&text).unwrap();
        let mut obj = j.as_obj().unwrap().clone();
        obj.insert("version".into(), Json::Num(99.0));
        let err = ShardSet::from_json(&manifest, &Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, ShardError::BadManifest { .. }), "{err}");
    }

    #[test]
    fn writer_rejects_duplicates_and_incomplete_coverage() {
        let dir = tmpdir("writer");
        let ckpt = nano_ckpt(2);
        let spec = ckpt.spec.clone();
        let mut w = ShardWriter::create(
            dir.join("w.manifest.json"),
            CkptKind::Dense,
            spec,
            Json::obj(vec![]),
        )
        .unwrap();
        w.write_shard(vec![("embed".into(), ShardParam::Dense(ckpt.params[0].clone()))]).unwrap();
        // duplicate param
        let err = w
            .write_shard(vec![("embed".into(), ShardParam::Dense(ckpt.params[0].clone()))])
            .unwrap_err();
        assert!(err.to_string().contains("more than one shard"), "{err}");
        // unknown param
        let err = w
            .write_shard(vec![("nope".into(), ShardParam::Dense(ckpt.params[0].clone()))])
            .unwrap_err();
        assert!(err.to_string().contains("not a parameter"), "{err}");
        // incomplete coverage at finish
        let err = w.finish().unwrap_err();
        assert!(err.to_string().contains("missing from every shard"), "{err}");
    }

    #[test]
    fn corrupt_shards_fail_typed_never_partial() {
        let dir = tmpdir("corrupt");
        let ckpt = nano_ckpt(3);
        let manifest = dir.join("c.manifest.json");
        ckpt.save_sharded(&manifest, 1).unwrap();
        let set = ShardSet::open_manifest(&manifest).unwrap();
        assert_eq!(set.n_shards(), ckpt.spec.n_layers + 2);
        let victim = dir.join(&set.shard(1).file);
        let orig = std::fs::read(&victim).unwrap();

        // sha256 mismatch: flip one payload byte, keep the length
        let mut flipped = orig.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        std::fs::write(&victim, &flipped).unwrap();
        let err = set.load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::ShaMismatch { .. }), "{err}");
        assert!(open(&manifest).unwrap().into_dense().is_err(), "full load must fail too");

        // truncated shard
        std::fs::write(&victim, &orig[..orig.len() - 7]).unwrap();
        let err = set.load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::Truncated { .. }), "{err}");

        // missing shard file
        std::fs::remove_file(&victim).unwrap();
        let err = set.load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::MissingShard { .. }), "{err}");
        assert!(open(&manifest).unwrap().into_dense().is_err());

        // restore -> loads again
        std::fs::write(&victim, &orig).unwrap();
        assert_eq!(set.load_shard(1).unwrap().len(), 10);
        assert_eq!(open(&manifest).unwrap().into_dense().unwrap().params, ckpt.params);
    }
}
