//! Sharded checkpoint storage: a JSON manifest + integrity-hashed shard
//! files.
//!
//! The monolithic `QKPT1`/`QQKP1` containers assume the whole model fits
//! in RAM; at the paper's flagship scale (4-bit Llama-3.1-70B) neither the
//! quantization pipeline nor serving can afford that.  A sharded
//! checkpoint is a directory of shard files — each holding the parameters
//! of a few transformer blocks — described by a manifest:
//!
//! ```json
//! {
//!   "format": "qera-ckpt-manifest",
//!   "version": 1,
//!   "kind": "quant",
//!   "spec": { "name": "nano", ... },
//!   "meta": { "method": "qera-exact", ... },
//!   "shards": [
//!     { "file": "nano.shard-000.bin", "bytes": 16520,
//!       "sha256": "9f2c…", "params": ["embed", "pos_embed"] },
//!     ...
//!   ]
//! }
//! ```
//!
//! Every shard records its byte size and sha256, so readers verify
//! integrity before deserializing, shards load independently (and
//! therefore in parallel), and a partial or corrupted transfer fails with
//! a typed [`ShardError`] instead of a partially-loaded model.  Shard
//! payloads reuse the exact per-parameter record encodings of the
//! monolithic containers, so a sharded round-trip is bit-identical to a
//! monolithic one.
//!
//! [`ShardWriter`] streams shards out one group at a time (peak memory =
//! one shard, not one model); [`ShardSet`] is the verified reader behind
//! [`super::ckpt::open`].
//!
//! Crash safety: beside the manifest (written last), the writer keeps a
//! **resume journal** (`<manifest>.journal`) — rewritten atomically and
//! fsynced after every completed shard, one record per shard with its
//! file, size, sha256, parameter list, and global solver site-index
//! range.  A crashed run resumes via [`ShardWriter::resume`], which
//! re-verifies each journaled shard on disk and skips the verified
//! prefix; the journal is deleted when [`ShardWriter::finish`] lands the
//! manifest.  All file traffic goes through a [`CkptIo`], so tests and
//! `QERA_FAULTS` chaos runs inject torn writes, bit flips, ENOSPC, and
//! transient read errors deterministically; transient faults retry under
//! a [`RetryPolicy`], permanent corruption fails fast with a typed
//! [`ShardError`].

use super::ckpt::{
    read_dense_record, read_lowrank_record, read_quant_record, spec_from_json, spec_json,
    write_dense_record, write_lowrank_record, write_quant_record, QWeight,
};
use super::spec::ModelSpec;
use crate::solver::LowRank;
use crate::tensor::Tensor;
use crate::util::fault;
use crate::util::fsio::{read_u32, write_u32, CkptIo, StdIo};
use crate::util::json::Json;
use crate::util::retry::{self, RetryPolicy};
use crate::util::rng::Rng;
use crate::util::sha256;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Manifest `format` discriminator.
pub const MANIFEST_FORMAT: &str = "qera-ckpt-manifest";
/// Resume journal `format` discriminator.
pub const JOURNAL_FORMAT: &str = "qera-resume-journal";
/// Current manifest + shard container version.
pub const MANIFEST_VERSION: u32 = 1;
/// Magic prefix of every shard file.
const SHARD_MAGIC: &[u8; 5] = b"QSHD1";

/// What a checkpoint holds: dense f32 params or quantized weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptKind {
    Dense,
    Quant,
}

impl CkptKind {
    pub fn name(&self) -> &'static str {
        match self {
            CkptKind::Dense => "dense",
            CkptKind::Quant => "quant",
        }
    }

    fn parse(s: &str) -> Option<CkptKind> {
        match s {
            "dense" => Some(CkptKind::Dense),
            "quant" => Some(CkptKind::Quant),
            _ => None,
        }
    }

    fn code(&self) -> u32 {
        match self {
            CkptKind::Dense => 0,
            CkptKind::Quant => 1,
        }
    }
}

/// One parameter's payload inside a shard.
#[derive(Clone, Debug)]
pub enum ShardParam {
    /// Dense f32 tensor — every entry of a dense checkpoint, and the
    /// unquantized entries (embeddings, LayerNorms) of a quantized one.
    Dense(Tensor),
    /// Quantized weight plus its optional low-rank correction.
    Quant { qw: QWeight, lr: Option<LowRank> },
}

impl ShardParam {
    /// Serialized weight payload under the paper's memory accounting
    /// (mirrors `QuantCheckpoint::payload_bytes` per entry).
    pub fn payload_bytes(&self) -> usize {
        match self {
            ShardParam::Dense(t) => t.numel() * 4,
            ShardParam::Quant { qw, lr } => {
                qw.payload_bytes() + lr.as_ref().map(|l| l.n_params() * 4).unwrap_or(0)
            }
        }
    }

    /// Approximate live f32 bytes this entry holds in memory.
    pub fn live_bytes(&self) -> usize {
        self.payload_bytes()
    }
}

/// Typed failure modes of sharded checkpoint I/O.  Every load either
/// returns a fully-verified result or one of these — never a partial
/// model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardError {
    /// Manifest references a shard file that cannot be read.
    MissingShard { file: String, reason: String },
    /// Shard file size differs from the manifest's `bytes`.
    Truncated { file: String, expect: u64, got: u64 },
    /// Shard content hash differs from the manifest's `sha256`.
    ShaMismatch { file: String, expect: String, got: String },
    /// Two manifest entries name the same shard file.
    DuplicateShard { file: String },
    /// A parameter appears in more than one shard.
    DuplicateParam { name: String },
    /// A parameter of the model spec is covered by no shard.
    MissingParam { name: String },
    /// Manifest is not valid (json, schema, version, or unknown params).
    BadManifest { reason: String },
    /// Shard bytes hash correctly but do not decode (wrong magic/version/
    /// kind, malformed records, trailing bytes).
    BadShard { file: String, reason: String },
}

impl fmt::Display for ShardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardError::MissingShard { file, reason } => {
                write!(f, "missing shard file '{file}': {reason}")
            }
            ShardError::Truncated { file, expect, got } => {
                write!(f, "shard '{file}' truncated: {got} bytes on disk, manifest says {expect}")
            }
            ShardError::ShaMismatch { file, expect, got } => {
                write!(
                    f,
                    "sha256 mismatch for shard '{file}': computed {got}, manifest says {expect}"
                )
            }
            ShardError::DuplicateShard { file } => {
                write!(f, "duplicate shard file '{file}' in manifest")
            }
            ShardError::DuplicateParam { name } => {
                write!(f, "param '{name}' appears in more than one shard")
            }
            ShardError::MissingParam { name } => {
                write!(f, "param '{name}' missing from every shard in the manifest")
            }
            ShardError::BadManifest { reason } => {
                write!(f, "invalid checkpoint manifest: {reason}")
            }
            ShardError::BadShard { file, reason } => {
                write!(f, "invalid shard '{file}': {reason}")
            }
        }
    }
}

impl std::error::Error for ShardError {}

/// One manifest entry: a shard file with its integrity data and the
/// parameters it contains.
#[derive(Clone, Debug)]
pub struct ShardInfo {
    pub file: String,
    pub bytes: u64,
    pub sha256: String,
    pub params: Vec<String>,
}

/// Group the canonical parameter layout into shard-sized index groups:
/// `[embed, pos_embed]`, then `shard_layers` transformer blocks per group,
/// then `[lnf_g, lnf_b]`.  `shard_layers == 0` is treated as 1.
pub fn param_groups(spec: &ModelSpec, shard_layers: usize) -> Vec<Vec<usize>> {
    let per = shard_layers.max(1);
    let mut groups = vec![vec![0usize, 1]];
    let mut b = 0;
    while b < spec.n_layers {
        let hi = (b + per).min(spec.n_layers);
        groups.push((2 + b * 10..2 + hi * 10).collect());
        b = hi;
    }
    let tail = 2 + spec.n_layers * 10;
    groups.push(vec![tail, tail + 1]);
    groups
}

/// Streaming shard writer: serialize one parameter group at a time, hash
/// it while writing, then emit the manifest on [`ShardWriter::finish`].
/// Peak memory is one shard's worth of serialized bytes, never the model.
///
/// The manifest is written last and atomically, so a crashed or failed
/// write never leaves a loadable-but-incomplete checkpoint behind; the
/// resume journal makes the completed shards of such a run recoverable
/// (see [`ShardWriter::resume`]).  Every shard write is fsynced, renamed
/// into place, dir-fsynced, and read back to verify its sha256 — a
/// silently corrupted write is caught immediately and rewritten, never
/// discovered hours later at load time.
pub struct ShardWriter {
    manifest_path: PathBuf,
    journal_path: PathBuf,
    dir: PathBuf,
    /// Shard file name prefix (the manifest's stem, `.manifest` stripped).
    prefix: String,
    kind: CkptKind,
    spec: ModelSpec,
    meta: Json,
    layout: BTreeMap<String, Vec<usize>>,
    shards: Vec<ShardInfo>,
    /// Global solver site-index range per shard (half-open; `(0, 0)` for
    /// shards holding no solver sites).
    site_ranges: Vec<(usize, usize)>,
    written: BTreeSet<String>,
    io: Arc<dyn CkptIo>,
    retry: RetryPolicy,
    backoff_rng: Rng,
    io_retries: usize,
}

impl ShardWriter {
    /// Start a sharded checkpoint at `manifest_path` (shard files are
    /// created next to it, named `<prefix>.shard-NNN.bin`), on the
    /// ambient I/O layer (`QERA_FAULTS`-aware) with default retries.
    pub fn create(
        manifest_path: impl AsRef<Path>,
        kind: CkptKind,
        spec: ModelSpec,
        meta: Json,
    ) -> Result<ShardWriter> {
        let io = fault::io_from_env()?;
        Self::create_with(manifest_path, kind, spec, meta, io, RetryPolicy::io_default())
    }

    /// [`ShardWriter::create`] with an explicit I/O layer and retry policy.
    pub fn create_with(
        manifest_path: impl AsRef<Path>,
        kind: CkptKind,
        spec: ModelSpec,
        meta: Json,
        io: Arc<dyn CkptIo>,
        retry: RetryPolicy,
    ) -> Result<ShardWriter> {
        let manifest_path = manifest_path.as_ref().to_path_buf();
        let mut journal_name = manifest_path.as_os_str().to_os_string();
        journal_name.push(".journal");
        let journal_path = PathBuf::from(journal_name);
        let dir = manifest_path.parent().map(Path::to_path_buf).unwrap_or_else(|| ".".into());
        std::fs::create_dir_all(&dir)?;
        let stem =
            manifest_path.file_stem().and_then(|s| s.to_str()).unwrap_or("ckpt").to_string();
        let prefix = stem.strip_suffix(".manifest").unwrap_or(&stem).to_string();
        let layout = spec.param_layout().into_iter().collect();
        Ok(ShardWriter {
            manifest_path,
            journal_path,
            dir,
            prefix,
            kind,
            spec,
            meta,
            layout,
            shards: Vec::new(),
            site_ranges: Vec::new(),
            written: BTreeSet::new(),
            io,
            retry,
            backoff_rng: Rng::new(0xb0ff_5eed_ca7e),
            io_retries: 0,
        })
    }

    /// Resume a crashed run: open the resume journal next to
    /// `manifest_path`, re-verify each journaled shard on disk in order
    /// (size + sha256, stopping at the first failure), and return a
    /// writer that continues after the verified prefix, plus the verified
    /// records (shard info + global site range each).
    ///
    /// A missing journal (fresh run, or a crash before the first shard
    /// completed) resumes from nothing.  A journal whose kind, spec, or
    /// meta differs from this run is refused: its shards were produced
    /// under different settings, and silently requantizing over them
    /// would mask the mismatch.
    pub fn resume(
        manifest_path: impl AsRef<Path>,
        kind: CkptKind,
        spec: ModelSpec,
        meta: Json,
        io: Arc<dyn CkptIo>,
        retry: RetryPolicy,
    ) -> Result<(ShardWriter, Vec<(ShardInfo, (usize, usize))>)> {
        let mut w = Self::create_with(manifest_path, kind, spec, meta, io, retry)?;
        let verified = w.scan_journal()?;
        Ok((w, verified))
    }

    fn scan_journal(&mut self) -> Result<Vec<(ShardInfo, (usize, usize))>> {
        let io = Arc::clone(&self.io);
        let journal_path = self.journal_path.clone();
        let (res, tries) =
            retry::retry_io(&self.retry, &mut self.backoff_rng, || io.read(&journal_path));
        self.io_retries += tries as usize;
        let bytes = match res {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading resume journal {}", journal_path.display()))
            }
        };
        let text = String::from_utf8(bytes).context("resume journal is not utf-8")?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing resume journal: {e:?}"))?;
        ensure!(
            j.req_str("format")? == JOURNAL_FORMAT,
            "not a qera resume journal: {}",
            journal_path.display()
        );
        let version = j.req_usize("version")? as u32;
        ensure!(version == MANIFEST_VERSION, "unsupported resume journal version {version}");
        let jkind = j.req_str("kind")?;
        ensure!(
            jkind == self.kind.name(),
            "resume journal kind '{jkind}' does not match this run ('{}')",
            self.kind.name()
        );
        let jspec = j.get("spec").ok_or_else(|| anyhow!("resume journal missing 'spec'"))?;
        ensure!(
            jspec.dump() == spec_json(&self.spec).dump(),
            "resume journal model spec does not match this run"
        );
        let jmeta = j.get("meta").cloned().unwrap_or_else(|| Json::obj(vec![]));
        ensure!(
            jmeta.dump() == self.meta.dump(),
            "resume journal was written under a different quantization config; refusing to \
             resume over its shards (delete {} to start fresh)",
            journal_path.display()
        );

        let mut verified = Vec::new();
        for (i, entry) in j.req_arr("shards")?.iter().enumerate() {
            let file = entry.req_str("file")?.to_string();
            let expect_file = format!("{}.shard-{:03}.bin", self.prefix, i);
            ensure!(
                file == expect_file,
                "resume journal shard {i} is '{file}', expected '{expect_file}'"
            );
            let bytes_expect = entry.req_f64("bytes")? as u64;
            let sha = entry.req_str("sha256")?.to_string();
            let site_lo = entry.req_usize("site_lo")?;
            let site_hi = entry.req_usize("site_hi")?;
            let mut params = Vec::new();
            for p in entry.req_arr("params")? {
                let name = p
                    .as_str()
                    .ok_or_else(|| anyhow!("non-string param name in resume journal"))?
                    .to_string();
                ensure!(
                    self.layout.contains_key(&name),
                    "resume journal shard '{file}' lists unknown param '{name}'"
                );
                params.push(name);
            }
            // re-verify the shard's bytes on disk; the first shard that
            // fails (or cannot be read) truncates the trusted prefix and
            // gets rewritten by the resumed run
            let path = self.dir.join(&file);
            let io = Arc::clone(&self.io);
            let (res, tries) =
                retry::retry_io(&self.retry, &mut self.backoff_rng, || io.read(&path));
            self.io_retries += tries as usize;
            let on_disk = match res {
                Ok(b) => b,
                Err(_) => break,
            };
            if on_disk.len() as u64 != bytes_expect || sha256::hex_digest(&on_disk) != sha {
                break;
            }
            for name in &params {
                if !self.written.insert(name.clone()) {
                    return Err(ShardError::DuplicateParam { name: name.clone() }.into());
                }
            }
            let info = ShardInfo { file, bytes: bytes_expect, sha256: sha, params };
            self.shards.push(info.clone());
            self.site_ranges.push((site_lo, site_hi));
            verified.push((info, (site_lo, site_hi)));
        }
        Ok(verified)
    }

    /// Serialize `entries` as the next shard, hashing while writing.
    /// Every entry must name a parameter of the spec, exactly once across
    /// the whole checkpoint, with a layout-matching shape.
    pub fn write_shard(&mut self, entries: Vec<(String, ShardParam)>) -> Result<()> {
        self.write_shard_ranged(entries, (0, 0))
    }

    /// [`ShardWriter::write_shard`], additionally journaling the global
    /// solver site-index range `sites` (half-open) this shard covers —
    /// what lets a resumed streaming run re-derive per-site solver seeds.
    pub fn write_shard_ranged(
        &mut self,
        entries: Vec<(String, ShardParam)>,
        sites: (usize, usize),
    ) -> Result<()> {
        ensure!(!entries.is_empty(), "empty shard");
        // validate every name before serializing or committing any state:
        // a failed write must leave the writer consistent and retryable
        let mut fresh: BTreeSet<&str> = BTreeSet::new();
        for (name, _) in &entries {
            ensure!(
                self.layout.contains_key(name),
                "shard entry '{name}' is not a parameter of model '{}'",
                self.spec.name
            );
            if self.written.contains(name) || !fresh.insert(name) {
                return Err(ShardError::DuplicateParam { name: name.clone() }.into());
            }
        }
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(SHARD_MAGIC);
        write_u32(&mut buf, MANIFEST_VERSION)?;
        write_u32(&mut buf, self.kind.code())?;
        write_u32(&mut buf, entries.len() as u32)?;
        let mut names = Vec::with_capacity(entries.len());
        for (name, param) in &entries {
            let shape = &self.layout[name];
            match (self.kind, param) {
                (CkptKind::Dense, ShardParam::Dense(t)) => {
                    ensure!(t.shape() == &shape[..], "shape mismatch for {name}");
                    write_dense_record(&mut buf, name, t)?;
                }
                (CkptKind::Dense, ShardParam::Quant { .. }) => {
                    bail!("quantized entry '{name}' in a dense checkpoint shard");
                }
                (CkptKind::Quant, ShardParam::Dense(t)) => {
                    ensure!(t.shape() == &shape[..], "shape mismatch for {name}");
                    write_quant_record(&mut buf, name, Some(t), None)?;
                    write_u32(&mut buf, 0)?; // no low-rank
                }
                (CkptKind::Quant, ShardParam::Quant { qw, lr }) => {
                    write_quant_record(&mut buf, name, None, Some(qw))?;
                    match lr {
                        Some(lr) => {
                            write_u32(&mut buf, 1)?;
                            write_lowrank_record(&mut buf, lr)?;
                        }
                        None => write_u32(&mut buf, 0)?,
                    }
                }
            }
            names.push(name.clone());
        }
        let file = format!("{}.shard-{:03}.bin", self.prefix, self.shards.len());
        let sha = sha256::hex_digest(&buf);
        let path = self.dir.join(&file);
        self.write_verified(&path, &buf, &sha)?;
        // the shard is durably on disk and verified: commit writer state,
        // then journal it so a crash from here on can skip this shard
        for name in &names {
            self.written.insert(name.clone());
        }
        self.shards.push(ShardInfo { file, bytes: buf.len() as u64, sha256: sha, params: names });
        self.site_ranges.push(sites);
        self.write_journal()
    }

    /// One atomic durable write attempt: fsynced tmp file, rename,
    /// parent-dir fsync, then a read-back sha256 check.  A mismatch comes
    /// back as `InvalidData` so the caller can treat silent write
    /// corruption as retryable (a rewrite fixes it).
    fn write_once(&self, path: &Path, buf: &[u8], sha: &str) -> std::io::Result<()> {
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        let tmp = PathBuf::from(tmp_name);
        self.io.write(&tmp, buf)?;
        self.io.rename(&tmp, path)?;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                self.io.sync_dir(dir)?;
            }
        }
        let got = self.io.read(path)?;
        if got.len() != buf.len() || sha256::hex_digest(&got) != sha {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("read-back verification failed for {}", path.display()),
            ));
        }
        Ok(())
    }

    /// Write-and-verify under the retry policy: transient I/O errors and
    /// read-back mismatches back off and rewrite; permanent errors
    /// (ENOSPC, permissions) fail fast — retrying cannot fix them and the
    /// resume journal already protects everything written so far.
    fn write_verified(&mut self, path: &Path, buf: &[u8], sha: &str) -> Result<()> {
        let mut attempt = 0u32;
        loop {
            match self.write_once(path, buf, sha) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    let retryable = retry::is_transient(e.kind())
                        || e.kind() == std::io::ErrorKind::InvalidData;
                    if retryable && attempt < self.retry.max_retries {
                        let pause = self.retry.backoff(attempt, &mut self.backoff_rng);
                        std::thread::sleep(pause);
                        attempt += 1;
                        self.io_retries += 1;
                    } else {
                        return Err(e).with_context(|| format!("writing {}", path.display()));
                    }
                }
            }
        }
    }

    /// Rewrite the resume journal to record every completed shard.
    /// Atomic + fsynced after each shard, so a crash at any point loses
    /// at most the shard that was in flight.
    fn write_journal(&mut self) -> Result<()> {
        let shards = Json::Arr(
            self.shards
                .iter()
                .zip(&self.site_ranges)
                .map(|(s, &(lo, hi))| {
                    Json::obj(vec![
                        ("file", Json::str(s.file.clone())),
                        ("bytes", Json::Num(s.bytes as f64)),
                        ("sha256", Json::str(s.sha256.clone())),
                        ("params", Json::Arr(s.params.iter().map(Json::str).collect())),
                        ("site_lo", Json::Num(lo as f64)),
                        ("site_hi", Json::Num(hi as f64)),
                    ])
                })
                .collect(),
        );
        let j = Json::obj(vec![
            ("format", Json::str(JOURNAL_FORMAT)),
            ("version", Json::Num(MANIFEST_VERSION as f64)),
            ("kind", Json::str(self.kind.name())),
            ("spec", spec_json(&self.spec)),
            ("meta", self.meta.clone()),
            ("shards", shards),
        ]);
        let buf = j.dump_pretty().into_bytes();
        let sha = sha256::hex_digest(&buf);
        let path = self.journal_path.clone();
        self.write_verified(&path, &buf, &sha)
    }

    /// I/O retries taken so far (shard writes, journal writes, resume
    /// scans).
    pub fn io_retries(&self) -> usize {
        self.io_retries
    }

    /// Faults the underlying I/O layer injected (0 outside chaos runs).
    pub fn faults_injected(&self) -> usize {
        self.io.faults_injected()
    }

    /// Shards written or resume-verified so far.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Path of the resume journal kept beside the manifest.
    pub fn journal_path(&self) -> &Path {
        &self.journal_path
    }

    /// Check full parameter coverage, atomically write the manifest, and
    /// delete the resume journal.  Returns the manifest path.
    pub fn finish(mut self) -> Result<PathBuf> {
        for name in self.layout.keys() {
            if !self.written.contains(name) {
                return Err(ShardError::MissingParam { name: name.clone() }.into());
            }
        }
        let shards = Json::Arr(
            self.shards
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("file", Json::str(s.file.clone())),
                        ("bytes", Json::Num(s.bytes as f64)),
                        ("sha256", Json::str(s.sha256.clone())),
                        ("params", Json::Arr(s.params.iter().map(Json::str).collect())),
                    ])
                })
                .collect(),
        );
        let manifest = Json::obj(vec![
            ("format", Json::str(MANIFEST_FORMAT)),
            ("version", Json::Num(MANIFEST_VERSION as f64)),
            ("kind", Json::str(self.kind.name())),
            ("spec", spec_json(&self.spec)),
            ("meta", self.meta.clone()),
            ("shards", shards),
        ]);
        let buf = manifest.dump_pretty().into_bytes();
        let sha = sha256::hex_digest(&buf);
        let path = self.manifest_path.clone();
        self.write_verified(&path, &buf, &sha)?;
        match self.io.remove_file(&self.journal_path) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("removing resume journal {}", self.journal_path.display())
                })
            }
        }
        Ok(self.manifest_path)
    }
}

/// A parsed, schema-validated sharded checkpoint: the typed low-level
/// reader behind `ckpt::open`.  Construction validates the manifest
/// (version, kind, spec, shard uniqueness, exact parameter coverage);
/// [`ShardSet::load_shard`] verifies size + sha256 before decoding, and
/// rides out transient read faults under the set's [`RetryPolicy`] —
/// permanent corruption still fails fast with its typed [`ShardError`].
pub struct ShardSet {
    dir: PathBuf,
    pub(crate) kind: CkptKind,
    pub(crate) spec: ModelSpec,
    pub(crate) meta: Json,
    shards: Vec<ShardInfo>,
    layout: BTreeMap<String, Vec<usize>>,
    /// Parameter name → index of the shard containing it.
    by_param: BTreeMap<String, usize>,
    io: Arc<dyn CkptIo>,
    retry: RetryPolicy,
    /// Backoff jitter source, shared across the parallel shard loaders.
    rng: Mutex<Rng>,
    retries: AtomicUsize,
}

fn bad(reason: impl Into<String>) -> ShardError {
    ShardError::BadManifest { reason: reason.into() }
}

impl ShardSet {
    /// Parse and validate a manifest file on the ambient I/O layer
    /// (`QERA_FAULTS`-aware) with default retries.
    pub fn open_manifest(path: &Path) -> Result<ShardSet, ShardError> {
        let io = fault::io_from_env().map_err(|e| bad(format!("{e:#}")))?;
        Self::open_manifest_with(path, io, RetryPolicy::io_default())
    }

    /// [`ShardSet::open_manifest`] with an explicit I/O layer and retry
    /// policy (threaded through to every shard load).
    pub fn open_manifest_with(
        path: &Path,
        io: Arc<dyn CkptIo>,
        retry: RetryPolicy,
    ) -> Result<ShardSet, ShardError> {
        let mut rng = Rng::new(0x5ead_0f_5e7);
        let (res, _) = retry::retry_io(&retry, &mut rng, || io.read(path));
        let bytes = res.map_err(|e| bad(format!("reading {}: {e}", path.display())))?;
        let text =
            String::from_utf8(bytes).map_err(|_| bad("manifest is not valid utf-8".to_string()))?;
        let j = Json::parse(&text).map_err(|e| bad(format!("{e:?}")))?;
        let mut set = Self::from_json(path, &j)?;
        set.io = io;
        set.retry = retry;
        Ok(set)
    }

    fn from_json(path: &Path, j: &Json) -> Result<ShardSet, ShardError> {
        let fmt = j.req_str("format").map_err(|e| bad(format!("{e:#}")))?;
        if fmt != MANIFEST_FORMAT {
            return Err(bad(format!("unknown format '{fmt}'")));
        }
        let version = j.req_usize("version").map_err(|e| bad(format!("{e:#}")))? as u32;
        if version != MANIFEST_VERSION {
            return Err(bad(format!(
                "unsupported manifest version {version} (this build reads {MANIFEST_VERSION})"
            )));
        }
        let kind_s = j.req_str("kind").map_err(|e| bad(format!("{e:#}")))?;
        let kind = CkptKind::parse(kind_s).ok_or_else(|| bad(format!("unknown kind '{kind_s}'")))?;
        let spec = spec_from_json(j.get("spec").ok_or_else(|| bad("missing 'spec'"))?)
            .map_err(|e| bad(format!("{e:#}")))?;
        let meta = j.get("meta").cloned().unwrap_or_else(|| Json::obj(vec![]));
        let layout: BTreeMap<String, Vec<usize>> = spec.param_layout().into_iter().collect();

        let mut shards = Vec::new();
        let mut files = BTreeSet::new();
        let mut by_param = BTreeMap::new();
        for entry in j.req_arr("shards").map_err(|e| bad(format!("{e:#}")))? {
            let file = entry.req_str("file").map_err(|e| bad(format!("{e:#}")))?.to_string();
            let bytes = entry.req_f64("bytes").map_err(|e| bad(format!("{e:#}")))? as u64;
            let sha256 = entry.req_str("sha256").map_err(|e| bad(format!("{e:#}")))?.to_string();
            if !files.insert(file.clone()) {
                return Err(ShardError::DuplicateShard { file });
            }
            let mut params = Vec::new();
            for p in entry.req_arr("params").map_err(|e| bad(format!("{e:#}")))? {
                let name = p.as_str().ok_or_else(|| bad("non-string param name"))?.to_string();
                if !layout.contains_key(&name) {
                    return Err(bad(format!(
                        "shard '{file}' lists unknown param '{name}' for model '{}'",
                        spec.name
                    )));
                }
                if by_param.insert(name.clone(), shards.len()).is_some() {
                    return Err(ShardError::DuplicateParam { name });
                }
                params.push(name);
            }
            shards.push(ShardInfo { file, bytes, sha256, params });
        }
        for name in layout.keys() {
            if !by_param.contains_key(name) {
                return Err(ShardError::MissingParam { name: name.clone() });
            }
        }
        let dir = path.parent().map(Path::to_path_buf).unwrap_or_else(|| ".".into());
        Ok(ShardSet {
            dir,
            kind,
            spec,
            meta,
            shards,
            layout,
            by_param,
            io: Arc::new(StdIo),
            retry: RetryPolicy::io_default(),
            rng: Mutex::new(Rng::new(0x10ad_ba0f)),
            retries: AtomicUsize::new(0),
        })
    }

    pub fn kind(&self) -> CkptKind {
        self.kind
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn meta(&self) -> &Json {
        &self.meta
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, idx: usize) -> &ShardInfo {
        &self.shards[idx]
    }

    /// Index of the shard holding `name` (validated total at open time).
    pub fn shard_of(&self, name: &str) -> Option<usize> {
        self.by_param.get(name).copied()
    }

    /// I/O retries taken across all shard loads so far.
    pub fn io_retries(&self) -> usize {
        self.retries.load(Ordering::Relaxed)
    }

    /// Faults the underlying I/O layer injected (0 outside chaos runs).
    pub fn faults_injected(&self) -> usize {
        self.io.faults_injected()
    }

    /// Read, verify (size + sha256), and decode one shard.  Fails with a
    /// typed [`ShardError`] before any partial result escapes; transient
    /// read errors retry with backoff first.
    pub fn load_shard(&self, idx: usize) -> Result<Vec<(String, ShardParam)>, ShardError> {
        let info = &self.shards[idx];
        let path = self.dir.join(&info.file);
        let mut attempt = 0u32;
        let read = loop {
            match self.io.read(&path) {
                Ok(b) => break Ok(b),
                Err(e) if retry::is_transient(e.kind()) && attempt < self.retry.max_retries => {
                    let pause = {
                        let mut rng = self.rng.lock().unwrap();
                        self.retry.backoff(attempt, &mut rng)
                    };
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(pause);
                    attempt += 1;
                }
                Err(e) => break Err(e),
            }
        };
        let bytes = read.map_err(|e| ShardError::MissingShard {
            file: info.file.clone(),
            reason: e.to_string(),
        })?;
        if bytes.len() as u64 != info.bytes {
            return Err(ShardError::Truncated {
                file: info.file.clone(),
                expect: info.bytes,
                got: bytes.len() as u64,
            });
        }
        let got = sha256::hex_digest(&bytes);
        if got != info.sha256 {
            return Err(ShardError::ShaMismatch {
                file: info.file.clone(),
                expect: info.sha256.clone(),
                got,
            });
        }
        self.decode_shard(info, &bytes)
            .map_err(|e| ShardError::BadShard { file: info.file.clone(), reason: format!("{e:#}") })
    }

    fn decode_shard(&self, info: &ShardInfo, bytes: &[u8]) -> Result<Vec<(String, ShardParam)>> {
        ensure!(bytes.len() >= 5 && &bytes[..5] == SHARD_MAGIC, "bad shard magic");
        let mut r = &bytes[5..];
        let version = read_u32(&mut r)?;
        ensure!(version == MANIFEST_VERSION, "unsupported shard version {version}");
        let kind_code = read_u32(&mut r)?;
        ensure!(kind_code == self.kind.code(), "shard kind does not match manifest");
        let n = read_u32(&mut r)? as usize;
        ensure!(
            n == info.params.len(),
            "entry count {} != manifest params {}",
            n,
            info.params.len()
        );
        let mut out = Vec::with_capacity(n);
        for name in &info.params {
            let shape = &self.layout[name];
            let param = match self.kind {
                CkptKind::Dense => ShardParam::Dense(read_dense_record(&mut r, name, shape)?),
                CkptKind::Quant => {
                    let (dense, qw) = read_quant_record(&mut r, name, shape)?;
                    let has_lr = read_u32(&mut r)?;
                    let lr = match has_lr {
                        0 => None,
                        1 => Some(read_lowrank_record(&mut r)?),
                        v => bail!("bad low-rank flag {v} for {name}"),
                    };
                    match (dense, qw) {
                        (Some(t), None) => {
                            ensure!(lr.is_none(), "low-rank on unquantized param {name}");
                            ShardParam::Dense(t)
                        }
                        (None, Some(qw)) => ShardParam::Quant { qw, lr },
                        _ => bail!("malformed record for {name}"),
                    }
                }
            };
            out.push((name.clone(), param));
        }
        ensure!(r.is_empty(), "{} trailing bytes after the last record", r.len());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ckpt::{open, Checkpoint};
    use crate::model::init::init_params;
    use crate::util::fault::{FaultKind, FaultOp, FaultSpec, FaultyIo};
    use crate::util::rng::Rng;
    use std::time::Duration;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("qera_shard_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn nano_ckpt(seed: u64) -> Checkpoint {
        let spec = ModelSpec::builtin("nano").unwrap();
        let params = init_params(&spec, &mut Rng::new(seed));
        Checkpoint::new(spec, params)
    }

    /// The checkpoint's params grouped for sharding, as `write_shard`
    /// entry lists.
    fn dense_groups(ckpt: &Checkpoint, shard_layers: usize) -> Vec<Vec<(String, ShardParam)>> {
        let layout = ckpt.spec.param_layout();
        param_groups(&ckpt.spec, shard_layers)
            .into_iter()
            .map(|g| {
                g.into_iter()
                    .map(|i| (layout[i].0.clone(), ShardParam::Dense(ckpt.params[i].clone())))
                    .collect()
            })
            .collect()
    }

    /// io_default with near-zero sleeps so fault tests stay fast.
    fn fast_retry() -> RetryPolicy {
        RetryPolicy { base: Duration::from_micros(10), ..RetryPolicy::io_default() }
    }

    #[test]
    fn param_groups_cover_layout_exactly_once() {
        let spec = ModelSpec::builtin("nano").unwrap();
        for per in [0usize, 1, 2, 5] {
            let groups = param_groups(&spec, per);
            let mut seen: Vec<usize> = groups.iter().flatten().copied().collect();
            seen.sort_unstable();
            let want: Vec<usize> = (0..spec.param_layout().len()).collect();
            assert_eq!(seen, want, "shard_layers={per}");
        }
        // one block per shard: head + n_layers + tail groups
        assert_eq!(param_groups(&spec, 1).len(), spec.n_layers + 2);
    }

    #[test]
    fn manifest_validation_catches_schema_abuse() {
        let dir = tmpdir("schema");
        let ckpt = nano_ckpt(1);
        let manifest = dir.join("m.manifest.json");
        ckpt.save_sharded(&manifest, 1).unwrap();
        let text = std::fs::read_to_string(&manifest).unwrap();

        // duplicate shard file entries
        let j = Json::parse(&text).unwrap();
        let mut obj = j.as_obj().unwrap().clone();
        let mut shards = obj["shards"].as_arr().unwrap().to_vec();
        shards.push(shards[0].clone());
        obj.insert("shards".into(), Json::Arr(shards));
        let err = ShardSet::from_json(&manifest, &Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, ShardError::DuplicateShard { .. }), "{err}");

        // a shard dropped from the manifest -> params uncovered
        let j = Json::parse(&text).unwrap();
        let mut obj = j.as_obj().unwrap().clone();
        let shards = obj["shards"].as_arr().unwrap()[1..].to_vec();
        obj.insert("shards".into(), Json::Arr(shards));
        let err = ShardSet::from_json(&manifest, &Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, ShardError::MissingParam { .. }), "{err}");

        // future version refused
        let j = Json::parse(&text).unwrap();
        let mut obj = j.as_obj().unwrap().clone();
        obj.insert("version".into(), Json::Num(99.0));
        let err = ShardSet::from_json(&manifest, &Json::Obj(obj)).unwrap_err();
        assert!(matches!(err, ShardError::BadManifest { .. }), "{err}");
    }

    #[test]
    fn writer_rejects_duplicates_and_incomplete_coverage() {
        let dir = tmpdir("writer");
        let ckpt = nano_ckpt(2);
        let spec = ckpt.spec.clone();
        let mut w = ShardWriter::create(
            dir.join("w.manifest.json"),
            CkptKind::Dense,
            spec,
            Json::obj(vec![]),
        )
        .unwrap();
        w.write_shard(vec![("embed".into(), ShardParam::Dense(ckpt.params[0].clone()))]).unwrap();
        // duplicate param
        let err = w
            .write_shard(vec![("embed".into(), ShardParam::Dense(ckpt.params[0].clone()))])
            .unwrap_err();
        assert!(err.to_string().contains("more than one shard"), "{err}");
        // unknown param
        let err = w
            .write_shard(vec![("nope".into(), ShardParam::Dense(ckpt.params[0].clone()))])
            .unwrap_err();
        assert!(err.to_string().contains("not a parameter"), "{err}");
        // incomplete coverage at finish
        let err = w.finish().unwrap_err();
        assert!(err.to_string().contains("missing from every shard"), "{err}");
    }

    #[test]
    fn corrupt_shards_fail_typed_never_partial() {
        let dir = tmpdir("corrupt");
        let ckpt = nano_ckpt(3);
        let manifest = dir.join("c.manifest.json");
        ckpt.save_sharded(&manifest, 1).unwrap();
        let set = ShardSet::open_manifest(&manifest).unwrap();
        assert_eq!(set.n_shards(), ckpt.spec.n_layers + 2);
        let victim = dir.join(&set.shard(1).file);
        let orig = std::fs::read(&victim).unwrap();

        // sha256 mismatch: flip one payload byte, keep the length
        let mut flipped = orig.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        std::fs::write(&victim, &flipped).unwrap();
        let err = set.load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::ShaMismatch { .. }), "{err}");
        assert!(open(&manifest).unwrap().into_dense().is_err(), "full load must fail too");

        // truncated shard
        std::fs::write(&victim, &orig[..orig.len() - 7]).unwrap();
        let err = set.load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::Truncated { .. }), "{err}");

        // missing shard file
        std::fs::remove_file(&victim).unwrap();
        let err = set.load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::MissingShard { .. }), "{err}");
        assert!(open(&manifest).unwrap().into_dense().is_err());

        // restore -> loads again
        std::fs::write(&victim, &orig).unwrap();
        assert_eq!(set.load_shard(1).unwrap().len(), 10);
        assert_eq!(open(&manifest).unwrap().into_dense().unwrap().params, ckpt.params);
    }

    #[test]
    fn journal_written_after_each_shard_and_removed_by_finish() {
        let ckpt = nano_ckpt(4);
        let groups = dense_groups(&ckpt, 2);
        let dir = tmpdir("journal");
        let manifest = dir.join("j.manifest.json");
        let mut w = ShardWriter::create(
            &manifest,
            CkptKind::Dense,
            ckpt.spec.clone(),
            Json::obj(vec![]),
        )
        .unwrap();
        let journal = dir.join("j.manifest.json.journal");
        assert_eq!(w.journal_path(), journal.as_path());
        for (i, g) in groups.iter().enumerate() {
            w.write_shard_ranged(g.clone(), (i * 3, i * 3 + 3)).unwrap();
            let j = Json::parse(&std::fs::read_to_string(&journal).unwrap()).unwrap();
            assert_eq!(j.req_str("format").unwrap(), JOURNAL_FORMAT);
            let shards = j.req_arr("shards").unwrap();
            assert_eq!(shards.len(), i + 1, "journal records every completed shard");
            assert_eq!(shards[i].req_usize("site_lo").unwrap(), i * 3);
            assert_eq!(shards[i].req_usize("site_hi").unwrap(), i * 3 + 3);
            assert_eq!(shards[i].req_str("file").unwrap(), format!("j.shard-{i:03}.bin"));
        }
        assert!(!manifest.exists(), "manifest must land only at finish");
        w.finish().unwrap();
        assert!(manifest.exists());
        assert!(!journal.exists(), "finish removes the journal");
    }

    #[test]
    fn resume_skips_verified_prefix_and_finishes_bit_identically() {
        let ckpt = nano_ckpt(5);
        let spec = ckpt.spec.clone();
        let groups = dense_groups(&ckpt, 1);
        let meta = Json::obj(vec![("method", Json::str("test"))]);

        // uncrashed baseline
        let base_dir = tmpdir("resume-base");
        let base_manifest = base_dir.join("r.manifest.json");
        let mut w =
            ShardWriter::create(&base_manifest, CkptKind::Dense, spec.clone(), meta.clone())
                .unwrap();
        for g in &groups {
            w.write_shard(g.clone()).unwrap();
        }
        w.finish().unwrap();

        for k in [1usize, groups.len() / 2, groups.len() - 1] {
            let dir = tmpdir(&format!("resume-{k}"));
            let manifest = dir.join("r.manifest.json");
            let mut w =
                ShardWriter::create(&manifest, CkptKind::Dense, spec.clone(), meta.clone())
                    .unwrap();
            for g in &groups[..k] {
                w.write_shard(g.clone()).unwrap();
            }
            drop(w); // crash: no finish, journal left behind
            assert!(!manifest.exists());

            let (mut w, verified) = ShardWriter::resume(
                &manifest,
                CkptKind::Dense,
                spec.clone(),
                meta.clone(),
                Arc::new(StdIo),
                RetryPolicy::io_default(),
            )
            .unwrap();
            assert_eq!(verified.len(), k, "crash after {k} shards");
            assert_eq!(w.n_shards(), k);
            for g in &groups[k..] {
                w.write_shard(g.clone()).unwrap();
            }
            let out = w.finish().unwrap();
            assert_eq!(
                std::fs::read(&out).unwrap(),
                std::fs::read(&base_manifest).unwrap(),
                "resumed manifest differs from uncrashed baseline (crash at {k})"
            );
            for i in 0..groups.len() {
                let f = format!("r.shard-{i:03}.bin");
                assert_eq!(
                    std::fs::read(dir.join(&f)).unwrap(),
                    std::fs::read(base_dir.join(&f)).unwrap(),
                    "{f} differs (crash at {k})"
                );
            }
        }
    }

    #[test]
    fn resume_reverifies_and_truncates_at_first_bad_shard() {
        let ckpt = nano_ckpt(6);
        let spec = ckpt.spec.clone();
        let groups = dense_groups(&ckpt, 1);
        let dir = tmpdir("resume-reverify");
        let manifest = dir.join("v.manifest.json");
        let mut w =
            ShardWriter::create(&manifest, CkptKind::Dense, spec.clone(), Json::obj(vec![]))
                .unwrap();
        for g in &groups[..3] {
            w.write_shard(g.clone()).unwrap();
        }
        drop(w);
        // rot shard 1 on disk: the journal still lists it, but the resume
        // scan must distrust it and everything after it
        let victim = dir.join("v.shard-001.bin");
        let mut bytes = std::fs::read(&victim).unwrap();
        bytes[10] ^= 0x40;
        std::fs::write(&victim, &bytes).unwrap();
        let (_, verified) = ShardWriter::resume(
            &manifest,
            CkptKind::Dense,
            spec,
            Json::obj(vec![]),
            Arc::new(StdIo),
            RetryPolicy::io_default(),
        )
        .unwrap();
        assert_eq!(verified.len(), 1, "only the prefix before the rotted shard survives");
        assert_eq!(verified[0].0.file, "v.shard-000.bin");
    }

    #[test]
    fn resume_refuses_config_mismatch() {
        let ckpt = nano_ckpt(7);
        let groups = dense_groups(&ckpt, 1);
        let dir = tmpdir("resume-mismatch");
        let manifest = dir.join("m.manifest.json");
        let meta_a = Json::obj(vec![("bits", Json::Num(4.0))]);
        let mut w =
            ShardWriter::create(&manifest, CkptKind::Dense, ckpt.spec.clone(), meta_a.clone())
                .unwrap();
        w.write_shard(groups[0].clone()).unwrap();
        drop(w);

        let err = ShardWriter::resume(
            &manifest,
            CkptKind::Dense,
            ckpt.spec.clone(),
            Json::obj(vec![("bits", Json::Num(3.0))]),
            Arc::new(StdIo),
            RetryPolicy::io_default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("different quantization config"), "{err:#}");

        // the matching config still resumes
        let (_, verified) = ShardWriter::resume(
            &manifest,
            CkptKind::Dense,
            ckpt.spec.clone(),
            meta_a,
            Arc::new(StdIo),
            RetryPolicy::io_default(),
        )
        .unwrap();
        assert_eq!(verified.len(), 1);
    }

    #[test]
    fn write_faults_retry_or_fail_fast() {
        let ckpt = nano_ckpt(8);
        let groups = dense_groups(&ckpt, 1);
        let spec = ckpt.spec.clone();

        // transient write fault: retried, then the shard lands
        let dir = tmpdir("wfault-transient");
        let io = Arc::new(FaultyIo::std(
            vec![FaultSpec::new(FaultKind::Transient, FaultOp::Write, "shard-000")],
            1,
        ));
        let mut w = ShardWriter::create_with(
            dir.join("t.manifest.json"),
            CkptKind::Dense,
            spec.clone(),
            Json::obj(vec![]),
            io,
            fast_retry(),
        )
        .unwrap();
        w.write_shard(groups[0].clone()).unwrap();
        assert!(w.io_retries() >= 1, "transient write fault must cost a retry");
        assert_eq!(w.faults_injected(), 1);

        // silently flipped write: the read-back sha check catches it and
        // the rewrite lands clean bytes
        let dir = tmpdir("wfault-flip");
        let io = Arc::new(FaultyIo::std(
            vec![FaultSpec::new(FaultKind::Flip, FaultOp::Write, "shard-000")],
            9,
        ));
        let mut w = ShardWriter::create_with(
            dir.join("f.manifest.json"),
            CkptKind::Dense,
            spec.clone(),
            Json::obj(vec![]),
            io,
            fast_retry(),
        )
        .unwrap();
        w.write_shard(groups[0].clone()).unwrap();
        assert!(w.io_retries() >= 1, "silent corruption must be caught at write time");
        let on_disk = std::fs::read(dir.join("f.shard-000.bin")).unwrap();
        let journal =
            Json::parse(&std::fs::read_to_string(dir.join("f.manifest.json.journal")).unwrap())
                .unwrap();
        let rec = &journal.req_arr("shards").unwrap()[0];
        assert_eq!(sha256::hex_digest(&on_disk), rec.req_str("sha256").unwrap());

        // disk full: permanent, fails fast without burning the budget
        let dir = tmpdir("wfault-enospc");
        let io = Arc::new(FaultyIo::std(
            vec![FaultSpec::new(FaultKind::Enospc, FaultOp::Write, "shard-000")],
            0,
        ));
        let mut w = ShardWriter::create_with(
            dir.join("e.manifest.json"),
            CkptKind::Dense,
            spec,
            Json::obj(vec![]),
            io,
            fast_retry(),
        )
        .unwrap();
        let err = w.write_shard(groups[0].clone()).unwrap_err();
        assert!(format!("{err:#}").contains("no space"), "{err:#}");
        assert_eq!(w.io_retries(), 0, "enospc must not be retried");
    }

    #[test]
    fn read_faults_map_to_typed_errors_and_transients_retry() {
        let dir = tmpdir("rfault");
        let ckpt = nano_ckpt(9);
        let manifest = dir.join("c.manifest.json");
        ckpt.save_sharded(&manifest, 1).unwrap();

        let open_faulty = |script: &str| {
            let io = Arc::new(FaultyIo::from_script(script, Box::new(StdIo)).unwrap());
            ShardSet::open_manifest_with(&manifest, io, fast_retry()).unwrap()
        };

        let err = open_faulty("flip@r:shard-001").load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::ShaMismatch { .. }), "{err}");

        let err = open_faulty("torn@r:shard-001").load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::Truncated { .. }), "{err}");

        let err = open_faulty("perm@r:shard-001").load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::MissingShard { .. }), "{err}");

        // transient read faults ride out under the retry policy
        let set = open_faulty("transient@r:shard-001:2");
        assert_eq!(set.load_shard(1).unwrap().len(), 10);
        assert_eq!(set.io_retries(), 2);
        assert_eq!(set.faults_injected(), 2);

        // a permanently unreadable manifest is BadManifest
        let io = Arc::new(FaultyIo::from_script("perm@r:manifest", Box::new(StdIo)).unwrap());
        let err = ShardSet::open_manifest_with(&manifest, io, fast_retry()).unwrap_err();
        assert!(matches!(err, ShardError::BadManifest { .. }), "{err}");

        // a transient manifest read recovers
        let io = Arc::new(FaultyIo::from_script("transient@r:manifest", Box::new(StdIo)).unwrap());
        let set = ShardSet::open_manifest_with(&manifest, io, fast_retry()).unwrap();
        assert_eq!(set.n_shards(), ckpt.spec.n_layers + 2);
    }

    #[test]
    fn bad_shard_bytes_fail_typed_after_hash_verification() {
        let dir = tmpdir("badshard");
        let ckpt = nano_ckpt(10);
        let manifest = dir.join("b.manifest.json");
        ckpt.save_sharded(&manifest, 1).unwrap();
        let set = ShardSet::open_manifest(&manifest).unwrap();
        let victim = dir.join(&set.shard(1).file);
        // valid-by-hash, invalid-by-content: corrupt the shard magic, then
        // patch the manifest so size and sha256 both verify
        let mut bytes = std::fs::read(&victim).unwrap();
        bytes[0] ^= 0xff;
        std::fs::write(&victim, &bytes).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&manifest).unwrap()).unwrap();
        let mut obj = j.as_obj().unwrap().clone();
        let mut shards = obj["shards"].as_arr().unwrap().to_vec();
        let mut entry = shards[1].as_obj().unwrap().clone();
        entry.insert("sha256".into(), Json::str(sha256::hex_digest(&bytes)));
        shards[1] = Json::Obj(entry);
        obj.insert("shards".into(), Json::Arr(shards));
        std::fs::write(&manifest, Json::Obj(obj).dump_pretty()).unwrap();
        let set = ShardSet::open_manifest(&manifest).unwrap();
        let err = set.load_shard(1).unwrap_err();
        assert!(matches!(err, ShardError::BadShard { .. }), "{err}");
    }
}
