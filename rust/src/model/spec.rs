//! Model specification — the Rust mirror of `python/compile/configs.py`.
//!
//! The parameter layout below defines the positional argument order of every
//! lowered HLO entry point; `from_manifest` cross-checks it against the
//! layout the AOT step actually baked in (defense against drift between the
//! two languages).

use crate::util::json::Json;
use anyhow::{ensure, Context, Result};

/// Calibration tap sites per block, in artifact output order.
pub const TAP_SITES: [&str; 4] = ["attn_in", "o_in", "mlp_in", "mlp_mid"];

/// Quantizable linear sites per block, in canonical order.
pub const LINEAR_SITES: [&str; 6] = ["wq", "wk", "wv", "wo", "w_up", "w_down"];

#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub n_classes: usize,
}

/// One quantizable linear layer of the model.
#[derive(Clone, Debug, PartialEq)]
pub struct LinearSite {
    /// Parameter name, e.g. `blk2.w_up`.
    pub name: String,
    /// Index in the canonical parameter layout.
    pub param_idx: usize,
    /// Block index.
    pub block: usize,
    /// Site kind (one of [`LINEAR_SITES`]).
    pub site: &'static str,
    /// Tap feeding this linear's input (one of [`TAP_SITES`]).
    pub tap: &'static str,
    /// [in_dim, out_dim].
    pub shape: [usize; 2],
}

impl ModelSpec {
    /// Built-in specs (mirror python `CONFIGS`) for tests without artifacts.
    pub fn builtin(name: &str) -> Option<ModelSpec> {
        let (vocab, d_model, n_layers, n_heads, d_ff, seq, batch) = match name {
            "micro" => (64, 32, 1, 2, 64, 16, 2),
            "nano" => (256, 64, 2, 4, 256, 64, 4),
            "small" => (512, 128, 4, 4, 512, 128, 8),
            "base" => (1024, 256, 6, 8, 1024, 128, 4),
            _ => return None,
        };
        Some(ModelSpec {
            name: name.to_string(),
            vocab,
            d_model,
            n_layers,
            n_heads,
            d_ff,
            seq,
            batch,
            n_classes: 8,
        })
    }

    /// Parse from a manifest `configs.<name>` object and verify the baked
    /// param layout matches ours.
    pub fn from_manifest_cfg(j: &Json) -> Result<ModelSpec> {
        let spec = ModelSpec {
            name: j.req_str("name")?.to_string(),
            vocab: j.req_usize("vocab")?,
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            d_ff: j.req_usize("d_ff")?,
            seq: j.req_usize("seq")?,
            batch: j.req_usize("batch")?,
            n_classes: j.req_usize("n_classes")?,
        };
        let baked = j.req_arr("param_layout")?;
        let ours = spec.param_layout();
        ensure!(
            baked.len() == ours.len(),
            "param layout length mismatch: manifest {} vs rust {}",
            baked.len(),
            ours.len()
        );
        for (b, (name, shape)) in baked.iter().zip(&ours) {
            let pair = b.as_arr().context("param_layout entry")?;
            let bname = pair[0].as_str().context("param name")?;
            let bshape: Vec<usize> =
                pair[1].as_arr().context("shape")?.iter().filter_map(Json::as_usize).collect();
            ensure!(
                bname == name && &bshape == shape,
                "param layout drift at '{name}': manifest ({bname}, {bshape:?}) vs rust {shape:?}"
            );
        }
        Ok(spec)
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Canonical (name, shape) parameter list — HLO argument order.
    pub fn param_layout(&self) -> Vec<(String, Vec<usize>)> {
        let (v, d, f, s) = (self.vocab, self.d_model, self.d_ff, self.seq);
        let mut out: Vec<(String, Vec<usize>)> =
            vec![("embed".into(), vec![v, d]), ("pos_embed".into(), vec![s, d])];
        for i in 0..self.n_layers {
            let p = format!("blk{i}.");
            out.push((p.clone() + "ln1_g", vec![d]));
            out.push((p.clone() + "ln1_b", vec![d]));
            out.push((p.clone() + "wq", vec![d, d]));
            out.push((p.clone() + "wk", vec![d, d]));
            out.push((p.clone() + "wv", vec![d, d]));
            out.push((p.clone() + "wo", vec![d, d]));
            out.push((p.clone() + "ln2_g", vec![d]));
            out.push((p.clone() + "ln2_b", vec![d]));
            out.push((p.clone() + "w_up", vec![d, f]));
            out.push((p + "w_down", vec![f, d]));
        }
        out.push(("lnf_g".into(), vec![d]));
        out.push(("lnf_b".into(), vec![d]));
        out
    }

    /// LoRA adapter (name, shape) list for a given rank — HLO trailing args.
    pub fn lora_layout(&self, rank: usize) -> Vec<(String, Vec<usize>)> {
        let mut out = Vec::new();
        for site in self.linear_sites() {
            let [m, n] = site.shape;
            out.push((format!("{}.A", site.name), vec![m, rank]));
            out.push((format!("{}.B", site.name), vec![rank, n]));
        }
        out
    }

    /// All quantizable linears with their parameter indices and tap sites.
    pub fn linear_sites(&self) -> Vec<LinearSite> {
        let layout = self.param_layout();
        let idx_of = |name: &str| layout.iter().position(|(n, _)| n == name).unwrap();
        let (d, f) = (self.d_model, self.d_ff);
        let mut out = Vec::new();
        for i in 0..self.n_layers {
            for &site in LINEAR_SITES.iter() {
                let name = format!("blk{i}.{site}");
                let (tap, shape) = match site {
                    "wq" | "wk" | "wv" => ("attn_in", [d, d]),
                    "wo" => ("o_in", [d, d]),
                    "w_up" => ("mlp_in", [d, f]),
                    "w_down" => ("mlp_mid", [f, d]),
                    _ => unreachable!(),
                };
                out.push(LinearSite {
                    param_idx: idx_of(&name),
                    name,
                    block: i,
                    site,
                    tap,
                    shape,
                });
            }
        }
        out
    }

    /// Dimension of a tap site's vectors.
    pub fn tap_dim(&self, tap: &str) -> usize {
        match tap {
            "mlp_mid" => self.d_ff,
            _ => self.d_model,
        }
    }

    /// Stats-accumulator index for (block, tap): block-major, tap-minor —
    /// matches the `lm_fwd_taps` output order.
    pub fn tap_index(&self, block: usize, tap: &str) -> usize {
        let t = TAP_SITES.iter().position(|&x| x == tap).unwrap();
        block * TAP_SITES.len() + t
    }

    pub fn n_taps(&self) -> usize {
        self.n_layers * TAP_SITES.len()
    }

    pub fn n_params(&self) -> usize {
        self.param_layout().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Tokens per full training batch.
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_shapes() {
        let s = ModelSpec::builtin("nano").unwrap();
        assert_eq!(s.head_dim(), 16);
        let layout = s.param_layout();
        assert_eq!(layout.len(), 2 + 10 * 2 + 2);
        assert_eq!(layout[0], ("embed".to_string(), vec![256, 64]));
        assert_eq!(layout[2].0, "blk0.ln1_g");
        assert!(ModelSpec::builtin("huge").is_none());
    }

    #[test]
    fn linear_sites_consistent() {
        let s = ModelSpec::builtin("small").unwrap();
        let sites = s.linear_sites();
        assert_eq!(sites.len(), 6 * 4);
        let layout = s.param_layout();
        for site in &sites {
            assert_eq!(layout[site.param_idx].0, site.name);
            assert_eq!(layout[site.param_idx].1, site.shape.to_vec());
            assert_eq!(s.tap_dim(site.tap), site.shape[0], "{}", site.name);
        }
        // q/k/v share the tap
        assert_eq!(sites[0].tap, "attn_in");
        assert_eq!(sites[1].tap, "attn_in");
        assert_eq!(sites[2].tap, "attn_in");
        assert_eq!(sites[3].tap, "o_in");
    }

    #[test]
    fn tap_indexing() {
        let s = ModelSpec::builtin("nano").unwrap();
        assert_eq!(s.tap_index(0, "attn_in"), 0);
        assert_eq!(s.tap_index(0, "mlp_mid"), 3);
        assert_eq!(s.tap_index(1, "attn_in"), 4);
        assert_eq!(s.n_taps(), 8);
    }

    #[test]
    fn lora_layout_shapes() {
        let s = ModelSpec::builtin("nano").unwrap();
        let lora = s.lora_layout(4);
        assert_eq!(lora.len(), 2 * 6 * 2);
        assert_eq!(lora[0], ("blk0.wq.A".to_string(), vec![64, 4]));
        assert_eq!(lora[1], ("blk0.wq.B".to_string(), vec![4, 64]));
        // w_down adapter has the f-dim on A
        let wd = lora.iter().find(|(n, _)| n == "blk0.w_down.A").unwrap();
        assert_eq!(wd.1, vec![256, 4]);
    }

    #[test]
    fn param_count_matches_python() {
        // python: configs.py reports these through the manifest; pin a value
        let s = ModelSpec::builtin("nano").unwrap();
        // embed 256*64 + pos 64*64 + 2 blocks * (4*64*64*... ) computed:
        let expect: usize = 256 * 64
            + 64 * 64
            + 2 * (64 + 64 + 4 * 64 * 64 + 64 + 64 + 64 * 256 + 256 * 64)
            + 64
            + 64;
        assert_eq!(s.n_params(), expect);
    }

    #[test]
    fn from_manifest_roundtrip() {
        let s = ModelSpec::builtin("nano").unwrap();
        // build the json the way aot.py does
        let layout = Json::Arr(
            s.param_layout()
                .into_iter()
                .map(|(n, shape)| Json::Arr(vec![Json::Str(n), Json::arr_usize(&shape)]))
                .collect(),
        );
        let j = Json::obj(vec![
            ("name", Json::str("nano")),
            ("vocab", Json::Num(256.0)),
            ("d_model", Json::Num(64.0)),
            ("n_layers", Json::Num(2.0)),
            ("n_heads", Json::Num(4.0)),
            ("d_ff", Json::Num(256.0)),
            ("seq", Json::Num(64.0)),
            ("batch", Json::Num(4.0)),
            ("n_classes", Json::Num(8.0)),
            ("param_layout", layout),
        ]);
        let back = ModelSpec::from_manifest_cfg(&j).unwrap();
        assert_eq!(back, s);
    }
}
