//! Weight initialization for in-repo pretrained subject models.
//!
//! GPT-2-style: N(0, 0.02) for embeddings and linears, residual-branch
//! outputs scaled by 1/√(2L), ones/zeros for LayerNorm — the same scheme as
//! `python/compile/model.py::init_params` (distributionally; the subject
//! checkpoints are *pretrained* in-repo so bit-level init parity is not
//! required).

use super::spec::ModelSpec;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub fn init_params(spec: &ModelSpec, rng: &mut Rng) -> Vec<Tensor> {
    let resid_std = 0.02 / ((2 * spec.n_layers) as f32).sqrt();
    spec.param_layout()
        .into_iter()
        .map(|(name, shape)| {
            if name.ends_with("ln1_g") || name.ends_with("ln2_g") || name.ends_with("lnf_g") {
                Tensor::ones(shape)
            } else if name.ends_with("_b") && !name.ends_with("pos_embed") {
                Tensor::zeros(shape)
            } else {
                let std = if name.ends_with("wo") || name.ends_with("w_down") {
                    resid_std
                } else {
                    0.02
                };
                Tensor::randn(shape, std, rng)
            }
        })
        .collect()
}

/// Classifier head (Table 1 experiments): N(0, 0.02) weight, zero bias.
pub fn init_head(spec: &ModelSpec, rng: &mut Rng) -> (Tensor, Tensor) {
    (
        Tensor::randn(vec![spec.d_model, spec.n_classes], 0.02, rng),
        Tensor::zeros(vec![spec.n_classes]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_layout() {
        let spec = ModelSpec::builtin("nano").unwrap();
        let mut rng = Rng::new(42);
        let params = init_params(&spec, &mut rng);
        let layout = spec.param_layout();
        assert_eq!(params.len(), layout.len());
        for (p, (name, shape)) in params.iter().zip(&layout) {
            assert_eq!(p.shape(), &shape[..], "{name}");
        }
    }

    #[test]
    fn layernorm_init() {
        let spec = ModelSpec::builtin("nano").unwrap();
        let mut rng = Rng::new(0);
        let params = init_params(&spec, &mut rng);
        let layout = spec.param_layout();
        for (p, (name, _)) in params.iter().zip(&layout) {
            if name.ends_with("ln1_g") {
                assert!(p.data().iter().all(|&v| v == 1.0), "{name}");
            }
            if name.ends_with("ln1_b") {
                assert!(p.data().iter().all(|&v| v == 0.0), "{name}");
            }
        }
    }

    #[test]
    fn residual_scaling() {
        let spec = ModelSpec::builtin("small").unwrap();
        let mut rng = Rng::new(1);
        let params = init_params(&spec, &mut rng);
        let layout = spec.param_layout();
        let std_of = |name: &str| {
            let i = layout.iter().position(|(n, _)| n == name).unwrap();
            let p = &params[i];
            (p.data().iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / p.numel() as f64).sqrt()
        };
        let wq = std_of("blk0.wq");
        let wo = std_of("blk0.wo");
        assert!((wq - 0.02).abs() < 0.002, "{wq}");
        assert!((wo - 0.02 / (8f64).sqrt()).abs() < 0.002, "{wo}");
    }

    #[test]
    fn deterministic_from_seed() {
        let spec = ModelSpec::builtin("micro").unwrap();
        let a = init_params(&spec, &mut Rng::new(7));
        let b = init_params(&spec, &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
