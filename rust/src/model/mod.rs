//! Transformer model metadata, checkpoints, and initialization.
//!
//! The architecture itself lives in L2 (`python/compile/model.py`); this
//! module owns the *Rust-side contract*: the canonical parameter layout
//! (positional HLO argument order), checkpoint I/O (`.qkpt` dense /
//! quantized with bit-packed payloads), and weight init for the
//! in-repo pretrained subject models.

pub mod spec;
pub mod ckpt;
pub mod init;
pub mod shard;

pub use ckpt::{open, Checkpoint, CkptReader, QWeight, QuantCheckpoint};
pub use shard::{CkptKind, ShardError, ShardParam, ShardSet, ShardWriter};
pub use spec::{LinearSite, ModelSpec, TAP_SITES};
