//! Hierarchical span tracing with Chrome trace-event JSON export.
//!
//! A [`Span`] is an RAII timer: it records start time at construction and
//! pushes one complete (`"ph": "X"`) trace event at drop.  Nesting is
//! tracked per thread — each span records its depth, and because children
//! start after and drop before their parent, their time ranges nest inside
//! the parent's on the same `tid`, which is exactly how `chrome://tracing`
//! and Perfetto reconstruct the hierarchy.
//!
//! The global tracer is off by default.  It turns on when `QERA_TRACE=<path>`
//! is set (resolved lazily, once) or when the CLI calls
//! [`enable_to`] for `--trace-out <path>`.  While off, [`span`] is a single
//! relaxed atomic load followed by constructing an inert guard — no
//! allocation, no lock — so it is safe to leave in hot paths; the `obs`
//! bench group gates that cost.  Timestamps are microseconds from a
//! process-local epoch; tests inject a mock clock via
//! [`Tracer::with_clock`] so durations are asserted exactly.

use crate::util::json::Json;
use std::cell::Cell;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Hard cap on buffered events; past it new events are counted as dropped
/// so a long traced run degrades instead of exhausting memory.
const MAX_EVENTS: usize = 1 << 18;

const STATE_OFF: u8 = 0;
const STATE_ON: u8 = 1;
const STATE_UNRESOLVED: u8 = 255;

struct Event {
    name: &'static str,
    ts_us: u64,
    dur_us: u64,
    tid: u64,
    depth: usize,
    args: Vec<(&'static str, String)>,
}

struct Inner {
    events: Vec<Event>,
    out: Option<PathBuf>,
    /// Thread ids in first-record order; a thread's `tid` is its index here,
    /// so single-threaded traces are deterministic.
    tids: Vec<std::thread::ThreadId>,
    dropped: u64,
}

pub struct Tracer {
    state: AtomicU8,
    /// Whether an unresolved state consults `QERA_TRACE` (global tracer
    /// only; test tracers resolve to off).
    env_backed: bool,
    /// Microseconds since this tracer's epoch.
    clock: fn() -> u64,
    inner: Mutex<Inner>,
}

thread_local! {
    static DEPTH: Cell<usize> = const { Cell::new(0) };
}

impl Tracer {
    const fn new_const(env_backed: bool, clock: fn() -> u64) -> Tracer {
        Tracer {
            state: AtomicU8::new(STATE_UNRESOLVED),
            env_backed,
            clock,
            inner: Mutex::new(Inner {
                events: Vec::new(),
                out: None,
                tids: Vec::new(),
                dropped: 0,
            }),
        }
    }

    /// A disabled tracer with an injected clock (tests).
    pub fn with_clock(clock: fn() -> u64) -> Tracer {
        Tracer::new_const(false, clock)
    }

    /// One relaxed load in the steady state; the first call on an
    /// env-backed tracer resolves `QERA_TRACE` and caches the answer.
    pub fn enabled(&self) -> bool {
        match self.state.load(Ordering::Relaxed) {
            STATE_ON => true,
            STATE_OFF => false,
            _ => self.resolve_env(),
        }
    }

    fn resolve_env(&self) -> bool {
        let path = if self.env_backed {
            match std::env::var("QERA_TRACE") {
                Ok(p) if !p.trim().is_empty() => Some(PathBuf::from(p)),
                _ => None,
            }
        } else {
            None
        };
        let on = path.is_some();
        if let Some(p) = path {
            self.inner.lock().unwrap().out = Some(p);
        }
        self.state.store(if on { STATE_ON } else { STATE_OFF }, Ordering::Relaxed);
        on
    }

    /// Enable and write the trace to `path` on [`Tracer::flush`].
    pub fn enable_to(&self, path: impl Into<PathBuf>) {
        self.inner.lock().unwrap().out = Some(path.into());
        self.state.store(STATE_ON, Ordering::Relaxed);
    }

    /// Enable buffering without an output path (render manually).
    pub fn enable(&self) {
        self.state.store(STATE_ON, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.state.store(STATE_OFF, Ordering::Relaxed);
    }

    /// Disable and discard all buffered events (tests/benches).
    pub fn reset(&self) {
        self.disable();
        let mut inner = self.inner.lock().unwrap();
        inner.events.clear();
        inner.tids.clear();
        inner.dropped = 0;
        inner.out = None;
    }

    /// Start a span.  Disabled tracers return an inert guard without
    /// touching any shared state.
    pub fn span(&self, name: &'static str) -> Span<'_> {
        if !self.enabled() {
            return Span { tracer: None, name, t0: 0, depth: 0, args: Vec::new() };
        }
        let depth = DEPTH.with(|d| {
            let v = d.get();
            d.set(v + 1);
            v
        });
        Span { tracer: Some(self), name, t0: (self.clock)(), depth, args: Vec::new() }
    }

    fn record(
        &self,
        name: &'static str,
        ts_us: u64,
        dur_us: u64,
        depth: usize,
        args: Vec<(&'static str, String)>,
    ) {
        let mut inner = self.inner.lock().unwrap();
        if inner.events.len() >= MAX_EVENTS {
            inner.dropped += 1;
            return;
        }
        let id = std::thread::current().id();
        let tid = match inner.tids.iter().position(|t| *t == id) {
            Some(i) => i as u64,
            None => {
                inner.tids.push(id);
                (inner.tids.len() - 1) as u64
            }
        };
        inner.events.push(Event { name, ts_us, dur_us, tid, depth, args });
    }

    pub fn event_count(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Render all buffered events as Chrome trace-event JSON.
    pub fn render(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let events = inner
            .events
            .iter()
            .map(|e| {
                let mut args: Vec<(&str, Json)> = vec![("depth", Json::Num(e.depth as f64))];
                for (k, v) in &e.args {
                    args.push((k, Json::str(v.clone())));
                }
                Json::obj(vec![
                    ("args", Json::obj(args)),
                    ("cat", Json::str("qera")),
                    ("dur", Json::Num(e.dur_us as f64)),
                    ("name", Json::str(e.name)),
                    ("ph", Json::str("X")),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(e.tid as f64)),
                    ("ts", Json::Num(e.ts_us as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("displayTimeUnit", Json::str("ms")),
            ("traceEvents", Json::Arr(events)),
        ])
        .dump()
    }

    /// Write the trace to the configured output path (no-op when unset).
    /// Buffered events are kept, so flushing twice rewrites a superset.
    pub fn flush(&self) -> std::io::Result<()> {
        let out = self.inner.lock().unwrap().out.clone();
        match out {
            Some(p) => self.flush_to(&p),
            None => Ok(()),
        }
    }

    pub fn flush_to(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

/// RAII span guard; records one trace event when dropped.
pub struct Span<'a> {
    tracer: Option<&'a Tracer>,
    name: &'static str,
    t0: u64,
    depth: usize,
    args: Vec<(&'static str, String)>,
}

impl<'a> Span<'a> {
    /// Attach an attribute (only materialized when the span is live).
    pub fn attr(mut self, key: &'static str, value: impl std::fmt::Display) -> Span<'a> {
        if self.tracer.is_some() {
            self.args.push((key, value.to_string()));
        }
        self
    }

    pub fn active(&self) -> bool {
        self.tracer.is_some()
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let Some(t) = self.tracer else { return };
        let end = (t.clock)();
        DEPTH.with(|d| d.set(self.depth));
        let args = std::mem::take(&mut self.args);
        t.record(self.name, self.t0, end.saturating_sub(self.t0), self.depth, args);
    }
}

static EPOCH: crate::obs::lazy::Lazy<Instant> = crate::obs::lazy::Lazy::new(Instant::now);

fn global_clock() -> u64 {
    EPOCH.elapsed().as_micros() as u64
}

static GLOBAL: Tracer = Tracer::new_const(true, global_clock);

/// The process-global tracer behind `QERA_TRACE` / `--trace-out`.
pub fn global() -> &'static Tracer {
    &GLOBAL
}

/// Whether global tracing is on (one relaxed load once resolved).
pub fn enabled() -> bool {
    GLOBAL.enabled()
}

/// Start a span on the global tracer.
pub fn span(name: &'static str) -> Span<'static> {
    GLOBAL.span(name)
}

/// Start a span on every `every`-th call (per call site cadence is shared
/// process-wide).  Used on per-token hot paths so steady-state decode does
/// not allocate: disabled tracing costs one relaxed load, enabled tracing
/// materializes only the sampled fraction of spans.
pub fn sample_span(name: &'static str, every: u64) -> Span<'static> {
    if !GLOBAL.enabled() {
        return Span { tracer: None, name, t0: 0, depth: 0, args: Vec::new() };
    }
    static N: AtomicU64 = AtomicU64::new(0);
    if N.fetch_add(1, Ordering::Relaxed) % every.max(1) == 0 {
        GLOBAL.span(name)
    } else {
        Span { tracer: None, name, t0: 0, depth: 0, args: Vec::new() }
    }
}

/// Enable the global tracer, writing to `path` at [`flush`] (CLI
/// `--trace-out`).
pub fn enable_to(path: impl Into<PathBuf>) {
    GLOBAL.enable_to(path)
}

/// Flush the global tracer to its configured path (no-op when disabled or
/// pathless).
pub fn flush() -> std::io::Result<()> {
    GLOBAL.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Each test that needs a deterministic clock gets its own mock backed
    // by a static it advances by hand; tests run in parallel, so the
    // statics are per-test (declared inside the test fn).

    #[test]
    fn disabled_span_is_inert() {
        let t = Tracer::with_clock(|| 0);
        {
            let s = t.span("noop");
            assert!(!s.active());
        }
        assert_eq!(t.event_count(), 0);
    }

    #[test]
    fn mock_clock_parent_child_nesting_and_durations() {
        static NOW: AtomicU64 = AtomicU64::new(0);
        fn clock() -> u64 {
            NOW.load(Ordering::Relaxed)
        }
        let t = Tracer::with_clock(clock);
        t.enable();
        NOW.store(100, Ordering::Relaxed);
        {
            let _parent = t.span("parent");
            NOW.store(110, Ordering::Relaxed);
            {
                let _child = t.span("child").attr("k", "v");
                NOW.store(125, Ordering::Relaxed);
            }
            NOW.store(150, Ordering::Relaxed);
        }
        let inner = t.inner.lock().unwrap();
        // children drop first, so the child event is recorded first
        assert_eq!(inner.events.len(), 2);
        let child = &inner.events[0];
        let parent = &inner.events[1];
        assert_eq!((child.name, child.ts_us, child.dur_us, child.depth), ("child", 110, 15, 1));
        assert_eq!(child.args, vec![("k", "v".to_string())]);
        assert_eq!(
            (parent.name, parent.ts_us, parent.dur_us, parent.depth),
            ("parent", 100, 50, 0)
        );
        assert_eq!(child.tid, parent.tid);
    }

    #[test]
    fn golden_trace_json() {
        static NOW: AtomicU64 = AtomicU64::new(0);
        fn clock() -> u64 {
            NOW.load(Ordering::Relaxed)
        }
        let t = Tracer::with_clock(clock);
        t.enable();
        NOW.store(5, Ordering::Relaxed);
        {
            let _s = t.span("load").attr("shard", 0);
            NOW.store(12, Ordering::Relaxed);
        }
        let want = concat!(
            "{\"displayTimeUnit\":\"ms\",\"traceEvents\":[{\"args\":{\"depth\":0,",
            "\"shard\":\"0\"},\"cat\":\"qera\",\"dur\":7,\"name\":\"load\",\"ph\":\"X\",",
            "\"pid\":1,\"tid\":0,\"ts\":5}]}",
        );
        assert_eq!(t.render(), want);
        // and the rendered form parses back as JSON with a traceEvents array
        let parsed = Json::parse(&t.render()).unwrap();
        assert!(matches!(parsed.get("traceEvents"), Some(Json::Arr(a)) if a.len() == 1));
    }

    #[test]
    fn event_cap_counts_drops() {
        let t = Tracer::with_clock(|| 0);
        t.enable();
        {
            let mut inner = t.inner.lock().unwrap();
            for _ in 0..MAX_EVENTS {
                inner.events.push(Event {
                    name: "pad",
                    ts_us: 0,
                    dur_us: 0,
                    tid: 0,
                    depth: 0,
                    args: Vec::new(),
                });
            }
        }
        {
            let _s = t.span("over");
        }
        assert_eq!(t.dropped(), 1);
        assert_eq!(t.event_count(), MAX_EVENTS);
    }

    #[test]
    fn flush_writes_parseable_trace_file() {
        let dir = std::env::temp_dir().join("qera_obs_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        let t = Tracer::with_clock(|| 3);
        t.enable_to(&path);
        {
            let _s = t.span("solve");
        }
        t.flush().unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&body).unwrap();
        assert!(parsed.get("traceEvents").is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
