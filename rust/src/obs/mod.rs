//! Observability: process-global metrics registry + hierarchical span
//! tracing, zero external dependencies.
//!
//! Two halves, both observe-only (nothing in here may perturb numeric
//! results — the streaming quantizer's bit-identity tests run with and
//! without instrumentation enabled and demand identical manifests):
//!
//! * [`metrics`] — atomic counters, gauges, and fixed-bucket histograms
//!   with labeled series, registered in a process-global [`metrics::Registry`]
//!   and exported as Prometheus-style text or JSON (`--metrics-out`,
//!   [`crate::serve::Server::metrics`]).
//! * [`trace`] — timed spans with parent/child nesting and per-span
//!   attributes, buffered in memory and flushed as Chrome trace-event JSON
//!   (loadable in `chrome://tracing` / Perfetto) when `QERA_TRACE=<path>`
//!   or `--trace-out <path>` is set.  When tracing is off the span
//!   constructor is a single relaxed atomic load — cheap enough for the
//!   fused-matmul hot path — and the `obs` bench group in
//!   `benches/hotpath.rs` gates that disabled-path cost in CI.

pub mod metrics;
pub mod trace;

/// Minimal `Lazy` for statics holding metric handles (same shape as the
/// private one in `util/logging.rs`; duplicated to keep `obs` standalone).
pub mod lazy {
    use std::sync::Once;

    pub struct Lazy<T> {
        once: Once,
        init: fn() -> T,
        value: std::cell::UnsafeCell<Option<T>>,
    }
    unsafe impl<T: Sync> Sync for Lazy<T> {}
    impl<T> Lazy<T> {
        pub const fn new(init: fn() -> T) -> Self {
            Lazy { once: Once::new(), init, value: std::cell::UnsafeCell::new(None) }
        }
        pub fn get(&self) -> &T {
            self.once.call_once(|| unsafe {
                *self.value.get() = Some((self.init)());
            });
            unsafe { (*self.value.get()).as_ref().unwrap() }
        }
    }
    impl<T> std::ops::Deref for Lazy<T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.get()
        }
    }
}
