//! Process-global metrics registry: counters, gauges, fixed-bucket
//! histograms.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap clones of an
//! `Arc` around atomics — registration takes the registry mutex once, after
//! which every update is a relaxed atomic op.  Hot call sites keep a handle
//! in a `static obs::lazy::Lazy` so the steady state never touches the
//! registry lock.  Series are keyed by `(name, sorted labels)`; exporters
//! walk the registry in key order so both encodings are deterministic:
//!
//! * [`Registry::render_prometheus`] — text exposition format
//!   (`# TYPE` comments, `name{label="v"} value`, cumulative `le` buckets).
//! * [`Registry::to_json`] — the same data as a [`Json`] tree for
//!   machine-readable dumps (`--metrics-out metrics.json`).
//!
//! All update paths are observe-only: they never branch on metric values
//! and never feed back into computation, preserving the repo-wide
//! bit-identity invariants.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotone event counter.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (queue depth, live bytes).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }
    /// Raise the gauge to `v` if it is below it (peak tracking).
    pub fn set_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Default bucket bounds (milliseconds) for latency histograms.
pub const LATENCY_MS_BUCKETS: &[f64] =
    &[0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0];

struct HistogramCore {
    /// Ascending upper bounds; an implicit `+Inf` bucket follows the last.
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts, `bounds.len() + 1` entries.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Running sum as f64 bits, accumulated with a CAS loop.
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram; a value lands in the first bucket whose upper
/// bound is `>= v` (Prometheus `le` semantics — bounds are inclusive).
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    pub fn observe(&self, v: f64) {
        let c = &self.0;
        let idx = c.bounds.iter().position(|&b| v <= b).unwrap_or(c.bounds.len());
        c.buckets[idx].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        let sb = &c.sum_bits;
        let mut cur = sb.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match sb.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }
    /// Cumulative `(upper_bound, count)` pairs; the final bound is
    /// `f64::INFINITY` and its count equals [`Histogram::count`] (modulo
    /// concurrent updates between the loads).
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let c = &self.0;
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(c.buckets.len());
        for (i, b) in c.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            let bound = c.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((bound, acc));
        }
        out
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

type Labels = Vec<(String, String)>;

/// A registry of labeled metric series.  One process-global instance backs
/// the CLI (`--metrics-out`) and `Server::metrics()`; tests construct their
/// own to keep assertions isolated under the parallel test runner.
pub struct Registry {
    inner: Mutex<BTreeMap<String, BTreeMap<Labels, Metric>>>,
}

fn label_key(labels: &[(&str, &str)]) -> Labels {
    let mut v: Labels = labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    v.sort();
    v
}

impl Registry {
    pub const fn new() -> Registry {
        Registry { inner: Mutex::new(BTreeMap::new()) }
    }

    fn register(&self, name: &str, labels: &[(&str, &str)], fresh: fn() -> Metric) -> Metric {
        let mut map = self.inner.lock().unwrap();
        let fam = map.entry(name.to_string()).or_default();
        let slot = fam.entry(label_key(labels)).or_insert_with(fresh);
        slot.clone()
    }

    /// Get-or-create a counter series.  Registering the same name as a
    /// different metric type is a programmer error and panics.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        fn fresh() -> Metric {
            Metric::Counter(Counter(Arc::new(AtomicU64::new(0))))
        }
        match self.register(name, labels, fresh) {
            Metric::Counter(c) => c,
            other => panic!("metric {name} already registered as {}", other.kind()),
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        fn fresh() -> Metric {
            Metric::Gauge(Gauge(Arc::new(AtomicI64::new(0))))
        }
        match self.register(name, labels, fresh) {
            Metric::Gauge(g) => g,
            other => panic!("metric {name} already registered as {}", other.kind()),
        }
    }

    /// Get-or-create a histogram series.  `bounds` must be ascending; if
    /// the series already exists its original bounds win.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)], bounds: &[f64]) -> Histogram {
        let mut map = self.inner.lock().unwrap();
        let fam = map.entry(name.to_string()).or_default();
        let slot = fam.entry(label_key(labels)).or_insert_with(|| {
            debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "histogram bounds not ascending");
            Metric::Histogram(Histogram(Arc::new(HistogramCore {
                bounds: bounds.to_vec(),
                buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
                count: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0f64.to_bits()),
            })))
        });
        match slot {
            Metric::Histogram(h) => h.clone(),
            other => panic!("metric {name} already registered as {}", other.kind()),
        }
    }

    /// Snapshot of every series, deterministically ordered.
    fn snapshot(&self) -> Vec<(String, Labels, Metric)> {
        let map = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for (name, fam) in map.iter() {
            for (labels, m) in fam.iter() {
                out.push((name.clone(), labels.clone(), m.clone()));
            }
        }
        out
    }

    /// Prometheus text exposition format.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_name = String::new();
        for (name, labels, m) in self.snapshot() {
            if name != last_name {
                out.push_str(&format!("# TYPE {name} {}\n", m.kind()));
                last_name = name.clone();
            }
            match m {
                Metric::Counter(c) => {
                    out.push_str(&format!("{name}{} {}\n", prom_labels(&labels, None), c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("{name}{} {}\n", prom_labels(&labels, None), g.get()));
                }
                Metric::Histogram(h) => {
                    for (le, n) in h.cumulative() {
                        let le = if le.is_finite() { fmt_f64(le) } else { "+Inf".to_string() };
                        out.push_str(&format!(
                            "{name}_bucket{} {n}\n",
                            prom_labels(&labels, Some(&le))
                        ));
                    }
                    out.push_str(&format!(
                        "{name}_sum{} {}\n",
                        prom_labels(&labels, None),
                        fmt_f64(h.sum())
                    ));
                    out.push_str(&format!(
                        "{name}_count{} {}\n",
                        prom_labels(&labels, None),
                        h.count()
                    ));
                }
            }
        }
        out
    }

    /// The same snapshot as a JSON tree:
    /// `{"counters": [...], "gauges": [...], "histograms": [...]}`.
    pub fn to_json(&self) -> Json {
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut histograms = Vec::new();
        for (name, labels, m) in self.snapshot() {
            let pairs = labels.iter().map(|(k, v)| (k.clone(), Json::str(v.clone())));
            let lbl = Json::Obj(pairs.collect());
            match m {
                Metric::Counter(c) => counters.push(Json::obj(vec![
                    ("labels", lbl),
                    ("name", Json::str(name)),
                    ("value", Json::Num(c.get() as f64)),
                ])),
                Metric::Gauge(g) => gauges.push(Json::obj(vec![
                    ("labels", lbl),
                    ("name", Json::str(name)),
                    ("value", Json::Num(g.get() as f64)),
                ])),
                Metric::Histogram(h) => {
                    let buckets = h
                        .cumulative()
                        .into_iter()
                        .map(|(le, n)| {
                            let le = if le.is_finite() { Json::Num(le) } else { Json::str("+Inf") };
                            Json::obj(vec![("count", Json::Num(n as f64)), ("le", le)])
                        })
                        .collect();
                    histograms.push(Json::obj(vec![
                        ("buckets", Json::Arr(buckets)),
                        ("count", Json::Num(h.count() as f64)),
                        ("labels", lbl),
                        ("name", Json::str(name)),
                        ("sum", Json::Num(h.sum())),
                    ]));
                }
            }
        }
        Json::obj(vec![
            ("counters", Json::Arr(counters)),
            ("gauges", Json::Arr(gauges)),
            ("histograms", Json::Arr(histograms)),
        ])
    }

    /// Dump the registry to `path`: JSON when the extension is `.json`,
    /// Prometheus text otherwise.
    pub fn dump(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        let body = if path.extension().is_some_and(|e| e == "json") {
            self.to_json().dump_pretty()
        } else {
            self.render_prometheus()
        };
        std::fs::write(path, body)
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

fn prom_labels(labels: &Labels, le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
        .collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

/// Integral floats print without a decimal point (matches `util::json`).
fn fmt_f64(n: f64) -> String {
    if n == n.trunc() && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

static GLOBAL: Registry = Registry::new();

/// The process-global registry backing `--metrics-out` and
/// `Server::metrics()`.
pub fn global() -> &'static Registry {
    &GLOBAL
}

/// Get-or-create a counter in the global registry.
pub fn counter(name: &str, labels: &[(&str, &str)]) -> Counter {
    GLOBAL.counter(name, labels)
}

/// Get-or-create a gauge in the global registry.
pub fn gauge(name: &str, labels: &[(&str, &str)]) -> Gauge {
    GLOBAL.gauge(name, labels)
}

/// Get-or-create a histogram in the global registry.
pub fn histogram(name: &str, labels: &[(&str, &str)], bounds: &[f64]) -> Histogram {
    GLOBAL.histogram(name, labels, bounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool;

    #[test]
    fn counter_exact_under_concurrent_increments() {
        let r = Registry::new();
        let c = r.counter("hits", &[]);
        let c2 = c.clone();
        pool::parallel_map(64, 8, |i| c2.add(i as u64 + 1));
        assert_eq!(c.get(), (1..=64).sum::<u64>());
    }

    #[test]
    fn gauge_add_sub_balance_under_concurrency() {
        let r = Registry::new();
        let g = r.gauge("live", &[]);
        pool::parallel_map(32, 8, |i| {
            g.add(i as i64 + 1);
            g.sub(i as i64 + 1);
        });
        assert_eq!(g.get(), 0);
        g.set_max(40);
        g.set_max(10);
        assert_eq!(g.get(), 40);
    }

    #[test]
    fn histogram_exact_under_concurrent_observes() {
        let r = Registry::new();
        let h = r.histogram("lat", &[], &[8.0, 32.0]);
        // integer-valued observations sum exactly in f64 regardless of order
        pool::parallel_map(64, 8, |i| h.observe(i as f64));
        assert_eq!(h.count(), 64);
        assert_eq!(h.sum(), (0..64).sum::<i64>() as f64);
        let cum = h.cumulative();
        assert_eq!(cum, vec![(8.0, 9), (32.0, 33), (f64::INFINITY, 64)]);
    }

    #[test]
    fn histogram_bucket_boundaries_are_inclusive() {
        let r = Registry::new();
        let h = r.histogram("b", &[], &[1.0, 2.5]);
        h.observe(1.0); // lands in le=1 (inclusive upper bound)
        h.observe(1.0000001); // just over -> le=2.5
        h.observe(2.5); // le=2.5
        h.observe(2.6); // +Inf
        h.observe(-1.0); // below first bound -> le=1
        assert_eq!(h.cumulative(), vec![(1.0, 2), (2.5, 4), (f64::INFINITY, 5)]);
    }

    #[test]
    fn same_series_returns_same_handle_and_labels_are_canonicalized() {
        let r = Registry::new();
        let a = r.counter("x", &[("b", "2"), ("a", "1")]);
        let b = r.counter("x", &[("a", "1"), ("b", "2")]);
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn type_mismatch_panics() {
        let r = Registry::new();
        r.counter("m", &[]);
        r.gauge("m", &[]);
    }

    #[test]
    fn golden_prometheus_text() {
        let r = Registry::new();
        r.counter("qera_test_total", &[("kind", "a")]).add(3);
        r.gauge("qera_live", &[]).set(7);
        let h = r.histogram("qera_lat_ms", &[], &[1.0, 2.5]);
        for v in [0.5, 1.0, 2.0, 9.0] {
            h.observe(v);
        }
        let want = "\
# TYPE qera_lat_ms histogram
qera_lat_ms_bucket{le=\"1\"} 2
qera_lat_ms_bucket{le=\"2.5\"} 3
qera_lat_ms_bucket{le=\"+Inf\"} 4
qera_lat_ms_sum 12.5
qera_lat_ms_count 4
# TYPE qera_live gauge
qera_live 7
# TYPE qera_test_total counter
qera_test_total{kind=\"a\"} 3
";
        assert_eq!(r.render_prometheus(), want);
    }

    #[test]
    fn golden_json() {
        let r = Registry::new();
        r.counter("qera_test_total", &[("kind", "a")]).add(3);
        let h = r.histogram("qera_lat_ms", &[], &[1.0]);
        h.observe(0.5);
        h.observe(4.0);
        let want = concat!(
            "{\"counters\":[{\"labels\":{\"kind\":\"a\"},\"name\":\"qera_test_total\",",
            "\"value\":3}],\"gauges\":[],\"histograms\":[{\"buckets\":[{\"count\":1,",
            "\"le\":1},{\"count\":2,\"le\":\"+Inf\"}],\"count\":2,\"labels\":{},",
            "\"name\":\"qera_lat_ms\",\"sum\":4.5}]}",
        );
        assert_eq!(r.to_json().dump(), want);
    }

    #[test]
    fn dump_picks_format_by_extension() {
        let dir = std::env::temp_dir().join("qera_obs_dump_test");
        std::fs::create_dir_all(&dir).unwrap();
        let r = Registry::new();
        r.counter("c_total", &[]).inc();
        let jp = dir.join("m.json");
        let tp = dir.join("m.prom");
        r.dump(&jp).unwrap();
        r.dump(&tp).unwrap();
        let js = std::fs::read_to_string(&jp).unwrap();
        assert!(Json::parse(&js).is_ok());
        let txt = std::fs::read_to_string(&tp).unwrap();
        assert!(txt.contains("# TYPE c_total counter"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
