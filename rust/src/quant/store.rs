//! Packed storage form of a quantized weight — one enum over the three
//! format payloads (bit-packed codes + per-group side parameters), so the
//! checkpoint container and the fused execution kernels ([`crate::quant::exec`])
//! speak a single storage type instead of per-format tuples.
//!
//! The data is a flat stream of `group()`-sized chunks (a ragged final
//! chunk is its own short group), matching the `quantize_packed` /
//! `dequantize_packed` convention of the format modules.  Decoding
//! reproduces each format's `qdq` bit-for-bit.

use super::{fp4, intq, mxint, packing, QFormat};
use anyhow::{ensure, Result};

/// Bit-packed quantized weight payload.
#[derive(Clone, Debug, PartialEq)]
pub enum PackedWeight {
    /// MXINT: signed codes + one shared exponent per block (`i8::MIN` marks
    /// an all-zero block).
    Mxint { bits: u8, block: usize, packed: Vec<u8>, exps: Vec<i8> },
    /// Affine INT: unsigned codes + one `(scale, zero)` pair per group
    /// (`scale == 0` marks a constant group decoding to exactly `zero`).
    IntAffine { bits: u8, group: usize, packed: Vec<u8>, scales: Vec<f32>, zeros: Vec<f32> },
    /// E2M1 FP4: 4-bit sign|index codes + one absmax scale per group
    /// (`scale == 0` marks an all-zero group with signs preserved).
    Fp4 { group: usize, packed: Vec<u8>, scales: Vec<f32> },
}

impl PackedWeight {
    /// Quantize a flat weight slice to storage form.  Returns `None` for
    /// [`QFormat::None`] (identity formats stay dense).
    pub fn quantize(w: &[f32], fmt: &QFormat) -> Option<PackedWeight> {
        match *fmt {
            QFormat::None => None,
            QFormat::Mxint { bits, block } => {
                let (codes, exps) = mxint::quantize_packed(w, bits, block);
                let packed = packing::pack_bits(&codes, bits);
                Some(PackedWeight::Mxint { bits, block, packed, exps })
            }
            QFormat::IntAffine { bits, group, refine_iters } => {
                let (codes, scales, zeros) = intq::quantize_packed(w, bits, group, refine_iters);
                let packed = packing::pack_bits(&codes, bits);
                Some(PackedWeight::IntAffine { bits, group, packed, scales, zeros })
            }
            QFormat::Fp4 { group } => {
                let (codes, scales) = fp4::quantize_packed(w, group);
                let packed = packing::pack_bits(&codes, 4);
                Some(PackedWeight::Fp4 { group, packed, scales })
            }
        }
    }

    /// Elements per quantization group.
    pub fn group(&self) -> usize {
        match self {
            PackedWeight::Mxint { block, .. } => (*block).max(1),
            PackedWeight::IntAffine { group, .. } => (*group).max(1),
            PackedWeight::Fp4 { group, .. } => (*group).max(1),
        }
    }

    /// Code width in bits.
    pub fn bits(&self) -> u8 {
        match self {
            PackedWeight::Mxint { bits, .. } => *bits,
            PackedWeight::IntAffine { bits, .. } => *bits,
            PackedWeight::Fp4 { .. } => 4,
        }
    }

    /// Check that the payload covers exactly `numel` elements — run once
    /// after deserialization so the decode paths can assume well-formed
    /// buffers.
    pub fn validate(&self, numel: usize) -> Result<()> {
        let n_groups = numel.div_ceil(self.group());
        let need = (numel * self.bits() as usize).div_ceil(8);
        match self {
            PackedWeight::Mxint { packed, exps, .. } => {
                ensure!(packed.len() >= need, "mxint payload too short: {} < {need}", packed.len());
                ensure!(exps.len() == n_groups, "mxint exps {} != {n_groups}", exps.len());
            }
            PackedWeight::IntAffine { packed, scales, zeros, .. } => {
                ensure!(packed.len() >= need, "intq payload too short: {} < {need}", packed.len());
                ensure!(scales.len() == n_groups, "intq scales {} != {n_groups}", scales.len());
                ensure!(zeros.len() == n_groups, "intq zeros {} != {n_groups}", zeros.len());
            }
            PackedWeight::Fp4 { packed, scales, .. } => {
                ensure!(packed.len() >= need, "fp4 payload too short: {} < {need}", packed.len());
                ensure!(scales.len() == n_groups, "fp4 scales {} != {n_groups}", scales.len());
            }
        }
        Ok(())
    }

    /// Decode quantization group `g` (elements `[g·group, g·group +
    /// out.len())` of the flat stream) into `out`, using `scratch` (at
    /// least `out.len()` slots) for the unpacked integer codes.  This is
    /// the unit the fused kernels address — one group at a time, no
    /// whole-tensor allocation.
    pub fn decode_group_into(&self, g: usize, scratch: &mut [i32], out: &mut [f32]) -> Result<()> {
        let start = g * self.group();
        let codes = &mut scratch[..out.len()];
        match self {
            PackedWeight::Mxint { bits, packed, exps, .. } => {
                packing::unpack_bits_at(packed, *bits, start, codes)?;
                mxint::decode_group(codes, exps[g], *bits, out);
            }
            PackedWeight::IntAffine { bits, packed, scales, zeros, .. } => {
                packing::unpack_bits_at_unsigned(packed, *bits, start, codes)?;
                intq::decode_group(codes, scales[g], zeros[g], out);
            }
            PackedWeight::Fp4 { packed, scales, .. } => {
                packing::unpack_bits_at_unsigned(packed, 4, start, codes)?;
                fp4::decode_group(codes, scales[g], out);
            }
        }
        Ok(())
    }

    /// Dequantize the full stream back to `numel` f32 elements.
    pub fn dequantize(&self, numel: usize) -> Vec<f32> {
        let group = self.group();
        let mut out = vec![0.0f32; numel];
        let mut scratch = vec![0i32; group];
        for (g, chunk) in out.chunks_mut(group).enumerate() {
            self.decode_group_into(g, &mut scratch, chunk).expect("packed weight too short");
        }
        out
    }

    /// Serialized payload size under the paper's memory accounting: packed
    /// code bytes plus side parameters at their nominal width (8-bit block
    /// exponent for mxint, f16 scale + grid zero-point totalling 16 bits
    /// per group for intq, 8-bit scale for fp4 — matching
    /// [`QFormat::avg_bits`]).  The container serializes intq/fp4 side
    /// params as f32 for exactness; that container overhead is not what the
    /// paper counts.
    pub fn payload_bytes(&self) -> usize {
        match self {
            PackedWeight::Mxint { packed, exps, .. } => packed.len() + exps.len(),
            PackedWeight::IntAffine { packed, scales, .. } => packed.len() + scales.len() * 2,
            PackedWeight::Fp4 { packed, scales, .. } => packed.len() + scales.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn formats() -> Vec<QFormat> {
        vec![
            QFormat::Mxint { bits: 4, block: 32 },
            QFormat::Mxint { bits: 3, block: 16 },
            QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 },
            QFormat::Fp4 { group: 64 },
        ]
    }

    #[test]
    fn dequantize_matches_qdq_bitwise() {
        let mut rng = Rng::new(30);
        let w = Tensor::randn(vec![8, 64], 0.1, &mut rng);
        for fmt in formats() {
            let pw = PackedWeight::quantize(w.data(), &fmt).unwrap();
            pw.validate(w.numel()).unwrap();
            let want = fmt.qdq(&w);
            assert_eq!(pw.dequantize(w.numel()), want.data(), "{}", fmt.name());
        }
        assert!(PackedWeight::quantize(w.data(), &QFormat::None).is_none());
    }

    #[test]
    fn group_decode_matches_full_dequantize() {
        let mut rng = Rng::new(31);
        // 300 elements: ragged final group for every format above
        let w = rng.normal_vec(300, 0.2);
        for fmt in formats() {
            let pw = PackedWeight::quantize(&w, &fmt).unwrap();
            pw.validate(w.len()).unwrap();
            let full = pw.dequantize(w.len());
            let g = pw.group();
            let mut scratch = vec![0i32; g];
            for (gi, want) in full.chunks(g).enumerate() {
                let mut out = vec![0.0f32; want.len()];
                pw.decode_group_into(gi, &mut scratch, &mut out).unwrap();
                assert_eq!(out, want, "{} group {gi}", fmt.name());
            }
        }
    }

    #[test]
    fn validate_rejects_truncation() {
        let mut rng = Rng::new(32);
        let w = rng.normal_vec(128, 0.1);
        for fmt in formats() {
            let pw = PackedWeight::quantize(&w, &fmt).unwrap();
            assert!(pw.validate(w.len()).is_ok(), "{}", fmt.name());
            // claiming more elements than packed must fail
            assert!(pw.validate(w.len() * 2).is_err(), "{}", fmt.name());
        }
    }

    #[test]
    fn payload_matches_avg_bits() {
        let mut rng = Rng::new(33);
        let n = 64 * 64;
        let w = rng.normal_vec(n, 0.1);
        for fmt in formats() {
            let pw = PackedWeight::quantize(&w, &fmt).unwrap();
            let bits = pw.payload_bytes() as f64 * 8.0 / n as f64;
            assert!((bits - fmt.avg_bits()).abs() < 1e-9, "{}: {bits}", fmt.name());
        }
    }
}
