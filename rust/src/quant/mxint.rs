//! MXINT quantize-dequantize — bit-exact mirror of the L1 Pallas kernel.
//!
//! Per block of `block` consecutive elements (last axis): shared exponent
//! `e = floor(log2(max|v|))` extracted from the f32 exponent bits (exact;
//! a libm log2 could round differently near powers of two), elements are
//! `bits`-bit integers with scale `2^(e - bits + 2)` and ties-to-even
//! rounding, clamped symmetrically to ±(2^(bits-1) − 1).

use crate::tensor::Tensor;

/// Exact floor(log2(x)) for positive f32; subnormals clamp to -126.
#[inline]
pub fn floor_log2(x: f32) -> i32 {
    debug_assert!(x > 0.0);
    let e = ((x.to_bits() >> 23) & 0xFF) as i32 - 127;
    e.max(-126)
}

/// Quantize-dequantize one contiguous group sharing an exponent.
#[inline]
pub fn qdq_group(group: &mut [f32], bits: u8) {
    let amax = group.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 || !amax.is_finite() {
        for v in group.iter_mut() {
            *v = 0.0;
        }
        return;
    }
    let e = floor_log2(amax);
    let scale = f32::powi(2.0, e - (bits as i32 - 2));
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    for v in group.iter_mut() {
        let q = (*v / scale).round_ties_even().clamp(-qmax, qmax);
        *v = q * scale;
    }
}

/// Quantize-dequantize a tensor (groups along the last axis), threaded
/// over block chunks (blocks are independent → bit-identical per count).
pub fn qdq(w: &Tensor, bits: u8, block: usize) -> Tensor {
    qdq_workers(w, bits, block, 0)
}

/// [`qdq`] with an explicit worker count (`0` = auto).
pub fn qdq_workers(w: &Tensor, bits: u8, block: usize, workers: usize) -> Tensor {
    assert!(bits >= 2, "mxint bits >= 2");
    let last = *w.shape().last().expect("mxint on scalar");
    assert_eq!(last % block, 0, "last axis {last} not divisible by block {block}");
    let mut out = w.clone();
    crate::quant::par_groups(out.data_mut(), block, workers, |group| qdq_group(group, bits));
    out
}

/// Quantize to integer codes + per-block exponents (storage form).  The
/// data is treated as a flat stream of `block`-sized chunks; a ragged final
/// chunk becomes its own short block.  Decoding reproduces [`qdq`]
/// bit-for-bit.
pub fn quantize_packed(w: &[f32], bits: u8, block: usize) -> (Vec<i32>, Vec<i8>) {
    let block = block.max(1);
    let mut codes = Vec::with_capacity(w.len());
    let mut exps = Vec::with_capacity(w.len().div_ceil(block));
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    for group in w.chunks(block) {
        let amax = group.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if amax == 0.0 {
            exps.push(i8::MIN);
            codes.extend(std::iter::repeat(0).take(group.len()));
            continue;
        }
        let e = floor_log2(amax);
        exps.push(e as i8);
        let scale = f32::powi(2.0, e - (bits as i32 - 2));
        for &v in group {
            codes.push((v / scale).round_ties_even().clamp(-qmax, qmax) as i32);
        }
    }
    (codes, exps)
}

/// Decode one block's codes given its stored exponent (`i8::MIN` marks an
/// all-zero block).
#[inline]
pub fn decode_group(codes: &[i32], e: i8, bits: u8, out: &mut [f32]) {
    if e == i8::MIN {
        out.fill(0.0);
        return;
    }
    let scale = f32::powi(2.0, e as i32 - (bits as i32 - 2));
    for (o, &q) in out.iter_mut().zip(codes) {
        *o = q as f32 * scale;
    }
}

/// Dequantize storage form back to f32 (flat stream of blocks).
pub fn dequantize_packed(codes: &[i32], exps: &[i8], bits: u8, block: usize) -> Vec<f32> {
    let block = block.max(1);
    let mut out = vec![0.0f32; codes.len()];
    for (bi, chunk) in out.chunks_mut(block).enumerate() {
        decode_group(&codes[bi * block..bi * block + chunk.len()], exps[bi], bits, chunk);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn golden_vectors_match_python() {
        // Mirrors python/tests/test_mxint.py::test_golden_vectors
        let base = [1.0f32, -1.0, 0.5, 0.25, 3.0, -2.5, 0.1, 0.0];
        let x: Vec<f32> = base.iter().cycle().take(32).copied().collect();
        let t = Tensor::new(vec![1, 32], x);
        let y = qdq(&t, 4, 32);
        let want = [1.0f32, -1.0, 0.5, 0.0, 3.0, -2.5, 0.0, 0.0];
        for (i, &v) in y.data().iter().enumerate() {
            assert_eq!(v, want[i % 8], "index {i}");
        }
    }

    #[test]
    fn floor_log2_exact() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(3.0), 1);
        assert_eq!(floor_log2(0.5), -1);
        assert_eq!(floor_log2(0.9999999), -1); // just below 2^0
        assert_eq!(floor_log2(f32::from_bits(0x3f7fffff)), -1); // largest < 1.0
        assert_eq!(floor_log2(6.0e-39), -126); // subnormal clamps
    }

    #[test]
    fn zero_block() {
        let t = Tensor::zeros(vec![2, 32]);
        assert!(qdq(&t, 4, 32).data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(vec![8, 64], 1.0, &mut rng);
        let once = qdq(&t, 4, 32);
        let twice = qdq(&once, 4, 32);
        assert_eq!(once, twice);
    }

    #[test]
    fn pow2_scale_equivariance() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(vec![4, 32], 1.0, &mut rng);
        let mut t4 = t.clone();
        t4.scale(4.0);
        let a = qdq(&t4, 4, 32);
        let mut b = qdq(&t, 4, 32);
        b.scale(4.0);
        assert_eq!(a, b);
    }

    #[test]
    fn negation_symmetry() {
        let mut rng = Rng::new(2);
        let t = Tensor::randn(vec![4, 32], 1.0, &mut rng);
        let neg = t.map(|v| -v);
        let a = qdq(&neg, 3, 16);
        let b = qdq(&t, 3, 16).map(|v| -v);
        assert_eq!(a, b);
    }

    #[test]
    fn error_bounded_by_lsb() {
        let mut rng = Rng::new(3);
        let t = Tensor::randn(vec![16, 32], 2.0, &mut rng);
        for bits in [3u8, 4, 6] {
            let y = qdq(&t, bits, 32);
            for (g, gy) in t.data().chunks(32).zip(y.data().chunks(32)) {
                let amax = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let lsb = f32::powi(2.0, floor_log2(amax) - (bits as i32 - 2));
                for (a, b) in g.iter().zip(gy) {
                    assert!((a - b).abs() <= lsb + 1e-9, "bits={bits}");
                }
            }
        }
    }

    #[test]
    fn packed_roundtrip_matches_qdq() {
        let mut rng = Rng::new(4);
        let t = Tensor::randn(vec![8, 64], 0.3, &mut rng);
        for (bits, block) in [(4u8, 32usize), (3, 32), (2, 16), (8, 32)] {
            let want = qdq(&t, bits, block);
            let (codes, exps) = quantize_packed(t.data(), bits, block);
            let got = dequantize_packed(&codes, &exps, bits, block);
            assert_eq!(got, want.data(), "bits={bits} block={block}");
            // codes fit in `bits`
            let qmax = (1i32 << (bits - 1)) - 1;
            assert!(codes.iter().all(|&c| c >= -qmax && c <= qmax));
        }
    }

    #[test]
    fn ties_to_even() {
        // scale = 2^(0-2) = 0.25 when amax = 1.0 (bits=4); 0.125/0.25 = 0.5 -> 0
        let mut x = vec![0.0f32; 32];
        x[0] = 1.0;
        x[1] = 0.125;
        x[2] = 0.375; // 1.5 -> 2 (even)
        let t = Tensor::new(vec![1, 32], x);
        let y = qdq(&t, 4, 32);
        assert_eq!(y.data()[1], 0.0);
        assert_eq!(y.data()[2], 0.5);
    }
}
