//! Weight quantizers — the paper's `q(·)` / `dq(·)`.
//!
//! QERA places no constraint on the quantization function, so the pipeline
//! is generic over [`QFormat`]:
//!
//! * [`mxint`] — MXINT shared-exponent integer (OCP MX style), the paper's
//!   main format (bits+8/block avg: 4.25 = MXINT4 bs=32, 3.25 = MXINT3
//!   bs=32, 2.50 = MXINT2 bs=16, 2.25 = MXINT2 bs=32).  Bit-exact mirror of
//!   the L1 Pallas kernel (`python/compile/kernels/mxint.py`).
//! * [`intq`] — group-wise affine INT with HQQ-style alternating (s, z)
//!   refinement: the "no error reconstruction" SoTA baseline.
//! * [`fp4`] — E2M1 4-bit float with per-group absmax scale (the QLoRA FP4
//!   family stand-in).
//! * [`packing`] — bit packing, so checkpoint sizes reflect true W-bits.
//! * [`store`] — [`PackedWeight`]: one storage enum over the three formats'
//!   packed payloads (codes + side params), shared by the checkpoint
//!   container and the execution kernels.
//! * [`exec`] — fused quantized matmul `y = x·W_q + (x·A)·B` evaluated
//!   straight from packed blocks (in-register dequantize per k-tile), plus
//!   the dequantize-then-matmul reference it is bit-identical to.
//!
//! All quantize-dequantize kernels thread over contiguous runs of their
//! independent blocks via [`par_groups`] — bit-identical for every worker
//! count, and automatically serial inside the per-layer solver pool jobs.

pub mod mxint;
pub mod intq;
pub mod fp4;
pub mod packing;
pub mod store;
pub mod exec;

pub use store::PackedWeight;

use crate::tensor::Tensor;
use crate::util::pool;
use anyhow::{bail, Result};

/// Apply `f` to every independent `group`-sized chunk of `data`, threading
/// over contiguous runs of groups via the worker pool (`workers == 0` =
/// auto; serial for small tensors or inside pool workers — the per-layer
/// solver jobs already quantize on the pool).  Groups are transformed
/// independently by the same scalar code, so the output is **bit-identical
/// for every worker count**.  Shared by all three quantizer families
/// (`mxint` / `intq` / `fp4`) so their threading can't diverge.
pub fn par_groups<F>(data: &mut [f32], group: usize, workers: usize, f: F)
where
    F: Fn(&mut [f32]) + Sync,
{
    let group = group.max(1);
    let n_groups = data.len() / group;
    let base = if workers == 0 { pool::quant_workers(data.len()) } else { workers.max(1) };
    let w = base.min(n_groups.max(1));
    if w <= 1 {
        for g in data.chunks_exact_mut(group) {
            f(g);
        }
        return;
    }
    let groups_per = (n_groups + w - 1) / w;
    pool::parallel_chunks_mut(data, groups_per * group, w, |_, chunk| {
        for g in chunk.chunks_exact_mut(group) {
            f(g);
        }
    });
}

/// A quantization format specification.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QFormat {
    /// Shared-exponent integer: `bits` per element + 8-bit exponent / block.
    Mxint { bits: u8, block: usize },
    /// Group-wise affine integer with HQQ-style refinement.
    IntAffine { bits: u8, group: usize, refine_iters: usize },
    /// E2M1 float4 with per-group absmax scaling.
    Fp4 { group: usize },
    /// Identity (BF16/FP16 reference runs).
    None,
}

impl QFormat {
    /// Parse `"mxint4:32"`, `"int4:64"`, `"fp4:64"`, `"none"`.
    pub fn parse(s: &str) -> Result<QFormat> {
        let s = s.trim().to_lowercase();
        if s == "none" || s == "bf16" || s == "fp16" {
            return Ok(QFormat::None);
        }
        let (head, tail) = match s.split_once(':') {
            Some((h, t)) => (h, t),
            None => (s.as_str(), ""),
        };
        let grp = |d: usize| -> Result<usize> {
            if tail.is_empty() {
                Ok(d)
            } else {
                Ok(tail.parse()?)
            }
        };
        if let Some(b) = head.strip_prefix("mxint") {
            let bits: u8 = b.parse()?;
            anyhow::ensure!((2..=8).contains(&bits), "mxint bits out of range: {bits}");
            return Ok(QFormat::Mxint { bits, block: grp(32)? });
        }
        if let Some(b) = head.strip_prefix("int") {
            let bits: u8 = b.parse()?;
            anyhow::ensure!((2..=8).contains(&bits), "int bits out of range: {bits}");
            return Ok(QFormat::IntAffine { bits, group: grp(64)?, refine_iters: 20 });
        }
        if head == "fp4" {
            return Ok(QFormat::Fp4 { group: grp(64)? });
        }
        bail!("unknown quant format '{s}'")
    }

    /// Average bits per weight element (paper's "W-bits" column).
    pub fn avg_bits(&self) -> f64 {
        match self {
            QFormat::Mxint { bits, block } => *bits as f64 + 8.0 / *block as f64,
            // f16 scale + q-grid zero-point per group
            QFormat::IntAffine { bits, group, .. } => *bits as f64 + 16.0 / *group as f64,
            QFormat::Fp4 { group } => 4.0 + 8.0 / *group as f64,
            QFormat::None => 16.0,
        }
    }

    /// Quantize-dequantize a tensor; groups run along the last axis.
    /// Threads over block chunks via [`par_groups`] (auto worker count).
    pub fn qdq(&self, w: &Tensor) -> Tensor {
        self.qdq_workers(w, 0)
    }

    /// [`QFormat::qdq`] with an explicit worker count (`0` = auto).  Blocks
    /// are independent, so results are bit-identical for any count.
    pub fn qdq_workers(&self, w: &Tensor, workers: usize) -> Tensor {
        match self {
            QFormat::None => w.clone(),
            QFormat::Mxint { bits, block } => mxint::qdq_workers(w, *bits, *block, workers),
            QFormat::IntAffine { bits, group, refine_iters } => {
                intq::qdq_workers(w, *bits, *group, *refine_iters, workers)
            }
            QFormat::Fp4 { group } => fp4::qdq_workers(w, *group, workers),
        }
    }

    pub fn name(&self) -> String {
        match self {
            QFormat::Mxint { bits, block } => format!("mxint{bits}:{block}"),
            QFormat::IntAffine { bits, group, .. } => format!("int{bits}:{group}"),
            QFormat::Fp4 { group } => format!("fp4:{group}"),
            QFormat::None => "none".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn parse_roundtrip() {
        for s in ["mxint4:32", "mxint3:32", "mxint2:16", "int4:64", "fp4:64", "none"] {
            let f = QFormat::parse(s).unwrap();
            if s != "none" {
                assert_eq!(f.name(), s);
            }
        }
        assert!(QFormat::parse("mxint9:32").is_err());
        assert!(QFormat::parse("banana").is_err());
    }

    #[test]
    fn paper_wbits() {
        assert!((QFormat::parse("mxint4:32").unwrap().avg_bits() - 4.25).abs() < 1e-12);
        assert!((QFormat::parse("mxint3:32").unwrap().avg_bits() - 3.25).abs() < 1e-12);
        assert!((QFormat::parse("mxint2:16").unwrap().avg_bits() - 2.5).abs() < 1e-12);
        assert!((QFormat::parse("mxint2:32").unwrap().avg_bits() - 2.25).abs() < 1e-12);
        assert!((QFormat::parse("int4:64").unwrap().avg_bits() - 4.25).abs() < 1e-12);
    }

    #[test]
    fn qdq_error_decreases_with_bits() {
        let mut rng = Rng::new(0);
        let w = Tensor::randn(vec![16, 64], 0.05, &mut rng);
        let mut prev = f64::INFINITY;
        for bits in [2u8, 3, 4, 6, 8] {
            let f = QFormat::Mxint { bits, block: 32 };
            let err = f.qdq(&w).sub(&w).frob_norm();
            assert!(err < prev, "bits={bits}: {err} !< {prev}");
            prev = err;
        }
    }

    #[test]
    fn none_is_identity() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(vec![4, 32], 1.0, &mut rng);
        assert_eq!(QFormat::None.qdq(&w), w);
    }

    #[test]
    fn threaded_qdq_bit_identical_across_worker_counts() {
        let mut rng = Rng::new(2);
        // 48 groups of 32/64/16: enough to straddle chunk boundaries for
        // every worker count below
        let w = Tensor::randn(vec![24, 64], 0.05, &mut rng);
        for fmt in [
            QFormat::Mxint { bits: 4, block: 32 },
            QFormat::Mxint { bits: 2, block: 16 },
            QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 },
            QFormat::Fp4 { group: 64 },
        ] {
            let serial = fmt.qdq_workers(&w, 1);
            for workers in [2usize, 4, 8] {
                assert_eq!(serial, fmt.qdq_workers(&w, workers), "{} w={workers}", fmt.name());
            }
            // and the auto path (whatever count it picks) agrees too
            assert_eq!(serial, fmt.qdq(&w), "{} auto", fmt.name());
        }
    }

    #[test]
    fn par_groups_covers_ragged_group_counts() {
        // group counts that don't divide evenly across workers
        for (len, group, workers) in [(7 * 16, 16usize, 3usize), (5 * 8, 8, 4), (64, 64, 8)] {
            let mut data: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let mut want = data.clone();
            for g in want.chunks_exact_mut(group) {
                let s: f32 = g.iter().sum();
                for v in g.iter_mut() {
                    *v += s;
                }
            }
            par_groups(&mut data, group, workers, |g| {
                let s: f32 = g.iter().sum();
                for v in g.iter_mut() {
                    *v += s;
                }
            });
            assert_eq!(data, want, "len={len} group={group} w={workers}");
        }
    }
}
