//! E2M1 FP4 quantization with per-group absmax scaling — the 4-bit
//! floating-point family (QLoRA's NF4/FP4 role in the paper's QPEFT
//! experiments; the image has no bitsandbytes, so we implement the format).
//!
//! Representable magnitudes (before scaling): {0, 0.5, 1, 1.5, 2, 3, 4, 6}.
//! A group of `group` elements shares `s = amax / 6`; each element maps to
//! the nearest representable (ties toward the even mantissa, matching
//! IEEE-style rounding).

use crate::tensor::Tensor;

/// The non-negative E2M1 value grid.
pub const FP4_GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Grid index of the nearest representable magnitude for `a = |v|` —
/// shared by [`snap`] and the packed storage path so the two can't drift.
#[inline]
pub(crate) fn snap_idx(a: f32) -> usize {
    // midpoints between consecutive grid values
    if a < 0.25 {
        0
    } else if a < 0.75 {
        1
    } else if a < 1.25 {
        2
    } else if a < 1.75 {
        3
    } else if a < 2.5 {
        4
    } else if a < 3.5 {
        5
    } else if a < 5.0 {
        6
    } else {
        7
    }
}

/// Nearest grid value (ties to the even-indexed neighbour).
#[inline]
pub fn snap(v: f32) -> f32 {
    FP4_GRID[snap_idx(v.abs())].copysign(v)
}

/// Quantize-dequantize one group sharing an absmax scale.
#[inline]
fn qdq_group(g: &mut [f32]) {
    let amax = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 {
        return;
    }
    let s = amax / 6.0;
    for v in g.iter_mut() {
        *v = snap(*v / s) * s;
    }
}

/// Quantize-dequantize (groups along the last axis), threaded over group
/// chunks (groups are independent → bit-identical per worker count).
pub fn qdq(w: &Tensor, group: usize) -> Tensor {
    qdq_workers(w, group, 0)
}

/// [`qdq`] with an explicit worker count (`0` = auto).
pub fn qdq_workers(w: &Tensor, group: usize, workers: usize) -> Tensor {
    let last = *w.shape().last().expect("fp4 on scalar");
    assert_eq!(last % group, 0);
    let mut out = w.clone();
    crate::quant::par_groups(out.data_mut(), group, workers, qdq_group);
    out
}

/// Quantize to storage form: one 4-bit code per element (bit 3 = sign,
/// bits 0..=2 = grid index) plus one absmax scale per group.  `scale == 0`
/// marks an all-zero group, where [`qdq`] leaves every element untouched —
/// the sign bits are kept so decode reproduces `±0.0` exactly.  Decoding
/// reproduces [`qdq`] bit-for-bit.  A ragged final chunk becomes its own
/// short group.
pub fn quantize_packed(w: &[f32], group: usize) -> (Vec<i32>, Vec<f32>) {
    let group = group.max(1);
    let mut codes = Vec::with_capacity(w.len());
    let mut scales = Vec::with_capacity(w.len().div_ceil(group));
    for g in w.chunks(group) {
        let amax = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if amax == 0.0 {
            scales.push(0.0);
            codes.extend(g.iter().map(|&v| (v.is_sign_negative() as i32) << 3));
            continue;
        }
        let s = amax / 6.0;
        scales.push(s);
        codes.extend(g.iter().map(|&v| {
            let t = v / s;
            (snap_idx(t.abs()) as i32) | ((t.is_sign_negative() as i32) << 3)
        }));
    }
    (codes, scales)
}

/// Decode one group's 4-bit codes given its stored scale.
#[inline]
pub fn decode_group(codes: &[i32], s: f32, out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        let mag = FP4_GRID[(c & 7) as usize];
        let signed = if c & 8 != 0 { -mag } else { mag };
        *o = signed * s;
    }
}

/// Dequantize storage form back to f32 (flat stream of groups).
pub fn dequantize_packed(codes: &[i32], scales: &[f32], group: usize) -> Vec<f32> {
    let group = group.max(1);
    let mut out = vec![0.0f32; codes.len()];
    for (gi, chunk) in out.chunks_mut(group).enumerate() {
        decode_group(&codes[gi * group..gi * group + chunk.len()], scales[gi], chunk);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn grid_values_fixed_points() {
        for &g in &FP4_GRID {
            assert_eq!(snap(g), g);
            assert_eq!(snap(-g), -g);
        }
    }

    #[test]
    fn snap_midpoints() {
        assert_eq!(snap(0.24), 0.0);
        assert_eq!(snap(0.26), 0.5);
        assert_eq!(snap(2.4), 2.0);
        assert_eq!(snap(2.6), 3.0);
        assert_eq!(snap(5.5), 6.0);
        assert_eq!(snap(100.0), 6.0);
        assert_eq!(snap(-1.3), -1.5);
    }

    #[test]
    fn amax_preserved() {
        // the group max maps exactly to ±6 * s = ±amax
        let mut rng = Rng::new(0);
        let w = Tensor::randn(vec![4, 64], 1.0, &mut rng);
        let y = qdq(&w, 64);
        for (gw, gy) in w.data().chunks(64).zip(y.data().chunks(64)) {
            let amax = gw.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let ymax = gy.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert!((amax - ymax).abs() < 1e-6 * amax);
        }
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(vec![8, 64], 0.1, &mut rng);
        let once = qdq(&w, 64);
        let twice = qdq(&once, 64);
        for (a, b) in once.data().iter().zip(twice.data()) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn zero_group() {
        let w = Tensor::zeros(vec![1, 64]);
        assert_eq!(qdq(&w, 64), w);
    }

    #[test]
    fn packed_roundtrip_matches_qdq() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(vec![8, 64], 0.1, &mut rng);
        for group in [32usize, 64] {
            let want = qdq(&w, group);
            let (codes, scales) = quantize_packed(w.data(), group);
            assert_eq!(dequantize_packed(&codes, &scales, group), want.data(), "group={group}");
            assert!(codes.iter().all(|&c| (0..16).contains(&c)));
        }
        // all-zero group: s = 0 sentinel, signs preserved bit-for-bit
        let z = vec![0.0f32, -0.0, 0.0, -0.0];
        let (codes, scales) = quantize_packed(&z, 4);
        assert_eq!(scales, vec![0.0]);
        let back = dequantize_packed(&codes, &scales, 4);
        for (a, b) in back.iter().zip(&z) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // ragged tail becomes its own short group with its own scale
        let v: Vec<f32> = (0..70).map(|i| (i as f32 * 0.17).cos()).collect();
        let (codes, scales) = quantize_packed(&v, 64);
        assert_eq!((codes.len(), scales.len()), (70, 2));
        let back = dequantize_packed(&codes, &scales, 64);
        assert!(back.iter().zip(&v).all(|(a, b)| (a - b).abs() < 0.3));
    }

    #[test]
    fn relative_error_reasonable() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(vec![32, 64], 0.05, &mut rng);
        let y = qdq(&w, 64);
        let rel = y.sub(&w).frob_norm() / w.frob_norm();
        assert!(rel < 0.15, "{rel}");
    }
}
