//! Group-wise affine integer quantization with HQQ-style refinement.
//!
//! The paper's HQQ baseline (Badri & Shaji 2023): 4-bit INT, group 64, no
//! error reconstruction, but a half-quadratic optimization of the group
//! (scale, zero) parameters.  We implement the ℓ2 proximal variant:
//! alternating exact coordinate updates of `z` and `s` against the current
//! integer codes — each step can only lower ||W − s·(Q − z)||², giving the
//! same "optimized affine grid" role as HQQ's Lp solver.

use crate::tensor::Tensor;

/// Quantize-dequantize with `iters` rounds of (s, z) refinement, threaded
/// over group chunks (groups are independent → bit-identical per count).
pub fn qdq(w: &Tensor, bits: u8, group: usize, iters: usize) -> Tensor {
    qdq_workers(w, bits, group, iters, 0)
}

/// [`qdq`] with an explicit worker count (`0` = auto).
pub fn qdq_workers(w: &Tensor, bits: u8, group: usize, iters: usize, workers: usize) -> Tensor {
    let last = *w.shape().last().expect("intq on scalar");
    assert_eq!(last % group, 0, "last axis {last} % group {group} != 0");
    let mut out = w.clone();
    crate::quant::par_groups(out.data_mut(), group, workers, |g| qdq_group(g, bits, iters));
    out
}

/// The fitted affine grid of one group: either every element dequantizes
/// to exactly the constant, or to `s·(q − z)` with `|s| > 0`.
pub(crate) enum GroupFit {
    Constant(f32),
    Affine { s: f32, z: f32 },
}

/// Quantize one value onto the `[0, levels]` code grid.
#[inline]
pub(crate) fn quant_code(v: f32, s: f32, z: f32, levels: f32) -> f32 {
    (v / s + z).round_ties_even().clamp(0.0, levels)
}

/// Run the alternating (s, z) refinement and return the final grid — the
/// single source of truth shared by [`qdq`] and the packed storage path
/// ([`quantize_packed`]), so the two can never drift apart numerically.
pub(crate) fn fit_group(g: &[f32], bits: u8, iters: usize) -> GroupFit {
    let levels = ((1u32 << bits) - 1) as f32; // codes in [0, levels]
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in g.iter() {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !(hi > lo) {
        // constant group: represent exactly with s=0 -> dq = lo
        return GroupFit::Constant(lo);
    }
    let mut s = (hi - lo) / levels;
    let mut z = -lo / s; // float zero-point: dq = s * (q - z)... using q - z form

    let mut best_err = f64::INFINITY;
    let mut best: Option<(f32, f32)> = None;
    for _ in 0..iters.max(1) {
        // E-step: codes for current grid
        let codes: Vec<f32> = g.iter().map(|&v| quant_code(v, s, z, levels)).collect();
        // M-step: least-squares optimal (s, z') for fixed codes:
        //   dq_i = s * (q_i - z)  =>  linear regression of w on q.
        let n = g.len() as f64;
        let mean_q = codes.iter().map(|&q| q as f64).sum::<f64>() / n;
        let mean_w = g.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mut cov = 0.0f64;
        let mut var = 0.0f64;
        for (&q, &v) in codes.iter().zip(g.iter()) {
            cov += (q as f64 - mean_q) * (v as f64 - mean_w);
            var += (q as f64 - mean_q).powi(2);
        }
        if var <= 0.0 {
            break;
        }
        let s_new = (cov / var) as f32;
        if s_new.abs() < 1e-20 {
            break;
        }
        let z_new = (mean_q - mean_w / s_new as f64) as f32;
        // measure error of (s_new, z_new) with re-quantized codes
        let err: f64 = g
            .iter()
            .map(|&v| {
                let q = quant_code(v, s_new, z_new, levels);
                let d = v as f64 - s_new as f64 * (q as f64 - z_new as f64);
                d * d
            })
            .sum();
        if err < best_err {
            best_err = err;
            best = Some((s_new, z_new));
        }
        if (s_new - s).abs() < 1e-9 * s.abs() && (z_new - z).abs() < 1e-6 {
            break;
        }
        s = s_new;
        z = z_new;
    }
    let (s, z) = best.unwrap_or((s, z));
    GroupFit::Affine { s, z }
}

fn qdq_group(g: &mut [f32], bits: u8, iters: usize) {
    match fit_group(g, bits, iters) {
        GroupFit::Constant(c) => {
            for v in g.iter_mut() {
                *v = c;
            }
        }
        GroupFit::Affine { s, z } => {
            let levels = ((1u32 << bits) - 1) as f32;
            for v in g.iter_mut() {
                let q = quant_code(*v, s, z, levels);
                *v = s * (q - z);
            }
        }
    }
}

/// Quantize to storage form: unsigned codes in `[0, 2^bits − 1]` plus one
/// `(scale, zero)` pair per group.  `scale == 0` marks a constant group
/// whose every element decodes to exactly `zero`.  Decoding reproduces
/// [`qdq`] bit-for-bit.  The data is treated as a flat stream of
/// `group`-sized chunks; a ragged final chunk becomes its own short group.
pub fn quantize_packed(
    w: &[f32],
    bits: u8,
    group: usize,
    iters: usize,
) -> (Vec<i32>, Vec<f32>, Vec<f32>) {
    let levels = ((1u32 << bits) - 1) as f32;
    let n_groups = w.len().div_ceil(group.max(1));
    let mut codes = Vec::with_capacity(w.len());
    let mut scales = Vec::with_capacity(n_groups);
    let mut zeros = Vec::with_capacity(n_groups);
    for g in w.chunks(group.max(1)) {
        match fit_group(g, bits, iters) {
            GroupFit::Constant(c) => {
                scales.push(0.0);
                zeros.push(c);
                codes.extend(std::iter::repeat(0).take(g.len()));
            }
            GroupFit::Affine { s, z } => {
                scales.push(s);
                zeros.push(z);
                codes.extend(g.iter().map(|&v| quant_code(v, s, z, levels) as i32));
            }
        }
    }
    (codes, scales, zeros)
}

/// Decode one group's unsigned codes given its stored `(s, z)` pair.
#[inline]
pub fn decode_group(codes: &[i32], s: f32, z: f32, out: &mut [f32]) {
    if s == 0.0 {
        out.fill(z);
        return;
    }
    for (o, &q) in out.iter_mut().zip(codes) {
        *o = s * (q as f32 - z);
    }
}

/// Dequantize storage form back to f32 (flat stream of groups).
pub fn dequantize_packed(codes: &[i32], scales: &[f32], zeros: &[f32], group: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; codes.len()];
    for (gi, chunk) in out.chunks_mut(group.max(1)).enumerate() {
        let start = gi * group.max(1);
        decode_group(&codes[start..start + chunk.len()], scales[gi], zeros[gi], chunk);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn err(w: &Tensor, y: &Tensor) -> f64 {
        y.sub(w).frob_norm()
    }

    #[test]
    fn refinement_does_not_hurt() {
        let mut rng = Rng::new(0);
        let w = Tensor::randn(vec![8, 64], 0.05, &mut rng);
        let e0 = err(&w, &qdq(&w, 4, 64, 1));
        let e20 = err(&w, &qdq(&w, 4, 64, 20));
        assert!(e20 <= e0 * 1.0 + 1e-12, "refined {e20} vs initial {e0}");
    }

    #[test]
    fn exact_on_grid_values() {
        // values already on an affine grid quantize losslessly
        let vals: Vec<f32> = (0..64).map(|i| 0.1 * (i % 16) as f32 - 0.3).collect();
        let w = Tensor::new(vec![1, 64], vals);
        let y = qdq(&w, 4, 64, 10);
        for (a, b) in w.data().iter().zip(y.data()) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn constant_group_exact() {
        let w = Tensor::full(vec![2, 64], 0.7);
        let y = qdq(&w, 4, 64, 5);
        for &v in y.data() {
            assert!((v - 0.7).abs() < 1e-7);
        }
    }

    #[test]
    fn error_decreases_with_bits() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(vec![16, 64], 0.02, &mut rng);
        let mut prev = f64::INFINITY;
        for bits in [2u8, 3, 4, 6, 8] {
            let e = err(&w, &qdq(&w, bits, 64, 10));
            assert!(e < prev, "bits={bits}");
            prev = e;
        }
    }

    #[test]
    fn asymmetric_data_handled() {
        // all-positive weights exercise the zero-point
        let mut rng = Rng::new(2);
        let w = Tensor::randn(vec![4, 64], 0.1, &mut rng).map(|v| v.abs() + 1.0);
        let y = qdq(&w, 4, 64, 10);
        let rel = err(&w, &y) / w.frob_norm();
        assert!(rel < 0.02, "{rel}");
    }

    #[test]
    fn packed_roundtrip_matches_qdq() {
        let mut rng = Rng::new(9);
        let w = Tensor::randn(vec![8, 64], 0.05, &mut rng);
        for (bits, group, iters) in [(4u8, 64usize, 20usize), (3, 32, 10), (8, 64, 1)] {
            let want = qdq(&w, bits, group, iters);
            let (codes, scales, zeros) = quantize_packed(w.data(), bits, group, iters);
            let got = dequantize_packed(&codes, &scales, &zeros, group);
            assert_eq!(got, want.data(), "bits={bits} group={group}");
            let hi = (1i32 << bits) - 1;
            assert!(codes.iter().all(|&c| (0..=hi).contains(&c)), "bits={bits}");
        }
        // constant groups store the exact value behind the s == 0 sentinel
        let c = Tensor::full(vec![1, 64], 0.7);
        let (codes, scales, zeros) = quantize_packed(c.data(), 4, 64, 5);
        assert_eq!(scales, vec![0.0]);
        assert_eq!(zeros, vec![0.7]);
        assert_eq!(dequantize_packed(&codes, &scales, &zeros, 64), c.data());
        // ragged tail becomes its own short group
        let v: Vec<f32> = (0..70).map(|i| (i as f32 * 0.31).sin()).collect();
        let (codes, scales, zeros) = quantize_packed(&v, 4, 32, 10);
        assert_eq!(codes.len(), 70);
        assert_eq!(scales.len(), 3);
        let back = dequantize_packed(&codes, &scales, &zeros, 32);
        assert_eq!(back.len(), 70);
        assert!(back.iter().zip(&v).all(|(a, b)| (a - b).abs() < 0.2));
    }

    #[test]
    fn int4_beats_mxint4_on_uniform_data_sometimes() {
        // sanity: affine grid adapts to offset distributions better than
        // symmetric mxint — the reason HQQ is a strong baseline.
        let mut rng = Rng::new(3);
        let w = Tensor::randn(vec![8, 64], 0.05, &mut rng).map(|v| v + 0.5);
        let e_int = err(&w, &qdq(&w, 4, 64, 20));
        let e_mx = err(&w, &super::super::mxint::qdq(&w, 4, 64));
        assert!(e_int < e_mx, "int {e_int} vs mxint {e_mx}");
    }
}
