//! Bit packing for quantized integer codes — checkpoint bytes reflect true
//! W-bits (a 4-bit MXINT tensor occupies 4 bits/element + 8 bits/block on
//! disk, matching the paper's memory-footprint accounting).

use anyhow::{ensure, Result};

/// Pack signed codes (each in [-2^(bits-1), 2^(bits-1)-1]) LSB-first.
pub fn pack_bits(codes: &[i32], bits: u8) -> Vec<u8> {
    assert!((1..=16).contains(&bits));
    let mask = (1u32 << bits) - 1;
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        let u = (c as u32) & mask; // two's complement truncation
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= (u << off) as u8;
        let spill = (bits as usize + off).saturating_sub(8);
        if spill > 0 {
            out[byte + 1] |= (u >> (bits as usize - spill)) as u8;
            if spill > 8 {
                out[byte + 2] |= (u >> (bits as usize - spill + 8)) as u8;
            }
        }
        bitpos += bits as usize;
    }
    out
}

/// Shared bit-extraction loop: decode `out.len()` codes starting at code
/// index `start`, sign-extending when `signed`.
fn unpack_with(bytes: &[u8], bits: u8, start: usize, out: &mut [i32], signed: bool) -> Result<()> {
    ensure!((1..=16).contains(&bits));
    let need = ((start + out.len()) * bits as usize).div_ceil(8);
    ensure!(bytes.len() >= need, "packed buffer too short: {} < {}", bytes.len(), need);
    let mask = (1u32 << bits) - 1;
    let sign_bit = 1u32 << (bits - 1);
    let mut bitpos = start * bits as usize;
    for slot in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut u = (bytes[byte] as u32) >> off;
        let mut have = 8 - off;
        let mut next = byte + 1;
        while have < bits as usize {
            u |= (bytes[next] as u32) << have;
            have += 8;
            next += 1;
        }
        u &= mask;
        // sign-extend
        *slot = if signed && u & sign_bit != 0 { (u | !mask) as i32 } else { u as i32 };
        bitpos += bits as usize;
    }
    Ok(())
}

/// Unpack `n` signed codes.
pub fn unpack_bits(bytes: &[u8], bits: u8, n: usize) -> Result<Vec<i32>> {
    let mut out = vec![0i32; n];
    unpack_with(bytes, bits, 0, &mut out, true)?;
    Ok(out)
}

/// Unpack `n` unsigned codes (zero-extended; intq/fp4 storage codes).
pub fn unpack_bits_unsigned(bytes: &[u8], bits: u8, n: usize) -> Result<Vec<i32>> {
    let mut out = vec![0i32; n];
    unpack_with(bytes, bits, 0, &mut out, false)?;
    Ok(out)
}

/// Block-strided group decoder: unpack `out.len()` signed codes starting at
/// code index `start`, into a caller-provided buffer.  This is how the
/// fused execution kernels address one quantization group inside a packed
/// tensor without unpacking (or allocating) the whole buffer.
pub fn unpack_bits_at(bytes: &[u8], bits: u8, start: usize, out: &mut [i32]) -> Result<()> {
    unpack_with(bytes, bits, start, out, true)
}

/// [`unpack_bits_at`] for unsigned codes (zero-extended).
pub fn unpack_bits_at_unsigned(bytes: &[u8], bits: u8, start: usize, out: &mut [i32]) -> Result<()> {
    unpack_with(bytes, bits, start, out, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(0);
        for bits in 2u8..=8 {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let codes: Vec<i32> =
                (0..1000).map(|_| lo + rng.below((hi - lo + 1) as usize) as i32).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(packed.len(), (1000 * bits as usize).div_ceil(8));
            let back = unpack_bits(&packed, bits, 1000).unwrap();
            assert_eq!(codes, back, "bits={bits}");
        }
    }

    #[test]
    fn extremes() {
        for bits in [2u8, 4, 7] {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let codes = vec![lo, hi, 0, -1, 1, lo, hi];
            let back = unpack_bits(&pack_bits(&codes, bits), bits, codes.len()).unwrap();
            assert_eq!(codes, back);
        }
    }

    #[test]
    fn density() {
        let codes = vec![0i32; 64];
        assert_eq!(pack_bits(&codes, 4).len(), 32);
        assert_eq!(pack_bits(&codes, 3).len(), 24);
        assert_eq!(pack_bits(&codes, 2).len(), 16);
    }

    #[test]
    fn short_buffer_errors() {
        let packed = pack_bits(&[1, 2, 3], 4);
        assert!(unpack_bits(&packed, 4, 10).is_err());
    }

    #[test]
    fn empty() {
        let packed = pack_bits(&[], 4);
        assert!(packed.is_empty());
        assert!(unpack_bits(&packed, 4, 0).unwrap().is_empty());
    }

    #[test]
    fn odd_widths_cross_byte_boundaries() {
        // bits ∈ {3, 5, 7}: no code width divides 8, so every few codes
        // straddle a byte boundary (spill > 0 in pack_bits)
        let mut rng = Rng::new(21);
        for bits in [3u8, 5, 7] {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            for n in [1usize, 7, 8, 9, 255, 256, 257] {
                let codes: Vec<i32> =
                    (0..n).map(|_| lo + rng.below((hi - lo + 1) as usize) as i32).collect();
                let packed = pack_bits(&codes, bits);
                assert_eq!(packed.len(), (n * bits as usize).div_ceil(8), "bits={bits} n={n}");
                assert_eq!(unpack_bits(&packed, bits, n).unwrap(), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn wide_codes_exercise_spill_gt_8() {
        // spill = bits + off - 8 > 8 needs bits ≥ 9 (a code spanning three
        // bytes); cover every width up to the supported maximum
        let mut rng = Rng::new(22);
        for bits in 9u8..=16 {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let codes: Vec<i32> =
                (0..500).map(|_| lo + rng.below((hi - lo + 1) as usize) as i32).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(unpack_bits(&packed, bits, codes.len()).unwrap(), codes, "bits={bits}");
        }
        // deterministic three-byte-span case: bits = 11, so the second code
        // starts at off = 3 and spills 6, the fifth at off = 4 spills 7, and
        // widths ≥ 10 with off = 7 hit spill > 8 within the 500-code sweep
        let codes = vec![-1i32, -1024, 1023, 0, -1, 512, -513];
        assert_eq!(unpack_bits(&pack_bits(&codes, 11), 11, 7).unwrap(), codes);
    }

    #[test]
    fn roundtrip_property_random_widths_and_lengths() {
        // property test: for random (bits, n, codes), unpack ∘ pack = id
        // and the packed length is exactly ceil(n·bits/8)
        let mut rng = Rng::new(23);
        for _ in 0..200 {
            let bits = 1 + rng.below(16) as u8;
            let n = rng.below(97);
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let codes: Vec<i32> =
                (0..n).map(|_| lo + rng.below((hi - lo + 1) as usize) as i32).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(packed.len(), (n * bits as usize).div_ceil(8), "bits={bits} n={n}");
            assert_eq!(unpack_bits(&packed, bits, n).unwrap(), codes, "bits={bits} n={n}");
        }
    }

    #[test]
    fn unsigned_roundtrip_and_signed_agreement() {
        let mut rng = Rng::new(24);
        for bits in [2u8, 3, 4, 5, 7, 8] {
            let hi = (1u32 << bits) as usize;
            let codes: Vec<i32> = (0..300).map(|_| rng.below(hi) as i32).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(unpack_bits_unsigned(&packed, bits, 300).unwrap(), codes, "bits={bits}");
            // non-negative codes below the sign bit decode identically
            let small: Vec<i32> = codes.iter().map(|&c| c % (1 << (bits - 1))).collect();
            let sp = pack_bits(&small, bits);
            assert_eq!(
                unpack_bits(&sp, bits, 300).unwrap(),
                unpack_bits_unsigned(&sp, bits, 300).unwrap(),
                "bits={bits}"
            );
        }
    }

    #[test]
    fn strided_group_decode_matches_full_unpack() {
        // decoding any aligned or unaligned group window out of the stream
        // must agree with slicing the full unpack
        let mut rng = Rng::new(25);
        for bits in [3u8, 4, 5, 8, 11] {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let codes: Vec<i32> =
                (0..256).map(|_| lo + rng.below((hi - lo + 1) as usize) as i32).collect();
            let packed = pack_bits(&codes, bits);
            let full = unpack_bits(&packed, bits, codes.len()).unwrap();
            for (start, len) in [(0usize, 32usize), (32, 32), (13, 7), (96, 64), (250, 6)] {
                let mut out = vec![0i32; len];
                unpack_bits_at(&packed, bits, start, &mut out).unwrap();
                assert_eq!(out, &full[start..start + len], "bits={bits} start={start}");
            }
        }
        // unsigned variant, 4-bit fp4-style codes
        let codes: Vec<i32> = (0..64).map(|i| (i % 16) as i32).collect();
        let packed = pack_bits(&codes, 4);
        let mut out = vec![0i32; 16];
        unpack_bits_at_unsigned(&packed, 4, 32, &mut out).unwrap();
        assert_eq!(out, &codes[32..48]);
    }

    #[test]
    fn short_buffer_error_paths() {
        let packed = pack_bits(&[1i32; 64], 5); // 40 bytes
        assert!(unpack_bits(&packed, 5, 65).is_err());
        assert!(unpack_bits_unsigned(&packed, 5, 65).is_err());
        let mut out = vec![0i32; 8];
        // start + len runs past the stream end
        assert!(unpack_bits_at(&packed, 5, 60, &mut out).is_err());
        assert!(unpack_bits_at_unsigned(&packed, 5, 60, &mut out).is_err());
        // exactly at the end is fine
        assert!(unpack_bits_at(&packed, 5, 56, &mut out).is_ok());
        // bits out of range rejected
        assert!(unpack_bits(&packed, 17, 1).is_err());
        assert!(unpack_bits(&packed, 0, 1).is_err());
    }
}
