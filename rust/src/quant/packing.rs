//! Bit packing for quantized integer codes — checkpoint bytes reflect true
//! W-bits (a 4-bit MXINT tensor occupies 4 bits/element + 8 bits/block on
//! disk, matching the paper's memory-footprint accounting).

use anyhow::{ensure, Result};

/// Pack signed codes (each in [-2^(bits-1), 2^(bits-1)-1]) LSB-first.
pub fn pack_bits(codes: &[i32], bits: u8) -> Vec<u8> {
    assert!((1..=16).contains(&bits));
    let mask = (1u32 << bits) - 1;
    let total_bits = codes.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        let u = (c as u32) & mask; // two's complement truncation
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= (u << off) as u8;
        let spill = (bits as usize + off).saturating_sub(8);
        if spill > 0 {
            out[byte + 1] |= (u >> (bits as usize - spill)) as u8;
            if spill > 8 {
                out[byte + 2] |= (u >> (bits as usize - spill + 8)) as u8;
            }
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack `n` signed codes.
pub fn unpack_bits(bytes: &[u8], bits: u8, n: usize) -> Result<Vec<i32>> {
    ensure!((1..=16).contains(&bits));
    let need = (n * bits as usize).div_ceil(8);
    ensure!(bytes.len() >= need, "packed buffer too short: {} < {}", bytes.len(), need);
    let mask = (1u32 << bits) - 1;
    let sign_bit = 1u32 << (bits - 1);
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut u = (bytes[byte] as u32) >> off;
        let mut have = 8 - off;
        let mut next = byte + 1;
        while have < bits as usize {
            u |= (bytes[next] as u32) << have;
            have += 8;
            next += 1;
        }
        u &= mask;
        // sign-extend
        let v = if u & sign_bit != 0 { (u | !mask) as i32 } else { u as i32 };
        out.push(v);
        bitpos += bits as usize;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(0);
        for bits in 2u8..=8 {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let codes: Vec<i32> =
                (0..1000).map(|_| lo + rng.below((hi - lo + 1) as usize) as i32).collect();
            let packed = pack_bits(&codes, bits);
            assert_eq!(packed.len(), (1000 * bits as usize).div_ceil(8));
            let back = unpack_bits(&packed, bits, 1000).unwrap();
            assert_eq!(codes, back, "bits={bits}");
        }
    }

    #[test]
    fn extremes() {
        for bits in [2u8, 4, 7] {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let codes = vec![lo, hi, 0, -1, 1, lo, hi];
            let back = unpack_bits(&pack_bits(&codes, bits), bits, codes.len()).unwrap();
            assert_eq!(codes, back);
        }
    }

    #[test]
    fn density() {
        let codes = vec![0i32; 64];
        assert_eq!(pack_bits(&codes, 4).len(), 32);
        assert_eq!(pack_bits(&codes, 3).len(), 24);
        assert_eq!(pack_bits(&codes, 2).len(), 16);
    }

    #[test]
    fn short_buffer_errors() {
        let packed = pack_bits(&[1, 2, 3], 4);
        assert!(unpack_bits(&packed, 4, 10).is_err());
    }

    #[test]
    fn empty() {
        let packed = pack_bits(&[], 4);
        assert!(packed.is_empty());
        assert!(unpack_bits(&packed, 4, 0).unwrap().is_empty());
    }
}
