//! Fused quantized execution: `y = x·W_q + (x·A)·B` straight from packed
//! blocks.
//!
//! The reference path dequantizes the whole weight to a dense f32 tensor
//! (`k·n` floats allocated and streamed from DRAM per call) and then runs a
//! dense matmul.  The fused kernel instead walks the packed code stream in
//! [`BLOCK_K`]-row k-tiles: each tile is decoded **once** into a bounded
//! L2-resident scratch slab (`BLOCK_K·n` floats, reused across tiles and
//! amortized over every output row in the panel) and immediately consumed
//! by the same blocked accumulation kernel the dense matmul uses
//! ([`crate::tensor`]'s `mm_nn_ktile_f32`).  Weight bytes read per call
//! shrink by the quantization ratio (~8× at 4 bits) and no `k·n` f32
//! buffer is ever materialized.
//!
//! Threading mirrors `Tensor::matmul_workers`: only output-row panels are
//! partitioned and per-element k-accumulation runs strictly ascending, so
//! the result is **bit-identical to the dequantize-then-matmul reference
//! for every worker count** — verified by the tests below for all three
//! formats at odd shapes.

use super::store::PackedWeight;
use crate::tensor::{mm_nn_ktile_f32, mm_nn_panel_f32, Tensor, BLOCK_K};
use crate::util::pool;

/// Decode the flat element range `[e0, e1)` of a packed stream covering
/// `numel` elements into `dst[0..e1-e0]`.  Quantization groups need not
/// align with the range: a group straddling either edge is decoded whole
/// into `gbuf` and the overlap copied, while fully-interior groups decode
/// straight into `dst`.
fn decode_range(
    pw: &PackedWeight,
    numel: usize,
    e0: usize,
    e1: usize,
    dst: &mut [f32],
    scratch: &mut [i32],
    gbuf: &mut [f32],
) {
    debug_assert!(e0 < e1 && e1 <= numel && dst.len() == e1 - e0);
    let g = pw.group();
    for gi in e0 / g..=(e1 - 1) / g {
        let gs = gi * g;
        let ge = (gs + g).min(numel);
        if gs >= e0 && ge <= e1 {
            let off = gs - e0;
            pw.decode_group_into(gi, scratch, &mut dst[off..off + (ge - gs)]).expect("validated");
        } else {
            let whole = &mut gbuf[..ge - gs];
            pw.decode_group_into(gi, scratch, whole).expect("validated");
            let s = gs.max(e0);
            let e = ge.min(e1);
            dst[s - e0..e - e0].copy_from_slice(&whole[s - gs..e - gs]);
        }
    }
}

/// [`fused_matmul_workers`] with the auto worker count.
pub fn fused_matmul(
    x: &Tensor,
    pw: &PackedWeight,
    k: usize,
    n: usize,
    lowrank: Option<(&Tensor, &Tensor)>,
) -> Tensor {
    fused_matmul_workers(x, pw, k, n, lowrank, 0)
}

/// Fused quantized matmul: `x [m,k] · W_q [k,n] (+ (x·A)·B)` evaluated
/// directly from the packed payload, with an explicit worker count (`0` =
/// auto).  Bit-identical to [`dequant_matmul_ref`] for every count.
pub fn fused_matmul_workers(
    x: &Tensor,
    pw: &PackedWeight,
    k: usize,
    n: usize,
    lowrank: Option<(&Tensor, &Tensor)>,
    workers: usize,
) -> Tensor {
    let (m, kx) = (x.rows(), x.cols());
    assert_eq!(kx, k, "fused matmul inner dim mismatch");
    pw.validate(k * n).expect("packed weight does not cover k*n elements");
    // rank-r projection t = x·A once up front (dense and tiny); the B side
    // is applied per panel so the correction shares the panel partition
    let proj = lowrank.map(|(a, b)| {
        assert_eq!(a.shape(), &[k, b.rows()], "lowrank A shape");
        assert_eq!(b.cols(), n, "lowrank B shape");
        (x.matmul_workers(a, workers), b)
    });
    let mut out = vec![0.0f32; m * n];
    let w = if workers == 0 {
        pool::matmul_workers(m, m.saturating_mul(k).saturating_mul(n))
    } else {
        workers.max(1).min(m.max(1))
    };
    let rows_per = (m + w - 1) / w.max(1);
    let group = pw.group();
    pool::parallel_chunks_mut(&mut out, rows_per * n, w, |ci, chunk| {
        let i0 = ci * rows_per;
        let i1 = i0 + chunk.len() / n.max(1);
        let mut wtile = vec![0.0f32; BLOCK_K * n];
        let mut scratch = vec![0i32; group];
        let mut gbuf = vec![0.0f32; group];
        for k0 in (0..k).step_by(BLOCK_K) {
            let k1 = (k0 + BLOCK_K).min(k);
            let tile = &mut wtile[..(k1 - k0) * n];
            decode_range(pw, k * n, k0 * n, k1 * n, tile, &mut scratch, &mut gbuf);
            mm_nn_ktile_f32(x.data(), tile, k, n, k0, k1, i0, i1, chunk);
        }
        if let Some((t, b)) = &proj {
            // correction accumulated from zero in its own buffer, then added
            // elementwise — the exact op sequence of `ref = x·W + (x·A)·B`
            let mut corr = vec![0.0f32; (i1 - i0) * n];
            mm_nn_panel_f32(t.data(), b.data(), t.cols(), n, i0, i1, &mut corr);
            for (o, c) in chunk.iter_mut().zip(&corr) {
                *o += c;
            }
        }
    });
    Tensor::new(vec![m, n], out)
}

/// Dequantize-then-matmul reference: materialize the dense `[k,n]` weight,
/// run the dense kernel, add the low-rank term.  The fused kernel must
/// match this bit-for-bit; the bench `exec` group measures how much faster
/// the fused path is.
pub fn dequant_matmul_ref(
    x: &Tensor,
    pw: &PackedWeight,
    k: usize,
    n: usize,
    lowrank: Option<(&Tensor, &Tensor)>,
) -> Tensor {
    let w_dq = Tensor::new(vec![k, n], pw.dequantize(k * n));
    let y = x.matmul(&w_dq);
    match lowrank {
        Some((a, b)) => y.add(&x.matmul(a).matmul(b)),
        None => y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QFormat;
    use crate::util::rng::Rng;

    fn formats() -> Vec<QFormat> {
        vec![
            QFormat::Mxint { bits: 4, block: 32 },
            QFormat::IntAffine { bits: 4, group: 64, refine_iters: 20 },
            QFormat::Fp4 { group: 64 },
        ]
    }

    #[test]
    fn fused_bit_identical_to_reference_across_workers() {
        let mut rng = Rng::new(40);
        // odd shapes: m, n not multiples of any block/group size, k crossing
        // BLOCK_K, so k-tiles slice groups mid-stream in every format
        for (m, k, n) in [(5usize, 96usize, 50usize), (33, 130, 35), (1, 64, 7)] {
            for fmt in formats() {
                let w = Tensor::randn(vec![k, n], 0.1, &mut rng);
                let pw = PackedWeight::quantize(w.data(), &fmt).unwrap();
                let x = Tensor::randn(vec![m, k], 1.0, &mut rng);
                let want = dequant_matmul_ref(&x, &pw, k, n, None);
                for workers in [1usize, 4, 8] {
                    let got = fused_matmul_workers(&x, &pw, k, n, None, workers);
                    assert_eq!(got, want, "{} {m}x{k}x{n} w={workers}", fmt.name());
                }
                assert_eq!(fused_matmul(&x, &pw, k, n, None), want, "{} auto", fmt.name());
            }
        }
    }

    #[test]
    fn fused_with_lowrank_bit_identical() {
        let mut rng = Rng::new(41);
        let (m, k, n, r) = (9usize, 130usize, 70usize, 16usize);
        for fmt in formats() {
            let w = Tensor::randn(vec![k, n], 0.1, &mut rng);
            let pw = PackedWeight::quantize(w.data(), &fmt).unwrap();
            let x = Tensor::randn(vec![m, k], 1.0, &mut rng);
            let a = Tensor::randn(vec![k, r], 0.02, &mut rng);
            let b = Tensor::randn(vec![r, n], 0.02, &mut rng);
            let want = dequant_matmul_ref(&x, &pw, k, n, Some((&a, &b)));
            for workers in [1usize, 4, 8] {
                let got = fused_matmul_workers(&x, &pw, k, n, Some((&a, &b)), workers);
                assert_eq!(got, want, "{} w={workers}", fmt.name());
            }
        }
    }

    #[test]
    fn fused_matches_qdq_then_dense_matmul() {
        // ties the packed path to the qdq oracle end-to-end: quantize →
        // pack → fused multiply == qdq → dense multiply, bit for bit
        let mut rng = Rng::new(42);
        let (m, k, n) = (6usize, 128usize, 64usize);
        for fmt in formats() {
            let w = Tensor::randn(vec![k, n], 0.1, &mut rng);
            let pw = PackedWeight::quantize(w.data(), &fmt).unwrap();
            let x = Tensor::randn(vec![m, k], 1.0, &mut rng);
            let want = x.matmul(&fmt.qdq(&w));
            assert_eq!(fused_matmul(&x, &pw, k, n, None), want, "{}", fmt.name());
        }
    }

    #[test]
    fn zero_activations_hit_the_skip_path() {
        // the av == 0.0 skip must fire identically on both sides
        let mut rng = Rng::new(43);
        let (m, k, n) = (4usize, 96usize, 40usize);
        let fmt = QFormat::Mxint { bits: 4, block: 32 };
        let w = Tensor::randn(vec![k, n], 0.1, &mut rng);
        let pw = PackedWeight::quantize(w.data(), &fmt).unwrap();
        let mut x = Tensor::randn(vec![m, k], 1.0, &mut rng);
        for (i, v) in x.data_mut().iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = 0.0;
            }
            if i % 7 == 0 {
                *v = -0.0;
            }
        }
        let want = dequant_matmul_ref(&x, &pw, k, n, None);
        for workers in [1usize, 4, 8] {
            assert_eq!(fused_matmul_workers(&x, &pw, k, n, None, workers), want, "w={workers}");
        }
    }
}
