//! Integration tests: the full pipeline through the public API, the CLI
//! surface, and cross-layer contracts that unit tests can't cover.
//!
//! These need built artifacts (`make artifacts`); they skip gracefully when
//! the directory is absent so `cargo test` stays green on a fresh clone.

use qera::budget::{allocate, profile, AllocStrategy, BudgetPlan, CandidateGrid};
use qera::coordinator::{calibrate, quantize, CalibResult, PipelineConfig};
use qera::data::Corpus;
use qera::linalg::Mat64;
use qera::model::{init::init_params, Checkpoint, ModelSpec, QuantCheckpoint};
use qera::quant::QFormat;
use qera::runtime::Registry;
use qera::solver::{expected_output_error, Method, PsdBackend, SvdBackend};
use qera::util::rng::Rng;
use std::path::PathBuf;

fn registry() -> Option<Registry> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then(|| Registry::open(p).unwrap())
}

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join("qera_integration");
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn randomized_svd_backend_tracks_exact_on_nano() {
    // Acceptance check for the rank-aware solver fast path: on the nano
    // checkpoint the randomized backend must keep the expected layer output
    // error (Tr(R P Pᵀ), the paper's Problem-2 objective) within 1e-2
    // relative of the exact backend, per method, aggregated over layers.
    // Runs without PJRT artifacts: calibration statistics are synthetic.
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(7)));
    let calib = CalibResult::synthetic(&spec, 256, 11);
    let fmt = QFormat::Mxint { bits: 3, block: 32 };
    let rank = 8; // rank * 4 <= 64 = min layer dim -> randomized engages
    let sites = spec.linear_sites();

    for method in [Method::QeraExact, Method::QeraApprox] {
        let exact = quantize(
            &ckpt,
            &PipelineConfig::new(method, fmt, rank).with_svd(SvdBackend::Exact),
            Some(&calib),
        )
        .unwrap();
        let rand = quantize(
            &ckpt,
            &PipelineConfig::new(method, fmt, rank).with_svd(SvdBackend::Randomized {
                oversample: SvdBackend::DEFAULT_OVERSAMPLE,
                power_iters: SvdBackend::DEFAULT_POWER_ITERS,
            }),
            Some(&calib),
        )
        .unwrap();

        let mut total_exact = 0.0f64;
        let mut total_rand = 0.0f64;
        for site in &sites {
            let rxx = calib.for_site(site).rxx_mean().unwrap();
            let w = Mat64::from_tensor(&ckpt.params[site.param_idx]);
            let p_exact = Mat64::from_tensor(&exact.merged[site.param_idx]).sub(&w);
            let p_rand = Mat64::from_tensor(&rand.merged[site.param_idx]).sub(&w);
            let e_exact = expected_output_error(&p_exact, &rxx);
            let e_rand = expected_output_error(&p_rand, &rxx);
            // per-site sanity: no catastrophic divergence
            assert!(
                (e_rand - e_exact).abs() <= 5e-2 * e_exact.max(1e-12),
                "{} {}: rand {e_rand} vs exact {e_exact}",
                method.name(),
                site.name
            );
            total_exact += e_exact;
            total_rand += e_rand;
        }
        // the acceptance bound: within 1e-2 relative, model-wide
        assert!(
            (total_rand - total_exact).abs() <= 1e-2 * total_exact,
            "{}: rand {total_rand} vs exact {total_exact}",
            method.name()
        );
    }
}

#[test]
fn lowrank_psd_backend_tracks_exact_on_nano() {
    // Acceptance check for the low-rank whitening fast path: on the nano
    // checkpoint, qera-exact solved with the low-rank + diagonal
    // `(R^{1/2}, R^{-1/2})` split must keep the expected layer output error
    // (Tr(R P Pᵀ), the paper's Problem-2 objective) within 1e-2 relative of
    // the exact eigendecomposition, aggregated over layers.  rank_mult 2
    // keeps the split genuinely approximate on nano's 64-wide layers
    // (k = 16 < 64); the exact SVD isolates the psd backend's effect.
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(13)));
    let calib = CalibResult::synthetic(&spec, 256, 11);
    let fmt = QFormat::Mxint { bits: 3, block: 32 };
    let rank = 8;
    let sites = spec.linear_sites();

    let exact = quantize(
        &ckpt,
        &PipelineConfig::new(Method::QeraExact, fmt, rank)
            .with_svd(SvdBackend::Exact)
            .with_psd(PsdBackend::Exact),
        Some(&calib),
    )
    .unwrap();
    let low = quantize(
        &ckpt,
        &PipelineConfig::new(Method::QeraExact, fmt, rank)
            .with_svd(SvdBackend::Exact)
            .with_psd(PsdBackend::LowRank {
                rank_mult: 2,
                power_iters: PsdBackend::DEFAULT_POWER_ITERS,
            }),
        Some(&calib),
    )
    .unwrap();

    let mut total_exact = 0.0f64;
    let mut total_low = 0.0f64;
    for site in &sites {
        let rxx = calib.for_site(site).rxx_mean().unwrap();
        let w = Mat64::from_tensor(&ckpt.params[site.param_idx]);
        let p_exact = Mat64::from_tensor(&exact.merged[site.param_idx]).sub(&w);
        let p_low = Mat64::from_tensor(&low.merged[site.param_idx]).sub(&w);
        total_exact += expected_output_error(&p_exact, &rxx);
        total_low += expected_output_error(&p_low, &rxx);
    }
    // per-layer exact is the Problem-2 optimum, so low-rank can only lose
    // (1e-6 margin: merged weights round through f32, ~1e-7 relative noise)
    assert!(total_low >= total_exact * (1.0 - 1e-6), "low-rank beat the optimum?");
    // the acceptance bound: within 1e-2 relative, model-wide
    assert!(
        (total_low - total_exact).abs() <= 1e-2 * total_exact,
        "lowrank {total_low} vs exact {total_exact}"
    );

    // and the low-rank pipeline stays deterministic
    let again = quantize(
        &ckpt,
        &PipelineConfig::new(Method::QeraExact, fmt, rank)
            .with_svd(SvdBackend::Exact)
            .with_psd(PsdBackend::LowRank {
                rank_mult: 2,
                power_iters: PsdBackend::DEFAULT_POWER_ITERS,
            }),
        Some(&calib),
    )
    .unwrap();
    for (x, y) in low.merged.iter().zip(&again.merged) {
        assert_eq!(x, y);
    }
}

#[test]
fn randomized_backend_pipeline_is_deterministic() {
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(9)));
    let cfg = PipelineConfig::new(Method::ZeroQuantV2, QFormat::Mxint { bits: 3, block: 32 }, 8)
        .with_svd(SvdBackend::Randomized {
            oversample: SvdBackend::DEFAULT_OVERSAMPLE,
            power_iters: SvdBackend::DEFAULT_POWER_ITERS,
        });
    let a = quantize(&ckpt, &cfg, None).unwrap();
    let b = quantize(&ckpt, &cfg, None).unwrap();
    for (x, y) in a.merged.iter().zip(&b.merged) {
        assert_eq!(x, y);
    }
    assert!(a.solve_ms_total > 0.0);
}

#[test]
fn budget_plans_beat_uniform_at_matched_bits() {
    // Acceptance check for the budget allocator (PR 5): on the nano PTQ
    // setup, the greedy and Lagrangian plans must achieve strictly lower
    // total predicted output error than the uniform plan at the same
    // bits/weight budget, and the executed pipeline must realize exactly
    // the error and bits the plan predicted (same seeds, same solves).
    // Runs without PJRT artifacts: calibration statistics are synthetic.
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(21)));
    let calib = CalibResult::synthetic(&spec, 256, 22);
    let base = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 4, block: 32 }, 8);
    let prof = profile(&ckpt, &calib, &base, &CandidateGrid::default_ptq()).unwrap();
    let budget = 3.75;

    let uni = allocate(&prof, budget, AllocStrategy::Uniform).unwrap();
    let gre = allocate(&prof, budget, AllocStrategy::Greedy).unwrap();
    let lag = allocate(&prof, budget, AllocStrategy::Lagrangian).unwrap();
    for plan in [&uni, &gre, &lag] {
        assert!(
            plan.achieved_bits <= budget + 1e-9,
            "{}: {} > {budget}",
            plan.strategy.name(),
            plan.achieved_bits
        );
    }
    // the acceptance bound: non-uniform spending strictly wins
    assert!(
        gre.total_error < uni.total_error,
        "greedy {} !< uniform {}",
        gre.total_error,
        uni.total_error
    );
    assert!(
        lag.total_error <= uni.total_error + 1e-12,
        "lagrangian {} > uniform {}",
        lag.total_error,
        uni.total_error
    );

    // executing the greedy plan realizes the predicted error and bits:
    // the profiler solves with the pipeline's own per-site seeds
    let qm = quantize(&ckpt, &base.clone().with_plan(gre.clone()), Some(&calib)).unwrap();
    assert!(
        (qm.effective_bits() - gre.achieved_bits).abs() < 1e-9,
        "{} vs {}",
        qm.effective_bits(),
        gre.achieved_bits
    );
    let sites = spec.linear_sites();
    let mut realized = 0.0f64;
    for site in &sites {
        let rxx = calib.for_site(site).rxx_mean().unwrap();
        let w = Mat64::from_tensor(&ckpt.params[site.param_idx]);
        let p = Mat64::from_tensor(&qm.merged[site.param_idx]).sub(&w);
        realized += expected_output_error(&p, &rxx);
    }
    assert!(
        (realized - gre.total_error).abs() <= 1e-6 * gre.total_error.max(1e-12),
        "realized {realized} vs predicted {}",
        gre.total_error
    );

    // ... and strictly beats the executed uniform plan on the same metric
    let qm_uni = quantize(&ckpt, &base.clone().with_plan(uni.clone()), Some(&calib)).unwrap();
    let mut realized_uni = 0.0f64;
    for site in &sites {
        let rxx = calib.for_site(site).rxx_mean().unwrap();
        let w = Mat64::from_tensor(&ckpt.params[site.param_idx]);
        let p = Mat64::from_tensor(&qm_uni.merged[site.param_idx]).sub(&w);
        realized_uni += expected_output_error(&p, &rxx);
    }
    assert!(realized < realized_uni, "{realized} !< {realized_uni}");
}

#[test]
fn budget_plan_artifact_reproduces_identical_checkpoint() {
    // Acceptance check for the plan round trip: --plan-out then --plan-in
    // must reproduce the identical quantized checkpoint.  The JSON form
    // prints shortest-round-trip f64s, so the reloaded plan is equal and
    // the re-executed pipeline is bit-identical.
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(23)));
    let calib = CalibResult::synthetic(&spec, 192, 24);
    let base = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 3, block: 32 }, 8);
    let prof = profile(&ckpt, &calib, &base, &CandidateGrid::default_ptq()).unwrap();
    let plan = allocate(&prof, 3.5, AllocStrategy::Greedy).unwrap();

    let path = tmpdir().join("nano-plan.json");
    plan.save(&path).unwrap();
    let reloaded = BudgetPlan::load(&path).unwrap();
    assert_eq!(reloaded, plan);

    let a = quantize(&ckpt, &base.clone().with_plan(plan), Some(&calib)).unwrap();
    let b = quantize(&ckpt, &base.clone().with_plan(reloaded), Some(&calib)).unwrap();
    for (x, y) in a.merged.iter().zip(&b.merged) {
        assert_eq!(x, y);
    }
    assert_eq!(a.ckpt.payload_bytes(), b.ckpt.payload_bytes());

    // the packed on-disk form round-trips too
    let qpath = tmpdir().join("nano-plan.qqkpt");
    a.ckpt.save(&qpath).unwrap();
    let back = QuantCheckpoint::load(&qpath).unwrap();
    assert_eq!(back.materialize_merged(), a.merged);
}

#[test]
fn full_ptq_pipeline_roundtrip() {
    let Some(reg) = registry() else {
        eprintln!("skipped: artifacts not built");
        return;
    };
    let spec = reg.spec("nano").unwrap().clone();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(0)));
    let corpus = Corpus::generate(spec.vocab, 20_000, 1);

    // calibrate -> quantize -> save -> load -> evaluate == in-memory result
    let calib = calibrate(&reg, &spec, &ckpt.params, &corpus, 4, true).unwrap();
    let cfg = PipelineConfig::new(Method::QeraExact, QFormat::Mxint { bits: 3, block: 32 }, 8);
    let qm = quantize(&ckpt, &cfg, Some(&calib)).unwrap();

    let path = tmpdir().join("pipeline.qqkpt");
    qm.ckpt.save(&path).unwrap();
    let back = QuantCheckpoint::load(&path).unwrap();
    assert_eq!(back.materialize_merged(), qm.merged);

    let ppl_mem = qera::eval::perplexity(&reg, &spec, &qm.merged, &corpus, 2).unwrap();
    let ppl_disk =
        qera::eval::perplexity(&reg, &spec, &back.materialize_merged(), &corpus, 2).unwrap();
    assert_eq!(ppl_mem, ppl_disk);
}

#[test]
fn quantized_model_output_error_ordering() {
    // end-to-end statement of the paper's core claim on the real model
    // forward: output error (logit MSE) orders w-only > zeroquant >= qera
    let Some(reg) = registry() else {
        return;
    };
    let spec = reg.spec("nano").unwrap().clone();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(3)));
    let corpus = Corpus::generate(spec.vocab, 30_000, 4);
    let calib = calibrate(&reg, &spec, &ckpt.params, &corpus, 8, true).unwrap();
    let fmt = QFormat::Mxint { bits: 2, block: 16 };

    let err_of = |method: Method, rank: usize| -> f64 {
        let qm = quantize(&ckpt, &PipelineConfig::new(method, fmt, rank), Some(&calib)).unwrap();
        qera::eval::model_output_error(&reg, &spec, &ckpt.params, &qm.merged, &corpus, 3)
            .unwrap()
    };
    let e_wonly = err_of(Method::WOnly, 0);
    let e_zq = err_of(Method::ZeroQuantV2, 16);
    let e_approx = err_of(Method::QeraApprox, 16);
    let e_exact = err_of(Method::QeraExact, 16);
    assert!(e_zq < e_wonly, "zq {e_zq} !< w-only {e_wonly}");
    // qera should beat plain SVD on *output* error (the theorem's claim,
    // allowing a sliver of slack for finite calibration + nonlinear layers)
    assert!(e_approx < e_zq * 1.05, "approx {e_approx} vs zq {e_zq}");
    assert!(e_exact < e_zq * 1.05, "exact {e_exact} vs zq {e_zq}");
}

#[test]
fn cli_pretrain_quantize_eval() {
    let Some(_reg) = registry() else {
        return;
    };
    let dir = tmpdir();
    let ckpt_path = dir.join("cli.qkpt").to_string_lossy().to_string();
    let art = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let art = art.to_string_lossy().to_string();

    let run = |args: &[&str]| {
        let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        qera::cli::main_with_args(&argv)
    };
    run(&[
        "pretrain",
        "--artifacts",
        &art,
        "--model",
        "nano",
        "--pretrain-steps",
        "20",
        "--corpus-tokens",
        "30000",
        "--out",
        &ckpt_path,
    ])
    .unwrap();
    assert!(PathBuf::from(&ckpt_path).exists());

    let q_path = dir.join("cli.qqkpt").to_string_lossy().to_string();
    run(&[
        "quantize",
        "--artifacts",
        &art,
        "--ckpt",
        &ckpt_path,
        "--method",
        "qera-approx",
        "--format",
        "mxint4:32",
        "--rank",
        "4",
        "--calib-batches",
        "2",
        "--corpus-tokens",
        "30000",
        "--out",
        &q_path,
    ])
    .unwrap();
    assert!(PathBuf::from(&q_path).exists());

    run(&[
        "eval-ppl",
        "--artifacts",
        &art,
        "--qckpt",
        &q_path,
        "--corpus-tokens",
        "30000",
        "--eval-batches",
        "2",
    ])
    .unwrap();

    // unknown command / bad flags fail cleanly
    assert!(run(&["frobnicate"]).is_err());
    assert!(run(&["quantize", "--artifacts", &art]).is_err());
}

#[test]
fn cli_native_eval_and_serve_without_artifacts() {
    // the --exec native path needs no xla artifacts: build a quantized nano
    // checkpoint in-process, then drive eval-ppl and serve through the CLI
    let spec = ModelSpec::builtin("nano").unwrap();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(21)));
    let cfg = PipelineConfig::new(Method::WOnly, QFormat::Mxint { bits: 4, block: 32 }, 0);
    let qm = quantize(&ckpt, &cfg, None).unwrap();

    let dir = tmpdir();
    let q_path = dir.join("native.qqkpt").to_string_lossy().to_string();
    qm.ckpt.save(&q_path).unwrap();

    let run = |args: &[&str]| {
        let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        qera::cli::main_with_args(&argv)
    };
    // point --artifacts at a dir with no manifest: native must not open it
    let bogus = dir.join("no-artifacts-here").to_string_lossy().to_string();
    for _ in 0..2 {
        // reproducible: identical output both runs (same corpus seed)
        run(&[
            "eval-ppl",
            "--artifacts",
            &bogus,
            "--qckpt",
            &q_path,
            "--exec",
            "native",
            "--corpus-tokens",
            "30000",
            "--eval-batches",
            "2",
        ])
        .unwrap();
    }
    run(&[
        "serve",
        "--artifacts",
        &bogus,
        "--qckpt",
        &q_path,
        "--exec",
        "native",
        "--prompts",
        "3",
        "--new-tokens",
        "4",
    ])
    .unwrap();
    // and the flag rejects unknown backends
    assert!(run(&["eval-ppl", "--qckpt", &q_path, "--exec", "tpu"]).is_err());
}

#[test]
fn serving_consistency_with_direct_eval() {
    // the batcher must produce exactly the greedy tokens the engine produces
    let Some(reg) = registry() else {
        return;
    };
    let spec = reg.spec("nano").unwrap().clone();
    let params = init_params(&spec, &mut Rng::new(9));
    let engine = qera::serve::Engine::new(&reg, spec.clone(), params.clone()).unwrap();
    let prompts = vec![vec![3i32, 1, 4], vec![1i32, 5, 9, 2]];
    let direct = engine.generate(&prompts, 6, 0.0, &mut Rng::new(0)).unwrap();

    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let server = qera::serve::Server::start(
        dir,
        spec,
        params,
        qera::serve::ServerConfig {
            max_wait: std::time::Duration::from_millis(1),
            seed: 0,
            ..Default::default()
        },
    );
    for (i, p) in prompts.iter().enumerate() {
        let rx = server.submit(p.clone(), 6, 0.0);
        let resp = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(resp.tokens, direct[i][p.len()..].to_vec(), "prompt {i}");
    }
    server.stop();
}

#[test]
fn lora_init_respects_method_semantics() {
    let Some(reg) = registry() else {
        return;
    };
    let spec = reg.spec("nano").unwrap().clone();
    let ckpt = Checkpoint::new(spec.clone(), init_params(&spec, &mut Rng::new(11)));
    let corpus = Corpus::generate(spec.vocab, 20_000, 12);
    let calib = calibrate(&reg, &spec, &ckpt.params, &corpus, 4, true).unwrap();
    let fmt = QFormat::Mxint { bits: 2, block: 16 };

    // at init, merged(qera) must be closer (in model output) to the full-
    // precision model than merged(qlora) = plain dequantized weights
    let q = qera::train::lora::lora_init(&ckpt, Method::QloraZero, fmt, 8, None, 1).unwrap();
    let e = qera::train::lora::lora_init(&ckpt, Method::QeraApprox, fmt, 8, Some(&calib), 1)
        .unwrap();
    let err_q = qera::eval::model_output_error(
        &reg, &spec, &ckpt.params, &q.merged(&spec), &corpus, 2,
    )
    .unwrap();
    let err_e = qera::eval::model_output_error(
        &reg, &spec, &ckpt.params, &e.merged(&spec), &corpus, 2,
    )
    .unwrap();
    assert!(err_e < err_q, "qera init {err_e} !< qlora init {err_q}");
}

#[test]
fn manifest_covers_every_needed_artifact() {
    let Some(reg) = registry() else {
        return;
    };
    let arts = [
        "lm_fwd",
        "lm_nll",
        "lm_logits_last",
        "lm_fwd_taps",
        "lm_pool",
        "pretrain_step",
        "full_cls_step",
    ];
    for cfg in ["nano", "small"] {
        for art in arts {
            assert!(reg.info(&format!("{art}.{cfg}")).is_ok(), "{art}.{cfg}");
        }
    }
    assert!(reg.info("lora_cls_step.small.r12").is_ok());
    assert!(reg.info("qlinear.m64k128n96r8").is_ok());
}
